"""App. E / Fig. 12: a relufied LARGER model beats a dense SMALLER model at
equal inference MACs (the relufied points sit above the dense scaling line)."""
from __future__ import annotations

import json

import jax.numpy as jnp

from benchmarks.common import BASE, data_cfg, eval_nll, get_model, train_model
from repro.core import flops as fl
from repro.core.sparsity import measure_site_sparsity
from repro.data.pipeline import eval_batches


def run():
    # dense-small: half width
    small_cfg = BASE.replace(name="bench-small", d_model=48, d_ff=192,
                             head_dim=12)
    small_params, _ = train_model(small_cfg, 150, "scratch_small")
    small_nll = eval_nll(small_cfg, small_params)
    small_macs = fl.macs_per_token(small_cfg) / 1e6

    # relufied-large at its measured sparsity
    cfg2, p2, _ = get_model("relufied_s2")
    batch = {k: jnp.asarray(v) for k, v in eval_batches(data_cfg(), 1)[0].items()}
    m = measure_site_sparsity(p2, batch, cfg2)
    sp = fl.SparsityLevels(qkv=m.get("mean/qkv", 0), up=m.get("mean/up", 0),
                           down=m.get("mean/down", 0))
    reluf_nll = eval_nll(cfg2, p2)
    reluf_macs = fl.macs_per_token(cfg2, sp) / 1e6

    full = {"dense_small": {"nll": small_nll, "MMACs": small_macs},
            "relufied_large": {"nll": reluf_nll, "MMACs": reluf_macs},
            "wins": reluf_nll < small_nll}
    with open("experiments/bench_appE.json", "w") as f:
        json.dump(full, f, indent=2)
    return [
        f"appE/dense_small,0,nll={small_nll:.4f};mmacs={small_macs:.3f}",
        f"appE/relufied_large,0,nll={reluf_nll:.4f};mmacs={reluf_macs:.3f};"
        f"better_at_similar_macs={full['wins']}",
    ]
