"""Bench-regression gate for CI: compare the fresh ``--smoke`` trajectory
JSON (benchmarks/run.py writes repo-root BENCH_<pr>.json) against the last
committed baseline and FAIL the job when serving throughput drops more than
the tolerance or a sparsity-machinery metric silently collapses to zero.

    python benchmarks/check_trajectory.py                 # auto-pick files
    python benchmarks/check_trajectory.py \
        --fresh BENCH_PR5.json --baseline BENCH_PR4.json --tolerance 0.2

Auto-pick: the fresh file is BENCH_<BENCH_PR env, default pr tag>.json (the
one the smoke run just wrote); the baseline is the highest-numbered other
BENCH_*.json in the repo root — the committed PR-over-PR trajectory.

Three failure classes (exit code 1, one line per violation):

* throughput: ``serving_tokens_per_s`` (and the prefix-cache case) dropping
  > tolerance (default 20%) vs baseline — CI runners are noisy, a real
  engine regression is not.
* zero-collapse: any ``weight_io_saved*`` / ``prefix_hit_rate`` /
  ``prefill_tokens_saved`` headline that was positive in the baseline
  reading 0 (or missing) now — the sparsity machinery silently rotted even
  if throughput looks fine.
* streaming latency: ``api_ttft_ms`` / ``api_tpot_ms`` rising more than
  the latency tolerance (default 50%) vs baseline — a serve-loop
  pathology, gated only once a baseline records the keys. The engine-side
  span percentiles (``ttft_p50_ms`` .. ``tpot_p99_ms``, read off the obs
  histograms) gate on a rise of more than one factor-2 histogram bucket.

A fourth class gates against FIXED bounds rather than the baseline
(``ABSOLUTE_BOUNDS``): the kernel/engine byte-accounting cross-check, and
the SLO-scheduling outcomes (``slo_goodput`` in (0, 1], ``slo_goodput_gain``
strictly positive — priorities+preemption must beat FIFO at the same
offered load — and ``preemption_count`` >= 1). These are checked whenever
the fresh run records the key, and failing to record a key the baseline
had is itself a violation.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys

THROUGHPUT_KEYS = ("serving_tokens_per_s", "prefix_cache_tokens_per_s",
                   "api_stream_tokens_per_s")
ZERO_COLLAPSE_KEYS = ("weight_io_saved_gamma4", "spec_s_agg_gamma4",
                      "weight_io_saved_predictor", "prefix_hit_rate",
                      "prefill_tokens_saved",
                      # MoE through the engine: a zero/missing tokens/s or
                      # expert-I/O fraction means MoE serving silently
                      # stopped flowing through the CB engine
                      "moe_tokens_per_s", "moe_expert_io_fraction",
                      # SLO scheduling (ISSUE 10): goodput collapsing to
                      # zero (or the benchmark vanishing) means the
                      # priority/preemption machinery silently stopped
                      # serving the interactive class
                      "slo_goodput", "preemption_count")
# streaming-latency headlines (lower is better): gate on INCREASES. The
# tolerance is generous (latency on shared CI runners is far noisier than
# throughput) — this catches a serve-loop pathology (an extra barrier per
# step, a lost wakeup), not a 10% scheduling wobble. Only active once a
# committed baseline records the key.
LATENCY_KEYS = ("api_ttft_ms", "api_tpot_ms")
# engine-side span percentiles from the obs histograms (serving_throughput
# merges every CB case's snapshot). These values are log-bucket UPPER EDGES
# (factor-2 buckets), so a measurement wobbling across one bucket boundary
# reads as exactly 2x — gate only on a rise of MORE than one bucket
# (fresh > 2x baseline), which no same-bucket or adjacent-bucket jitter can
# trip. Only active once a committed baseline records the key.
PERCENTILE_LATENCY_KEYS = ("ttft_p50_ms", "ttft_p99_ms",
                           "tpot_p50_ms", "tpot_p99_ms")
PERCENTILE_BUCKET_FACTOR = 2.0
# absolute-bounds headlines: gated against FIXED bounds, not the baseline —
# kernel_bytes_ratio is (fused-kernel BlockSpec-modeled HBM bytes/step) /
# (engine density-accounted bytes/step); the two are independent
# derivations of the same quantity, so any drift outside ±15% means the
# kernel geometry and the serving accounting no longer describe the same
# machine. Gated whenever the fresh run records the key.
ABSOLUTE_BOUNDS = {
    "kernel_bytes_ratio": (0.85, 1.15),
    # goodput is a fraction; the SLO run must STRICTLY beat the FIFO
    # baseline at the same offered load (gain > 0), and the benchmark must
    # actually exercise preemption (>= 1) — both are step-deterministic,
    # so fixed bounds, not baseline-relative tolerances
    "slo_goodput": (1e-9, 1.0),
    "slo_goodput_gain": (1e-9, 1.0),
    "preemption_count": (1, float("inf")),
}


def _pr_num(path: str) -> int:
    m = re.search(r"BENCH_PR(\d+)\.json$", os.path.basename(path))
    return int(m.group(1)) if m else -1


def autodetect(fresh: str | None, baseline: str | None):
    if fresh is None:
        tag = os.environ.get("BENCH_PR")
        if tag is None:
            sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
            from run import PR_TAG  # the tag run.py just wrote with
            tag = PR_TAG
        fresh = f"BENCH_{tag.upper()}.json"
    if baseline is None:
        others = [p for p in glob.glob("BENCH_*.json")
                  if os.path.basename(p) != os.path.basename(fresh)]
        if not others:
            raise SystemExit(f"no baseline BENCH_*.json besides {fresh} — "
                             "commit one before gating on it")
        baseline = max(others, key=_pr_num)
    return fresh, baseline


def check(fresh: dict, baseline: dict, tolerance: float,
          latency_tolerance: float = 0.5):
    """Returns a list of violation strings (empty = gate passes)."""
    fh = fresh.get("headline") or {}
    bh = baseline.get("headline") or {}
    bad = []
    for key in LATENCY_KEYS:
        b, f = bh.get(key), fh.get(key)
        if not b:  # baseline never measured it — nothing to regress from
            continue
        if not f:
            bad.append(f"{key}: missing/0 in fresh run "
                       f"(baseline {b:.1f} ms)")
        elif f > b * (1.0 + latency_tolerance):
            bad.append(f"{key}: {f:.1f} ms is {f / b - 1:.0%} above "
                       f"baseline {b:.1f} ms (tolerance "
                       f"{latency_tolerance:.0%})")
    for key in PERCENTILE_LATENCY_KEYS:
        b, f = bh.get(key), fh.get(key)
        if not b:  # baseline never measured it — nothing to regress from
            continue
        if not f:
            bad.append(f"{key}: missing/0 in fresh run "
                       f"(baseline {b:.2f} ms)")
        elif f > b * PERCENTILE_BUCKET_FACTOR:
            bad.append(f"{key}: {f:.2f} ms is more than one histogram "
                       f"bucket (> {PERCENTILE_BUCKET_FACTOR:.0f}x) above "
                       f"baseline {b:.2f} ms")
    for key in THROUGHPUT_KEYS:
        b, f = bh.get(key), fh.get(key)
        if not b:  # baseline never measured it — nothing to regress from
            continue
        if not f:
            bad.append(f"{key}: missing/0 in fresh run (baseline {b:.1f})")
        elif f < b * (1.0 - tolerance):
            bad.append(f"{key}: {f:.1f} tok/s is {1 - f / b:.0%} below "
                       f"baseline {b:.1f} (tolerance {tolerance:.0%})")
    for key in ZERO_COLLAPSE_KEYS:
        b, f = bh.get(key), fh.get(key)
        if b and not f:
            bad.append(f"{key}: was {b} in baseline, now "
                       f"{'missing' if f is None else f} — sparsity "
                       "machinery silently collapsed")
    for key, (lo, hi) in ABSOLUTE_BOUNDS.items():
        b, f = bh.get(key), fh.get(key)
        if f is None and b is not None:
            bad.append(f"{key}: recorded in baseline ({b}) but missing in "
                       "fresh run — absolute-bound gate silently dropped")
        elif f is not None and not (lo <= f <= hi):
            bad.append(f"{key}: {f:.4f} outside [{lo}, {hi}] — "
                       "absolute-bound headline out of range")
    return bad


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", default=None,
                    help="fresh trajectory JSON (default: BENCH_<tag>.json "
                         "for the current BENCH_PR tag)")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline (default: highest-numbered "
                         "other BENCH_*.json)")
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="allowed fractional throughput drop (default 0.2)")
    ap.add_argument("--latency-tolerance", type=float, default=0.5,
                    help="allowed fractional TTFT/TPOT increase "
                         "(default 0.5 — CI latency is noisy)")
    args = ap.parse_args()
    fresh_path, base_path = autodetect(args.fresh, args.baseline)
    with open(fresh_path) as f:
        fresh = json.load(f)
    with open(base_path) as f:
        baseline = json.load(f)
    bad = check(fresh, baseline, args.tolerance, args.latency_tolerance)
    print(f"bench gate: {fresh_path} (pr={fresh.get('pr')}) vs "
          f"{base_path} (pr={baseline.get('pr')}), "
          f"tolerance {args.tolerance:.0%}")
    for line in bad:
        print(f"  REGRESSION {line}")
    if bad:
        sys.exit(1)
    print("  ok — no throughput regression, no zero-collapsed metric")


if __name__ == "__main__":
    main()
