"""Shared trained tiny models for the benchmark harness (disk-cached so the
whole suite trains each model once)."""
from __future__ import annotations

import os
from typing import Dict, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import TrainConfig, get_config
from repro.configs.base import ModelConfig
from repro.core import relufication
from repro.data.pipeline import DataConfig
from repro.models import registry
from repro.train.loop import Trainer

CACHE = os.environ.get("BENCH_CACHE", "experiments/bench_models")

BASE = ModelConfig(
    name="bench-base", family="dense", n_layers=4, d_model=96, n_heads=4,
    n_kv_heads=4, d_ff=384, vocab_size=256, max_seq_len=128,
    activation="silu", ffn_kind="glu", norm_kind="rmsnorm",
)

BASE_OPT = BASE.replace(name="bench-opt", ffn_kind="mlp",
                        norm_kind="layernorm", use_rope=False,
                        tie_embeddings=True, activation="relu")

DC = DataConfig(vocab_size=256, seq_len=64, batch_size=8)


def data_cfg() -> DataConfig:
    return DC


def train_model(cfg: ModelConfig, steps: int, tag: str,
                init_params=None, lr: float = 5e-3,
                log=lambda *_: None) -> Tuple[dict, list]:
    """Train (or load cached) tiny model; returns (params, losses)."""
    path = os.path.join(CACHE, tag)
    mgr = CheckpointManager(path, keep=1, async_save=False)
    fam = registry.get_family(cfg)
    template = fam.init_params(jax.random.PRNGKey(0), cfg)
    if mgr.latest_step() is not None:
        params, extras = mgr.restore(template)
        return params, extras.get("losses", [])
    tc = TrainConfig(learning_rate=lr, total_steps=steps, warmup_steps=10,
                     schedule="cosine", num_microbatches=1,
                     remat_policy="none", seed=0)
    tr = Trainer(cfg, tc, DC, log=log)
    rep = tr.run(steps, params=init_params)
    mgr.save(steps, tr.params, block=True,
             extras={"step": steps, "losses": [float(x) for x in rep.losses]})
    return tr.params, rep.losses


def eval_nll(cfg: ModelConfig, params, n_batches: int = 3) -> float:
    from repro.data.pipeline import eval_batches
    from repro.train.step import lm_loss
    import jax.numpy as jnp
    batches = eval_batches(DC, n_batches)
    return float(np.mean([
        float(lm_loss(params, {k: jnp.asarray(v) for k, v in b.items()}, cfg)[0])
        for b in batches]))


_MODELS: Dict[str, tuple] = {}


def get_model(kind: str):
    """Returns (cfg, params, losses). kinds: silu / gelu / relu / beta8
    (scratch); relufied_s1 / relufied_s2 / shifted (surgery on the silu
    base, paper Sec. 4/5.3); draft (1-layer, for speculative decoding)."""
    if kind in _MODELS:
        return _MODELS[kind]
    if kind in ("silu", "gelu", "relu", "beta8"):
        act = {"beta8": "beta=8"}.get(kind, kind)
        cfg = BASE.replace(name=f"bench-{kind}", activation=act)
        params, losses = train_model(cfg, 150, f"scratch_{kind}")
    elif kind == "relufied_s1":
        _, base_params, _ = get_model("silu")
        cfg = relufication.relufy_stage1(BASE).replace(name="bench-reluf1")
        params, losses = train_model(cfg, 80, "relufied_s1",
                                     init_params=base_params, lr=2e-3)
    elif kind == "relufied_s2":
        _, p1, _ = get_model("relufied_s1")
        cfg = relufication.relufy_stage2(BASE).replace(name="bench-reluf2")
        params, losses = train_model(cfg, 80, "relufied_s2",
                                     init_params=p1, lr=2e-3)
    elif kind == "shifted":
        import jax.numpy as jnp
        from repro.data.pipeline import eval_batches
        _, base_params, _ = get_model("silu")
        batch = {k: jnp.asarray(v) for k, v in eval_batches(DC, 1)[0].items()}
        cfg1 = relufication.relufy_stage1(BASE)
        b = relufication.calibrate_shift(base_params, batch, cfg1,
                                         target_sparsity=0.9)
        cfg = relufication.shifted_relufy(BASE, shift=max(0.0, b)).replace(
            name="bench-shifted")
        params, losses = train_model(cfg, 80, "shifted",
                                     init_params=base_params, lr=2e-3)
    elif kind == "draft":
        cfg = BASE.replace(name="bench-draft", n_layers=1, d_model=48,
                           n_heads=4, head_dim=12, d_ff=192, activation="relu")
        params, losses = train_model(cfg, 100, "draft")
    else:
        raise KeyError(kind)
    _MODELS[kind] = (cfg, params, losses)
    return _MODELS[kind]
