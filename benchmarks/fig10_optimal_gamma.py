"""Fig. 10 / App. C: optimal γ for sparse vs standard speculative decoding.
The sparse optimum sits at a smaller γ (gap < 20%), and random-sparsity
benefits die out at large γ while aggregated sparsity's persist."""
from __future__ import annotations

import json

from repro.core import spec_theory


def _s_agg_aggregated(g: int, s1: float = 0.55, floor: float = 0.25) -> float:
    """Aggregated-sparsity curve model: slow decay to a floor (reuse)."""
    return floor + (s1 - floor) * (0.97 ** g)


def _s_agg_random(g: int, s1: float = 0.55) -> float:
    return s1 ** g  # i.i.d. random activation: union shrinks exponentially


def run():
    alpha, c = 0.8, 0.02  # paper's case study
    g_std, sp_std = spec_theory.optimal_gamma(c, alpha)
    g_agg, sp_agg = spec_theory.optimal_gamma(c, alpha, _s_agg_aggregated)
    g_rnd, sp_rnd = spec_theory.optimal_gamma(c, alpha, _s_agg_random)

    full = {
        "standard": {"gamma*": g_std, "speedup": sp_std},
        "sparse_aggregated": {"gamma*": g_agg, "speedup": sp_agg},
        "sparse_random": {"gamma*": g_rnd, "speedup": sp_rnd},
        "thm1_at_16": spec_theory.thm1_speedup(16, c, _s_agg_aggregated(16)),
        "thm1_random_at_16": spec_theory.thm1_speedup(16, c, _s_agg_random(16)),
        "thm1_at_64": spec_theory.thm1_speedup(64, c, _s_agg_aggregated(64)),
        "thm1_random_at_64": spec_theory.thm1_speedup(64, c, _s_agg_random(64)),
        "gamma_gap_frac": abs(g_std - g_agg) / g_std,
    }
    with open("experiments/bench_fig10.json", "w") as f:
        json.dump(full, f, indent=2)
    return [
        f"fig10_gamma/standard,0,gamma*={g_std};speedup={sp_std:.3f}",
        f"fig10_gamma/sparse,0,gamma*={g_agg};speedup={sp_agg:.3f};"
        f"gap={full['gamma_gap_frac']:.2f}",
        f"fig10_gamma/thm1_g16,0,aggregated={full['thm1_at_16']:.3f};"
        f"random={full['thm1_random_at_16']:.3f}",
        f"fig10_gamma/thm1_g64,0,aggregated={full['thm1_at_64']:.3f};"
        f"random={full['thm1_random_at_64']:.3f}",
    ]
