"""Fig. 1a: activation sparsity per layer — ReLU-trained models are sparse
(>~0.9 at scale; high double digits at tiny scale), SiLU/GELU near zero."""
from __future__ import annotations

import json
import time

import jax.numpy as jnp

from benchmarks.common import data_cfg, get_model
from repro.core.sparsity import measure_site_sparsity
from repro.data.pipeline import eval_batches


def run():
    rows, full = [], {}
    batch = {k: jnp.asarray(v) for k, v in eval_batches(data_cfg(), 1)[0].items()}
    for kind in ("relu", "silu", "gelu"):
        cfg, params, _ = get_model(kind)
        t0 = time.time()
        sp = measure_site_sparsity(params, batch, cfg)
        us = (time.time() - t0) * 1e6
        full[kind] = sp
        rows.append(f"fig1_sparsity/{kind},{us:.0f},"
                    f"down_sparsity={sp.get('mean/down', 0):.4f}")
        per_layer = [round(sp.get(f"layer{i}/down_in", 0), 4)
                     for i in range(cfg.n_layers)]
        rows.append(f"fig1_sparsity/{kind}_layers,0,\"{per_layer}\"")
    with open("experiments/bench_fig1.json", "w") as f:
        json.dump(full, f, indent=2)
    return rows
