"""Fig. 2: the β-gated family f(x)=x·σ(βx) from SiLU (β=1) to ReLU (β→∞):
trained-from-scratch quality is ~equal; sparsity increases with β."""
from __future__ import annotations

import json

from benchmarks.common import data_cfg, eval_nll, get_model
from repro.core.sparsity import measure_site_sparsity
from repro.data.pipeline import eval_batches
import jax.numpy as jnp


def run():
    rows, full = [], {}
    batch = {k: jnp.asarray(v) for k, v in eval_batches(data_cfg(), 1)[0].items()}
    for kind in ("silu", "gelu", "beta8", "relu"):
        cfg, params, losses = get_model(kind)
        nll = eval_nll(cfg, params)
        sp = measure_site_sparsity(params, batch, cfg)
        full[kind] = {"eval_nll": nll, "down_sparsity": sp.get("mean/down", 0),
                      "final_train_loss": losses[-1] if losses else None}
        rows.append(f"fig2_actfn/{kind},0,"
                    f"nll={nll:.4f};sparsity={sp.get('mean/down', 0):.4f}")
    with open("experiments/bench_fig2.json", "w") as f:
        json.dump(full, f, indent=2)
    return rows
