"""Fig. 5: the pre-activation distribution barely moves during the (short)
relufication fine-tune — which is why sparsity is predictable in advance."""
from __future__ import annotations

import json

import jax.numpy as jnp
import numpy as np

from benchmarks.common import data_cfg, get_model
from repro.core.sparsity import preactivation_stats
from repro.data.pipeline import eval_batches


def run():
    rows, full = [], {}
    batch = {k: jnp.asarray(v) for k, v in eval_batches(data_cfg(), 1)[0].items()}
    _, base_params, _ = get_model("silu")
    cfg1, p1, _ = get_model("relufied_s1")

    before = preactivation_stats(base_params, batch, cfg1)  # silu weights, relu cfg
    after = preactivation_stats(p1, batch, cfg1)
    keys = [k for k in before if k.endswith("/mean")]
    d_mean = float(np.mean([abs(before[k] - after[k]) for k in keys]))
    d_std = float(np.mean([abs(before[k[:-5] + "/std"] - after[k[:-5] + "/std"])
                           for k in keys]))
    scale = float(np.mean([abs(before[k[:-5] + "/std"]) for k in keys])) + 1e-9
    full = {"before": before, "after": after,
            "mean_shift": d_mean, "std_shift": d_std,
            "relative_std_shift": d_std / scale}
    rows.append(f"fig5_preact/stability,0,"
                f"mean_shift={d_mean:.4f};rel_std_shift={d_std / scale:.4f}")
    with open("experiments/bench_fig5.json", "w") as f:
        json.dump(full, f, indent=2)
    return rows
