"""Fig. 6: the relufied model quickly recovers the performance lost to the
architecture surgery during fine-tuning."""
from __future__ import annotations

import json

from benchmarks.common import eval_nll, get_model


def run():
    cfg_base, p_base, _ = get_model("silu")
    cfg1, p1, losses1 = get_model("relufied_s1")
    cfg2, p2, losses2 = get_model("relufied_s2")

    base_nll = eval_nll(cfg_base, p_base)
    # NLL right after surgery (base weights under the relufied config)
    surgery_nll = eval_nll(cfg1, p_base)
    s1_nll = eval_nll(cfg1, p1)
    s2_nll = eval_nll(cfg2, p2)

    recovered = (surgery_nll - s1_nll) / max(1e-9, surgery_nll - base_nll)
    full = {"base_nll": base_nll, "post_surgery_nll": surgery_nll,
            "s1_finetuned_nll": s1_nll, "s2_finetuned_nll": s2_nll,
            "recovery_fraction": recovered,
            "s1_loss_curve": losses1, "s2_loss_curve": losses2}
    with open("experiments/bench_fig6.json", "w") as f:
        json.dump(full, f, indent=2)
    return [
        f"fig6_recovery/surgery_gap,0,base={base_nll:.4f};"
        f"post_surgery={surgery_nll:.4f}",
        f"fig6_recovery/finetuned,0,s1={s1_nll:.4f};s2={s2_nll:.4f};"
        f"recovered={recovered:.3f}",
    ]
