"""Fig. 7a/b/c: aggregated sparsity during generation, the random baseline
s^t, and the perplexity cost of γ-window weight reuse (reused vs random
row subsets)."""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import data_cfg, get_model
from repro.data.pipeline import eval_batches
from repro.serving.engine import ServeEngine


def run():
    cfg, params, _ = get_model("relufied_s1")
    eng = ServeEngine(cfg, params, max_len=128, track_sparsity=True)
    prompt = {k: jnp.asarray(v[:, :16]) for k, v in
              eval_batches(data_cfg(), 1)[0].items() if k == "tokens"}
    prompt["tokens"] = prompt["tokens"][:1]

    # (a)/(b): aggregated curve + random baseline
    res = eng.generate(prompt, max_new=48)
    tr = res.aggregated
    curve = [round(v, 4) for v in tr.curve]
    rand = [round(tr.mean_token_sparsity() ** (t + 1), 4)
            for t in range(len(curve))]
    rows = [
        f"fig7a_aggregated/final,0,agg_sparsity={tr.aggregated_sparsity():.4f};"
        f"per_token={tr.mean_token_sparsity():.4f}",
        f"fig7b_vs_random/final,0,aggregated={curve[-1]:.4f};"
        f"random={rand[-1]:.6f}",
    ]

    # (c): γ-window reuse perplexity vs no-reuse vs RANDOM row subsets
    nll = {}
    for mode in ("none", "reuse", "random"):
        eng2 = ServeEngine(cfg, params, max_len=128, track_sparsity=False)
        if mode == "none":
            r = eng2.generate(prompt, max_new=32)
        elif mode == "reuse":
            r = eng2.generate(prompt, max_new=32, reuse_window=8)
        else:  # random subsets of the same density as the reused masks
            rng = np.random.RandomState(0)
            density = 1.0 - tr.mean_token_sparsity()
            masks = jnp.asarray(
                rng.rand(cfg.n_layers, cfg.d_ff) < min(1.0, density * 1.5))
            last, cache = eng2.prefill(prompt)
            tok = jnp.argmax(last[:, : cfg.vocab_size], -1).astype(jnp.int32)
            lps = []
            for step in range(32):
                pos = jnp.full((1,), 16 + step, jnp.int32)
                logits, cache = eng2.decode(cache, tok, pos, ffn_masks=masks)
                lp = jax.nn.log_softmax(
                    logits[:, : cfg.vocab_size].astype(jnp.float32))
                tok = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
                lps.append(float(jnp.max(lp)))
            nll[mode] = -float(np.mean(lps))
            continue
        nll[mode] = -float(np.mean(r.logprobs))
    rows.append(
        f"fig7c_reuse_ppl,0,none={nll['none']:.4f};reuse={nll['reuse']:.4f};"
        f"random={nll['random']:.4f}")
    with open("experiments/bench_fig7.json", "w") as f:
        json.dump({"curve": curve, "random": rand, "nll": nll}, f, indent=2)
    return rows
