"""Fig. 7d + App. C: sparse speculative decoding THROUGH THE ENGINE — the
continuous-batching engine drafts γ tokens per slot and verifies each slot's
whole window in one jitted target forward. Reports the measured target-call
reduction, per-proposal acceptance α, aggregated window sparsity s_agg(γ),
and the paper's Thm 1 / Thm 2 speedups at those measurements; plus the
exactness of greedy speculative decoding vs autoregressive serving.

BENCH_SMOKE=1 (CI) uses random-init tiny models — no training — so the
speculative serving path is exercised on every push.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.core import spec_theory
from repro.serving.config import EngineConfig
from repro.serving.engine import ContinuousBatchingEngine
from repro.serving.spec_decode import spec_metrics

SMOKE = bool(int(os.environ.get("BENCH_SMOKE", "0")))


def _models():
    # f32 compute: the decode and verify executables agree bitwise, so the
    # exactness row compares token streams across modes (DESIGN: bf16
    # rounding placement differs between differently-shaped programs)
    if SMOKE:
        from repro.configs import get_config
        from repro.models import registry
        cfg = get_config("tiny-relu").replace(compute_dtype="float32")
        fam = registry.get_family(cfg)
        params = fam.init_params(jax.random.PRNGKey(0), cfg)
        dcfg = cfg.replace(name="tiny-draft", n_layers=1)
        dparams = fam.init_params(jax.random.PRNGKey(9), dcfg)
        return cfg, params, dcfg, dparams
    from benchmarks.common import get_model
    tcfg, tparams, _ = get_model("relufied_s1")
    dcfg, dparams, _ = get_model("draft")
    return (tcfg.replace(compute_dtype="float32"), tparams,
            dcfg.replace(compute_dtype="float32"), dparams)


def _prompts(cfg, n):
    if SMOKE:
        rng = np.random.RandomState(0)
        return [rng.randint(0, cfg.vocab_size, 12).astype(np.int32)
                for _ in range(n)]
    from benchmarks.common import data_cfg
    from repro.data.pipeline import eval_batches
    data = eval_batches(data_cfg(), 1)[0]["tokens"]
    return [np.asarray(data[i, :12], np.int32) for i in range(n)]


def _serve(cfg, params, prompts, max_new, *, dcfg=None, dparams=None,
           gamma=4):
    eng = ContinuousBatchingEngine(cfg, params, config=EngineConfig(
        n_slots=min(4, len(prompts)), block_size=16,
        max_blocks_per_seq=4, draft_cfg=dcfg, draft_params=dparams,
        gamma=gamma))
    uids = [eng.submit(p, max_new) for p in prompts]
    t0 = time.time()
    res = eng.run()
    return eng, [res[u] for u in uids], time.time() - t0


def run():
    tcfg, tparams, dcfg, dparams = _models()
    n_req, max_new = (2, 8) if SMOKE else (4, 16)
    prompts = _prompts(tcfg, n_req)
    c = 0.1

    _, ar, _ = _serve(tcfg, tparams, prompts, max_new)  # autoregressive ref

    rows, full = [], {}
    for gamma in ((4,) if SMOKE else (4, 8)):
        eng, results, dt = _serve(tcfg, tparams, prompts, max_new,
                                  dcfg=dcfg, dparams=dparams, gamma=gamma)
        s_agg = eng.s_agg_window()
        ms = [spec_metrics(r, gamma=gamma, c=c, s_agg=s_agg)
              for r in results]
        alpha = float(np.mean([m.accept_rate for m in ms]))
        red = float(np.mean([m.target_call_reduction for m in ms]))
        us = dt * 1e6 / (n_req * max_new)
        full[f"gamma{gamma}"] = {
            "s_agg": s_agg, "accept_rate": alpha,
            "target_call_reduction": red,
            "target_calls": [m.n_target_calls for m in ms],
            "thm1": spec_theory.thm1_speedup(gamma, c, s_agg),
            "thm2": [m.thm2_speedup for m in ms],
        }
        rows.append(
            f"fig7d_spec/gamma{gamma},{us:.0f},"
            f"s_agg={s_agg:.3f};alpha={alpha:.3f};"
            f"target_call_reduction={red:.2f}x;"
            f"thm1_speedup={full[f'gamma{gamma}']['thm1']:.3f}")

        # exactness: greedy spec through the engine == greedy autoregressive
        exact = all(bool((a.tokens == s.tokens).all())
                    for a, s in zip(ar, results))
        full[f"gamma{gamma}"]["exact"] = exact
        rows.append(f"fig7d_spec/exactness_g{gamma},0,greedy_match={exact}")

    # paper's OPT-6.7B case study numbers through the same theory
    rows.append(
        f"fig7d_theory/paper_case,0,"
        f"thm1(g=16,c=0.02,s=.30)={spec_theory.thm1_speedup(16, 0.02, 0.30):.3f}")
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/bench_fig7d.json", "w") as f:
        json.dump(full, f, indent=2)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
