"""Fig. 7d + App. C: sparse speculative decoding speedup over standard
speculative decoding (Thm 1) at measured aggregated sparsity s_agg(γ), and
the exactness of greedy speculative decoding."""
from __future__ import annotations

import json
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import data_cfg, get_model
from repro.core import spec_theory
from repro.data.pipeline import eval_batches
from repro.serving.engine import ServeEngine
from repro.serving.spec_decode import speculative_generate


def run():
    tcfg, tparams, _ = get_model("relufied_s1")
    dcfg, dparams, _ = get_model("draft")
    prompt = jnp.asarray(eval_batches(data_cfg(), 1)[0]["tokens"][:1, :12])

    rows, full = [], {}
    for gamma in (4, 8):
        t0 = time.time()
        res = speculative_generate(tcfg, tparams, dcfg, dparams, prompt,
                                   max_new=10, gamma=gamma, c=0.1, sparse=True)
        us = (time.time() - t0) * 1e6 / 10
        full[f"gamma{gamma}"] = {
            "s_agg": res.s_agg_window, "thm1": res.thm1_speedup,
            "thm2": res.thm2_speedup, "target_calls": res.n_target_calls,
            "accept_rate": res.accept_rate,
        }
        rows.append(
            f"fig7d_spec/gamma{gamma},{us:.0f},"
            f"s_agg={res.s_agg_window:.3f};thm1_speedup={res.thm1_speedup:.3f};"
            f"target_calls={res.n_target_calls}")

    # exactness: greedy spec == greedy target
    res = speculative_generate(tcfg, tparams, dcfg, dparams, prompt,
                               max_new=8, gamma=4, sparse=False)
    eng = ServeEngine(tcfg, tparams, max_len=64)
    pure = eng.generate({"tokens": prompt}, max_new=8)
    exact = bool((res.tokens == pure.tokens[0]).all())
    rows.append(f"fig7d_spec/exactness,0,greedy_match={exact}")
    full["exact"] = exact

    # paper's OPT-6.7B case study numbers through the same theory
    # (s_agg(16)=~? -> 1.27x; random sparsity -> 1.20x at gamma=16)
    s16 = 0.5  # paper Fig 7a: ~50% unused at ~150 tokens; window-16 higher
    rows.append(
        f"fig7d_theory/paper_case,0,"
        f"thm1(g=16,c=0.02,s=.30)={spec_theory.thm1_speedup(16, 0.02, 0.30):.3f}")
    with open("experiments/bench_fig7d.json", "w") as f:
        json.dump(full, f, indent=2)
    return rows
