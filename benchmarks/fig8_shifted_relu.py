"""Fig. 8: shifted ReLU — much sparser than plain ReLU at on-par quality."""
from __future__ import annotations

import json

import jax.numpy as jnp

from benchmarks.common import data_cfg, eval_nll, get_model
from repro.core.sparsity import measure_site_sparsity
from repro.data.pipeline import eval_batches


def run():
    batch = {k: jnp.asarray(v) for k, v in eval_batches(data_cfg(), 1)[0].items()}
    out = {}
    for kind in ("relufied_s1", "shifted"):
        cfg, params, _ = get_model(kind)
        sp = measure_site_sparsity(params, batch, cfg)
        out[kind] = {"nll": eval_nll(cfg, params),
                     "down_sparsity": sp.get("mean/down", 0.0),
                     "shift": cfg.sparsity.shift}
    with open("experiments/bench_fig8.json", "w") as f:
        json.dump(out, f, indent=2)
    return [
        f"fig8_shifted/relu,0,nll={out['relufied_s1']['nll']:.4f};"
        f"sparsity={out['relufied_s1']['down_sparsity']:.4f}",
        f"fig8_shifted/shifted(b={out['shifted']['shift']:.2f}),0,"
        f"nll={out['shifted']['nll']:.4f};"
        f"sparsity={out['shifted']['down_sparsity']:.4f}",
    ]
