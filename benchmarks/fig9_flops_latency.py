"""Fig. 9b / App. B: with structured (tile) activation sparsity, FLOPs is an
honest latency proxy — measured wall-clock of the gathered matmul tracks the
density linearly. Measured on the XLA path (the Pallas kernel is validated
in interpret mode; its FLOP/byte model is in kernels/ops.flops_saved)."""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else None
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
        jax.tree.leaves(out)[0].block_until_ready()
    return (time.time() - t0) / iters * 1e6


def run():
    rng = np.random.RandomState(0)
    T, d, F = 4, 512, 8192
    x = jnp.asarray(rng.randn(T, d), jnp.float32)
    wu = jnp.asarray(rng.randn(d, F) / np.sqrt(d), jnp.float32)
    wd = jnp.asarray(rng.randn(F, d) / np.sqrt(F), jnp.float32)

    rows, full = [], {}
    for density in (1.0, 0.5, 0.25, 0.125):
        fn = jax.jit(lambda x, wu, wd, dn=density:
                     ops.sparse_ffn_apply_xla(x, wu, wd, density=dn)[0])
        us = _time(fn, x, wu, wd)
        model = ops.flops_saved(F, d, T, density)
        full[str(density)] = {"us": us, **model}
        rows.append(f"fig9_latency/density{density},{us:.0f},"
                    f"flops_saving={model['flops_saving']:.3f};"
                    f"io_saving={model['io_saving']:.3f}")
    # correlation between time and density (paper: FLOPS ~ latency)
    ds = [1.0, 0.5, 0.25, 0.125]
    ts = [full[str(d)]["us"] for d in ds]
    corr = float(np.corrcoef(ds, ts)[0, 1])
    rows.append(f"fig9_latency/corr,0,pearson_time_vs_density={corr:.3f}")
    with open("experiments/bench_fig9.json", "w") as f:
        json.dump(full, f, indent=2)
    return rows
