"""Per-kernel microbenchmarks for the fused decode hot loop (ISSUE 7).

Three lowerings of the same sparse decode FFN are timed against each other —
dense XLA matmuls, the unfused Pallas pair (``sparse_up_matmul`` +
``sparse_matmul_tokens``), and the one-pass fused kernel
(``fused_sparse_ffn``) — plus the paged-attention pair (materializing
``paged_gather`` + dense softmax vs the in-kernel block-table gather).
Each row reports wall time AND the analytic HBM bytes the lowering moves,
so the bytes column shows the point of the exercise even on CPU (where the
Pallas kernels run in interpret mode and wall time is meaningless — on an
accelerator the same rows time the compiled kernels).

The module also runs the serving bytes-per-step roofline
(``launch/roofline.py``) and emits its modeled/measured agreement as
``kernel_bytes_ratio`` — the trajectory headline the CI bench gate bounds
to [0.85, 1.15] (benchmarks/check_trajectory.py): if the kernel BlockSpec
geometry and the engine's density accounting drift apart, the gate trips
even though every stream still matches.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

SMOKE = bool(int(os.environ.get("BENCH_SMOKE", "0")))


def _time(fn, iters=None):
    iters = iters or (3 if SMOKE else 20)
    fn()  # compile / warm
    t0 = time.time()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def _ffn_case():
    """One decode-step FFN workload at 50% tile density (GLU, f32)."""
    from repro.predictor.predictors import pack_tile_indices

    T, d, F, tile = (4, 64, 512, 128) if SMOKE else (8, 128, 1024, 128)
    n_tiles = F // tile
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(T, d), jnp.float32)
    wg = jnp.asarray(rng.randn(d, F) / np.sqrt(d), jnp.float32)
    wu = jnp.asarray(rng.randn(d, F) / np.sqrt(d), jnp.float32)
    wd = jnp.asarray(rng.randn(F, d) / np.sqrt(F), jnp.float32)
    mask = jnp.asarray(rng.rand(T, n_tiles) < 0.5) | jnp.eye(
        T, n_tiles, dtype=bool)[:, :n_tiles]
    idx, nvalid = pack_tile_indices(mask, n_tiles)
    return x, wg, wu, wd, idx, nvalid, tile, n_tiles


def _ffn_rows():
    from repro.kernels import fused_decode as kfd
    from repro.kernels import sparse_matmul as ksm

    x, wg, wu, wd, idx, nvalid, tile, n_tiles = _ffn_case()
    T, d = x.shape
    F = wg.shape[1]
    itemsize = 4
    k_mean = float(jnp.mean(nvalid))
    dense_bytes = 3 * d * F * itemsize
    sparse_bytes = kfd.modeled_weight_bytes(k_mean, tile, d, itemsize, 3)

    def dense():
        h = jnp.maximum(x @ wg, 0.0) * (x @ wu)
        return h @ wd

    def unfused():
        pre = ksm.sparse_up_matmul(x, wg, idx, nvalid, tile=tile)
        hh = jnp.maximum(pre, 0.0) * ksm.sparse_up_matmul(x, wu, idx,
                                                          nvalid, tile=tile)
        return ksm.sparse_matmul_tokens(hh, wd, idx, nvalid, tile=tile)

    def fused():
        y, _ = kfd.fused_sparse_ffn(x, wg, wd, idx, nvalid, w_up=wu,
                                    activation="relu", tile=tile)
        return y

    # fused == unfused bit-exactly (the exactness tests pin this; assert
    # here too so a bench run can never report a speedup of wrong numerics)
    np.testing.assert_array_equal(np.asarray(fused()), np.asarray(unfused()))
    rows, full = [], {}
    for name, fn, nbytes in (("dense_xla", dense, dense_bytes),
                             ("unfused_pair", unfused, sparse_bytes),
                             ("fused_kernel", fused, sparse_bytes)):
        us = _time(fn)
        rows.append(f"kernel/ffn_{name},{us:.0f},weight_bytes={nbytes:.0f}")
        full[f"ffn_{name}"] = {"us_per_call": us, "weight_bytes": nbytes}
    full["ffn_density"] = k_mean / n_tiles
    return rows, full


def _attn_rows():
    from repro.kernels import paged_attention as kpa
    from repro.models import common as cm

    b, W, kvp, g, hd = (2, 1, 2, 2, 16) if SMOKE else (4, 1, 4, 2, 32)
    n_blocks, bs, nb = (9, 8, 4) if SMOKE else (17, 16, 8)
    rng = np.random.RandomState(1)
    q = jnp.asarray(rng.randn(b, W, kvp, g, hd), jnp.float32)
    kp = jnp.asarray(rng.randn(n_blocks, kvp, bs, hd), jnp.float32)
    vp = jnp.asarray(rng.randn(n_blocks, kvp, bs, hd), jnp.float32)
    table = jnp.asarray(rng.randint(1, n_blocks, (b, nb)), jnp.int32)
    pos = jnp.full((b, W), nb * bs - 1, jnp.int32)
    itemsize = 4
    cache = kpa.modeled_cache_bytes(nb, bs, kvp, hd, itemsize) * b

    def gathered():
        kg = cm.paged_gather(kp, table)
        vg = cm.paged_gather(vp, table)
        return cm.window_attention(q, kg, vg, pos, window=0)

    def fused():
        return kpa.paged_window_attention(q, kp, vp, table, pos, window=0)

    np.testing.assert_allclose(np.asarray(fused()), np.asarray(gathered()),
                               atol=1e-5)
    rows, full = [], {}
    # the gather path writes AND re-reads the materialized copy on top of
    # the one pool read the kernel pays
    for name, fn, nbytes in (("gathered_xla", gathered, 3 * cache),
                             ("fused_kernel", fused, cache)):
        us = _time(fn)
        rows.append(f"kernel/attn_{name},{us:.0f},cache_bytes={nbytes:.0f}")
        full[f"attn_{name}"] = {"us_per_call": us, "cache_bytes": nbytes}
    return rows, full


def run():
    rows, full = [], {}
    r, f = _ffn_rows()
    rows += r
    full.update(f)
    r, f = _attn_rows()
    rows += r
    full.update(f)

    # serving bytes-per-step roofline: kernel-modeled vs engine-measured
    from repro.launch.roofline import serving_records

    recs = serving_records("tiny-relu")
    ratios = [rec["ratio"] for rec in recs]
    ratio = float(np.mean(ratios))
    full["kernel_bytes_ratio"] = ratio
    full["roofline"] = recs
    rows.append(f"kernel/bytes_ratio,0,modeled_over_measured={ratio:.4f}")

    os.makedirs("experiments", exist_ok=True)
    with open("experiments/bench_kernels.json", "w") as f:
        json.dump(full, f, indent=2)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
