"""Predictor-mode weight-I/O savings vs recall, per activation function.

For each ReLU-family model, calibrate activity predictors (training-free
sign probe and learned low-rank factors) at several target recalls, then
serve a mixed-length workload through ``ContinuousBatchingEngine``'s
predictor mode and report what the paper's Sec. 5 headroom actually buys:
the fraction of up+down FFN weight reads skipped (both projections gather
the SAME predicted tile set, so the saving applies to each) against the
recall the predictor realized in-graph on served tokens.

Full mode uses the shared trained tiny models (benchmarks/common.py);
BENCH_SMOKE=1 uses random-init models so the CI smoke job exercises the
whole predictor serving path with no training. tile=1 (exact row-skipping)
keeps the savings observable at tiny-model widths; TPU-scale configs use
the 128-lane tile.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import registry
from repro.predictor import calibrate
from repro.serving import ContinuousBatchingEngine, EngineConfig

SMOKE = bool(int(os.environ.get("BENCH_SMOKE", "0")))


def _models():
    """(label, cfg, params) per activation function. Measurement runs at f32
    compute: the probe and the served pre-activation then share a dtype, so
    a recall-1.0 sign calibration measures recall exactly 1.0 (at bf16 the
    probe/compute rounding gap costs ~1e-3 recall — a real deployment
    effect, but noise for the I/O-vs-recall curve this module draws)."""
    out = []
    if SMOKE:
        for label, name in (("relu", "tiny-relu"), ("relu_mlp", "tiny-opt")):
            cfg = get_config(name)
            params = registry.get_family(cfg).init_params(
                jax.random.PRNGKey(0), cfg)
            out.append((label, cfg, params))
        cfg = get_config("tiny-relu").replace(
            activation="shifted_relu").replace_sparsity(shift=0.5)
        out.append(("shifted_relu", cfg,
                    registry.get_family(cfg).init_params(
                        jax.random.PRNGKey(0), cfg)))
    else:
        from benchmarks.common import get_model
        for label, kind in (("relu", "relu"), ("shifted_relu", "shifted")):
            cfg, params, _ = get_model(kind)
            out.append((label, cfg, params))
        # fatrelu: serving-time thresholding of the trained relu model
        cfg, params, _ = get_model("relu")
        out.append(("fatrelu", cfg.replace(name="bench-fatrelu",
                                           activation="fatrelu:0.05"),
                    params))
    return [(label, cfg.replace(compute_dtype="float32"), params)
            for label, cfg, params in out]


def _settings():
    """(kind, target_recall, calibrate kwargs) sweep."""
    if SMOKE:
        return [("sign", 1.0, dict(probe_dtype="float32")),
                ("lowrank", 0.9, dict(rank=8))]
    return [("sign", 1.0, dict(probe_dtype="float32")),
            ("sign", 0.97, dict(probe_dtype="bfloat16")),
            ("lowrank", 0.97, dict(rank=16)),
            ("lowrank", 0.9, dict(rank=8))]


def _serve(cfg, params, pred):
    rng = np.random.RandomState(0)
    n_req, max_new = (3, 10) if SMOKE else (6, 16)
    eng = ContinuousBatchingEngine(cfg, params, config=EngineConfig(
        n_slots=2, block_size=16, max_blocks_per_seq=4, predictor=pred))
    uids = [eng.submit(rng.randint(0, cfg.vocab_size, int(s)), max_new)
            for s in rng.randint(6, 20, n_req)]
    t0 = time.time()
    res = eng.run()
    dt = time.time() - t0
    n_tok = sum(len(res[u].tokens) for u in uids)
    return {
        "io_saved": eng.weight_io_saved(),
        "density": eng.predictor_density(),
        "recall": eng.predictor_recall(),
        "misses": int(sum(res[u].pred_misses for u in uids)),
        "us_per_token": dt / n_tok * 1e6,
        "calib": pred.mean_report(),
    }


def run():
    rows, full = [], {}
    for label, cfg, params in _models():
        calib = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)}
        for kind, target, kw in _settings():
            pred = calibrate(params, cfg, calib, kind=kind,
                             target_recall=target, tile=1, **kw)
            m = _serve(cfg, params, pred)
            m["target_recall"] = target
            full[f"{label}/{kind}_t{target}"] = m
            rows.append(
                f"predictor/{label}_{kind}_t{target},"
                f"{m['us_per_token']:.0f},"
                f"io_saved={m['io_saved']:.3f};recall={m['recall']:.4f};"
                f"target={target};density={m['density']:.3f}")
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/bench_predictor.json", "w") as f:
        json.dump(full, f, indent=2)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
