"""Benchmark harness — one module per paper table/figure.

Each module runs in its OWN subprocess (the XLA CPU JIT accumulates code
memory across eager stats passes; isolation keeps the suite within RAM).
Prints ``name,us_per_call,derived`` CSV rows; full results in
experiments/bench_*.json. Trained tiny models are disk-cached and shared.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

MODULES = [
    "benchmarks.fig1_sparsity",
    "benchmarks.fig2_actfn_spectrum",
    "benchmarks.table1_flops",
    "benchmarks.fig5_preactivation",
    "benchmarks.fig6_recovery",
    "benchmarks.fig7_aggregated",
    "benchmarks.fig7_spec_decode",
    "benchmarks.fig8_shifted_relu",
    "benchmarks.fig9_flops_latency",
    "benchmarks.fig10_optimal_gamma",
    "benchmarks.appE_scaling",
    "benchmarks.serving_throughput",
    "benchmarks.slo_traffic",
    "benchmarks.predictor_sparsity",
    "benchmarks.kernel_bench",
]

# training-free modules that exercise the kernel + serving hot paths; the CI
# benchmark-smoke job runs these (BENCH_SMOKE=1 shrinks workloads further and
# makes fig7_spec_decode use random-init tiny models, so the engine's
# speculative path is exercised on every push)
SMOKE_MODULES = [
    "benchmarks.fig9_flops_latency",
    "benchmarks.fig10_optimal_gamma",
    "benchmarks.fig7_spec_decode",
    "benchmarks.serving_throughput",
    "benchmarks.slo_traffic",
    "benchmarks.predictor_sparsity",
    "benchmarks.kernel_bench",
]


def run_module(mod_name: str) -> None:
    import importlib
    # script invocation puts benchmarks/ (not the repo root) on sys.path;
    # make `import benchmarks.*` work either way
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if root not in sys.path:
        sys.path.insert(0, root)
    mod = importlib.import_module(mod_name)
    for r in mod.run():
        print(r, flush=True)


PR_TAG = os.environ.get("BENCH_PR", "pr10")


def write_trajectory(tag: str = PR_TAG) -> str:
    """Collapse experiments/bench_*.json into a repo-root ``BENCH_<pr>.json``
    so the perf trajectory is tracked PR-over-PR in git (the experiments/
    files are gitignored run artifacts; this one is committed). Headline
    numbers: serving throughput, the weight-I/O savings of every serving
    mode, and the prefix cache's hit rate / prefill-tokens-saved."""
    import glob
    import json

    sources = {}
    for path in sorted(glob.glob("experiments/bench_*.json")):
        try:
            with open(path) as f:
                sources[os.path.basename(path)] = json.load(f)
        except (OSError, ValueError):  # a failed module's partial file
            continue
    serving = sources.get("bench_serving.json", {})
    kernels = sources.get("bench_kernels.json", {})
    slo = sources.get("bench_slo.json", {})
    out = {
        "pr": tag,
        "headline": {
            "legacy_tokens_per_s": serving.get("legacy_tokens_per_s"),
            "serving_tokens_per_s": serving.get("cb_rate0_tokens_per_s"),
            "cb_speedup_vs_legacy": serving.get("cb_rate0_speedup"),
            "weight_io_saved_gamma4": serving.get("cb_gamma4_io_saved"),
            "spec_s_agg_gamma4": serving.get("cb_spec_gamma4_s_agg"),
            "weight_io_saved_predictor": serving.get("cb_predictor_io_saved"),
            "prefix_cache_tokens_per_s":
                serving.get("cb_prefix_cache_tokens_per_s"),
            "prefix_hit_rate": serving.get("cb_prefix_cache_hit_rate"),
            "prefill_tokens_saved":
                serving.get("cb_prefix_cache_prefill_tokens_saved"),
            # MoE through the engine (ISSUE 9): throughput + the
            # activated-expert fraction of FFN weight I/O per step
            "moe_tokens_per_s": serving.get("cb_moe_tokens_per_s"),
            "moe_expert_io_fraction":
                serving.get("cb_moe_expert_io_fraction"),
            "api_stream_tokens_per_s":
                serving.get("cb_api_stream_tokens_per_s"),
            "api_ttft_ms": serving.get("cb_api_stream_ttft_ms"),
            "api_tpot_ms": serving.get("cb_api_stream_tpot_ms"),
            # engine-side span percentiles from the obs histograms, merged
            # across every continuous-batching case (serving_throughput.py)
            "ttft_p50_ms": serving.get("serving_ttft_p50_ms"),
            "ttft_p99_ms": serving.get("serving_ttft_p99_ms"),
            "tpot_p50_ms": serving.get("serving_tpot_p50_ms"),
            "tpot_p99_ms": serving.get("serving_tpot_p99_ms"),
            "queue_wait_p50_ms": serving.get("serving_queue_wait_p50_ms"),
            "queue_wait_p99_ms": serving.get("serving_queue_wait_p99_ms"),
            # SLO scheduling (ISSUE 10): interactive-class goodput under a
            # step-based TTFT SLO, the FIFO baseline at the same offered
            # load, their gap (gated > 0), and the preemptions exercised
            "slo_goodput": slo.get("slo_goodput"),
            "slo_goodput_fifo": slo.get("slo_goodput_fifo"),
            "slo_goodput_gain": slo.get("slo_goodput_gain"),
            "preemption_count": slo.get("preemption_count"),
            "kernel_bytes_ratio": kernels.get("kernel_bytes_ratio"),
            "kernel_ffn_fused_us":
                (kernels.get("ffn_fused_kernel") or {}).get("us_per_call"),
            "kernel_attn_fused_us":
                (kernels.get("attn_fused_kernel") or {}).get("us_per_call"),
        },
        "sources": sources,
    }
    fname = f"BENCH_{tag.upper()}.json"
    with open(fname, "w") as f:
        json.dump(out, f, indent=2)
    return fname


def main() -> None:
    os.makedirs("experiments", exist_ok=True)
    smoke = "--smoke" in sys.argv
    args = [a for a in sys.argv[1:] if a not in ("--smoke", "--all")]
    if args:
        if smoke:
            os.environ["BENCH_SMOKE"] = "1"  # before the module import
        run_module(args[0])
        return
    print("name,us_per_call,derived", flush=True)
    failures = 0
    env = dict(os.environ)
    env.setdefault("PYTHONPATH", "src")
    if smoke:
        env["BENCH_SMOKE"] = "1"
    for mod_name in (SMOKE_MODULES if smoke else MODULES):
        t0 = time.time()
        r = subprocess.run([sys.executable, "-m", "benchmarks.run", mod_name],
                           capture_output=True, text=True, env=env)
        dt = time.time() - t0
        if r.returncode == 0:
            sys.stdout.write(r.stdout)
            print(f"# {mod_name} done in {dt:.1f}s", file=sys.stderr)
        else:
            failures += 1
            print(f"# FAILED {mod_name}:\n{r.stderr[-2000:]}", file=sys.stderr)
        sys.stdout.flush()
    print(f"# wrote {write_trajectory()}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
