"""Serving throughput: continuous batching vs the seed single-batch loop.

A mixed-length multi-request workload (the ROADMAP's heavy-traffic shape) is
served two ways:

* legacy ``ServeEngine.generate`` — the seed path: one request at a time
  (its contiguous cache pads every sequence to max_len and cannot mix
  prompt lengths in a batch);
* ``ContinuousBatchingEngine`` — requests share slots + the paged KV pool,
  admitted/retired mid-decode, at several request-arrival rates.

Reports aggregate tokens/sec, the CB speedup, and the down-projection
weight-I/O saved by γ-window reuse (paper Fig. 7c). Model quality is
irrelevant to throughput, so params are random — no training, which keeps
this runnable in the CI benchmark-smoke job (BENCH_SMOKE=1 shrinks the
workload).

Every case reports the best of ``_TIMED_REPS`` timed runs (compile/warm
dominates the wall; the timed section is ~1 s, so an unlucky scheduling
window on a shared runner would otherwise pollute the committed
trajectory the regression gate compares against).

Every continuous-batching case also reports engine-side latency
percentiles (TTFT / TPOT / queue-wait p50+p99, in ms) read from the
observability histograms (repro.obs) — the warm compile run is excluded
via ``eng.obs.reset()``, so the timed runs alone feed the buckets.
The merged Prometheus snapshot across all cases is written to
``experiments/bench_serving.prom`` (a CI artifact next to the JSON).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import registry
from repro.obs import merge_snapshots, render_prometheus
from repro.serving import ContinuousBatchingEngine, EngineConfig, ServeEngine

SMOKE = bool(int(os.environ.get("BENCH_SMOKE", "0")))

# engine-side span histograms -> reported percentile keys (values in ms)
_SPAN_METRICS = (("ttft", "repro_request_ttft_seconds"),
                 ("tpot", "repro_request_tpot_seconds"),
                 ("queue_wait", "repro_request_queue_wait_seconds"))


def _span_percentiles(eng, case: str) -> dict:
    """TTFT / TPOT / queue-wait p50+p99 (ms) from the engine's obs
    histograms — engine-side spans (admission to tokens recorded), so they
    isolate scheduler/step latency from asyncio plumbing. Values are
    log-bucket upper edges clamped to the observed [min, max]."""
    out = {}
    for short, metric in _SPAN_METRICS:
        for q, tag in ((0.5, "p50"), (0.99, "p99")):
            v = eng.obs.quantile(metric, q)
            if v is not None:
                out[f"{case}_{short}_{tag}_ms"] = v * 1e3
    return out


def _workload(cfg, n_requests):
    rng = np.random.RandomState(0)
    lengths = rng.randint(6, 30, n_requests)
    max_news = rng.randint(12, 28 if not SMOKE else 16, n_requests)
    prompts = [rng.randint(0, cfg.vocab_size, s).astype(np.int32)
               for s in lengths]
    return prompts, [int(m) for m in max_news]


# every case reports the best of N timed runs: the timed section is ~1 s
# while warm/compile dominates the wall, and shared CI runners (and dev
# boxes) are noisy — a single unlucky scheduling window otherwise pollutes
# the committed trajectory the regression gate compares against
_TIMED_REPS = 3


def _run_legacy(cfg, params, prompts, max_news, max_len):
    eng = ServeEngine(cfg, params, max_len=max_len)
    def serve():
        n = 0
        for p, m in zip(prompts, max_news):
            r = eng.generate({"tokens": jnp.asarray(p[None], jnp.int32)}, m)
            n += r.tokens.shape[1]
        return n
    serve()  # warm (compile)
    best = 0.0
    for _ in range(_TIMED_REPS):
        t0 = time.time()
        n = serve()
        best = max(best, n / (time.time() - t0))
    return best


def _run_cb(cfg, params, prompts, max_news, *, arrival_every, gamma=0,
            n_slots=4, draft=None, predictor=None, max_blocks_per_seq=4,
            **engine_kw):
    """draft=(dcfg, dparams) switches the engine to speculative mode (γ-token
    drafts verified in one target forward per step); gamma is then the draft
    length instead of the Fig. 7c reuse window. predictor=Predictor switches
    it to predictor mode (gathered up+down FFN matmuls over predicted-active
    tiles). Extra engine_kw (prefill_chunk, prefix_cache, ...) pass through.
    Returns (tokens_per_s, engine) — metrics are read off the engine."""
    if draft is not None:
        engine_kw.update(draft_cfg=draft[0], draft_params=draft[1],
                         gamma=gamma)
    elif predictor is not None:
        engine_kw.update(predictor=predictor)
    eng = ContinuousBatchingEngine(cfg, params, config=EngineConfig(
        n_slots=n_slots, block_size=16,
        max_blocks_per_seq=max_blocks_per_seq, **engine_kw))
    def serve():
        pending = list(zip(prompts, max_news))
        next_arrival = eng.t  # engine step counter keeps running across runs
        uids = []
        while pending or eng.scheduler.has_work():
            while pending and eng.t >= next_arrival:
                p, m = pending.pop(0)
                uids.append(eng.submit(p, m, reuse_window=gamma))
                next_arrival = eng.t + arrival_every
            if not eng.step():
                if not pending:
                    break
                # idle gap before the next arrival: fast-forward the clock
                # instead of spinning (step() no longer advances eng.t)
                next_arrival = eng.t
        eng.scheduler.retire_finished(eng.t)
        res = eng.scheduler.results
        return sum(len(res[u].tokens) for u in uids)
    serve()  # warm (compile; the jit caches live on the engine instance)
    eng.scheduler.results.clear()
    # drop the warm run's spans/histograms so the reported percentiles
    # describe the timed workload only (safe here: every warm request has
    # retired; never call reset() on a live server)
    eng.obs.reset()
    sched = eng.scheduler
    best = 0.0
    for _ in range(_TIMED_REPS):
        if sched.prefix is not None:
            # measure the prefix cache COLD each run: no run may leak its
            # trie (which would turn every timed admission into a
            # full-prompt hit) or its hit counters into the next — the
            # timed numbers are the in-run sharing of the workload itself
            sched.prefix.evict(sched.allocator, len(sched.prefix))
            sched.prefill_tokens_total = 0
            sched.prefill_tokens_saved = 0
        t0 = time.time()
        n = serve()
        best = max(best, n / (time.time() - t0))
        eng.scheduler.results.clear()
    return best, eng


def _run_api_stream(cfg, params, prompts, max_news):
    """Serve the workload through the async streaming API (serving/api.py)
    with one concurrent client per request, measuring what an online
    caller feels: TTFT (submit -> first streamed token, queueing included)
    and TPOT (mean gap between consecutive streamed tokens), plus the
    aggregate streamed tokens/s. Returns (tokens_per_s, ttft_s, tpot_s,
    engine) — the engine carries the timed run's obs histograms."""
    import asyncio

    from repro.serving import AsyncServingEngine

    eng = ContinuousBatchingEngine(cfg, params, config=EngineConfig(
        n_slots=4, block_size=16, max_blocks_per_seq=4))

    async def client(api, p, m):
        t0 = time.time()
        stamps = []
        async for ev in api.stream(p, m):
            if not ev.finished:
                stamps.append(time.time())
        return t0, stamps

    async def serve():
        async with AsyncServingEngine(eng) as api:
            return await asyncio.gather(*[client(api, p, m)
                                          for p, m in zip(prompts, max_news)])

    asyncio.run(serve())  # warm (compile)
    eng.obs.reset()  # exclude the warm run from the obs histograms
    best = None  # (tokens/s, ttft, tpot) of the quietest timed run
    for _ in range(_TIMED_REPS):
        t0 = time.time()
        per_client = asyncio.run(serve())
        wall = time.time() - t0
        n = sum(len(stamps) for _, stamps in per_client)
        ttfts = [stamps[0] - t for t, stamps in per_client if stamps]
        gaps = [(stamps[-1] - stamps[0]) / (len(stamps) - 1)
                for _, stamps in per_client if len(stamps) > 1]
        cand = (n / wall, float(np.mean(ttfts)), float(np.mean(gaps)))
        if best is None or cand[0] > best[0]:
            best = cand
    return best[0], best[1], best[2], eng


def run():
    cfg = get_config("tiny-relu")
    fam = registry.get_family(cfg)
    params = fam.init_params(jax.random.PRNGKey(0), cfg)
    n_requests = 4 if SMOKE else 8
    prompts, max_news = _workload(cfg, n_requests)
    max_len = int(max(len(p) + m for p, m in zip(prompts, max_news))) + 2

    tps_legacy = _run_legacy(cfg, params, prompts, max_news, max_len)
    rows, full = [], {"n_requests": n_requests,
                      "legacy_tokens_per_s": tps_legacy}
    rows.append(f"serving/legacy_sequential,{1e6 / tps_legacy:.0f},"
                f"toks_per_s={tps_legacy:.1f}")

    engines = []  # every CB engine's obs snapshot merges into the .prom

    rates = [0, 2] if SMOKE else [0, 2, 6]
    for rate in rates:
        tps, eng_r = _run_cb(cfg, params, prompts, max_news,
                             arrival_every=rate)
        engines.append(eng_r)
        full[f"cb_rate{rate}_tokens_per_s"] = tps
        full[f"cb_rate{rate}_speedup"] = tps / tps_legacy
        full.update(_span_percentiles(eng_r, f"cb_rate{rate}"))
        rows.append(f"serving/cb_rate{rate},{1e6 / tps:.0f},"
                    f"toks_per_s={tps:.1f};speedup={tps / tps_legacy:.2f}x")

    # γ-window reuse: same workload, masked decode between refreshes
    tps_g, eng_g = _run_cb(cfg, params, prompts, max_news,
                           arrival_every=0, gamma=4)
    engines.append(eng_g)
    full.update(_span_percentiles(eng_g, "cb_gamma4"))
    io_saved, tiles = eng_g.weight_io_saved(), eng_g.tile_activity_rate()
    full["cb_gamma4_tokens_per_s"] = tps_g
    full["cb_gamma4_io_saved"] = io_saved
    full["cb_gamma4_tile_activity"] = tiles
    rows.append(f"serving/cb_gamma4,{1e6 / tps_g:.0f},"
                f"toks_per_s={tps_g:.1f};io_saved={io_saved:.3f};"
                f"tile_activity={tiles:.3f}")

    # speculative serving: batched γ-token drafts (1-layer random draft),
    # each slot's window verified in one target forward per step — io_saved
    # here is the measured s_agg(γ) of the sparse verification (Sec. 5.2)
    dcfg = cfg.replace(name="tiny-draft", n_layers=1)
    dparams = registry.get_family(dcfg).init_params(jax.random.PRNGKey(3),
                                                    dcfg)
    tps_s, eng_s = _run_cb(cfg, params, prompts, max_news,
                           arrival_every=0, gamma=4, draft=(dcfg, dparams))
    engines.append(eng_s)
    full.update(_span_percentiles(eng_s, "cb_spec_gamma4"))
    s_agg, tiles_s = eng_s.weight_io_saved(), eng_s.tile_activity_rate()
    full["cb_spec_gamma4_tokens_per_s"] = tps_s
    full["cb_spec_gamma4_s_agg"] = s_agg
    full["cb_spec_gamma4_tile_activity"] = tiles_s
    rows.append(f"serving/cb_spec_gamma4,{1e6 / tps_s:.0f},"
                f"toks_per_s={tps_s:.1f};s_agg={s_agg:.3f};"
                f"tile_activity={tiles_s:.3f}")

    # predictor serving: a training-free sign predictor (f32 probe, recall
    # 1.0 — identical token streams) names each token's active FFN rows and
    # the engine gathers only those for BOTH the up- and down-projections;
    # io_saved here is the up+down weight-I/O the predictor skipped
    from repro.predictor import calibrate
    calib = {"tokens": jnp.asarray(
        np.random.RandomState(7).randint(0, cfg.vocab_size, (4, 32)))}
    pred = calibrate(params, cfg, calib, kind="sign", probe_dtype="float32",
                     target_recall=1.0, tile=1)
    tps_p, eng_p = _run_cb(cfg, params, prompts, max_news,
                           arrival_every=0, predictor=pred)
    engines.append(eng_p)
    full.update(_span_percentiles(eng_p, "cb_predictor"))
    io_p, tiles_p = eng_p.weight_io_saved(), eng_p.tile_activity_rate()
    full["cb_predictor_tokens_per_s"] = tps_p
    full["cb_predictor_io_saved"] = io_p
    full["cb_predictor_tile_activity"] = tiles_p
    rows.append(f"serving/cb_predictor,{1e6 / tps_p:.0f},"
                f"toks_per_s={tps_p:.1f};io_saved={io_p:.3f};"
                f"tile_activity={tiles_p:.3f}")

    # MoE serving (ISSUE 9): the same mixed workload through tiny-moe —
    # routing is structured activation sparsity, so the engine's byte
    # accounting reports the activated-expert I/O fraction (top_k /
    # n_experts under drop-free capacity) alongside throughput
    mcfg = get_config("tiny-moe")
    mparams = registry.get_family(mcfg).init_params(jax.random.PRNGKey(5),
                                                    mcfg)
    mprompts, mmax_news = _workload(mcfg, n_requests)
    tps_m, eng_m = _run_cb(mcfg, mparams, mprompts, mmax_news,
                           arrival_every=0)
    engines.append(eng_m)
    full.update(_span_percentiles(eng_m, "cb_moe"))
    efrac = eng_m.expert_io_fraction()
    full["cb_moe_tokens_per_s"] = tps_m
    full["cb_moe_expert_io_fraction"] = efrac
    full["cb_moe_weight_io_bytes_per_step"] = eng_m.weight_io_bytes_per_step()
    rows.append(f"serving/cb_moe,{1e6 / tps_m:.0f},"
                f"toks_per_s={tps_m:.1f};expert_io_fraction={efrac:.3f}")

    # prefix caching + chunked prefill: every request shares a 2-block
    # (32-token) system prompt. Arrivals are staggered over 2 slots (the
    # trie only learns a prefix once its first request finishes prefilling,
    # so a same-instant burst is all cold misses): the first admissions
    # prefill the system prompt cold and register it, every later one maps
    # it from the trie (refcount++) and chunk-prefills only its cold
    # suffix, interleaved with decode
    shared = np.random.RandomState(11).randint(0, cfg.vocab_size,
                                               32).astype(np.int32)
    pc_prompts = [np.concatenate([shared, p]) for p in prompts]
    tps_pc, eng_pc = _run_cb(cfg, params, pc_prompts, max_news,
                             arrival_every=2, n_slots=2,
                             max_blocks_per_seq=6,
                             prefill_chunk=16, prefix_cache=True)
    engines.append(eng_pc)
    full.update(_span_percentiles(eng_pc, "cb_prefix_cache"))
    hit, saved = eng_pc.prefix_hit_rate(), eng_pc.prefill_tokens_saved()
    full["cb_prefix_cache_tokens_per_s"] = tps_pc
    full["cb_prefix_cache_hit_rate"] = hit
    full["cb_prefix_cache_prefill_tokens_saved"] = saved
    rows.append(f"serving/cb_prefix_cache,{1e6 / tps_pc:.0f},"
                f"toks_per_s={tps_pc:.1f};prefix_hit_rate={hit:.3f};"
                f"prefill_tokens_saved={saved}")

    # async streaming API: the same engine behind AsyncServingEngine with
    # one concurrent SSE-style client per request — the latency numbers
    # (TTFT / TPOT) are what check_trajectory.py gates PR-over-PR
    tps_api, ttft, tpot, eng_api = _run_api_stream(cfg, params, prompts,
                                                   max_news)
    engines.append(eng_api)
    full.update(_span_percentiles(eng_api, "cb_api_stream"))
    full["cb_api_stream_tokens_per_s"] = tps_api
    full["cb_api_stream_ttft_ms"] = ttft * 1e3
    full["cb_api_stream_tpot_ms"] = tpot * 1e3
    rows.append(f"serving/cb_api_stream,{1e6 / tps_api:.0f},"
                f"toks_per_s={tps_api:.1f};ttft_ms={ttft * 1e3:.1f};"
                f"tpot_ms={tpot * 1e3:.2f}")

    # workload-wide latency percentiles: merge every case's obs snapshot
    # (bucket-wise counter/histogram add — associative, so the merge order
    # is irrelevant) and read the aggregate quantiles off the union. These
    # are the keys check_trajectory.py gates PR-over-PR.
    from repro.obs import snapshot_quantile
    merged = merge_snapshots(*[e.obs.snapshot() for e in engines])
    for short, metric in _SPAN_METRICS:
        for q, tag in ((0.5, "p50"), (0.99, "p99")):
            v = snapshot_quantile(merged, metric, q)
            if v is not None:
                full[f"serving_{short}_{tag}_ms"] = v * 1e3
    rows.append(
        f"serving/latency_percentiles,0,"
        f"ttft_p50_ms={full.get('serving_ttft_p50_ms', float('nan')):.2f};"
        f"ttft_p99_ms={full.get('serving_ttft_p99_ms', float('nan')):.2f};"
        f"tpot_p50_ms={full.get('serving_tpot_p50_ms', float('nan')):.2f};"
        f"tpot_p99_ms={full.get('serving_tpot_p99_ms', float('nan')):.2f}")

    os.makedirs("experiments", exist_ok=True)
    with open("experiments/bench_serving.json", "w") as f:
        json.dump(full, f, indent=2)
    with open("experiments/bench_serving.prom", "w") as f:
        f.write(render_prometheus(merged))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
