"""Serving throughput: continuous batching vs the seed single-batch loop.

A mixed-length multi-request workload (the ROADMAP's heavy-traffic shape) is
served two ways:

* legacy ``ServeEngine.generate`` — the seed path: one request at a time
  (its contiguous cache pads every sequence to max_len and cannot mix
  prompt lengths in a batch);
* ``ContinuousBatchingEngine`` — requests share slots + the paged KV pool,
  admitted/retired mid-decode, at several request-arrival rates.

Reports aggregate tokens/sec, the CB speedup, and the down-projection
weight-I/O saved by γ-window reuse (paper Fig. 7c). Model quality is
irrelevant to throughput, so params are random — no training, which keeps
this runnable in the CI benchmark-smoke job (BENCH_SMOKE=1 shrinks the
workload).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import registry
from repro.serving import ContinuousBatchingEngine, ServeEngine

SMOKE = bool(int(os.environ.get("BENCH_SMOKE", "0")))


def _workload(cfg, n_requests):
    rng = np.random.RandomState(0)
    lengths = rng.randint(6, 30, n_requests)
    max_news = rng.randint(12, 28 if not SMOKE else 16, n_requests)
    prompts = [rng.randint(0, cfg.vocab_size, s).astype(np.int32)
               for s in lengths]
    return prompts, [int(m) for m in max_news]


def _run_legacy(cfg, params, prompts, max_news, max_len):
    eng = ServeEngine(cfg, params, max_len=max_len)
    def serve():
        n = 0
        for p, m in zip(prompts, max_news):
            r = eng.generate({"tokens": jnp.asarray(p[None], jnp.int32)}, m)
            n += r.tokens.shape[1]
        return n
    serve()  # warm (compile)
    t0 = time.time()
    n = serve()
    return n / (time.time() - t0)


def _run_cb(cfg, params, prompts, max_news, *, arrival_every, gamma=0,
            n_slots=4, draft=None, predictor=None):
    """draft=(dcfg, dparams) switches the engine to speculative mode (γ-token
    drafts verified in one target forward per step); gamma is then the draft
    length instead of the Fig. 7c reuse window. predictor=Predictor switches
    it to predictor mode (gathered up+down FFN matmuls over predicted-active
    tiles)."""
    if draft is not None:
        eng = ContinuousBatchingEngine(cfg, params, n_slots=n_slots,
                                       block_size=16, max_blocks_per_seq=4,
                                       draft_cfg=draft[0],
                                       draft_params=draft[1], gamma=gamma)
    elif predictor is not None:
        eng = ContinuousBatchingEngine(cfg, params, n_slots=n_slots,
                                       block_size=16, max_blocks_per_seq=4,
                                       predictor=predictor)
    else:
        eng = ContinuousBatchingEngine(cfg, params, n_slots=n_slots,
                                       block_size=16, max_blocks_per_seq=4)
    def serve():
        pending = list(zip(prompts, max_news))
        next_arrival = eng.t  # engine step counter keeps running across runs
        uids = []
        while pending or eng.scheduler.has_work():
            while pending and eng.t >= next_arrival:
                p, m = pending.pop(0)
                uids.append(eng.submit(p, m, reuse_window=gamma))
                next_arrival = eng.t + arrival_every
            if not eng.step():
                if not pending:
                    break
                # idle gap before the next arrival: fast-forward the clock
                # instead of spinning (step() no longer advances eng.t)
                next_arrival = eng.t
        eng.scheduler.retire_finished(eng.t)
        res = eng.scheduler.results
        return sum(len(res[u].tokens) for u in uids)
    serve()  # warm (compile; the jit caches live on the engine instance)
    eng.scheduler.results.clear()
    t0 = time.time()
    n = serve()
    dt = time.time() - t0
    return n / dt, eng.weight_io_saved(), eng.tile_activity_rate()


def run():
    cfg = get_config("tiny-relu")
    fam = registry.get_family(cfg)
    params = fam.init_params(jax.random.PRNGKey(0), cfg)
    n_requests = 4 if SMOKE else 8
    prompts, max_news = _workload(cfg, n_requests)
    max_len = int(max(len(p) + m for p, m in zip(prompts, max_news))) + 2

    tps_legacy = _run_legacy(cfg, params, prompts, max_news, max_len)
    rows, full = [], {"n_requests": n_requests,
                      "legacy_tokens_per_s": tps_legacy}
    rows.append(f"serving/legacy_sequential,{1e6 / tps_legacy:.0f},"
                f"toks_per_s={tps_legacy:.1f}")

    rates = [0, 2] if SMOKE else [0, 2, 6]
    for rate in rates:
        tps, _, _ = _run_cb(cfg, params, prompts, max_news,
                            arrival_every=rate)
        full[f"cb_rate{rate}_tokens_per_s"] = tps
        full[f"cb_rate{rate}_speedup"] = tps / tps_legacy
        rows.append(f"serving/cb_rate{rate},{1e6 / tps:.0f},"
                    f"toks_per_s={tps:.1f};speedup={tps / tps_legacy:.2f}x")

    # γ-window reuse: same workload, masked decode between refreshes
    tps_g, io_saved, tiles = _run_cb(cfg, params, prompts, max_news,
                                     arrival_every=0, gamma=4)
    full["cb_gamma4_tokens_per_s"] = tps_g
    full["cb_gamma4_io_saved"] = io_saved
    full["cb_gamma4_tile_activity"] = tiles
    rows.append(f"serving/cb_gamma4,{1e6 / tps_g:.0f},"
                f"toks_per_s={tps_g:.1f};io_saved={io_saved:.3f};"
                f"tile_activity={tiles:.3f}")

    # speculative serving: batched γ-token drafts (1-layer random draft),
    # each slot's window verified in one target forward per step — io_saved
    # here is the measured s_agg(γ) of the sparse verification (Sec. 5.2)
    dcfg = cfg.replace(name="tiny-draft", n_layers=1)
    dparams = registry.get_family(dcfg).init_params(jax.random.PRNGKey(3),
                                                    dcfg)
    tps_s, s_agg, tiles_s = _run_cb(cfg, params, prompts, max_news,
                                    arrival_every=0, gamma=4,
                                    draft=(dcfg, dparams))
    full["cb_spec_gamma4_tokens_per_s"] = tps_s
    full["cb_spec_gamma4_s_agg"] = s_agg
    full["cb_spec_gamma4_tile_activity"] = tiles_s
    rows.append(f"serving/cb_spec_gamma4,{1e6 / tps_s:.0f},"
                f"toks_per_s={tps_s:.1f};s_agg={s_agg:.3f};"
                f"tile_activity={tiles_s:.3f}")

    # predictor serving: a training-free sign predictor (f32 probe, recall
    # 1.0 — identical token streams) names each token's active FFN rows and
    # the engine gathers only those for BOTH the up- and down-projections;
    # io_saved here is the up+down weight-I/O the predictor skipped
    from repro.predictor import calibrate
    calib = {"tokens": jnp.asarray(
        np.random.RandomState(7).randint(0, cfg.vocab_size, (4, 32)))}
    pred = calibrate(params, cfg, calib, kind="sign", probe_dtype="float32",
                     target_recall=1.0, tile=1)
    tps_p, io_p, tiles_p = _run_cb(cfg, params, prompts, max_news,
                                   arrival_every=0, predictor=pred)
    full["cb_predictor_tokens_per_s"] = tps_p
    full["cb_predictor_io_saved"] = io_p
    full["cb_predictor_tile_activity"] = tiles_p
    rows.append(f"serving/cb_predictor,{1e6 / tps_p:.0f},"
                f"toks_per_s={tps_p:.1f};io_saved={io_p:.3f};"
                f"tile_activity={tiles_p:.3f}")

    os.makedirs("experiments", exist_ok=True)
    with open("experiments/bench_serving.json", "w") as f:
        json.dump(full, f, indent=2)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
