"""Goodput under SLO: Poisson-overload traffic through the SLO-aware
scheduler vs a FIFO baseline at the same offered load (ISSUE 10 tentpole).

Two priority classes share a deliberately undersized engine (2 slots):

* interactive — priority 2, short prompts/generations, a tight TTFT SLO;
* batch       — priority 0, long generations, a loose SLO.

Arrivals are a seeded Poisson process measured in ENGINE STEPS
(exponential inter-arrival times), and the SLO is judged on the
scheduler's deterministic step stamps (``RequestResult.submit_step`` /
``first_token_step``) — not wall clock — so the reported goodput is a
pure scheduling outcome, reproducible across machines and immune to CI
timing noise. (Token VALUES never influence the schedule here: every
request is greedy with no stop sequences, so it runs exactly ``max_new``
steps regardless of dtype or backend.)

The same workload is served twice:

* FIFO baseline — every request submitted at priority 0, preemption off,
  aging off: the pre-PR-10 scheduler, where a long batch request parked
  in a slot blocks an interactive arrival for its whole generation;
* SLO run — true priorities, preemption on: an interactive arrival
  preempts a batch slot (its KV blocks return to the pool, its prefix
  parks in the trie), decodes, and the batch request resumes via chunked
  prefill; aging bounds batch starvation.

Reported keys (experiments/bench_slo.json → BENCH_<pr>.json headline,
gated by check_trajectory.py):

* ``slo_goodput``       — interactive-class goodput under SLO scheduling
* ``slo_goodput_fifo``  — same class, same load, FIFO baseline
* ``slo_goodput_gain``  — the difference; the gate requires it > 0
  (priorities+preemption must strictly beat FIFO at the same load)
* ``preemption_count``  — must be >= 1 (the mechanism actually ran)
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from repro.configs import get_config
from repro.models import registry
from repro.serving import ContinuousBatchingEngine, EngineConfig

SMOKE = bool(int(os.environ.get("BENCH_SMOKE", "0")))

# class table: (priority, slo in engine steps, prompt-len range,
# max_new range). Interactive TTFT under preemptive scheduling is
# ~chunked-prefill steps (2-3) + queueing among its own class; under FIFO
# it waits out whole batch generations — the 12-step SLO separates the two.
_INTERACTIVE = dict(priority=2, slo_steps=12, plen=(8, 14), mnew=(6, 10))
_BATCH = dict(priority=0, slo_steps=400, plen=(14, 24), mnew=(16, 22))

_MAX_STEPS = 200_000  # driver backstop, far above any real schedule


def _workload(cfg, n_requests, mean_interarrival, seed=0):
    """[(arrival_step, prompt, max_new, class_dict)] — a seeded Poisson
    arrival process with ~1/4 interactive traffic."""
    rng = np.random.RandomState(seed)
    gaps = rng.exponential(mean_interarrival, n_requests)
    arrivals = np.floor(np.cumsum(gaps)).astype(int)
    out = []
    for i in range(n_requests):
        klass = _INTERACTIVE if rng.rand() < 0.25 else _BATCH
        plen = rng.randint(*klass["plen"])
        prompt = rng.randint(0, cfg.vocab_size, plen).astype(np.int32)
        max_new = int(rng.randint(*klass["mnew"]))
        out.append((int(arrivals[i]), prompt, max_new, klass))
    return out


def _serve(cfg, params, workload, *, slo_aware: bool):
    """Serve the workload once; returns (per-request records, engine)."""
    eng = ContinuousBatchingEngine(cfg, params, config=EngineConfig(
        n_slots=2, block_size=8, max_blocks_per_seq=6,
        prefill_chunk=8, prefix_cache=True,
        preemption=slo_aware, aging_steps=64 if slo_aware else 0))
    pending = sorted(workload, key=lambda w: w[0])
    meta = {}
    steps = 0
    while pending or eng.scheduler.has_work():
        while pending and eng.t >= pending[0][0]:
            _, prompt, max_new, klass = pending.pop(0)
            uid = eng.submit(prompt, max_new,
                             priority=klass["priority"] if slo_aware else 0,
                             slo_ms=float(klass["slo_steps"]) * 100.0)
            meta[uid] = klass
        if not eng.step() and pending:
            # fully idle until the next arrival: jump the step clock there
            # instead of spinning (preserves the offered load's timing)
            eng.t = max(eng.t, pending[0][0])
        steps += 1
        if steps > _MAX_STEPS:
            raise RuntimeError("slo_traffic driver did not converge")
    eng.scheduler.retire_finished(eng.t)
    res = eng.scheduler.results
    recs = []
    for uid, klass in meta.items():
        r = res[uid]
        ttft_steps = r.first_token_step - r.submit_step
        recs.append({"priority": klass["priority"],
                     "ttft_steps": int(ttft_steps),
                     "met": bool(ttft_steps <= klass["slo_steps"]
                                 and r.finish_reason == "length"),
                     "preemptions": r.preemptions})
    return recs, eng


def _goodput(recs, priority):
    sub = [r for r in recs if r["priority"] == priority]
    return float(np.mean([r["met"] for r in sub])) if sub else float("nan")


def run():
    cfg = get_config("tiny-relu")
    fam = registry.get_family(cfg)
    params = fam.init_params(jax.random.PRNGKey(0), cfg)
    n_requests = 16 if SMOKE else 32
    # mean service time is tens of steps per request on 2 slots; a 3-step
    # mean inter-arrival is firmly overloaded — the FIFO queue grows, which
    # is exactly the regime where priorities must earn their keep
    workload = _workload(cfg, n_requests, mean_interarrival=3.0)

    fifo, eng_f = _serve(cfg, params, workload, slo_aware=False)
    slo, eng_s = _serve(cfg, params, workload, slo_aware=True)

    # the FIFO submit path tags everything priority 0; recover the class
    # labels from the SLO run's records (same workload order)
    for rf, rs in zip(fifo, slo):
        rf["priority"] = rs["priority"]

    hi = _INTERACTIVE["priority"]
    full = {
        "n_requests": n_requests,
        "n_interactive": sum(r["priority"] == hi for r in slo),
        "slo_goodput": _goodput(slo, hi),
        "slo_goodput_fifo": _goodput(fifo, hi),
        "slo_goodput_batch": _goodput(slo, 0),
        "slo_goodput_batch_fifo": _goodput(fifo, 0),
        "preemption_count": int(eng_s.scheduler.preemption_count),
        "preemption_count_fifo": int(eng_f.scheduler.preemption_count),
        "interactive_ttft_steps_mean": float(np.mean(
            [r["ttft_steps"] for r in slo if r["priority"] == hi])),
        "interactive_ttft_steps_mean_fifo": float(np.mean(
            [r["ttft_steps"] for r in fifo if r["priority"] == hi])),
    }
    full["slo_goodput_gain"] = (full["slo_goodput"]
                                - full["slo_goodput_fifo"])

    os.makedirs("experiments", exist_ok=True)
    with open("experiments/bench_slo.json", "w") as f:
        json.dump(full, f, indent=2)
    return [
        f"serving/slo_traffic,0,"
        f"goodput={full['slo_goodput']:.3f};"
        f"goodput_fifo={full['slo_goodput_fifo']:.3f};"
        f"preemptions={full['preemption_count']};"
        f"ttft_steps={full['interactive_ttft_steps_mean']:.1f};"
        f"ttft_steps_fifo={full['interactive_ttft_steps_mean_fifo']:.1f}",
    ]


if __name__ == "__main__":
    for r in run():
        print(r)
