"""Table 1: per-token MACs across relufication stages.

Two parts: (a) EXACT reproduction of the paper's Table-1 FLOPS column from
their reported sparsity levels on OPT/Falcon/Llama (validates our
accounting); (b) the same accounting fed with sparsity MEASURED on our tiny
relufied models (mechanism demonstrated end-to-end)."""
from __future__ import annotations

import json

import jax.numpy as jnp

from benchmarks.common import data_cfg, get_model
from repro.configs import get_config
from repro.core import flops as fl
from repro.core.sparsity import measure_site_sparsity
from repro.data.pipeline import eval_batches

# (model, stage) -> paper-reported sparsity levels + paper GMACs
PAPER = [
    ("opt-6.7b", "dense", fl.SparsityLevels(), 4.5 + 2.1),       # 6.6 G
    ("opt-6.7b", "s1", fl.SparsityLevels(down=0.97), 4.5),
    ("opt-6.7b", "s2", fl.SparsityLevels(qkv=0.5, up=0.40, down=0.97), 2.8),
    ("falcon-7b", "dense", fl.SparsityLevels(), 6.6),
    ("falcon-7b", "s1", fl.SparsityLevels(down=0.94), 4.1),
    ("falcon-7b", "s2", fl.SparsityLevels(qkv=0.56, up=0.56, down=0.95), 2.2),
    ("llama-7b", "dense", fl.SparsityLevels(), 6.6),
    ("llama-7b", "s1", fl.SparsityLevels(down=0.62), 4.8),
    ("llama-7b", "s2", fl.SparsityLevels(qkv=0.51, up=0.67, down=0.65), 2.9),
]


def run():
    rows, full = [], {"paper": [], "measured": {}}
    for model, stage, sp, paper_g in PAPER:
        cfg = get_config(model)
        ours = fl.macs_per_token(cfg, sp) / 1e9
        full["paper"].append({"model": model, "stage": stage,
                              "paper_G": paper_g, "ours_G": round(ours, 2)})
        rows.append(f"table1/{model}/{stage},0,"
                    f"ours={ours:.2f}G;paper={paper_g}G")

    # measured sparsity on tiny relufied models -> same accounting
    batch = {k: jnp.asarray(v) for k, v in eval_batches(data_cfg(), 1)[0].items()}
    for kind in ("silu", "relufied_s1", "relufied_s2"):
        cfg, params, _ = get_model(kind)
        m = measure_site_sparsity(params, batch, cfg)
        sp = fl.SparsityLevels(qkv=m.get("mean/qkv", 0), up=m.get("mean/up", 0),
                               down=m.get("mean/down", 0))
        g = fl.macs_per_token(cfg, sp) / 1e6
        dense = fl.macs_per_token(cfg) / 1e6
        full["measured"][kind] = {"MMACs": round(g, 3),
                                  "dense_MMACs": round(dense, 3),
                                  "sparsity": vars(sp)}
        rows.append(f"table1_tiny/{kind},0,"
                    f"mmacs={g:.3f};saving={1 - g / dense:.3f};"
                    f"down_sp={sp.down:.3f}")
    with open("experiments/bench_table1.json", "w") as f:
        json.dump(full, f, indent=2)
    return rows
