"""Quickstart: build a tiny relufied model, measure activation sparsity,
and run the sparse FFN hot path (Pallas interpret + XLA fallback).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import flops as fl
from repro.core import relufication
from repro.core.sparsity import measure_site_sparsity
from repro.kernels import ops
from repro.models import registry


def main():
    # 1. a llama-style tiny model, relufied stage 2 (paper Sec. 4)
    cfg = get_config("tiny")  # SwiGLU/SiLU
    cfg = relufication.relufy_stage2(cfg)
    print(f"config: {cfg.name} activation={cfg.activation} "
          f"post_norm_relu={cfg.post_norm_relu}")

    fam = registry.get_family(cfg)
    params = fam.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                          cfg.vocab_size)}

    # 2. measure per-site sparsity (paper Table 1 columns)
    sp = measure_site_sparsity(params, batch, cfg)
    print(f"sparsity: down={sp.get('mean/down', 0):.3f} "
          f"up={sp.get('mean/up', 0):.3f} qkv={sp.get('mean/qkv', 0):.3f}")

    # 3. FLOPs accounting (the paper's efficiency metric)
    levels = fl.SparsityLevels(qkv=sp.get("mean/qkv", 0),
                               up=sp.get("mean/up", 0),
                               down=sp.get("mean/down", 0))
    dense = fl.macs_per_token(cfg) / 1e6
    sparse = fl.macs_per_token(cfg, levels) / 1e6
    print(f"MACs/token: dense {dense:.2f}M -> sparse {sparse:.2f}M "
          f"({1 - sparse / dense:.1%} saved)")

    # 4. the TPU sparse-FFN hot path (Pallas kernel, interpret mode on CPU)
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(4, 128), jnp.float32)
    wu = jnp.asarray(rng.randn(128, 1024) / 11.3, jnp.float32)
    wd = jnp.asarray(rng.randn(1024, 128) / 32.0, jnp.float32)
    y, h, idx, nvalid = ops.sparse_ffn_apply(x, wu, wd, density=0.25)
    y_ref, *_ = ops.sparse_ffn_apply_xla(x, wu, wd, density=0.25)
    err = float(jnp.max(jnp.abs(y - y_ref)))
    print(f"pallas sparse FFN: {int(nvalid)}/{h.shape[1] // 128} tiles active, "
          f"max|pallas - xla| = {err:.2e}")
    print("quickstart OK")


if __name__ == "__main__":
    main()
