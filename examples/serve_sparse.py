"""Serve a small relufied model with continuous batching: mixed-length
requests admitted/retired mid-decode over a paged KV cache, per-request
aggregated-sparsity tracking, γ-window weight reuse, sparse speculative
decoding, and predictor serving (paper Sec. 5).

    PYTHONPATH=src python examples/serve_sparse.py
    PYTHONPATH=src python examples/serve_sparse.py \
        --predictor lowrank --target-recall 0.95
"""
import argparse
import time

import numpy as np

from repro.configs.base import ModelConfig
from repro.configs import TrainConfig
from repro.core import spec_theory
from repro.data.pipeline import DataConfig, eval_batches
from repro.predictor import calibrate
from repro.serving import ContinuousBatchingEngine
from repro.serving.spec_decode import spec_metrics
from repro.train.loop import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--predictor", choices=["none", "sign", "lowrank"],
                    default="sign",
                    help="predictor serving demo kind (none skips it)")
    ap.add_argument("--target-recall", type=float, default=0.99)
    args = ap.parse_args()
    cfg = ModelConfig(name="srv", family="dense", n_layers=3, d_model=96,
                      n_heads=4, n_kv_heads=4, d_ff=384, vocab_size=256,
                      max_seq_len=256, activation="relu", ffn_kind="glu")
    dc = DataConfig(vocab_size=256, seq_len=64, batch_size=8)
    print("training a small ReLU model (~1 min)...")
    tr = Trainer(cfg, TrainConfig(learning_rate=5e-3, total_steps=100,
                                  warmup_steps=10), dc, log=lambda *_: None)
    tr.run(100)
    params = tr.params

    # mixed-length requests through the continuous-batching engine: 6
    # requests over 4 slots, so admission/retirement happens mid-decode
    data = eval_batches(dc, 1)[0]["tokens"]
    prompts = [np.asarray(data[i, : 8 + 6 * i], np.int32) for i in range(6)]
    eng = ContinuousBatchingEngine(cfg, params, n_slots=4, block_size=16,
                                   max_blocks_per_seq=6, track_sparsity=True)
    uids = [eng.submit(p, max_new=32) for p in prompts]
    t0 = time.time()
    res = eng.run()
    dt = time.time() - t0
    n_tok = sum(len(res[u].tokens) for u in uids)
    agg = eng.trackers[uids[0]]
    print(f"served {len(uids)} mixed-length requests ({n_tok} tokens) in "
          f"{dt:.2f}s ({n_tok / dt:.0f} tok/s incl. compile); request 0: "
          f"per-token FFN sparsity {agg.mean_token_sparsity():.3f}, "
          f"aggregated over its window {agg.aggregated_sparsity():.3f} "
          f"(random baseline {agg.random_baseline():.2e})")

    # γ-window weight reuse (paper Fig. 7c): same requests, masked decode
    eng_g = ContinuousBatchingEngine(cfg, params, n_slots=4, block_size=16,
                                     max_blocks_per_seq=6)
    uids_g = [eng_g.submit(p, max_new=32, reuse_window=8) for p in prompts]
    res_g = eng_g.run()
    nll_g = -np.mean(np.concatenate([res_g[u].logprobs for u in uids_g]))
    nll_0 = -np.mean(np.concatenate([res[u].logprobs for u in uids]))
    print(f"reuse γ=8: NLL {nll_g:.4f} vs fresh {nll_0:.4f} "
          f"(small gap = Fig. 7c); down-proj weight I/O saved "
          f"{eng_g.weight_io_saved():.1%}")

    # sparse speculative decoding THROUGH the engine: the draft proposes
    # γ tokens per slot, the target verifies each slot's whole window in one
    # forward using the window's aggregated-active FFN rows (Sec. 5.2)
    dcfg = cfg.replace(name="srv-draft", n_layers=1, d_model=48, d_ff=192,
                       head_dim=12)
    dtr = Trainer(dcfg, TrainConfig(learning_rate=5e-3, total_steps=80,
                                    warmup_steps=10), dc, log=lambda *_: None)
    dtr.run(80)
    eng_s = ContinuousBatchingEngine(cfg, params, n_slots=4, block_size=16,
                                     max_blocks_per_seq=6, draft_cfg=dcfg,
                                     draft_params=dtr.params, gamma=4)
    uids_s = [eng_s.submit(p, max_new=16) for p in prompts]
    res_s = eng_s.run()
    ms = [spec_metrics(res_s[u], gamma=4, c=0.1,
                       s_agg=eng_s.s_agg_window()) for u in uids_s]
    alpha = float(np.mean([m.accept_rate for m in ms]))
    print(f"speculative serving: {sum(m.n_target_calls for m in ms)} target "
          f"calls for {sum(len(m.tokens) for m in ms)} tokens across "
          f"{len(uids_s)} requests (alpha={alpha:.3f}); "
          f"window s_agg={eng_s.s_agg_window():.3f}; "
          f"Thm-1 sparse-over-standard speedup {ms[0].thm1_speedup:.3f}x")
    g_star, sp = spec_theory.optimal_gamma(0.1, alpha,
                                           lambda g: eng_s.s_agg_window())
    print(f"optimal gamma for this (c, alpha): {g_star} (speedup {sp:.2f}x)")

    # predictor serving (the third mode): a calibrated activity predictor
    # names each token's active FFN rows BEFORE the weights are read, so the
    # engine gathers only those rows for BOTH the up- and down-projections
    # (tile=1 = the paper's exact row-skipping; 128-wide tiles on TPU)
    if args.predictor != "none":
        pred = calibrate(params, cfg, {"tokens": data}, kind=args.predictor,
                         target_recall=args.target_recall, tile=1)
        eng_p = ContinuousBatchingEngine(cfg, params, n_slots=4,
                                         block_size=16, max_blocks_per_seq=6,
                                         predictor=pred)
        uids_p = [eng_p.submit(p, max_new=32) for p in prompts]
        res_p = eng_p.run()
        nll_p = -np.mean(np.concatenate([res_p[u].logprobs
                                         for u in uids_p]))
        print(f"predictor serving ({args.predictor}): tile density "
              f"{eng_p.predictor_density():.3f} -> up+down weight I/O saved "
              f"{eng_p.weight_io_saved():.1%} at realized recall "
              f"{eng_p.predictor_recall():.4f} "
              f"(target {args.target_recall}); "
              f"NLL {nll_p:.4f} vs dense {nll_0:.4f}")
    print("serve_sparse OK")


if __name__ == "__main__":
    main()
