"""Serve a small relufied model with batched requests: sparse decode,
aggregated-sparsity tracking, γ-window weight reuse, and sparse speculative
decoding (paper Sec. 5).

    PYTHONPATH=src python examples/serve_sparse.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.configs import TrainConfig
from repro.core import relufication, spec_theory
from repro.data.pipeline import DataConfig, eval_batches
from repro.models import registry
from repro.serving.engine import ServeEngine
from repro.serving.spec_decode import speculative_generate
from repro.train.loop import Trainer


def main():
    cfg = ModelConfig(name="srv", family="dense", n_layers=3, d_model=96,
                      n_heads=4, n_kv_heads=4, d_ff=384, vocab_size=256,
                      max_seq_len=256, activation="relu", ffn_kind="glu")
    dc = DataConfig(vocab_size=256, seq_len=64, batch_size=8)
    print("training a small ReLU model (~1 min)...")
    tr = Trainer(cfg, TrainConfig(learning_rate=5e-3, total_steps=100,
                                  warmup_steps=10), dc, log=lambda *_: None)
    tr.run(100)
    params = tr.params

    # batched requests
    prompts = {"tokens": jnp.asarray(eval_batches(dc, 1)[0]["tokens"][:4, :16])}
    eng = ServeEngine(cfg, params, max_len=128, track_sparsity=True)
    res = eng.generate(prompts, max_new=32)
    agg = res.aggregated
    print(f"served batch of 4: per-token FFN sparsity "
          f"{agg.mean_token_sparsity():.3f}, aggregated over 32 tokens "
          f"{agg.aggregated_sparsity():.3f} (random baseline "
          f"{agg.random_baseline():.2e})")

    # gamma-window weight reuse (paper Fig. 7c)
    r0 = eng.generate(prompts, max_new=32)
    r8 = eng.generate(prompts, max_new=32, reuse_window=8)
    print(f"reuse γ=8: NLL {-np.mean(r8.logprobs):.4f} vs fresh "
          f"{-np.mean(r0.logprobs):.4f} (small gap = Fig. 7c)")

    # sparse speculative decoding
    dcfg = cfg.replace(name="srv-draft", n_layers=1, d_model=48, d_ff=192,
                       head_dim=12)
    dtr = Trainer(dcfg, TrainConfig(learning_rate=5e-3, total_steps=80,
                                    warmup_steps=10), dc, log=lambda *_: None)
    dtr.run(80)
    sres = speculative_generate(cfg, params, dcfg, dtr.params,
                                prompts["tokens"][:1], max_new=16, gamma=4,
                                c=0.1, sparse=True)
    print(f"speculative decoding: {sres.n_target_calls} target calls for 16 "
          f"tokens; window s_agg={sres.s_agg_window:.3f}; "
          f"Thm-1 sparse-over-standard speedup {sres.thm1_speedup:.3f}x")
    g_star, sp = spec_theory.optimal_gamma(0.1, sres.accept_rate,
                                           lambda g: sres.s_agg_window)
    print(f"optimal gamma for this (c, alpha): {g_star} (speedup {sp:.2f}x)")
    print("serve_sparse OK")


if __name__ == "__main__":
    main()
