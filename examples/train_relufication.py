"""End-to-end relufication driver (paper Sec. 4, Figs. 4/6):

  1. pretrain a SiLU (SwiGLU) model from scratch,
  2. stage-1 surgery: swap SiLU -> ReLU, fine-tune, watch recovery,
  3. stage-2 surgery: insert post-norm ReLU, fine-tune,
  4. report sparsity + FLOPs saving at each stage.

Presets: --preset cpu (default, ~minutes on this container) runs a tiny
model; --preset pod emits the full production invocation (qwen2-7b on the
16x16 mesh) without running it.

    PYTHONPATH=src python examples/train_relufication.py --steps 120
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig
from repro.configs.base import ModelConfig
from repro.core import flops as fl
from repro.core import relufication
from repro.core.sparsity import measure_site_sparsity
from repro.data.pipeline import DataConfig, eval_batches
from repro.train.loop import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="cpu", choices=["cpu", "pod"])
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--finetune-steps", type=int, default=80)
    args = ap.parse_args()

    if args.preset == "pod":
        print("production invocation (per-host, v5e 16x16 pod):")
        print("  python -m repro.launch.train --arch qwen2-7b --shape train_4k"
              " --relufy-stage 2 --steps 30000 --ckpt gs://.../qwen2-relu")
        return

    cfg = ModelConfig(name="ex-base", family="dense", n_layers=4, d_model=96,
                      n_heads=4, n_kv_heads=4, d_ff=384, vocab_size=256,
                      max_seq_len=128, activation="silu", ffn_kind="glu")
    dc = DataConfig(vocab_size=256, seq_len=64, batch_size=8)
    batch = {k: jnp.asarray(v) for k, v in eval_batches(dc, 1)[0].items()}

    def fit(cfg, steps, init=None, lr=5e-3, tag=""):
        tc = TrainConfig(learning_rate=lr, total_steps=steps, warmup_steps=10,
                         schedule="cosine")
        tr = Trainer(cfg, tc, dc, log=lambda *_: None)
        rep = tr.run(steps, params=init)
        nll = tr.eval_loss(tr.params)
        sp = measure_site_sparsity(tr.params, batch, cfg)
        print(f"[{tag}] steps={rep.steps} train_loss={rep.losses[-1]:.4f} "
              f"eval_nll={nll:.4f} down_sparsity={sp.get('mean/down', 0):.3f} "
              f"qkv_sparsity={sp.get('mean/qkv', 0):.3f}")
        return tr.params, nll, sp

    print("== 1. pretrain (SiLU/SwiGLU) ==")
    base, base_nll, _ = fit(cfg, args.steps, tag="pretrain")

    print("== 2. stage-1 relufication + fine-tune ==")
    cfg1 = relufication.relufy_stage1(cfg)
    post_nll = None
    p1, s1_nll, sp1 = fit(cfg1, args.finetune_steps, init=base, lr=2e-3,
                          tag="stage1")

    print("== 3. stage-2 relufication + fine-tune ==")
    cfg2 = relufication.relufy_stage2(cfg)
    p2, s2_nll, sp2 = fit(cfg2, args.finetune_steps, init=p1, lr=2e-3,
                          tag="stage2")

    print("== 4. FLOPs accounting (paper Table 1 style) ==")
    for tag, c, sp in (("dense", cfg, {}), ("stage1", cfg1, sp1),
                       ("stage2", cfg2, sp2)):
        lv = fl.SparsityLevels(qkv=sp.get("mean/qkv", 0),
                               up=sp.get("mean/up", 0),
                               down=sp.get("mean/down", 0))
        m = fl.macs_per_token(c, lv) / 1e6
        print(f"  {tag:8s}: {m:8.3f} MMACs/token")
    print(f"quality: base {base_nll:.4f} -> s1 {s1_nll:.4f} -> s2 {s2_nll:.4f}"
          " (paper: recovers to within a few % after brief fine-tuning)")


if __name__ == "__main__":
    main()
