"""Checkpointing with atomic writes, async save, keep-k GC, and ELASTIC
restore (load into a different mesh / process count).

Format: one directory per step containing
  manifest.json   — step, pytree structure, per-array dtype/shape, extras
  arrays.npz      — flattened leaves keyed by index (host-local full arrays;
                    on a multi-host deployment each host writes its
                    addressable shards — the manifest records the layout)

Restore applies the *target* shardings via jax.device_put, so a checkpoint
written under one mesh loads under any other (elastic shrink/grow) — the
resharding test in tests/test_checkpoint.py exercises 8→4 fake devices.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: PyTree, extras: Optional[Dict] = None,
             block: bool = False) -> None:
        """Snapshot to host memory synchronously; write to disk (async by
        default so the train loop keeps stepping — preemption-safe because
        the previous complete checkpoint is never touched)."""
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(l) for l in leaves]  # sync device->host
        manifest = {
            "step": int(step),
            "n_leaves": len(host_leaves),
            "dtypes": [str(l.dtype) for l in host_leaves],
            "shapes": [list(l.shape) for l in host_leaves],
            "extras": extras or {},
        }
        self.wait()

        def write():
            tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_")
            try:
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                np.savez(os.path.join(tmp, "arrays.npz"),
                         **{str(i): l for i, l in enumerate(host_leaves)})
                final = os.path.join(self.dir, f"step_{step:010d}")
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)  # atomic publish
            finally:
                if os.path.exists(tmp):
                    shutil.rmtree(tmp, ignore_errors=True)
            self._gc()

        if self.async_save and not block:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: PyTree, step: Optional[int] = None,
                shardings: Optional[PyTree] = None) -> Tuple[PyTree, Dict]:
        """Restore into the structure of `template`. If `shardings` is given
        (pytree of jax.sharding.Sharding), leaves are device_put with the
        TARGET sharding — this is the elastic-rescale path: a checkpoint from
        a 512-chip mesh restores onto any other mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        leaves = [data[str(i)] for i in range(manifest["n_leaves"])]
        _, treedef = _flatten(template)
        if shardings is not None:
            sh_leaves, _ = _flatten(shardings)
            leaves = [jax.device_put(l, s) for l, s in zip(leaves, sh_leaves)]
        else:
            leaves = [jax.numpy.asarray(l) for l in leaves]
        return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extras"]
