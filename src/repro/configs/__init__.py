"""Architecture config registry.

``get_config(name)`` returns the full (assigned / paper) config;
``smoke_config(name)`` returns a reduced same-family config for CPU tests.
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import (  # noqa: F401
    LONG_500K, DECODE_32K, PREFILL_32K, SHAPES, TRAIN_4K,
    ModelConfig, ShapeConfig, SparsityConfig, TrainConfig,
)

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def _load_all() -> None:
    from repro.configs import (  # noqa: F401
        internvl2_1b, starcoder2_15b, qwen3_4b, qwen2_7b, deepseek_67b,
        zamba2_7b, mixtral_8x22b, phi35_moe, whisper_small, falcon_mamba_7b,
        paper_models, tiny,
    )


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_archs() -> List[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


ASSIGNED = [
    "internvl2-1b", "starcoder2-15b", "qwen3-4b", "qwen2-7b", "deepseek-67b",
    "zamba2-7b", "mixtral-8x22b", "phi3.5-moe-42b-a6.6b", "whisper-small",
    "falcon-mamba-7b",
]


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config: small dims, few layers/experts, tiny vocab."""
    cfg = get_config(name)
    kw = dict(
        name=cfg.name + "-smoke", n_layers=2, d_model=64,
        d_ff=0 if cfg.family == "mamba" else 128,
        vocab_size=256, max_seq_len=256,
        n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
    )
    if cfg.family == "moe":
        # high capacity factor -> drop-free routing, so cache-path equivalence
        # tests are exact (capacity behaviour is tested separately)
        kw.update(n_experts=4, moe_group_size=64, capacity_factor=8.0)
    if cfg.family in ("mamba", "hybrid"):
        kw.update(ssm_state=8, ssm_head_dim=16, ssm_chunk=32)
    if cfg.family == "hybrid":
        kw.update(n_layers=5, attn_every=2)
    if cfg.family == "encdec":
        kw.update(n_encoder_layers=2, n_audio_frames=24)
    if cfg.family == "vlm":
        kw.update(n_vision_tokens=8)
    return cfg.replace(**kw)
