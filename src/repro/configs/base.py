"""Config schema for the repro framework.

Every architecture (assigned pool + the paper's own models) is described by a
single ``ModelConfig``. The relufication surgery (paper Sec. 4) operates on
these configs: stage 1 rewrites ``activation``; stage 2 flips
``post_norm_relu``. Sparse-inference knobs (tile capacity, shift) live here
too so a config is a complete, serializable description of a deployment.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# helpers


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SparsityConfig:
    """Knobs for exploiting activation sparsity at inference (paper Sec. 4/5)."""

    enabled: bool = False
    # fraction of d_ff tiles loaded at decode (static capacity). 1.0 == dense.
    ffn_tile_density: float = 1.0
    # stage-2: density for QKV/up-projection *input* (d_model) tiles.
    input_tile_density: float = 1.0
    tile_size: int = 128
    # shifted ReLU (paper Sec. 5.3): activation is relu(x - shift).
    shift: float = 0.0
    # gamma-window weight reuse (paper Sec. 5.1 / Fig. 7c): refresh the active
    # tile set every `reuse_window` decoded tokens; 0 = refresh every token.
    reuse_window: int = 0
    # shard-local grouped tile selection: groups aligned to the TP degree so
    # the weight gather never crosses shards (beyond-paper §Perf opt; 1 = the
    # paper-faithful global top-k)
    n_groups: int = 1
    # activation-sparsity predictor (predictor serving mode, repro.predictor):
    # skip up+down projection weight reads for neurons predicted inactive.
    predictor: str = "none"        # none | sign | lowrank
    predictor_rank: int = 8        # low-rank factor rank (lowrank kind)
    predictor_recall: float = 0.99  # calibration target recall
    probe_dtype: str = "bfloat16"  # sign-probe precision (f32 = exact)


@dataclass(frozen=True)
class ModelConfig:
    name: str = "tiny"
    family: str = "dense"  # dense | moe | mamba | hybrid | encdec | vlm
    # -- core dims ----------------------------------------------------------
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0  # 0 -> d_model // n_heads
    d_ff: int = 512
    vocab_size: int = 512
    max_seq_len: int = 2048
    # -- flavor knobs -------------------------------------------------------
    activation: str = "silu"  # see core/activations.py registry
    ffn_kind: str = "glu"  # glu (gate*up) | mlp (single up)
    norm_kind: str = "rmsnorm"  # rmsnorm | layernorm
    post_norm_relu: bool = False  # relufication stage 2
    qk_norm: bool = False  # qwen3
    qkv_bias: bool = False  # qwen2
    rope_theta: float = 10000.0
    use_rope: bool = True  # OPT/whisper use learned/sinusoidal abs positions
    tie_embeddings: bool = False
    sliding_window: int = 0  # 0 = global attention (mixtral SWA supported)
    # -- MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    moe_group_size: int = 4096  # tokens per dispatch group (bounds dispatch flops)
    # -- SSM (mamba) --------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64  # mamba2 head dim
    ssm_chunk: int = 128  # chunked-scan chunk length
    # -- hybrid (zamba2) ----------------------------------------------------
    attn_every: int = 0  # insert shared attention block every N layers
    # -- encdec (whisper) ---------------------------------------------------
    n_encoder_layers: int = 0
    n_audio_frames: int = 1500
    # -- vlm (internvl2) ----------------------------------------------------
    n_vision_tokens: int = 0
    # -- sparsity / relufication -------------------------------------------
    sparsity: SparsityConfig = field(default_factory=SparsityConfig)
    # -- numerics ------------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # -- long context --------------------------------------------------------
    subquadratic: bool = False  # True for ssm/hybrid: long_500k cells run
    # Megatron-SP-style sharded residuals: block in/outputs (and hence the
    # remat-saved activations) are sharded over the model axis on d_model
    sp_residuals: bool = False

    # ------------------------------------------------------------------ derived
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    def padded_vocab(self, multiple: int = 2048) -> int:
        return round_up(self.vocab_size, multiple)

    def padded_heads(self, tp: int = 16) -> int:
        return round_up(self.n_heads, tp)

    @property
    def d_inner(self) -> int:  # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:  # mamba2 heads
        return self.d_inner // self.ssm_head_dim

    # ------------------------------------------------------------------ misc
    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def replace_sparsity(self, **kw) -> "ModelConfig":
        return self.replace(sparsity=dataclasses.replace(self.sparsity, **kw))

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        return json.dumps(d, indent=2, sort_keys=True)

    @staticmethod
    def from_json(s: str) -> "ModelConfig":
        d = json.loads(s)
        d["sparsity"] = SparsityConfig(**d.get("sparsity", {}))
        return ModelConfig(**d)


@dataclass(frozen=True)
class ShapeConfig:
    """An assigned (input-shape) cell: what gets lowered in the dry-run."""

    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int
    num_microbatches: int = 1


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524288, 1)

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 1.5e-5  # paper's fine-tuning LR
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    schedule: str = "cosine"  # constant | cosine | linear
    zero_stage: int = 1  # 0: replicated opt state, 1: sharded over dp, 3: fsdp params
    remat_policy: str = "minimal"  # none | minimal | full
    num_microbatches: int = 1
    grad_compression: str = "none"  # none | int8_ef
    skip_nonfinite: bool = True
    seed: int = 0
