"""falcon-mamba-7b [ssm] 64L d_model=4096 (attn-free) d_ff=0 vocab=65024,
ssm_state=16 — mamba1 arch [arXiv:2410.05355; unverified]."""
from repro.configs import register
from repro.configs.base import ModelConfig

CONFIG = register(ModelConfig(
    name="falcon-mamba-7b",
    family="mamba",
    n_layers=64,
    d_model=4096,
    n_heads=1,  # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab_size=65024,
    max_seq_len=524288,
    activation="silu",  # mamba gate; relufication swaps this (DESIGN.md §5)
    norm_kind="rmsnorm",
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    subquadratic=True,
))
