"""internvl2-1b [vlm] 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655
— InternViT + InternLM2 [arXiv:2404.16821; hf].

The transformer BACKBONE only; the ViT frontend is a STUB — input_specs()
provides precomputed patch embeddings (b, n_vision_tokens, d_model)."""
from repro.configs import register
from repro.configs.base import ModelConfig

CONFIG = register(ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    max_seq_len=32768,
    activation="silu",
    ffn_kind="glu",
    norm_kind="rmsnorm",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    n_vision_tokens=256,
))
