"""mixtral-8x22b [moe] 56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768,
MoE 8e top-2 — 8 experts top-2, SWA [arXiv:2401.04088; hf]."""
from repro.configs import register
from repro.configs.base import ModelConfig

CONFIG = register(ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    max_seq_len=65536,
    activation="silu",
    ffn_kind="glu",
    norm_kind="rmsnorm",
    rope_theta=1_000_000.0,
    sliding_window=4096,
    n_experts=8,
    top_k=2,
    moe_group_size=1024,
))
