"""The paper's own models (OPT / Llama-v1 / Falcon), used by the
benchmark harness to reproduce Table 1 / Fig. 1 / Fig. 12 numbers.

OPT uses ReLU already (the paper keeps it); Llama/Falcon are the
relufication subjects (stage 1: SiLU/GELU -> ReLU; stage 2: post-norm ReLU).
"""
from repro.configs import register
from repro.configs.base import ModelConfig

OPT_1_3B = register(ModelConfig(
    name="opt-1.3b", family="dense", n_layers=24, d_model=2048, n_heads=32,
    n_kv_heads=32, d_ff=8192, vocab_size=50272, max_seq_len=2048,
    activation="relu", ffn_kind="mlp", norm_kind="layernorm", use_rope=False,
    tie_embeddings=True,
))

OPT_2_7B = register(ModelConfig(
    name="opt-2.7b", family="dense", n_layers=32, d_model=2560, n_heads=32,
    n_kv_heads=32, d_ff=10240, vocab_size=50272, max_seq_len=2048,
    activation="relu", ffn_kind="mlp", norm_kind="layernorm", use_rope=False,
    tie_embeddings=True,
))

OPT_6_7B = register(ModelConfig(
    name="opt-6.7b", family="dense", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=32, d_ff=16384, vocab_size=50272, max_seq_len=2048,
    activation="relu", ffn_kind="mlp", norm_kind="layernorm", use_rope=False,
    tie_embeddings=True,
))

LLAMA_7B = register(ModelConfig(
    name="llama-7b", family="dense", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=32, d_ff=11008, vocab_size=32000, max_seq_len=2048,
    activation="silu", ffn_kind="glu", norm_kind="rmsnorm",
))

FALCON_7B = register(ModelConfig(
    name="falcon-7b", family="dense", n_layers=32, d_model=4544, n_heads=71,
    n_kv_heads=1, d_ff=18176, vocab_size=65024, max_seq_len=2048,
    activation="gelu", ffn_kind="mlp", norm_kind="layernorm",
))
