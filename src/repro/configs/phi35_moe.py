"""phi3.5-moe-42b-a6.6b [moe] 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16e top-2 [hf:microsoft/Phi-3.5-MoE-instruct; hf]."""
from repro.configs import register
from repro.configs.base import ModelConfig

CONFIG = register(ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    max_seq_len=131072,
    activation="silu",
    ffn_kind="glu",
    norm_kind="layernorm",
    rope_theta=10000.0,
    n_experts=16,
    top_k=2,
    moe_group_size=1024,
))
