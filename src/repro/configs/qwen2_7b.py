"""qwen2-7b [dense] 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064
— GQA, QKV bias [arXiv:2407.10671; hf]."""
from repro.configs import register
from repro.configs.base import ModelConfig

CONFIG = register(ModelConfig(
    name="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    max_seq_len=32768,
    activation="silu",
    ffn_kind="glu",
    norm_kind="rmsnorm",
    qkv_bias=True,
    rope_theta=1_000_000.0,
))
