"""starcoder2-15b [dense] 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152 — GQA, RoPE [arXiv:2402.19173; hf]. GELU MLP, layernorm."""
from repro.configs import register
from repro.configs.base import ModelConfig

CONFIG = register(ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    max_seq_len=16384,
    activation="gelu",
    ffn_kind="mlp",
    norm_kind="layernorm",
    qkv_bias=True,  # starcoder2 uses bias on attention & mlp projections
    rope_theta=100_000.0,
))
