"""Tiny configs for CPU tests / examples (one per family)."""
from repro.configs import register
from repro.configs.base import ModelConfig

TINY = register(ModelConfig(
    name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab_size=256, max_seq_len=256,
    activation="silu", ffn_kind="glu", norm_kind="rmsnorm",
))

TINY_RELU = register(TINY.replace(name="tiny-relu", activation="relu"))

TINY_OPT = register(ModelConfig(
    name="tiny-opt", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=256, vocab_size=256, max_seq_len=256,
    activation="relu", ffn_kind="mlp", norm_kind="layernorm", use_rope=False,
    tie_embeddings=True,
))

# capacity_factor >= n_experts makes routing drop-free (cap >= tokens·top_k),
# the precondition for the serving paths' batch-shape-invariant byte
# exactness (models/moe.py); moe_group_size > any serving batch keeps G = 1
TINY_MOE = register(ModelConfig(
    name="tiny-moe", family="moe", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab_size=256, max_seq_len=256,
    activation="relu", ffn_kind="mlp", norm_kind="rmsnorm",
    n_experts=4, top_k=2, capacity_factor=8.0, moe_group_size=64,
))
