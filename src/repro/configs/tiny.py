"""Tiny configs for CPU tests / examples (one per family)."""
from repro.configs import register
from repro.configs.base import ModelConfig

TINY = register(ModelConfig(
    name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab_size=256, max_seq_len=256,
    activation="silu", ffn_kind="glu", norm_kind="rmsnorm",
))

TINY_RELU = register(TINY.replace(name="tiny-relu", activation="relu"))

TINY_OPT = register(ModelConfig(
    name="tiny-opt", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=256, vocab_size=256, max_seq_len=256,
    activation="relu", ffn_kind="mlp", norm_kind="layernorm", use_rope=False,
    tie_embeddings=True,
))
