"""whisper-small [audio] 12L d_model=768 12H (MHA kv=12) d_ff=3072 vocab=51865
— enc-dec, conv frontend (stub) [arXiv:2212.04356; unverified].

12 encoder + 12 decoder layers; the conv frontend is a STUB — input_specs()
provides precomputed frame embeddings (b, n_audio_frames, d_model)."""
from repro.configs import register
from repro.configs.base import ModelConfig

CONFIG = register(ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,
    n_encoder_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    max_seq_len=32768,  # assigned decode_32k exercises a 32k decoder cache
    activation="gelu",
    ffn_kind="mlp",
    norm_kind="layernorm",
    use_rope=False,  # learned positions (decoder) + sinusoidal (encoder)
    n_audio_frames=1500,
))
