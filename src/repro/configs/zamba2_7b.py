"""zamba2-7b [hybrid] 81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000,
ssm_state=64 — Mamba2 + shared attn blocks [arXiv:2411.15242; unverified].

81 Mamba-2 blocks; a SHARED attention+FFN block (one set of weights) is
applied every `attn_every` layers (13 applications)."""
from repro.configs import register
from repro.configs.base import ModelConfig

CONFIG = register(ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,  # MHA in the shared block
    d_ff=14336,
    vocab_size=32000,
    max_seq_len=524288,
    activation="silu",
    ffn_kind="glu",
    norm_kind="rmsnorm",
    rope_theta=10000.0,
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_head_dim=64,
    attn_every=6,
    subquadratic=True,
))
