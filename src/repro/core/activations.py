"""Activation-function registry (paper Sec. 3 + Sec. 5.3).

The paper studies the one-parameter family ``f(x) = x * sigmoid(beta * x)``:
beta=1 is SiLU, beta≈1.7 approximates GELU, beta→inf is ReLU. We expose the
family plus exact GELU/ReLU and the paper's *shifted ReLU* ``relu(x - b)``
(Sec. 5.3) and FATReLU-style thresholding for completeness.

All functions are pure jnp and safe under jit/grad/vmap/pjit.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict

import jax
import jax.numpy as jnp

Act = Callable[[jnp.ndarray], jnp.ndarray]


def relu(x):
    return jax.nn.relu(x)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def silu(x):
    return jax.nn.silu(x)


def gated_sigmoid(x, beta: float):
    """f(x) = x * sigmoid(beta x). beta=1: SiLU; beta->inf: ReLU (Fig. 2a)."""
    return x * jax.nn.sigmoid(beta * x)


def shifted_relu(x, shift: float):
    """relu(x - b) — paper Sec. 5.3. b chosen from pre-activation quantiles."""
    return jax.nn.relu(x - shift)


def fat_relu(x, threshold: float):
    """FATReLU: x if x > t else 0 (keeps magnitudes, drops small positives)."""
    return jnp.where(x > threshold, x, jnp.zeros_like(x))


_REGISTRY: Dict[str, Act] = {
    "relu": relu,
    "gelu": gelu,
    "silu": silu,
    "swish": silu,
    "silu_b1": functools.partial(gated_sigmoid, beta=1.0),
    "gelu_b1.7": functools.partial(gated_sigmoid, beta=1.7),
    "gated_b8": functools.partial(gated_sigmoid, beta=8.0),
}


def register(name: str, fn: Act) -> None:
    _REGISTRY[name] = fn


def get(name: str, shift: float = 0.0) -> Act:
    """Resolve an activation by name.

    Supported names: registry keys, ``beta=<float>`` for the gated family,
    ``shifted_relu`` / ``shifted_relu:<b>`` for ReLU(x-b), ``fatrelu:<t>``.
    The ``shift`` argument overrides for "shifted_relu" (used by
    SparsityConfig.shift so the calibrated per-model shift applies).
    """
    if name in _REGISTRY:
        return _REGISTRY[name]
    if name.startswith("beta="):
        return functools.partial(gated_sigmoid, beta=float(name[5:]))
    if name == "shifted_relu":
        return functools.partial(shifted_relu, shift=shift)
    if name.startswith("shifted_relu:"):
        return functools.partial(shifted_relu, shift=float(name.split(":", 1)[1]))
    if name.startswith("fatrelu:"):
        return functools.partial(fat_relu, threshold=float(name.split(":", 1)[1]))
    raise KeyError(f"unknown activation {name!r}; known: {sorted(_REGISTRY)}")


def is_sparse_activation(name: str) -> bool:
    """Does this activation produce exact zeros (hence exploitable sparsity)?"""
    return name == "relu" or name.startswith("shifted_relu") or name.startswith("fatrelu")


def firing_threshold(name: str, shift: float = 0.0):
    """Pre-activation threshold above which a ReLU-family unit fires
    (f(pre) != 0 iff pre > threshold); None for soft activations.

    This is the quantity an activity predictor thresholds its probe against
    (repro.predictor): relu fires at 0, shifted_relu at its shift, fatrelu
    at its gate threshold.
    """
    if name == "relu":
        return 0.0
    if name == "shifted_relu":
        return shift
    if name.startswith("shifted_relu:"):
        return float(name.split(":", 1)[1])
    if name.startswith("fatrelu:"):
        return float(name.split(":", 1)[1])
    return None


def sparsity_of(x: jnp.ndarray, eps: float = 0.0) -> jnp.ndarray:
    """Fraction of entries that are (exactly or nearly) zero."""
    return jnp.mean((jnp.abs(x) <= eps).astype(jnp.float32))
