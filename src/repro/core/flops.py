"""Analytic FLOPs / IO accounting (paper Table 1, Fig. 1c, App. B/E + the
roofline MODEL_FLOPS term).

The paper counts MACs per token ("6.6G FLOPS" for OPT-6.7B is the forward
MAC count of the non-embedding weights). `macs_per_token` reproduces their
Table-1 numbers exactly when fed the measured sparsity levels; see
benchmarks/table1_flops.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass
class SparsityLevels:
    """Measured input sparsity per projection site (paper Table 1 columns)."""
    qkv: float = 0.0   # attention input sparsity (stage 2)
    up: float = 0.0    # FFN up/gate input sparsity (stage 2)
    down: float = 0.0  # down-projection input sparsity (stage 1, the big one)


def _attn_macs(cfg: ModelConfig, context: int) -> float:
    """Per-token attention score+value MACs at a given context length."""
    hd = cfg.resolved_head_dim
    ctx = min(context, cfg.sliding_window) if cfg.sliding_window else context
    return 2.0 * cfg.n_heads * hd * ctx


def macs_per_token(cfg: ModelConfig, sp: Optional[SparsityLevels] = None,
                   context: int = 0, include_unembed: bool = False) -> float:
    """Forward MACs per generated token (the paper's "FLOPS" metric)."""
    sp = sp or SparsityLevels()
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, K, F = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
    total = 0.0
    n_attn_layers = cfg.n_layers
    if cfg.family == "hybrid" and cfg.attn_every:
        n_attn_layers = cfg.n_layers // cfg.attn_every

    if cfg.family in ("dense", "vlm", "moe", "encdec", "hybrid"):
        qkv = d * (H + 2 * K) * hd * (1.0 - sp.qkv)
        out = H * hd * d
        if cfg.ffn_kind == "glu":
            # gate computed for all rows (input sparsity only); the UP
            # projection is additionally skipped wherever relu(gate)==0
            # (its product with a zero gate is never needed) — this is how
            # the paper reaches 4.8 G for Llama stage 1.
            per_ffn_in = d * F * (1.0 - sp.up) * (1.0 + (1.0 - sp.down))
        else:
            per_ffn_in = d * F * (1.0 - sp.up)
        per_ffn_down = F * d * (1.0 - sp.down)
        if cfg.family == "moe":
            ffn = cfg.top_k * (per_ffn_in + per_ffn_down) + d * cfg.n_experts
        else:
            ffn = per_ffn_in + per_ffn_down
        attn = _attn_macs(cfg, context) if context else 0.0
        total += n_attn_layers * (qkv + out + attn)
        total += cfg.n_layers * ffn if cfg.family != "hybrid" else cfg.n_layers * 0.0

    if cfg.family in ("mamba", "hybrid"):
        di, st = cfg.d_inner, cfg.ssm_state
        in_proj = d * 2 * di * (1.0 - sp.qkv)
        conv = di * cfg.ssm_conv
        if cfg.family == "mamba":  # mamba1: x_proj -> (dt_rank, B, C)
            dt_rank = max(1, d // 16)
            proj = di * (dt_rank + 2 * st) + dt_rank * di
        else:  # mamba2 (SSD): B/C/dt from in_proj extension
            proj = di * 2 * st
        scan = 3.0 * di * st  # state update + output contraction
        out_p = di * d * (1.0 - sp.down)  # gate sparsity -> sparse out_proj
        n_ssm = cfg.n_layers
        total += n_ssm * (in_proj + conv + proj + scan + out_p)
        if cfg.family == "hybrid":  # shared attention block incl. its FFN
            per_ffn = (2 if cfg.ffn_kind == "glu" else 1) * d * F * (1.0 - sp.up) \
                + F * d * (1.0 - sp.down)
            total += n_attn_layers * per_ffn

    if include_unembed:
        total += d * cfg.vocab_size
    return total


def param_count(cfg: ModelConfig, active_only: bool = False) -> float:
    """Non-embedding parameter count (active experts only if requested)."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, K, F = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
    attn = d * (H + 2 * K) * hd + H * hd * d
    ffn1 = (3 if cfg.ffn_kind == "glu" else 2) * d * F
    n = 0.0
    if cfg.family in ("dense", "vlm"):
        n = cfg.n_layers * (attn + ffn1)
    elif cfg.family == "encdec":
        n = (cfg.n_layers + cfg.n_encoder_layers) * (attn + ffn1) \
            + cfg.n_layers * attn  # cross-attention
    elif cfg.family == "moe":
        e = cfg.top_k if active_only else cfg.n_experts
        n = cfg.n_layers * (attn + e * ffn1 + d * cfg.n_experts)
    elif cfg.family == "mamba":
        di, st = cfg.d_inner, cfg.ssm_state
        dt_rank = max(1, d // 16)
        per = d * 2 * di + di * cfg.ssm_conv + di * (dt_rank + 2 * st) \
            + dt_rank * di + di * st + di * d
        n = cfg.n_layers * per
    elif cfg.family == "hybrid":
        di, st = cfg.d_inner, cfg.ssm_state
        per = d * 2 * di + di * cfg.ssm_conv + di * 2 * st + di * d
        n = cfg.n_layers * per
        if cfg.attn_every:
            n += attn + ffn1  # ONE shared block
    return n


def embed_params(cfg: ModelConfig) -> float:
    mult = 1 if cfg.tie_embeddings else 2
    return mult * cfg.vocab_size * cfg.d_model


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Global useful FLOPs for one step of this cell: 6·N·D train, 2·N·D
    serve (N = active non-embed params; D = tokens processed), plus exact
    attention-context FLOPs."""
    N = param_count(cfg, active_only=True)
    B, S = shape.global_batch, shape.seq_len
    n_attn = (cfg.n_layers // cfg.attn_every if cfg.family == "hybrid"
              else 0 if cfg.family == "mamba" else cfg.n_layers)
    # per-token attention MACs at average causal context S/2
    attn = 2.0 * n_attn * cfg.n_heads * cfg.resolved_head_dim * (S / 2)
    if shape.kind == "train":
        tokens = B * S
        return 6.0 * (N + attn) * tokens
    if shape.kind == "prefill":
        tokens = B * S
        return 2.0 * (N + attn) * tokens
    # decode: one token per sequence, full-context attention reads
    macs = macs_per_token(cfg, context=S, include_unembed=True)
    return 2.0 * macs * B


# ---------------------------------------------------------------------------
# paper Table 1 reproduction helpers


def table1_row(cfg: ModelConfig, sp: SparsityLevels) -> Dict[str, float]:
    """MACs/token in G, as the paper reports (no attention-context term —
    their per-token figure counts weight MACs only)."""
    g = macs_per_token(cfg, sp) / 1e9
    dense = macs_per_token(cfg, SparsityLevels()) / 1e9
    return {"gmacs": round(g, 2), "dense_gmacs": round(dense, 2),
            "saving": round(1.0 - g / dense, 3)}
