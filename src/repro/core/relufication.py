"""Relufication surgery (paper Sec. 4) + shifted-ReLU calibration (Sec. 5.3).

The paper's procedure keeps the pretrained weights and only swaps the
activation function (stage 1) / inserts ReLU after norms (stage 2), then
fine-tunes briefly. Surgery here is therefore a *config* transformation —
parameters pass through unchanged — mirroring exactly what the paper does.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig


def _norm_ppf(q: float) -> float:
    """Inverse normal CDF (Acklam's approximation; avoids scipy dep)."""
    a = [-3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
         1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00]
    b = [-5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
         6.680131188771972e+01, -1.328068155288572e+01]
    c = [-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
         -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00]
    d = [7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
         3.754408661907416e+00]
    plow, phigh = 0.02425, 1 - 0.02425
    if q < plow:
        ql = np.sqrt(-2 * np.log(q))
        return (((((c[0] * ql + c[1]) * ql + c[2]) * ql + c[3]) * ql + c[4]) * ql + c[5]) / \
               ((((d[0] * ql + d[1]) * ql + d[2]) * ql + d[3]) * ql + 1)
    if q > phigh:
        return -_norm_ppf(1 - q)
    ql = q - 0.5
    r = ql * ql
    return (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5]) * ql / \
           (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1)


def relufy_stage1(cfg: ModelConfig) -> ModelConfig:
    """Replace the FFN/gate activation with ReLU (weights unchanged)."""
    return cfg.replace(activation="relu")


def relufy_stage2(cfg: ModelConfig) -> ModelConfig:
    """Stage 1 + ReLU after normalization layers (sparse QKV/up inputs)."""
    return relufy_stage1(cfg).replace(post_norm_relu=True)


def shifted_relufy(cfg: ModelConfig, shift: float) -> ModelConfig:
    """ReLU(x - b) activation (paper Sec. 5.3)."""
    return cfg.replace(activation="shifted_relu").replace_sparsity(shift=shift)


def calibrate_shift(params, batch, cfg: ModelConfig,
                    target_sparsity: float = 0.95) -> float:
    """Pick the shift b so that ~target_sparsity of pre-activations fall
    below it, from the measured pre-activation distribution (the paper reads
    b off the distribution plot, e.g. b=1 for relufied Llama; we use the
    per-layer mean/std under a normal approximation and average).
    """
    from repro.core.sparsity import preactivation_stats
    stats = preactivation_stats(params, batch, cfg)
    shifts = []
    means = {k[: -len("/mean")]: v for k, v in stats.items() if k.endswith("/mean")}
    for base, mu in means.items():
        sd = stats.get(base + "/std", 0.0)
        if sd > 0:
            shifts.append(mu + _norm_ppf(target_sparsity) * sd)
    return float(np.mean(shifts)) if shifts else 0.0


def enable_sparse_serving(cfg: ModelConfig, ffn_density: float,
                          input_density: float = 1.0,
                          reuse_window: int = 0) -> ModelConfig:
    """Turn on the tile-gathered sparse decode path (DESIGN.md §3)."""
    return cfg.replace_sparsity(enabled=True, ffn_tile_density=ffn_density,
                                input_tile_density=input_density,
                                reuse_window=reuse_window)
