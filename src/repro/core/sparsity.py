"""Sparsity measurement + aggregated-sparsity machinery (paper Secs. 3-5).

* `measure_site_sparsity` — per-layer, per-site input sparsity (Fig. 1a /
  Fig. 4 / Table 1 columns) via the instrumented stats forward.
* `AggregatedTracker` — the union of neurons (or 128-tiles) activated while
  decoding tokens 1..t (Sec. 5.1, Fig. 7a/b), plus the paper's random
  baseline s_i^t.
* tile-level helpers shared with the serving engine's γ-window weight reuse
  (Fig. 7c) and sparse speculative decoding (Sec. 5.2).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models import registry


def measure_site_sparsity(params, batch, cfg: ModelConfig) -> Dict[str, float]:
    """Mean input sparsity per site, averaged over layers: keys 'qkv', 'up',
    'down' (paper Table 1 columns) + per-layer details."""
    stats = cm.StatsCollector(True)
    fam = registry.get_family(cfg)
    fam.model_forward(params, batch, cfg, stats=stats)
    out: Dict[str, float] = {}
    agg: Dict[str, List[float]] = {"qkv_in": [], "up_in": [], "down_in": []}
    for k, v in stats.stats.items():
        if getattr(v, "ndim", 0):  # vector stats (activity masks) skipped
            continue
        val = float(v)
        out[k] = val
        for site in agg:
            if k.endswith("/" + site):
                agg[site].append(val)
    for site, vals in agg.items():
        if vals:
            out["mean/" + site.replace("_in", "")] = float(np.mean(vals))
    return out


def preactivation_stats(params, batch, cfg: ModelConfig) -> Dict[str, float]:
    """Per-layer pre-activation mean/std/frac_neg (Fig. 5 / Fig. 11)."""
    stats = cm.StatsCollector(True)
    fam = registry.get_family(cfg)
    fam.model_forward(params, batch, cfg, stats=stats)
    return {k: float(v) for k, v in stats.stats.items()
            if "pre/" in k or k.endswith(("mean", "std", "frac_neg"))}


class AggregatedTracker:
    """Union of activated units over decoded tokens (paper Sec. 5.1).

    `update(masks)` takes per-layer boolean activity (n_layers, n_units)
    for one token; `aggregated_sparsity()` returns the fraction of units
    never used so far (non-increasing in t — the paper's Fig. 7a curve).
    """

    def __init__(self, n_layers: int, n_units: int):
        self.used = np.zeros((n_layers, n_units), bool)
        self.per_token_sparsity: List[float] = []
        self.curve: List[float] = []

    def update(self, masks: np.ndarray) -> None:
        masks = np.asarray(masks, bool)
        self.per_token_sparsity.append(1.0 - masks.mean())
        self.used |= masks
        self.curve.append(1.0 - self.used.mean())

    def aggregated_sparsity(self) -> float:
        return 1.0 - self.used.mean()

    def mean_token_sparsity(self) -> float:
        return float(np.mean(self.per_token_sparsity)) if self.per_token_sparsity else 0.0

    def random_baseline(self, t: Optional[int] = None) -> float:
        """Random aggregated sparsity s^t (paper Fig. 7b dashed line)."""
        s = self.mean_token_sparsity()
        t = t if t is not None else len(self.per_token_sparsity)
        return float(s ** t)


def ffn_activity_masks(stats: cm.StatsCollector, cfg: ModelConfig,
                       tile: int = 0) -> np.ndarray:
    """Extract per-layer down-proj input activity from a stats decode step.

    Requires the stats path to have stored 'layerN/down_act' vectors — see
    serving.engine (it runs decode with collect_activity=True).
    """
    masks = []
    for i in range(cfg.n_layers):
        key = f"layer{i}/down_act"
        if key in stats.stats:
            masks.append(np.asarray(stats.stats[key]))
    return np.stack(masks) if masks else np.zeros((0, 0))
