"""Speculative-decoding latency theory (paper Sec. 5.2, App. C).

Theorem 1: speedup of SPARSE speculative decoding over STANDARD
speculative decoding:   (c·γ + 1) / (c·γ + (1 - s_agg(γ)))

Theorem 2: speedup of sparse speculative decoding over plain
autoregressive decoding:  (1 - α^{γ+1}) / ((c·γ + (1 - s_agg(γ)))·(1 - α))

α = draft-token acceptance probability (i.i.d. assumption), c = draft/target
cost ratio, s_agg(γ) = aggregated sparsity over a γ-token window.
"""
from __future__ import annotations

from typing import Callable, Tuple

import numpy as np


def thm1_speedup(gamma: int, c: float, s_agg: float) -> float:
    return (c * gamma + 1.0) / (c * gamma + (1.0 - s_agg))


def thm2_speedup(gamma: int, c: float, s_agg: float, alpha: float) -> float:
    if alpha >= 1.0:  # limit of the geometric series: every draft accepted
        expected_tokens = gamma + 1.0
    else:
        expected_tokens = (1.0 - alpha ** (gamma + 1)) / (1.0 - alpha)
    return expected_tokens / (c * gamma + (1.0 - s_agg))


def standard_spec_speedup(gamma: int, c: float, alpha: float) -> float:
    """Standard speculative decoding vs autoregressive (Leviathan et al.)."""
    return thm2_speedup(gamma, c, 0.0, alpha)


def optimal_gamma(c: float, alpha: float,
                  s_agg_fn: Callable[[int], float] = lambda g: 0.0,
                  gamma_max: int = 64) -> Tuple[int, float]:
    """argmax_γ of Thm-2 speedup given a (measured) s_agg(γ) curve.

    With s_agg≡0 this is the standard spec-decoding optimum; with a real
    aggregated-sparsity curve the optimum shifts to smaller γ (paper
    Fig. 10a: the sparse optimum is below the standard one, gap < 20%).
    """
    best = (1, 0.0)
    for g in range(1, gamma_max + 1):
        sp = thm2_speedup(g, c, s_agg_fn(g), alpha)
        if sp > best[1]:
            best = (g, sp)
    return best


def expected_accepted_tokens(gamma: int, alpha: float) -> float:
    return (1.0 - alpha ** (gamma + 1)) / (1.0 - alpha)
