"""Deterministic data pipeline: synthetic corpus + packing + shard-aware,
resumable host iterator.

The synthetic corpus is a mixture of Zipfian unigrams and Markov bigram
chains ("documents") so tiny models have real structure to learn — loss
decreases and relufied fine-tuning (paper Sec. 4) is demonstrable on CPU.
Documents are packed into fixed-length rows with EOS separators and a loss
mask. The iterator state is one integer (next doc id) per host shard →
checkpointable and elastic (rescaling hosts re-partitions the id space).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int = 256
    seq_len: int = 64
    batch_size: int = 8
    seed: int = 17
    eos_id: int = 0
    doc_len_min: int = 16
    doc_len_max: int = 96
    n_markov_states: int = 64
    host_index: int = 0
    host_count: int = 1


class SyntheticCorpus:
    """Deterministic doc generator: doc id -> token array (stateless)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        root = np.random.RandomState(cfg.seed)
        v = cfg.vocab_size
        # Zipfian unigram base distribution (skip eos)
        ranks = np.arange(1, v)
        probs = 1.0 / ranks ** 1.1
        self.unigram = probs / probs.sum()
        # Markov transition matrix over a state subset -> strong structure
        m = cfg.n_markov_states
        trans = root.dirichlet(np.full(min(m, v - 1), 0.3), size=m)
        self.trans = trans
        self.state_tokens = root.choice(ranks, size=min(m, v - 1), replace=False)

    def doc(self, doc_id: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.RandomState((cfg.seed * 1_000_003 + doc_id) % 2**31)
        n = rng.randint(cfg.doc_len_min, cfg.doc_len_max + 1)
        m = self.trans.shape[0]
        state = rng.randint(m)
        out = np.empty((n,), np.int32)
        for i in range(n):
            if rng.rand() < 0.15:  # unigram noise
                out[i] = rng.choice(len(self.unigram), p=self.unigram) + 1
            else:
                state = rng.choice(m, p=self.trans[state])
                out[i] = self.state_tokens[state]
        return out


@dataclasses.dataclass
class IteratorState:
    next_doc: int

    def to_dict(self) -> Dict[str, int]:
        return {"next_doc": int(self.next_doc)}

    @staticmethod
    def from_dict(d) -> "IteratorState":
        return IteratorState(next_doc=int(d["next_doc"]))


class PackedIterator:
    """Packs documents into (batch, seq_len) rows with EOS separators.

    Host-sharded: host i consumes doc ids ≡ i (mod host_count). Resumable:
    state is the next doc id (plus a small carry buffer regenerated
    deterministically on restore).
    """

    def __init__(self, cfg: DataConfig, state: Optional[IteratorState] = None):
        self.cfg = cfg
        self.corpus = SyntheticCorpus(cfg)
        start = state.next_doc if state else cfg.host_index
        # align to this host's residue class
        if start % cfg.host_count != cfg.host_index:
            start += (cfg.host_index - start) % cfg.host_count
        self.next_doc = start
        self._carry = np.zeros((0,), np.int32)

    def state(self) -> IteratorState:
        return IteratorState(next_doc=self.next_doc)

    def _fill_row(self) -> np.ndarray:
        cfg = self.cfg
        buf = self._carry
        while len(buf) < cfg.seq_len:
            doc = self.corpus.doc(self.next_doc)
            self.next_doc += cfg.host_count
            buf = np.concatenate([buf, doc, [cfg.eos_id]])
        self._carry = buf[cfg.seq_len:]
        return buf[: cfg.seq_len].astype(np.int32)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        rows = np.stack([self._fill_row() for _ in range(self.cfg.batch_size)])
        mask = (rows != self.cfg.eos_id).astype(np.float32)
        return {"tokens": rows, "loss_mask": mask}


def eval_batches(cfg: DataConfig, n: int, offset: int = 10_000_000):
    """Held-out batches (disjoint doc-id range)."""
    it = PackedIterator(dataclasses.replace(cfg),
                        IteratorState(next_doc=offset + cfg.host_index))
    return [next(it) for _ in range(n)]
