"""Fused gather-up -> activation -> scatter-down decode FFN kernel.

The serving hot loop previously paid THREE passes per layer over the sparse
FFN: ``sparse_up_matmul`` for the gate pre-activation, (for GLU) a second
``sparse_up_matmul`` for the up-projection, then ``sparse_matmul_tokens``
for the down-projection — each materializing a (T, F)-shaped intermediate
in HBM between kernels. This kernel runs the whole per-tile chain in one
``pallas_call``: for every (token t, list slot i) grid point it DMAs ONLY
the tile-i weight columns/rows named by the token's packed tile list
(scalar prefetch — the DMA engine never touches a skipped tile), computes

    h_i = act(x_t @ Wg[:, tile_i]) [* (x_t @ Wu[:, tile_i])] [* mask_i]

entirely in VMEM/registers, and scatter-accumulates ``h_i @ Wd[tile_i, :]``
into the token's output row. HBM weight traffic per (token, layer) drops to
``nvalid x n_proj x tile x d_model x itemsize`` — exactly the paper's
"read only the live rows" claim, now with no intermediate round-trips.

Tile lists are the fixed-K padded int32 lists from
``predictors.pack_tile_indices`` (valid-first ascending, pads repeating the
row's first tile), which composes with PR 5's model-axis-local per-shard
packing unchanged. Numerics are pinned to the unfused pair: identical
per-tile dot shapes, identical f32 accumulation order over the same
ascending tile list — ``tests/test_fused_decode.py`` asserts bit-equality
against the ``sparse_up_matmul`` + ``sparse_matmul_tokens`` composition.

The kernel also emits the compact (T, K, tile) activation buffer so the
caller can reconstruct the full hidden activation (``scatter_compact``) for
the act/scores telemetry the γ-window machinery records — the scatter is
the same masked ``.at[].add`` the unfused path used, so duplicate pad tiles
contribute exactly once.

MoE (documented XLA fallback): this fused kernel has no expert-offset
variant yet, so MoE serving (models/moe.py) keeps its grouped one-hot
dispatch einsums — the frozen-exactness XLA path — and the engine forces
``fast_kernels=False`` for MoE configs with a warning. The building blocks
for a future fused expert path already exist as standalone kernels
(``sparse_matmul.expert_up_matmul`` / ``expert_down_matmul`` over
``expert_tile_lists``): fusing them here is a matter of adding the
expert-major index_map split (idx // tpe, idx % tpe) to the weight
BlockSpecs, exactly as those kernels do.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import activations as acts
from repro.kernels.runtime import resolve_interpret


def _make_kernel(activation: str, shift: float, glu: bool, masked: bool):
    act_fn = acts.get(activation, shift=shift)

    def kernel(idx_ref, nvalid_ref, x_ref, *refs):
        refs = list(refs)
        wg_ref = refs.pop(0)          # gate projection (wu when not GLU)
        wu_ref = refs.pop(0) if glu else None
        wd_ref = refs.pop(0)
        m_ref = refs.pop(0) if masked else None
        y_ref, h_ref = refs
        t, i = pl.program_id(0), pl.program_id(1)

        @pl.when(i == 0)
        def _zero():
            y_ref[...] = jnp.zeros_like(y_ref)

        @pl.when(i < nvalid_ref[t])
        def _acc():
            h = act_fn(jax.lax.dot_general(
                x_ref[...], wg_ref[...], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32))
            if glu:
                h = h * jax.lax.dot_general(
                    x_ref[...], wu_ref[...], (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
            if masked:
                h = h * m_ref[...]
            h_ref[...] = h[:, None, :]
            y_ref[...] += jax.lax.dot_general(
                h.astype(wd_ref.dtype), wd_ref[...], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)

        @pl.when(i >= nvalid_ref[t])
        def _pad():  # padded slots: no DMA'd tile is used, block zeroed
            h_ref[...] = jnp.zeros_like(h_ref)

    return kernel


@functools.partial(jax.jit, static_argnames=("activation", "shift", "tile",
                                             "interpret"))
def fused_sparse_ffn(x, w_gate, wd, idx, nvalid, *, w_up=None, unit_mask=None,
                     activation: str = "relu", shift: float = 0.0,
                     tile: int = 128, interpret: Optional[bool] = None):
    """One-pass sparse FFN over per-token tile lists.

    x: (T, d); w_gate: (d, F) the activation-gated projection (``wu`` for a
    plain MLP, ``wg`` for GLU — pass the GLU's ``wu`` as ``w_up``); wd:
    (F, d_out); idx: (T, K) int32 tile ids (valid-first, in-range pads);
    nvalid: (T,) int32; unit_mask: optional (T, F) f32/bool unit-resolution
    mask multiplied into the hidden activation (the γ-window ``eff`` mask —
    a gathered tile may still have masked-off units inside it).

    Returns (y (T, d_out) f32, h_compact (T, K, tile) f32). ``y`` is the
    down-projection accumulated over the valid tiles in list order;
    ``h_compact[t, i]`` is tile ``idx[t, i]``'s hidden activation (zeros
    past nvalid) — scatter with ``scatter_compact`` to recover the (T, F)
    activation for telemetry. Rows with nvalid == 0 return exact zeros.
    """
    T, d = x.shape
    F = w_gate.shape[1]
    K = idx.shape[1]
    d_out = wd.shape[1]
    assert F % tile == 0 and wd.shape[0] == F
    glu = w_up is not None
    masked = unit_mask is not None

    tile_spec = pl.BlockSpec((d, tile), lambda t, i, idx, nv: (0, idx[t, i]))
    in_specs = [pl.BlockSpec((1, d), lambda t, i, idx, nv: (t, 0)), tile_spec]
    args = [x, w_gate]
    if glu:
        in_specs.append(tile_spec)
        args.append(w_up)
    in_specs.append(
        pl.BlockSpec((tile, d_out), lambda t, i, idx, nv: (idx[t, i], 0)))
    args.append(wd)
    if masked:
        in_specs.append(
            pl.BlockSpec((1, tile), lambda t, i, idx, nv: (t, idx[t, i])))
        args.append(unit_mask.astype(jnp.float32))

    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(T, K),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, d_out), lambda t, i, idx, nv: (t, 0)),
            pl.BlockSpec((1, 1, tile), lambda t, i, idx, nv: (t, i, 0)),
        ],
    )
    y, compact = pl.pallas_call(
        _make_kernel(activation, shift, glu, masked),
        grid_spec=spec,
        out_shape=[
            jax.ShapeDtypeStruct((T, d_out), jnp.float32),
            jax.ShapeDtypeStruct((T, K, tile), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(idx.astype(jnp.int32), nvalid.astype(jnp.int32), *args)
    return y, compact


def scatter_compact(compact, idx, nvalid, n_tiles: int):
    """Place a (T, K, tile) compact activation buffer at its tile positions:
    returns (T, n_tiles * tile) f32 with exact zeros on non-gathered tiles.
    The same masked scatter-add ``sparse_up_matmul`` uses — padding is
    zeroed first, so duplicate pad indices contribute exactly once (i.e.
    nothing)."""
    T, K, tile = compact.shape
    valid = (jnp.arange(K)[None, :] < nvalid[:, None]).astype(compact.dtype)
    compact = compact * valid[:, :, None]
    y = jnp.zeros((T, n_tiles, tile), compact.dtype)
    y = y.at[jnp.arange(T)[:, None], idx].add(compact)
    return y.reshape(T, n_tiles * tile)


def modeled_weight_bytes(k_tiles: float, tile: int, d_model: int,
                         itemsize: int, n_proj: int) -> float:
    """Analytic HBM weight bytes ONE token reads through this kernel in one
    layer: ``k_tiles`` gathered tiles x ``n_proj`` projections touching that
    tile (gate + [up] + down) x the (tile x d_model) tile footprint. Derived
    purely from the BlockSpec geometry above — the roofline gate
    (launch/roofline.py) checks it against the engine's independently
    measured ``weight_io_bytes_per_step``."""
    return float(k_tiles) * n_proj * tile * d_model * itemsize
