"""Pallas TPU kernel: fused up-projection + (shifted) ReLU + tile-activity
scores in one HBM pass.

Produces the sparse activations h = relu(x@Wu − b) AND the per-128-tile
activity scores the sparse down-projection needs for its top-k selection —
without a second pass over h. Grid over F tiles; x stays VMEM-resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret


TILE = 128  # lane-width tile the activity scores are reduced over


def tile_activity(h: jnp.ndarray, tile: int = TILE) -> jnp.ndarray:
    """Per-token tile-activity scores — the kernel's s_ref definition as a
    plain-XLA function. h: (T, F) -> (T, F // tile). Shared by the serving
    decode step (which carries scores through the batch dimension) and the
    fused kernels below (validated equal in tests/test_kernels.py)."""
    T, F = h.shape
    return jnp.max(jnp.abs(h).reshape(T, F // tile, tile), axis=-1)


def window_tile_activity(h: jnp.ndarray, tile: int = TILE) -> jnp.ndarray:
    """Window-union tile-activity scores: per-slot max |h| over the window
    tokens AND the tile lanes. h: (B, W, F) -> (B, F // tile).

    The union is exactly what the sparse speculative verification loads
    (paper Sec. 5.2): a down-projection tile is read ONCE per γ-window if
    any window token activates it. W = 1 recovers ``tile_activity``."""
    B, W, F = h.shape
    return jnp.max(jnp.abs(h).reshape(B, W, F // tile, tile), axis=(1, 3))


def _make_kernel(shift: float):
    def kernel(x_ref, w_ref, h_ref, s_ref):
        h = jax.lax.dot_general(
            x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        h = jnp.maximum(h - shift, 0.0)
        h_ref[...] = h
        T, Fb = h.shape
        s_ref[...] = jnp.max(jnp.abs(h).reshape(T, Fb // TILE, TILE),
                             axis=(0, 2))[None, :]
    return kernel


def _make_kernel_window(shift: float, w: int):
    def kernel(x_ref, w_ref, h_ref, s_ref):
        h = jax.lax.dot_general(
            x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        h = jnp.maximum(h - shift, 0.0)
        h_ref[...] = h
        bw, Fb = h.shape  # rows are (slot, window-token) pairs
        s_ref[...] = jnp.max(jnp.abs(h).reshape(bw // w, w, Fb // TILE, TILE),
                             axis=(1, 3))
    return kernel


def _make_kernel_tokens(shift: float):
    def kernel(x_ref, w_ref, h_ref, s_ref):
        h = jax.lax.dot_general(
            x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        h = jnp.maximum(h - shift, 0.0)
        h_ref[...] = h
        s_ref[...] = tile_activity(h)
    return kernel


@functools.partial(jax.jit,
                   static_argnames=("shift", "block_f", "interpret"))
def fused_up_relu(x, wu, shift: float = 0.0, *, block_f: int = 512,
                  interpret=None):
    """x: (T, d), wu: (d, F) -> (h (T, F) f32, scores (1, F/128) f32)."""
    T, d = x.shape
    F = wu.shape[1]
    block_f = min(block_f, F)
    assert F % block_f == 0 and block_f % 128 == 0
    grid = (F // block_f,)
    h, scores = pl.pallas_call(
        _make_kernel(shift),
        grid=grid,
        in_specs=[
            pl.BlockSpec((T, d), lambda i: (0, 0)),
            pl.BlockSpec((d, block_f), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((T, block_f), lambda i: (0, i)),
            pl.BlockSpec((1, block_f // 128), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, F), jnp.float32),
            jax.ShapeDtypeStruct((1, F // 128), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(x, wu)
    return h, scores[0]


@functools.partial(jax.jit,
                   static_argnames=("shift", "block_f", "interpret"))
def fused_up_relu_tokens(x, wu, shift: float = 0.0, *, block_f: int = 512,
                         interpret=None):
    """Per-token variant for continuous-batching serving: every request in
    the batch keeps its OWN activity scores (the batch-union reduction of
    ``fused_up_relu`` would couple co-scheduled requests).

    x: (T, d), wu: (d, F) -> (h (T, F) f32, scores (T, F/128) f32)."""
    T, d = x.shape
    F = wu.shape[1]
    block_f = min(block_f, F)
    assert F % block_f == 0 and block_f % TILE == 0
    grid = (F // block_f,)
    h, scores = pl.pallas_call(
        _make_kernel_tokens(shift),
        grid=grid,
        in_specs=[
            pl.BlockSpec((T, d), lambda i: (0, 0)),
            pl.BlockSpec((d, block_f), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((T, block_f), lambda i: (0, i)),
            pl.BlockSpec((T, block_f // TILE), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, F), jnp.float32),
            jax.ShapeDtypeStruct((T, F // TILE), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(x, wu)
    return h, scores


@functools.partial(jax.jit,
                   static_argnames=("shift", "block_f", "interpret"))
def fused_up_relu_window(x, wu, shift: float = 0.0, *, block_f: int = 512,
                         interpret=None):
    """γ-window variant for speculative verification: all W window tokens of
    every slot pass through the up-projection once, and the activity scores
    come back ALREADY unioned over each slot's window — the selection input
    for the window's sparse down-projection (paper Sec. 5.2) with no second
    pass over h.

    x: (B, W, d), wu: (d, F) -> (h (B, W, F) f32, scores (B, F/128) f32);
    scores match ``window_tile_activity(h)`` (validated in tests)."""
    B, W, d = x.shape
    F = wu.shape[1]
    block_f = min(block_f, F)
    assert F % block_f == 0 and block_f % TILE == 0
    grid = (F // block_f,)
    h, scores = pl.pallas_call(
        _make_kernel_window(shift, W),
        grid=grid,
        in_specs=[
            pl.BlockSpec((B * W, d), lambda i: (0, 0)),
            pl.BlockSpec((d, block_f), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((B * W, block_f), lambda i: (0, i)),
            pl.BlockSpec((B, block_f // TILE), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * W, F), jnp.float32),
            jax.ShapeDtypeStruct((B, F // TILE), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(x.reshape(B * W, d), wu)
    return h.reshape(B, W, F), scores
