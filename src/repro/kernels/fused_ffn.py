"""Pallas TPU kernel: fused up-projection + (shifted) ReLU + tile-activity
scores in one HBM pass.

Produces the sparse activations h = relu(x@Wu − b) AND the per-128-tile
activity scores the sparse down-projection needs for its top-k selection —
without a second pass over h. Grid over F tiles; x stays VMEM-resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(shift: float):
    def kernel(x_ref, w_ref, h_ref, s_ref):
        h = jax.lax.dot_general(
            x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        h = jnp.maximum(h - shift, 0.0)
        h_ref[...] = h
        T, Fb = h.shape
        s_ref[...] = jnp.max(jnp.abs(h).reshape(T, Fb // 128, 128),
                             axis=(0, 2))[None, :]
    return kernel


@functools.partial(jax.jit,
                   static_argnames=("shift", "block_f", "interpret"))
def fused_up_relu(x, wu, shift: float = 0.0, *, block_f: int = 512,
                  interpret: bool = True):
    """x: (T, d), wu: (d, F) -> (h (T, F) f32, scores (1, F/128) f32)."""
    T, d = x.shape
    F = wu.shape[1]
    block_f = min(block_f, F)
    assert F % block_f == 0 and block_f % 128 == 0
    grid = (F // block_f,)
    h, scores = pl.pallas_call(
        _make_kernel(shift),
        grid=grid,
        in_specs=[
            pl.BlockSpec((T, d), lambda i: (0, 0)),
            pl.BlockSpec((d, block_f), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((T, block_f), lambda i: (0, i)),
            pl.BlockSpec((1, block_f // 128), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, F), jnp.float32),
            jax.ShapeDtypeStruct((1, F // 128), jnp.float32),
        ],
        interpret=interpret,
    )(x, wu)
    return h, scores[0]
