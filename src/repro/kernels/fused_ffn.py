"""Pallas TPU kernel: fused up-projection + (shifted) ReLU + tile-activity
scores in one HBM pass.

Produces the sparse activations h = relu(x@Wu − b) AND the per-128-tile
activity scores the sparse down-projection needs for its top-k selection —
without a second pass over h. Grid over F tiles; x stays VMEM-resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


TILE = 128  # lane-width tile the activity scores are reduced over


def tile_activity(h: jnp.ndarray, tile: int = TILE) -> jnp.ndarray:
    """Per-token tile-activity scores — the kernel's s_ref definition as a
    plain-XLA function. h: (T, F) -> (T, F // tile). Shared by the serving
    decode step (which carries scores through the batch dimension) and the
    fused kernels below (validated equal in tests/test_kernels.py)."""
    T, F = h.shape
    return jnp.max(jnp.abs(h).reshape(T, F // tile, tile), axis=-1)


def _make_kernel(shift: float):
    def kernel(x_ref, w_ref, h_ref, s_ref):
        h = jax.lax.dot_general(
            x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        h = jnp.maximum(h - shift, 0.0)
        h_ref[...] = h
        T, Fb = h.shape
        s_ref[...] = jnp.max(jnp.abs(h).reshape(T, Fb // TILE, TILE),
                             axis=(0, 2))[None, :]
    return kernel


def _make_kernel_tokens(shift: float):
    def kernel(x_ref, w_ref, h_ref, s_ref):
        h = jax.lax.dot_general(
            x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        h = jnp.maximum(h - shift, 0.0)
        h_ref[...] = h
        s_ref[...] = tile_activity(h)
    return kernel


@functools.partial(jax.jit,
                   static_argnames=("shift", "block_f", "interpret"))
def fused_up_relu(x, wu, shift: float = 0.0, *, block_f: int = 512,
                  interpret: bool = True):
    """x: (T, d), wu: (d, F) -> (h (T, F) f32, scores (1, F/128) f32)."""
    T, d = x.shape
    F = wu.shape[1]
    block_f = min(block_f, F)
    assert F % block_f == 0 and block_f % 128 == 0
    grid = (F // block_f,)
    h, scores = pl.pallas_call(
        _make_kernel(shift),
        grid=grid,
        in_specs=[
            pl.BlockSpec((T, d), lambda i: (0, 0)),
            pl.BlockSpec((d, block_f), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((T, block_f), lambda i: (0, i)),
            pl.BlockSpec((1, block_f // 128), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, F), jnp.float32),
            jax.ShapeDtypeStruct((1, F // 128), jnp.float32),
        ],
        interpret=interpret,
    )(x, wu)
    return h, scores[0]


@functools.partial(jax.jit,
                   static_argnames=("shift", "block_f", "interpret"))
def fused_up_relu_tokens(x, wu, shift: float = 0.0, *, block_f: int = 512,
                         interpret: bool = True):
    """Per-token variant for continuous-batching serving: every request in
    the batch keeps its OWN activity scores (the batch-union reduction of
    ``fused_up_relu`` would couple co-scheduled requests).

    x: (T, d), wu: (d, F) -> (h (T, F) f32, scores (T, F/128) f32)."""
    T, d = x.shape
    F = wu.shape[1]
    block_f = min(block_f, F)
    assert F % block_f == 0 and block_f % TILE == 0
    grid = (F // block_f,)
    h, scores = pl.pallas_call(
        _make_kernel_tokens(shift),
        grid=grid,
        in_specs=[
            pl.BlockSpec((T, d), lambda i: (0, 0)),
            pl.BlockSpec((d, block_f), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((T, block_f), lambda i: (0, i)),
            pl.BlockSpec((T, block_f // TILE), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, F), jnp.float32),
            jax.ShapeDtypeStruct((T, F // TILE), jnp.float32),
        ],
        interpret=interpret,
    )(x, wu)
    return h, scores
