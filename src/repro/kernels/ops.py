"""jit'd wrappers around the Pallas kernels + the XLA fallback path.

``sparse_ffn_apply`` is the deployment-shaped composition the serving engine
targets on TPU: fused up-proj+ReLU with tile scores, static top-k tile
selection, then the scalar-prefetch gathered down-projection. On this CPU
container the kernels run in interpret mode; the dry-run lowers the
mathematically identical XLA gather path (models/common.gathered_matmul).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels.fused_ffn import fused_up_relu
from repro.kernels.sparse_matmul import sparse_matmul
from repro.models import common as cm


def select_tiles_static(scores, density: float):
    """Top-k tile selection with static capacity (paper: predictable
    sparsity -> load only what's needed). Returns (idx (K,), nvalid ())."""
    n_tiles = scores.shape[-1]
    k = max(1, int(math.ceil(density * n_tiles)))
    top, idx = jax.lax.top_k(scores, k)
    nvalid = jnp.sum((top > 0).astype(jnp.int32))
    return idx.astype(jnp.int32), nvalid


@functools.partial(jax.jit, static_argnames=("density", "shift", "interpret"))
def sparse_ffn_apply(x, wu, wd, *, density: float = 0.25, shift: float = 0.0,
                     interpret=None):
    """Full sparse FFN hot path: h = relu(x@wu − b); y = h @ wd over the
    top-⌈density·F/128⌉ active tiles only. Returns (y, h, idx, nvalid)."""
    h, scores = fused_up_relu(x, wu, shift, interpret=interpret)
    idx, nvalid = select_tiles_static(scores, density)
    y = sparse_matmul(h.astype(x.dtype), wd, idx, nvalid, interpret=interpret)
    return y, h, idx, nvalid


def sparse_ffn_apply_xla(x, wu, wd, *, density: float = 0.25,
                         shift: float = 0.0):
    """XLA gather fallback (what the multi-pod dry-run lowers)."""
    h = jnp.maximum(
        jax.lax.dot_general(x, wu, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32) - shift, 0.0)
    scores = jnp.max(jnp.abs(h).reshape(h.shape[0], -1, 128), axis=(0, 2))
    idx, nvalid = select_tiles_static(scores, density)
    mask = (jnp.arange(idx.shape[0]) < nvalid).astype(h.dtype)
    y = cm.gathered_matmul(h.astype(x.dtype), wd, idx, mask, 128)
    return y, h, idx, nvalid


def flops_saved(F: int, D: int, T: int, density: float) -> dict:
    """Analytic savings of the gathered down-projection (paper Fig. 1c)."""
    dense = 2.0 * T * F * D
    sparse = 2.0 * T * math.ceil(density * F / 128) * 128 * D
    bytes_dense = F * D * 2
    bytes_sparse = math.ceil(density * F / 128) * 128 * D * 2
    return {"dense_flops": dense, "sparse_flops": sparse,
            "flops_saving": 1 - sparse / dense,
            "dense_weight_bytes": bytes_dense,
            "sparse_weight_bytes": bytes_sparse,
            "io_saving": 1 - bytes_sparse / bytes_dense}
