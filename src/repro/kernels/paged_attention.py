"""Paged window-attention kernel: block-table gather INSIDE the kernel.

The frozen serving path materializes every slot's whole gathered cache each
step (``common.paged_gather``: pool[table] -> (b, kvp, nb*bs, hd)) before a
dense-softmax attention reads it once — 2x the cache traffic, plus an HBM
round-trip for a tensor that exists only to be immediately consumed. This
kernel reads the K/V pool THROUGH the block table instead: the table rides
in as scalar prefetch, the K/V BlockSpecs dereference ``table[t, j]``, and
the DMA engine streams each slot's blocks straight from the pool into VMEM
— one read of exactly the blocks a slot owns, no gathered copy.

Softmax is the online (flash-decode) form over the block axis: running
(m, l, acc) scratch carried across the inner grid dimension, finalized on
the last block. Numerically this is the textbook-exact rewrite of the
frozen full-softmax ``window_attention`` — greedy token streams match at
f32 (tests/test_fused_decode.py); individual logits may differ in the last
ulp, which is the same contract the chunked ``flash_attention`` already
ships under.

Handles both serving shapes: W = 1 plain decode and the W = γ+1
speculative-verification window (causal within the window via per-token
positions), plus GQA (all kv heads batched per block) and the optional
sliding window. Block-table padding rows point at the scratch block; their
keys sit past every real position and mask to zero weight exactly as the
materialized path's ``pos`` masking did.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret


def _make_kernel(bs: int, window: int):
    def kernel(tbl_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
               m_ref, l_ref, acc_ref):
        j = pl.program_id(1)
        nb = pl.num_programs(1)

        @pl.when(j == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, -1e30)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        q = q_ref[0]  # (kvp, Wg, hd) — pre-scaled, cache dtype
        k = k_ref[0]  # (kvp, bs, hd) block table[t, j] of the pool
        v = v_ref[0]
        logits = jax.lax.dot_general(  # (kvp, Wg, bs)
            q, k, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        qpos = pos_ref[0]  # (Wg,) int32 absolute position per query row
        kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (q.shape[1], bs),
                                                 1)
        valid = kpos <= qpos[:, None]
        if window:
            valid &= kpos > qpos[:, None] - window
        logits = jnp.where(valid[None], logits, -1e30)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
        # explicit zeroing of masked probabilities: a block whose keys are
        # ALL masked for some row (sliding window past the head of the
        # cache) must contribute nothing even while m is still -1e30
        p = jnp.where(valid[None], jnp.exp(logits - m_new[..., None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[..., None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

        @pl.when(j == nb - 1)
        def _finalize():
            o_ref[0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]

    return kernel


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_window_attention(q, k_pages, v_pages, table, pos, *,
                           window: int = 0,
                           interpret: Optional[bool] = None):
    """Windowed grouped attention straight off the paged pool.

    q: (b, W, kvp, g, hd) the W-token query window per slot; k_pages /
    v_pages: (n_blocks, kvp, bs, hd) ONE layer's pool (head-major blocks);
    table: (b, nb) int32 block ids in sequence order (pads -> scratch
    block); pos: (b, W) absolute position of each window token. Causal
    within the window: query i attends to cache positions <= pos[:, i].
    Returns (b, W, kvp, g, hd) in q's dtype — drop-in for
    ``paged_gather`` + ``window_attention``.
    """
    b, W, kvp, g, hd = q.shape
    n_blocks, _, bs, _ = k_pages.shape
    nb = table.shape[1]
    scale = 1.0 / math.sqrt(hd)
    # mirror the frozen path's rounding placement: scale in q dtype, then
    # compute logits in the cache dtype with f32 accumulation
    qs = (q * jnp.asarray(scale, q.dtype)).astype(k_pages.dtype)
    qs = qs.transpose(0, 2, 1, 3, 4).reshape(b, kvp, W * g, hd)
    posr = jnp.repeat(pos.astype(jnp.int32), g, axis=1)  # (b, W*g)

    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, nb),
        in_specs=[
            pl.BlockSpec((1, kvp, W * g, hd), lambda t, j, tbl: (t, 0, 0, 0)),
            pl.BlockSpec((1, kvp, bs, hd),
                         lambda t, j, tbl: (tbl[t, j], 0, 0, 0)),
            pl.BlockSpec((1, kvp, bs, hd),
                         lambda t, j, tbl: (tbl[t, j], 0, 0, 0)),
            pl.BlockSpec((1, W * g), lambda t, j, tbl: (t, 0)),
        ],
        out_specs=pl.BlockSpec((1, kvp, W * g, hd),
                               lambda t, j, tbl: (t, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kvp, W * g), jnp.float32),
            pltpu.VMEM((kvp, W * g), jnp.float32),
            pltpu.VMEM((kvp, W * g, hd), jnp.float32),
        ],
    )
    o = pl.pallas_call(
        _make_kernel(bs, window),
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((b, kvp, W * g, hd), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(table.astype(jnp.int32), qs, k_pages, v_pages, posr)
    o = o.reshape(b, kvp, W, g, hd).transpose(0, 2, 1, 3, 4)
    return o.astype(q.dtype)


def paged_decode_attention(q, k_pages, v_pages, table, pos, *,
                           window: int = 0,
                           interpret: Optional[bool] = None):
    """W = 1 decode specialization. q: (b, kvp, g, hd); pos: (b,)."""
    return paged_window_attention(q[:, None], k_pages, v_pages, table,
                                  pos[:, None], window=window,
                                  interpret=interpret)[:, 0]


def modeled_cache_bytes(nb: int, bs: int, kvp: int, hd: int,
                        itemsize: int) -> float:
    """HBM bytes ONE slot's attention reads per layer through this kernel:
    each owned K and V block streamed exactly once (the materialized
    ``paged_gather`` path pays this twice — once building the gathered
    copy, once reading it — plus the copy's write)."""
    return 2.0 * nb * bs * kvp * hd * itemsize
