"""Pure-jnp oracles for the Pallas kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sparse_matmul_ref(x, w, idx, nvalid, tile: int):
    """y = x @ w restricted to the selected F-tiles.

    x: (T, F); w: (F, D); idx: (K,) tile indices (may contain padding past
    nvalid); nvalid: () int32 — only idx[:nvalid] participate.
    """
    T, F = x.shape
    n_tiles = F // tile
    k = idx.shape[0]
    valid = jnp.arange(k) < nvalid
    sel = jnp.zeros((n_tiles,), jnp.bool_).at[idx].max(valid)
    mask = jnp.repeat(sel, tile)
    xm = jnp.where(mask[None, :], x, 0)
    return jax.lax.dot_general(
        xm, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def fused_up_relu_ref(x, wu, shift: float):
    """h = relu(x @ wu - shift) and per-128-tile activity scores.

    x: (T, d); wu: (d, F). Returns (h (T, F) f32, scores (F//128,) f32).
    """
    h = jax.lax.dot_general(x, wu, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    h = jnp.maximum(h - shift, 0.0)
    T, F = h.shape
    scores = jnp.max(jnp.abs(h).reshape(T, F // 128, 128), axis=(0, 2))
    return h, scores
