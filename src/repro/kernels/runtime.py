"""Shared kernel-runtime policy for every Pallas kernel in this package.

One rule, one place: ``interpret=None`` (the default everywhere) autodetects
the backend — interpret mode on CPU (this container, CI), compiled Mosaic
on TPU — and an explicit bool always overrides, so tests can force either
lowering. Kernels must not hardcode ``interpret=True``: that silently pins
TPU callers to the emulator and the memory-bound win the paper promises
never materializes (ISSUE 7 satellite: unify interpret-mode defaults).
"""
from __future__ import annotations

from typing import Optional

import jax


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """None -> interpret iff running on CPU (explicit bool overrides)."""
    if interpret is None:
        return jax.default_backend() == "cpu"
    return bool(interpret)
