"""Pallas TPU kernels: tile-gathered sparse matmuls (the paper's row-skipping,
TPU-native — DESIGN.md §3).

``y = x @ w`` computed only over selected F-tiles. Tile index lists arrive
via *scalar prefetch*, so the weight BlockSpec's ``index_map`` dereferences
``idx[...]`` — the DMA engine fetches ONLY the active weight tiles from HBM.
This is exactly the paper's "skip loading zero rows" (App. B Fig. 9a)
expressed in the TPU memory hierarchy: HBM→VMEM traffic and MXU work both
shrink by the sparsity factor.

Dense-family variants:

* ``sparse_matmul`` — one shared tile list for all T rows (the batch-union
  selection the γ-window down-projection uses). Grid = (D_tiles, K) with K
  innermost: the (T, Dt) output block stays resident in VMEM while the K
  gathered tiles accumulate into it.
* ``sparse_matmul_tokens`` — PER-ROW tile lists (idx (T, K), nvalid (T,)):
  every row gathers its own tiles. This is the continuous-batching shape —
  co-scheduled requests predict different active sets and must not union
  (predictor serving mode, serving/engine.py).
* ``sparse_up_matmul`` — gathers OUTPUT tiles (columns of w): only the
  predicted-active up-projection tiles are computed/read; the rest of the
  output is exactly zero. The kernel emits a compact (T, K, tile) buffer
  (every grid point writes its own block, so nothing is left
  uninitialized); a plain-XLA scatter-add places it, padding masked to
  zero so duplicate pad indices are harmless.

Grouped per-expert gathers (MoE serving, models/moe.py): expert top-k
routing is the same structure one level up — a token reads only its routed
experts' weight tiles. ``expert_tile_lists`` flattens each token's top-k
expert ids into a per-token GLOBAL tile list over the (E, F) expert-unit
grid (expert e owns tiles [e·tpe, (e+1)·tpe)), and ``expert_up_matmul`` /
``expert_down_matmul`` are the stacked-weight (E, d, F) / (E, F, d)
twins of ``sparse_up_matmul`` / ``sparse_matmul_tokens``: the BlockSpec
index_map splits a global tile id into (expert, within-expert tile), so the
DMA engine fetches only activated experts' tiles. Router sparsity and
γ-window/ReLU sparsity thus ride the same gather mechanism — compose them
by intersecting the expert tile list with the within-expert active tiles.

``interpret=None`` (the default) autodetects: interpret mode on CPU (this
container), compiled on TPU. Pass an explicit bool to override.

Tensor-parallel serving (engine ``mesh=``): the weight's F axis is sharded
over the "model" mesh axis, and the caller packs the tile lists
MODEL-AXIS-LOCALLY (predictors.pack_tile_indices ``n_groups=TP`` — each
shard's indices name only tiles in its own F slice, capacity balanced per
shard), so every gather a shard issues is against weight tiles it already
owns: no cross-shard weight traffic, and per-device HBM reads shrink by
sparsity x 1/TP. The kernels themselves are unchanged — index locality is
a property of the lists they are handed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret as _resolve_interpret


def _kernel(idx_ref, nvalid_ref, x_ref, w_ref, o_ref):
    j, i = pl.program_id(0), pl.program_id(1)

    @pl.when(i == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(i < nvalid_ref[0])
    def _acc():
        o_ref[...] += jax.lax.dot_general(
            x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tile", "block_d", "interpret"))
def sparse_matmul(x, w, idx, nvalid, *, tile: int = 128, block_d: int = 256,
                  interpret=None):
    """x: (T, F), w: (F, D), idx: (K,) int32 tile ids, nvalid: () int32.

    Returns (T, D) f32. One tile list shared by every row (batch-union
    selection). interpret=None autodetects from the backend.
    """
    T, F = x.shape
    D = w.shape[1]
    K = idx.shape[0]
    block_d = min(block_d, D)
    assert F % tile == 0 and D % block_d == 0

    grid = (D // block_d, K)
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((T, tile), lambda j, i, idx, nv: (0, idx[i])),
            pl.BlockSpec((tile, block_d), lambda j, i, idx, nv: (idx[i], j)),
        ],
        out_specs=pl.BlockSpec((T, block_d), lambda j, i, idx, nv: (0, j)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((T, D), jnp.float32),
        interpret=_resolve_interpret(interpret),
    )(idx, jnp.reshape(nvalid, (1,)).astype(jnp.int32), x, w)


def _kernel_tokens(idx_ref, nvalid_ref, x_ref, w_ref, o_ref):
    t, i = pl.program_id(0), pl.program_id(2)

    @pl.when(i == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(i < nvalid_ref[t])
    def _acc():
        o_ref[...] += jax.lax.dot_general(
            x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tile", "block_d", "interpret"))
def sparse_matmul_tokens(x, w, idx, nvalid, *, tile: int = 128,
                         block_d: int = 256, interpret=None):
    """Per-row tile gather: x (T, F), w (F, D), idx (T, K) int32 tile ids,
    nvalid (T,) int32 valid-count per row. Returns (T, D) f32.

    Row t accumulates only its own idx[t, :nvalid[t]] tiles — the
    continuous-batching predictor shape, where each slot's predicted active
    set differs. Pad idx[t, i >= nvalid[t]] with any in-range tile id
    (repeating a valid id keeps the padded DMAs cache-resident); padded
    iterations are skipped by the nvalid guard either way.
    """
    T, F = x.shape
    D = w.shape[1]
    K = idx.shape[1]
    block_d = min(block_d, D)
    assert F % tile == 0 and D % block_d == 0

    grid = (T, D // block_d, K)
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile), lambda t, j, i, idx, nv: (t, idx[t, i])),
            pl.BlockSpec((tile, block_d),
                         lambda t, j, i, idx, nv: (idx[t, i], j)),
        ],
        out_specs=pl.BlockSpec((1, block_d),
                               lambda t, j, i, idx, nv: (t, j)),
    )
    return pl.pallas_call(
        _kernel_tokens,
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((T, D), jnp.float32),
        interpret=_resolve_interpret(interpret),
    )(idx.astype(jnp.int32), nvalid.astype(jnp.int32), x, w)


def _kernel_up(idx_ref, nvalid_ref, x_ref, w_ref, o_ref):
    t, i = pl.program_id(0), pl.program_id(1)

    @pl.when(i < nvalid_ref[t])
    def _compute():
        o_ref[...] = jax.lax.dot_general(
            x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[None]

    @pl.when(i >= nvalid_ref[t])
    def _zero():  # padded iterations: no MXU work, block zeroed
        o_ref[...] = jnp.zeros_like(o_ref)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def sparse_up_matmul(x, w, idx, nvalid, *, tile: int = 128, interpret=None):
    """Output-tile gather for the up-projection: x (T, d), w (d, F),
    idx (T, K) int32 OUTPUT tile ids, nvalid (T,). Returns (T, F) f32 where
    only row t's selected output tiles are computed (their weight columns
    read); everything else is exactly 0.

    The kernel writes a compact (T, K, tile) buffer — each grid point owns
    its own output block, so no block is left unvisited/uninitialized;
    iterations past nvalid[t] skip the matmul and just zero their block
    (their idx entries repeat the row's first tile, so their weight
    prefetch revisits an already-fetched block). A scatter-ADD then places
    the tiles, with padding masked to zero so duplicate pad indices cannot
    clobber real tiles.
    """
    T, d = x.shape
    F = w.shape[1]
    K = idx.shape[1]
    assert F % tile == 0
    n_tiles = F // tile

    grid = (T, K)
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda t, i, idx, nv: (t, 0)),
            pl.BlockSpec((d, tile), lambda t, i, idx, nv: (0, idx[t, i])),
        ],
        out_specs=pl.BlockSpec((1, 1, tile), lambda t, i, idx, nv: (t, i, 0)),
    )
    compact = pl.pallas_call(
        _kernel_up,
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((T, K, tile), jnp.float32),
        interpret=_resolve_interpret(interpret),
    )(idx.astype(jnp.int32), nvalid.astype(jnp.int32), x, w)
    valid = (jnp.arange(K)[None, :] < nvalid[:, None]).astype(jnp.float32)
    compact = compact * valid[:, :, None]
    y = jnp.zeros((T, n_tiles, tile), jnp.float32)
    y = y.at[jnp.arange(T)[:, None], idx].add(compact)
    return y.reshape(T, F)


# ---------------------------------------------------------------------------
# grouped per-expert gathers (MoE serving)


def expert_tile_lists(topi, tiles_per_expert: int, k_valid=None):
    """Per-token GLOBAL tile lists from top-k expert routing.

    topi: (T, k) int32 expert ids; tiles_per_expert = F // tile. Token t's
    list is its k experts' contiguous tile ranges in routing-priority order:
    [topi[t, 0]·tpe .. topi[t, 0]·tpe + tpe − 1, topi[t, 1]·tpe .. ] —
    exactly the blocks ``expert_up_matmul``/``expert_down_matmul`` gather
    from the stacked (E, ...) expert weights.

    k_valid: optional (T,) int32 count of live expert assignments per token
    (tokens that lost capacity slots route fewer); entries past
    k_valid·tpe repeat the token's FIRST tile so padded ids stay in range
    (the kernels skip them via nvalid either way). Returns
    (idx (T, k·tpe) int32, nvalid (T,) int32)."""
    T, k = topi.shape
    tpe = tiles_per_expert
    idx = (topi.astype(jnp.int32)[:, :, None] * tpe
           + jnp.arange(tpe, dtype=jnp.int32)[None, None, :])
    idx = idx.reshape(T, k * tpe)
    if k_valid is None:
        return idx, jnp.full((T,), k * tpe, jnp.int32)
    nvalid = (k_valid.astype(jnp.int32) * tpe)
    pos = jnp.arange(k * tpe, dtype=jnp.int32)[None, :]
    idx = jnp.where(pos < nvalid[:, None], idx, idx[:, :1])
    return idx, nvalid


def _kernel_expert_up(idx_ref, nvalid_ref, x_ref, w_ref, o_ref):
    t, i = pl.program_id(0), pl.program_id(1)

    @pl.when(i < nvalid_ref[t])
    def _compute():
        o_ref[...] = jax.lax.dot_general(
            x_ref[...], w_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[None]

    @pl.when(i >= nvalid_ref[t])
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def expert_up_matmul(x, w, idx, nvalid, *, tile: int = 128, interpret=None):
    """Expert-offset up-projection gather: x (T, d), w (E, d, F) stacked
    expert weights, idx (T, K) GLOBAL tile ids over the (E, F) grid
    (``expert_tile_lists``), nvalid (T,). Returns the compact (T, K, tile)
    f32 hidden blocks — token t's block i is x[t] @ w[e, :, ft·tile:...]
    with (e, ft) = divmod(idx[t, i], F // tile); blocks past nvalid[t] are
    exactly 0. Only routed experts' weight columns are DMA'd.

    Stays compact (no scatter): the natural consumer is the activation +
    ``expert_down_matmul``, which reads the same (idx, nvalid) layout."""
    T, d = x.shape
    E, _, F = w.shape
    K = idx.shape[1]
    assert F % tile == 0
    tpe = F // tile

    grid = (T, K)
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda t, i, idx, nv: (t, 0)),
            pl.BlockSpec((1, d, tile),
                         lambda t, i, idx, nv: (idx[t, i] // tpe, 0,
                                                idx[t, i] % tpe)),
        ],
        out_specs=pl.BlockSpec((1, 1, tile), lambda t, i, idx, nv: (t, i, 0)),
    )
    return pl.pallas_call(
        _kernel_expert_up,
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((T, K, tile), jnp.float32),
        interpret=_resolve_interpret(interpret),
    )(idx.astype(jnp.int32), nvalid.astype(jnp.int32), x, w)


def _kernel_expert_down(idx_ref, nvalid_ref, c_ref, w_ref, o_ref):
    t, i = pl.program_id(0), pl.program_id(2)

    @pl.when(i == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(i < nvalid_ref[t])
    def _acc():
        o_ref[...] += jax.lax.dot_general(
            c_ref[0], w_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def expert_down_matmul(compact, w, idx, nvalid, *, block_d: int = 256,
                       interpret=None):
    """Expert-offset down-projection: compact (T, K, tile) hidden blocks
    (``expert_up_matmul`` layout, post-activation), w (E, F, d) stacked
    expert weights, idx/nvalid as in ``expert_up_matmul``. Returns (T, d)
    f32: token t accumulates block i @ w[e, ft·tile:..., :] over its
    nvalid[t] live blocks — only routed experts' weight rows are DMA'd.

    NOTE: accumulates raw block products; the caller folds each token's
    combine gate into its blocks (scale compact per expert) beforehand."""
    T, K, tile = compact.shape
    E, F, d = w.shape
    assert F % tile == 0
    tpe = F // tile
    block_d = min(block_d, d)
    assert d % block_d == 0

    grid = (T, d // block_d, K)
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, tile), lambda t, j, i, idx, nv: (t, i, 0)),
            pl.BlockSpec((1, tile, block_d),
                         lambda t, j, i, idx, nv: (idx[t, i] // tpe,
                                                   idx[t, i] % tpe, j)),
        ],
        out_specs=pl.BlockSpec((1, block_d),
                               lambda t, j, i, idx, nv: (t, j)),
    )
    return pl.pallas_call(
        _kernel_expert_down,
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((T, d), jnp.float32),
        interpret=_resolve_interpret(interpret),
    )(idx.astype(jnp.int32), nvalid.astype(jnp.int32), compact, w)
