"""Pallas TPU kernel: tile-gathered sparse matmul (the paper's row-skipping,
TPU-native — DESIGN.md §3).

``y = x @ w`` computed only over K selected F-tiles. The tile index list
arrives via *scalar prefetch*, so the weight BlockSpec's ``index_map``
dereferences ``idx[i]`` — the DMA engine fetches ONLY the active weight
tiles from HBM. This is exactly the paper's "skip loading zero rows"
(App. B Fig. 9a) expressed in the TPU memory hierarchy: HBM→VMEM traffic
and MXU work both shrink by the sparsity factor.

Grid = (D_tiles, K) with K innermost: the (T, Dt) output block stays
resident in VMEM while the K gathered tiles accumulate into it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, nvalid_ref, x_ref, w_ref, o_ref):
    j, i = pl.program_id(0), pl.program_id(1)

    @pl.when(i == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(i < nvalid_ref[0])
    def _acc():
        o_ref[...] += jax.lax.dot_general(
            x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tile", "block_d", "interpret"))
def sparse_matmul(x, w, idx, nvalid, *, tile: int = 128, block_d: int = 256,
                  interpret: bool = True):
    """x: (T, F), w: (F, D), idx: (K,) int32 tile ids, nvalid: () int32.

    Returns (T, D) f32. `interpret=True` runs the kernel body on CPU (this
    container); on TPU pass interpret=False.
    """
    T, F = x.shape
    D = w.shape[1]
    K = idx.shape[0]
    block_d = min(block_d, D)
    assert F % tile == 0 and D % block_d == 0

    grid = (D // block_d, K)
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((T, tile), lambda j, i, idx, nv: (0, idx[i])),
            pl.BlockSpec((tile, block_d), lambda j, i, idx, nv: (idx[i], j)),
        ],
        out_specs=pl.BlockSpec((T, block_d), lambda j, i, idx, nv: (0, j)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((T, D), jnp.float32),
        interpret=interpret,
    )(idx, jnp.reshape(nvalid, (1,)).astype(jnp.int32), x, w)
