"""The assigned (arch × shape) cell plan.

40 nominal cells; long_500k runs only for subquadratic archs (zamba2,
falcon-mamba) per the assignment note — pure full-attention archs skip it
(recorded as 'skipped' in EXPERIMENTS.md §Dry-run). Decode cells are run
dense AND sparse (relufied) so the roofline table shows the paper's saving.
"""
from __future__ import annotations

from typing import Dict, Iterator, List

from repro.configs import ASSIGNED, SHAPES, get_config

# per-(arch kind, shape) microbatch counts tuned so train cells fit 16 GB HBM
_TRAIN_MICROBATCHES = {
    "deepseek-67b": 16,
    "mixtral-8x22b": 8,
    "phi3.5-moe-42b-a6.6b": 8,
    "starcoder2-15b": 8,
    "qwen2-7b": 8,
    "qwen3-4b": 8,
    "zamba2-7b": 16,
    "falcon-mamba-7b": 8,
    "internvl2-1b": 2,
    "whisper-small": 2,
}

# archs whose train/prefill cells need Megatron-SP sharded residuals to fit
_SP_RESIDUALS = {"deepseek-67b", "falcon-mamba-7b", "mixtral-8x22b", "zamba2-7b"}
_SP_PREFILL = {"deepseek-67b"}
# remat policy per arch (save_ars: keep TP-collective outputs, big mem win)
_REMAT = {"deepseek-67b": "save_ars", "mixtral-8x22b": "save_ars"}

# decode-cell sparse variants: ffn tile density (paper-faithful relufied
# serving). batch=1 long-context keeps per-token sparsity; batched decode
# uses the cross-batch tile union which is denser (DESIGN.md §3).
_SPARSE_DENSITY = {"decode_32k": 0.60, "long_500k": 0.125}


def skip_reason(arch: str, shape: str) -> str:
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.subquadratic:
        return "full-attention arch: 500k ctx needs sub-quadratic attention"
    return ""


def cell_plan(multi_pod: bool = False, include_sparse: bool = True) -> List[Dict]:
    cells = []
    for arch in ASSIGNED:
        for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            if skip_reason(arch, shape):
                continue
            cell = {"arch": arch, "shape": shape, "multi_pod": multi_pod}
            if shape == "train_4k":
                cell["microbatches"] = _TRAIN_MICROBATCHES.get(arch, 4)
                if arch in _SP_RESIDUALS:
                    cell["sp"] = True
                if arch in _REMAT:
                    cell["remat"] = _REMAT[arch]
            if shape == "prefill_32k" and arch in _SP_PREFILL:
                cell["sp"] = True
            cells.append(cell)
            if include_sparse and shape in _SPARSE_DENSITY and not multi_pod:
                cells.append({**cell, "sparse": _SPARSE_DENSITY[shape]})
    return cells
