"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell and
extract the roofline terms from the compiled artifact.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all          # every cell, subprocess each
  python -m repro.launch.dryrun --all --multi-pod

Outputs one JSON per cell (stdout in single-cell mode; aggregated into
experiments/dryrun_results.jsonl with --all).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.

import argparse
import json
import re
import subprocess
import sys
import time

# v5e hardware constants (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9
ICI_BW = 50e9  # per-link

# wire-byte multiplier per collective kind (ring algorithms)
_COLL_MULT = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0}

_COLL_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([\d,]*)\][^=]*?\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "tuple": 0}


def collective_bytes(hlo_text: str):
    """Per-device wire bytes by collective kind, from the partitioned HLO."""
    seen_done = set()
    out = {k: 0.0 for k in _COLL_MULT}
    counts = {k: 0 for k in _COLL_MULT}
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        if "-done(" in m.group(0):
            continue  # started ops counted at -start
        nbytes = _DTYPE_BYTES.get(dtype, 4)
        for d in dims.split(","):
            if d.strip():
                nbytes *= int(d)
        out[kind] += nbytes * _COLL_MULT[kind]
        counts[kind] += 1
    return out, counts


def run_cell(arch: str, shape_name: str, multi_pod: bool, sparse: float = 0.0,
             microbatches: int = 0, profile: int = 0, sp: bool = False,
             ngroups: int = 1, remat: str = "minimal") -> dict:
    import jax
    from repro.configs import SHAPES, get_config
    from repro.launch import mesh as mesh_lib
    from repro.launch import specs as specs_lib
    from repro.launch import hlo_cost
    from repro.core import flops as flops_lib

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if microbatches:
        import dataclasses
        shape = dataclasses.replace(shape, num_microbatches=microbatches)
    if sparse > 0:
        cfg = cfg.replace(activation="relu", post_norm_relu=True)
        cfg = cfg.replace_sparsity(enabled=True, ffn_tile_density=sparse,
                                   input_tile_density=min(1.0, sparse * 3.0),
                                   n_groups=ngroups)
    if sp:
        cfg = cfg.replace(sp_residuals=True)
    # All cells compile in f32: the CPU backend legalizes bf16 dots through
    # f32 converts, which wrecks buffer aliasing and byte/wire counts. An
    # all-f32 module has clean aliasing and uniformly 2x-sized tensors, so
    # bytes/wire/peak are scaled by 0.5 to model the bf16 TPU deployment
    # (FLOPs are dtype-independent). Caveat: f32-native state (AdamW m/v,
    # master params, logits softmax) is undercounted by 2x under this scale —
    # it is a small fraction of traffic and makes the fit check conservative
    # at the microbatch counts we pick.
    cfg = cfg.replace(param_dtype="float32", compute_dtype="float32")
    dscale = 0.5
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)

    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "sparse": sparse, "microbatches": shape.num_microbatches,
           "sp_residuals": sp, "n_groups": ngroups, "remat": remat,
           "dtype_scale": dscale}
    t0 = time.time()
    from repro.configs.base import TrainConfig
    tc = TrainConfig(num_microbatches=shape.num_microbatches,
                     remat_policy=remat)
    with mesh:
        jitted, args = specs_lib.build_cell(cfg, shape, mesh, tc=tc)
        lowered = jitted.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        ca = compiled.cost_analysis()
        ma = compiled.memory_analysis()
        cm = hlo_cost.CostModel(compiled.as_text())

    flops = cm.flops
    bytes_acc = cm.bytes * dscale
    wire = cm.wire * dscale

    n_chips = 512 if multi_pod else 256
    rec.update(
        hlo_flops_per_chip=flops,
        hlo_bytes_per_chip=bytes_acc,
        wire_bytes_per_chip=wire,
        collectives={k: round(v * dscale) for k, v in cm.coll.items() if v},
        collective_counts={k: v for k, v in cm.coll_counts.items() if v},
        xla_cost_flops=float(ca.get("flops", 0.0)),  # raw (loop bodies x1)
        xla_cost_bytes=float(ca.get("bytes accessed", 0.0)),
        peak_bytes_per_chip=int(dscale * (
            ma.temp_size_in_bytes + ma.argument_size_in_bytes
            + ma.output_size_in_bytes - ma.alias_size_in_bytes)),
        arg_bytes=int(ma.argument_size_in_bytes * dscale),
        temp_bytes=int(ma.temp_size_in_bytes * dscale),
        t_compute=flops / PEAK_FLOPS,
        t_memory=bytes_acc / HBM_BW,
        t_collective=wire / ICI_BW,
        n_chips=n_chips,
    )
    terms = {"compute": rec["t_compute"], "memory": rec["t_memory"],
             "collective": rec["t_collective"]}
    rec["bottleneck"] = max(terms, key=terms.get)
    rec["roofline_fraction"] = round(
        max(terms.values()) / max(sum(terms.values()), 1e-30), 4)
    # analytic model flops (6ND train / 2ND serve), per chip
    try:
        mf = flops_lib.model_flops(cfg, shape)
        rec["model_flops_per_chip"] = mf / n_chips
        rec["useful_flops_ratio"] = round((mf / n_chips) / max(flops, 1.0), 4)
    except Exception as e:  # accounting is best-effort
        rec["model_flops_error"] = str(e)
    if profile:
        rec["profile"] = cm.profile(profile)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--sparse", type=float, default=0.0,
                    help="ffn tile density for the relufied sparse variant")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--sp", action="store_true",
                    help="Megatron-SP-style sharded residuals")
    ap.add_argument("--ngroups", type=int, default=1,
                    help="shard-local grouped sparse selection (16 = TP-aligned)")
    ap.add_argument("--remat", default="minimal",
                    choices=["none", "minimal", "full", "save_ars"])
    ap.add_argument("--profile", type=int, default=0)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun_results.jsonl")
    args = ap.parse_args()

    if not args.all:
        rec = run_cell(args.arch, args.shape, args.multi_pod, args.sparse,
                       args.microbatches, sp=args.sp, ngroups=args.ngroups,
                       remat=args.remat, profile=args.profile)
        print(json.dumps(rec, indent=2))
        return

    from repro.configs import ASSIGNED, get_config
    from repro.launch.cells import cell_plan

    results = []
    for cell in cell_plan(multi_pod=args.multi_pod):
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", cell["arch"], "--shape", cell["shape"]]
        if cell.get("multi_pod"):
            cmd.append("--multi-pod")
        if cell.get("sparse"):
            cmd += ["--sparse", str(cell["sparse"])]
        if cell.get("microbatches"):
            cmd += ["--microbatches", str(cell["microbatches"])]
        if cell.get("sp"):
            cmd.append("--sp")
        if cell.get("remat"):
            cmd += ["--remat", cell["remat"]]
        t0 = time.time()
        r = subprocess.run(cmd, capture_output=True, text=True)
        dt = time.time() - t0
        if r.returncode == 0:
            rec = json.loads(r.stdout[r.stdout.index("{"):])
            rec["wall_s"] = round(dt, 1)
        else:
            rec = {**cell, "error": (r.stderr or r.stdout)[-2000:],
                   "wall_s": round(dt, 1)}
        results.append(rec)
        tag = "OK " if "error" not in rec else "ERR"
        print(f"[{tag}] {rec.get('arch')}/{rec.get('shape')}"
              f"/{rec.get('mesh', 'mp' if cell.get('multi_pod') else 'sp')}"
              f" sparse={cell.get('sparse', 0)} {dt:.0f}s", flush=True)
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
