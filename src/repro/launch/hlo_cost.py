"""Trip-count-aware cost analysis of compiled (SPMD-partitioned) HLO text.

``compiled.cost_analysis()`` counts while-loop bodies once regardless of trip
count (verified empirically), which makes it useless for scan-over-layers
models. This module re-derives the roofline terms directly from
``compiled.as_text()``:

  * FLOPs: every dot op contributes 2·|out|·K, multiplied by the trip count
    of every while loop on its call path.
  * HBM bytes: post-fusion, every materialized top-level value is written
    once by its producer and read by its consumers — we count operand+output
    bytes per op with special rules (DUS touches only the updated slice;
    bitcast/tuple/GTE are free). bf16→f32 ``convert`` wrappers that the CPU
    backend inserts to legalize bf16 dots are traced through to the original
    dtype (a TPU executes these natively in bf16; the converts and their f32
    copies are CPU-only artifacts and are NOT counted).
  * Collective wire bytes: per-kind ring multipliers, loop-aware.

The per-op tallies double as the optimization profile (top ops by bytes /
flops / wire) used in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8}

_COLL_MULT = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"^([a-z0-9]+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(([^)]*)\))?.*\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")


def _split_op_line(line: str):
    """'%n = TYPE opcode(rest' -> (name, type_str, opcode, rest) or None.

    Tuple types contain '=' inside /*index=k*/ comments, so we depth-scan
    instead of regexing the type.
    """
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    s = line[m.end():]
    if s.startswith("("):  # tuple type: find matching paren
        depth, i = 0, 0
        for i, ch in enumerate(s):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        typestr, s = s[: i + 1], s[i + 1:]
    else:
        sp = s.find(" ")
        if sp < 0:
            return None
        typestr, s = s[:sp], s[sp:]
    s = s.lstrip()
    mo = re.match(r"([\w\-]+)\((.*)$", s)
    if not mo:
        return None
    return name, typestr, mo.group(1), mo.group(2)


@dataclass
class Op:
    name: str
    dtype: str
    shape: Tuple[int, ...]
    opcode: str
    rest: str
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    ops: Dict[str, Op] = field(default_factory=dict)
    order: List[str] = field(default_factory=list)
    params: Dict[str, Tuple[str, Tuple[int, ...]]] = field(default_factory=dict)


def _parse_shape(s: str) -> Tuple[str, Tuple[int, ...]]:
    m = _SHAPE_RE.match(s.strip())
    if not m:
        return ("tuple", ())
    dims = tuple(int(d) for d in m.group(2).split(",") if d.strip())
    return m.group(1), dims


def _nbytes(dtype: str, shape: Tuple[int, ...]) -> float:
    b = _DTYPE_BYTES.get(dtype, 4)
    n = 1
    for d in shape:
        n *= d
    return float(b * n)


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_RE.match(stripped)
            if m and stripped.endswith("{"):
                cur = Computation(m.group(1))
                # parse params: "p0: f32[8,128], p1: bf16[...]"
                if m.group(2):
                    for part in m.group(2).split(","):
                        if ":" in part:
                            pname, ptype = part.split(":", 1)
                            dt, sh = _parse_shape(ptype)
                            cur.params[pname.strip().lstrip("%")] = (dt, sh)
                continue
        else:
            if stripped == "}":
                comps[cur.name] = cur
                cur = None
                continue
            parsed = _split_op_line(line)
            if parsed:
                name, typestr, opcode, rest = parsed
                dt, sh = _parse_shape(typestr.lstrip("("))
                op = Op(name, dt, sh, opcode, rest)
                if dt == "tuple" or typestr.startswith("("):
                    op.dtype = "tuple"
                    op.rest = typestr + " " + rest  # keep type text for tuple sizing
                # operands: %refs before attribute section
                body = rest.split("), ")[0] if "), " in rest else rest
                op.operands = _OPERAND_RE.findall(body)
                cur.ops[name] = op
                cur.order.append(name)
    return comps


def _trip_count(cond: Computation) -> int:
    """Max integer constant in the loop condition (iteration bound)."""
    best = 1
    for op in cond.ops.values():
        if op.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", "constant(" + op.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "iota", "after-all", "partition-id", "replica-id", "reshape",
             "convert", "copy-start", "copy-done"}


def _is_convert_fusion(name: str) -> bool:
    return name.startswith("wrapped_convert") or name.startswith("convert_bitcast")


class CostModel:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        entry = None
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = re.search(r"ENTRY\s+%?([\w.\-]+)", line)
                if m:
                    entry = m.group(1)
                break
        if entry is None:  # fall back: computation referenced by no one
            called = set()
            for c in self.comps.values():
                for op in c.ops.values():
                    for m in re.finditer(r"(?:calls|to_apply|body|condition|branch_computations)=\{?%?([\w.\-]+)", op.rest):
                        called.add(m.group(1))
            cands = [n for n in self.comps if n not in called]
            entry = cands[-1] if cands else next(iter(self.comps))
        self.entry = entry
        self.flops = 0.0
        self.bytes = 0.0
        self.wire = 0.0
        self.coll = defaultdict(float)
        self.coll_counts = defaultdict(int)
        self.dots: List[Tuple[float, str, Tuple[int, ...], str]] = []  # (scale, rhs dtype, rhs shape, op_name)
        self.top_ops: List[Tuple[float, float, str, str]] = []  # (bytes, flops, opcode, meta)
        self._walk(self.comps[self.entry], 1.0)
        self.top_ops.sort(reverse=True)

    # -- helpers -----------------------------------------------------------
    def _true_bytes(self, comp: Computation, ref: str, depth: int = 0) -> float:
        """Bytes of an operand, tracing through CPU bf16->f32 convert wrappers."""
        op = comp.ops.get(ref)
        if op is None:
            if ref in comp.params:
                dt, sh = comp.params[ref]
                return _nbytes(dt, sh)
            return 0.0
        if depth < 3 and op.opcode in ("convert", "copy", "bitcast", "reshape"):
            if op.operands:
                return self._true_bytes(comp, op.operands[0], depth + 1)
        if depth < 3 and op.opcode == "fusion" and _is_convert_fusion(op.name):
            return sum(self._true_bytes(comp, o, depth + 1) for o in op.operands)
        return _nbytes(op.dtype, op.shape)

    def _operand_shape(self, comp: Computation, ref: str):
        op = comp.ops.get(ref)
        if op is not None:
            return op.dtype, op.shape
        if ref in comp.params:
            return comp.params[ref]
        return ("f32", ())

    def _fusion_bytes(self, comp: Computation, op: Op) -> float:
        """HBM traffic of a fusion, classified by what it actually does.

        Scan xs-slicing / cache-slice extraction fusions touch only the slice
        (2x output); token-write DUS fusions touch only the update (in-place
        on the donated buffer); reductions read their full inputs; generic
        elementwise fusions read each operand at most output-size (larger
        operands are in-place-selected loop carries).
        """
        name = op.name
        out_b = _nbytes(op.dtype, op.shape)
        if name.startswith(("dynamic-slice", "slice")):
            return 0.0  # fused into consumers on TPU (consumer counts the read)
        if name.startswith(("copy", "transpose_copy", "bitcast")):
            return 2.0 * out_b
        if name.startswith("gather"):
            return out_b
        if "dynamic-update-slice" in name:
            mc = re.search(r"calls=%?([\w.\-]+)", op.rest)
            called = self.comps.get(mc.group(1)) if mc else None
            upd = 0.0
            if called:
                for o2 in called.ops.values():
                    if o2.opcode == "dynamic-update-slice" and len(o2.operands) > 1:
                        dt, sh = self._operand_shape(called, o2.operands[1])
                        upd += _nbytes(dt, sh)
            return 2.0 * upd if upd else 2.0 * out_b
        if name.startswith(("reduce", "wrapped_reduce")):
            return out_b + sum(self._true_bytes(comp, o) for o in op.operands)
        in_b = 0.0
        for o in op.operands:
            tb = self._true_bytes(comp, o)
            in_b += min(tb, out_b) if out_b > 0 else tb
        return out_b + in_b

    def _fusion_dot_flops(self, comp: Computation, scale: float = 1.0) -> float:
        f = 0.0
        for op in comp.ops.values():
            if op.opcode == "dot":
                mcon = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
                lhs_dt, lhs_sh = self._operand_shape(comp, op.operands[0]) \
                    if op.operands else ("f32", ())
                k = 1
                if mcon and lhs_sh:
                    for d in mcon.group(1).split(","):
                        if d.strip():
                            k *= lhs_sh[int(d)]
                out_elems = 1
                for d in op.shape:
                    out_elems *= d
                f += 2.0 * out_elems * k
                self._record_dot(comp, op, scale)
        return f

    def _record_dot(self, comp: Computation, op: Op, scale: float) -> None:
        """Log one (trip-scale, rhs dtype, rhs shape, op_name) dot
        occurrence for ``dot_weight_bytes`` — fusion-wrapped and top-level
        dots alike."""
        if len(op.operands) > 1:
            dt, sh = self._operand_shape(comp, op.operands[1])
            meta = re.search(r'op_name="([^"]*)"', op.rest)
            self.dots.append((scale, dt, tuple(sh),
                              meta.group(1) if meta else op.name))

    def dot_weight_bytes(self, rhs_shape, name_re: Optional[str] = None,
                         exclude_re: Optional[str] = None) -> float:
        """Trip-scaled HBM bytes of every dot whose RHS matches
        ``rhs_shape`` — e.g. ``(d_ff, d_model)`` counts the FFN
        down-projection weight reads of a decode step (the weight is read
        once per dot execution; batch rows share it). ``name_re`` /
        ``exclude_re`` filter on the dot's op_name metadata: jnp einsums
        carry their spec in the label (``bshd,hde->bse``) while plain
        matmuls do not, so ``exclude_re="->"`` separates an FFN
        down-projection from an attention output projection that happens
        to share its weight shape. Used by the roofline gate to pin the
        analytic ``weight_io_bytes_per_step`` accounting to what the
        compiled graph actually reads (launch/roofline.py --check,
        tests/test_hlo_cost.py)."""
        want = tuple(rhs_shape)
        total = 0.0
        for scale, dt, sh, name in self.dots:
            if sh != want:
                continue
            if name_re is not None and not re.search(name_re, name):
                continue
            if exclude_re is not None and re.search(exclude_re, name):
                continue
            total += scale * _nbytes(dt, sh)
        return total

    # -- walk --------------------------------------------------------------
    def _walk(self, comp: Computation, scale: float) -> None:
        for name in comp.order:
            op = comp.ops[name]
            oc = op.opcode
            if oc == "while":
                m = re.search(r"condition=%?([\w.\-]+)", op.rest)
                b = re.search(r"body=%?([\w.\-]+)", op.rest)
                trips = _trip_count(self.comps[m.group(1)]) if m and m.group(1) in self.comps else 1
                if b and b.group(1) in self.comps:
                    self._walk(self.comps[b.group(1)], scale * max(1, trips))
                continue
            if oc in ("call", "async-start"):
                m = re.search(r"to_apply=%?([\w.\-]+)", op.rest)
                if m and m.group(1) in self.comps:
                    self._walk(self.comps[m.group(1)], scale)
                continue
            if oc == "conditional":
                for m in re.finditer(r"%([\w.\-]+)", op.rest.split("),")[-1]):
                    if m.group(1) in self.comps:
                        self._walk(self.comps[m.group(1)], scale)
                continue
            if oc in _FREE_OPS:
                continue

            base = oc.replace("-start", "").replace("-done", "")
            if base in _COLL_MULT:
                if oc.endswith("-done"):
                    continue
                out_b = _nbytes(op.dtype, op.shape)
                # tuple results: sum parts
                if op.dtype == "tuple":
                    out_b = sum(_nbytes(*_parse_shape(p))
                                for p in re.findall(r"[a-z0-9]+\[[\d,]*\]", op.rest.split(")")[0]))
                w = out_b * _COLL_MULT[base] * scale
                self.wire += w
                self.coll[base] += w
                self.coll_counts[base] += int(scale)
                self.bytes += 2 * out_b * scale  # local read+write
                continue

            f = 0.0
            if oc == "dot":
                lhs_dt, lhs_sh = self._operand_shape(comp, op.operands[0]) if op.operands else ("f32", ())
                mcon = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
                k = 1
                if mcon and lhs_sh:
                    for d in mcon.group(1).split(","):
                        if d.strip():
                            k *= lhs_sh[int(d)]
                out_elems = 1
                for d in op.shape:
                    out_elems *= d
                f = 2.0 * out_elems * k
                self._record_dot(comp, op, scale)
            elif oc == "convolution":
                out_elems = 1
                for d in op.shape:
                    out_elems *= d
                _, lhs_sh = self._operand_shape(comp, op.operands[0]) if op.operands else ("f32", ())
                _, rhs_sh = self._operand_shape(comp, op.operands[1]) if len(op.operands) > 1 else ("f32", ())
                kernel = 1
                for d in rhs_sh[:-1] if rhs_sh else ():
                    kernel *= d
                f = 2.0 * out_elems * max(1, kernel)

            # bytes
            if oc == "dynamic-update-slice":
                upd = self._true_bytes(comp, op.operands[1]) if len(op.operands) > 1 else 0.0
                b = 2.0 * upd
            elif oc in ("dynamic-slice", "slice"):
                # contiguous slices fuse into their consumers on TPU; the
                # consumer's operand accounting counts the single read
                b = 0.0
            elif oc == "gather":
                b = _nbytes(op.dtype, op.shape)  # random-access read
            elif oc == "fusion" and _is_convert_fusion(op.name):
                b = 0.0  # CPU bf16-legalization artifact; free on TPU
            elif oc == "copy":
                # loop-carried buffer copy: count at the original dtype
                b = 2.0 * (self._true_bytes(comp, op.operands[0])
                           if op.operands else _nbytes(op.dtype, op.shape))
            elif oc == "fusion":
                b = self._fusion_bytes(comp, op)
                mc = re.search(r"calls=%?([\w.\-]+)", op.rest)
                if mc and mc.group(1) in self.comps:  # dots hidden in fusions
                    f += self._fusion_dot_flops(self.comps[mc.group(1)],
                                                scale)
            else:
                out_b = _nbytes(op.dtype, op.shape)
                in_b = sum(self._true_bytes(comp, o) for o in op.operands)
                b = out_b + in_b

            self.flops += f * scale
            self.bytes += b * scale
            meta = re.search(r'op_name="([^"]*)"', op.rest)
            self.top_ops.append((b * scale, f * scale, oc,
                                 (meta.group(1) if meta else name)[:120]))

    def summary(self) -> Dict[str, float]:
        return {"flops": self.flops, "bytes": self.bytes, "wire": self.wire,
                "collectives": dict(self.coll),
                "collective_counts": dict(self.coll_counts)}

    def profile(self, n: int = 20) -> List[str]:
        out = []
        for b, f, oc, meta in self.top_ops[:n]:
            out.append(f"{b/1e6:10.1f} MB {f/1e9:9.2f} GF  {oc:22s} {meta}")
        return out


def analyze(text: str) -> Dict[str, float]:
    return CostModel(text).summary()
