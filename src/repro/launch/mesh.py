"""Production mesh builders.

Functions, not module-level constants — importing this module never touches
jax device state. The dry-run entry point (launch/dryrun.py) sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before importing jax;
smoke tests and benchmarks see the single real CPU device.

``_make_mesh`` papers over the jax version split: explicit axis types
(jax.sharding.AxisType) exist only on jax >= 0.6; on the pinned 0.4.x line
meshes are implicitly Auto, which is exactly what every caller here wants.
"""
from __future__ import annotations

import warnings

import jax


def _make_mesh(shape, axes):
    if hasattr(jax.sharding, "AxisType"):  # jax >= 0.6: be explicit
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,)
                             * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, *, strict: bool = False):
    """A (data, model) mesh over whatever devices exist (tests / examples /
    the serving engine's --mesh flag).

    When fewer devices exist than ``data * model`` the shape is clamped —
    historically *silently*, so ``--mesh 1,8`` on a 1-device host quietly
    served single-device with no TP at all. Now a degenerate clamp WARNS,
    and ``strict=True`` (the launcher's serving path) raises instead: an
    unsatisfiable mesh shape is an operator error, not a fallback.
    """
    n = len(jax.devices())
    data_eff = min(data, n)
    model_eff = min(model, max(1, n // data_eff))
    if (data_eff, model_eff) != (data, model):
        msg = (f"mesh shape ({data}, {model}) needs {data * model} devices "
               f"but only {n} exist; degenerating to "
               f"({data_eff}, {model_eff})")
        if strict:
            raise ValueError(msg)
        warnings.warn(msg, stacklevel=2)
    return _make_mesh((data_eff, model_eff), ("data", "model"))
