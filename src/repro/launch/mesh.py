"""Production mesh builders.

Functions, not module-level constants — importing this module never touches
jax device state. The dry-run entry point (launch/dryrun.py) sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before importing jax;
smoke tests and benchmarks see the single real CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """A small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(1, n // data))
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
