"""Roofline reports: dry-run tables AND the serving bytes-per-step gate.

Two entry points:

  python -m repro.launch.roofline experiments/dryrun_results.jsonl [--md]
      The original dry-run table (per-cell three-term roofline, bottleneck,
      MODEL_FLOPS ratio, memory fit) over launch/dryrun.py JSONL records.

  python -m repro.launch.roofline --serving [--check [--tol 0.15]]
      The SERVING decode roofline: runs the tiny continuous-batching engine
      with the fused Pallas kernels forced on (interpret mode on CPU) in
      autoregressive and predictor modes, and reports, per mode, three
      independent figures for FFN weight HBM bytes per decode step:

        measured — the engine's own density-accounted
                   ``weight_io_bytes_per_step()`` (telemetry recorded
                   in-graph while serving real requests);
        modeled  — the fused kernel's BlockSpec geometry
                   (``fused_decode.modeled_weight_bytes``: gathered tiles x
                   projections x tile footprint) at the measured density;
        hlo      — trip-count-scaled down-projection dot reads counted in
                   the FROZEN XLA decode step's compiled HLO
                   (``hlo_cost.CostModel.dot_weight_bytes``), the
                   ground-truth anchor for what a dense step reads.

      --check turns the report into a CI regression gate: modeled/measured
      must agree within --tol (default 15%), and the HLO count must match
      the engine's dense accounting — exits nonzero on violation
      (.github/workflows/ci.yml bench-smoke).

Hardware model (v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s ICI.
Terms (per chip, per step):
  compute    = HLO_FLOPs / peak          (trip-count-corrected, hlo_cost.py)
  memory     = HLO_bytes / HBM_bw        (post-fusion op traffic, bf16-scaled)
  collective = wire_bytes / ICI_bw       (ring multipliers, loop-aware)
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

HBM_GB = 16.0
HBM_BW = 819e9  # v5e HBM bytes/s


def load(path: str) -> List[Dict]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def fmt_t(x: float) -> str:
    if x >= 1.0:
        return f"{x:8.2f}s "
    if x >= 1e-3:
        return f"{x * 1e3:8.2f}ms"
    return f"{x * 1e6:8.1f}us"


def table(recs: List[Dict], md: bool = False) -> str:
    rows = []
    hdr = ("cell", "sparse", "t_compute", "t_memory", "t_coll", "bound",
           "MF/HLO", "peak_GB", "fit", "step_est")
    rows.append(hdr)
    for r in recs:
        if "error" in r:
            rows.append((f"{r['arch']}/{r['shape']}", str(r.get("sparse", 0)),
                         "ERROR", "", "", "", "", "", "", ""))
            continue
        peak = r["peak_bytes_per_chip"] / 1e9
        cell = f"{r['arch']}/{r['shape']}"
        step = max(r["t_compute"], r["t_memory"], r["t_collective"])
        rows.append((
            cell, str(r.get("sparse", 0) or "-"),
            fmt_t(r["t_compute"]), fmt_t(r["t_memory"]),
            fmt_t(r["t_collective"]), r["bottleneck"][:4],
            f"{r.get('useful_flops_ratio', 0):.2f}",
            f"{peak:.1f}", "Y" if peak <= HBM_GB else "OVER",
            fmt_t(step),
        ))
    widths = [max(len(str(row[i])) for row in rows) for i in range(len(hdr))]
    sep = " | " if md else "  "
    lines = []
    for j, row in enumerate(rows):
        line = sep.join(str(c).ljust(w) for c, w in zip(row, widths))
        lines.append(("| " + line + " |") if md else line)
        if md and j == 0:
            lines.append("|" + "|".join("-" * (w + 2) for w in widths) + "|")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# serving bytes-per-step roofline (fused-kernel gate)


def kernel_bytes_per_step(engine) -> float:
    """Per-device FFN weight HBM bytes one decode step reads through the
    fused kernel path, modeled PURELY from the kernel's BlockSpec geometry
    (``fused_decode.modeled_weight_bytes``) at the engine's measured
    density: mean gathered tiles/step x projections touching each tile x
    the (tile x d_model) tile footprint x layers, split by the FFN's
    effective TP. Independent of the engine's own accounting — the gate
    compares the two."""
    import jax.numpy as jnp

    from repro.kernels import fused_decode as kfd
    from repro.models import common as cm

    cfg = engine.cfg
    itemsize = jnp.dtype(cfg.compute_dtype).itemsize
    n_proj = 3 if cfg.ffn_kind == "glu" else 2
    if engine.predictor is not None:
        tile, n_tiles = engine.predictor.tile, engine.predictor.n_tiles
    else:
        tile = cm.ffn_gather_tile(cfg)
        n_tiles = cfg.d_ff // tile
    dens = (1.0 if not engine._dens_n
            else engine._dens_sum / engine._dens_n)
    per_layer = kfd.modeled_weight_bytes(dens * n_tiles, tile, cfg.d_model,
                                         itemsize, n_proj)
    return cfg.n_layers * per_layer / engine.ffn_tp


def hlo_decode_ffn_bytes(engine, n_proj: int = 1) -> float:
    """Down-projection weight bytes a compiled FROZEN decode step reads,
    counted in its optimized HLO: trip-count-scaled dots whose RHS is the
    (d_ff, d_model) down-projection weight (``CostModel.dot_weight_bytes``
    — the layer scan's while trip count multiplies the single textual dot
    by n_layers). ``n_proj`` scales the one counted projection to the
    engine mode's skippable scope (the up/gate dots have a transposed
    shape, so the (d_ff, d_model) count is unambiguous)."""
    import jax.numpy as jnp

    from repro.launch.hlo_cost import CostModel

    cfg = engine.cfg
    n = engine.scheduler.n_slots
    nb = engine.scheduler.max_blocks_per_seq
    zi = jnp.zeros((n,), jnp.int32)
    args = (engine.params, engine.pages,
            jnp.zeros((n, nb), jnp.int32), zi, zi, engine.masks,
            jnp.ones((n,), bool), jnp.zeros((n,), jnp.float32), zi,
            jnp.zeros((n,), jnp.float32), jnp.zeros((n, 2), jnp.uint32), zi)
    text = engine._decode.lower(*args).compile().as_text()
    cm_ = CostModel(text)
    # the down-projection is a plain matmul; einsum-labeled dots (op_name
    # carries the spec, e.g. the attention output projection "bshd,hde->")
    # can collide with its (d_ff, d_model) weight shape and are excluded
    return n_proj * cm_.dot_weight_bytes((cfg.d_ff, cfg.d_model),
                                         exclude_re="->")


def serving_records(name: str = "tiny-relu", max_new: int = 8) -> List[Dict]:
    """Serve a few requests through the tiny engine with fast kernels
    forced on (interpret mode on CPU), in autoregressive and predictor
    modes; return one record per mode with the three bytes-per-step
    figures (measured / modeled / hlo) and the v5e memory-roofline time."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import registry
    from repro.predictor import calibrate_from_config
    from repro.serving import ContinuousBatchingEngine

    cfg = get_config(name).replace(compute_dtype="float32")
    fam = registry.get_family(cfg)
    params = fam.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [np.random.RandomState(s).randint(
                   0, cfg.vocab_size, ln).astype(np.int32)
               for s, ln in ((1, 9), (2, 5), (3, 13))]

    def run(cfg_, fast, **kw):
        eng = ContinuousBatchingEngine(cfg_, params, n_slots=2, block_size=8,
                                       max_blocks_per_seq=6,
                                       fast_kernels=fast, **kw)
        for p in prompts:
            eng.submit(p, max_new)
        eng.run()
        return eng

    recs = []
    # autoregressive: kernel path gathers gate/up AND down over the γ-mask
    # tile list; HLO anchor comes from the frozen engine (same accounting
    # scope only at density 1.0 — which the tiny config serves at)
    eng = run(cfg, True)
    frozen = run(cfg, False)
    n_proj = 3 if cfg.ffn_kind == "glu" else 2
    recs.append(_serve_record("ar", name, eng,
                              hlo=hlo_decode_ffn_bytes(frozen, n_proj)))
    # predictor: density < 1 — modeled bytes follow the measured tile
    # density exactly (nvalid is tile-granular); dense HLO anchor scaled
    # by the measured density
    cfgp = cfg.replace_sparsity(predictor="sign", predictor_recall=1.0)
    calib = {"tokens": jax.random.randint(jax.random.PRNGKey(7), (4, 32),
                                          0, cfgp.vocab_size)}
    pred = calibrate_from_config(params, cfgp, calib, tile=1)
    eng = run(cfgp, True, predictor=pred)
    dens = eng.predictor_density()
    recs.append(_serve_record("predictor", name, eng,
                              hlo=dens * hlo_decode_ffn_bytes(frozen,
                                                              n_proj)))
    return recs


def _serve_record(mode: str, name: str, eng, hlo: float) -> Dict:
    measured = eng.weight_io_bytes_per_step()
    modeled = kernel_bytes_per_step(eng)
    dens = (1.0 if not eng._dens_n else eng._dens_sum / eng._dens_n)
    return {"mode": mode, "config": name, "density": dens,
            "measured_bytes": measured, "modeled_bytes": modeled,
            "hlo_bytes": hlo,
            "ratio": modeled / measured if measured else float("inf"),
            "t_memory_v5e": modeled / HBM_BW}


def serving_table(recs: List[Dict]) -> str:
    hdr = ("mode", "config", "density", "measured", "modeled", "hlo",
           "model/meas", "t_mem(v5e)")
    rows = [hdr]
    for r in recs:
        rows.append((r["mode"], r["config"], f"{r['density']:.3f}",
                     f"{r['measured_bytes']:.0f}",
                     f"{r['modeled_bytes']:.0f}", f"{r['hlo_bytes']:.0f}",
                     f"{r['ratio']:.3f}", fmt_t(r["t_memory_v5e"])))
    widths = [max(len(str(row[i])) for row in rows) for i in range(len(hdr))]
    return "\n".join("  ".join(str(c).ljust(w) for c, w in zip(row, widths))
                     for row in rows)


def check_serving(recs: List[Dict], tol: float = 0.15) -> List[str]:
    """The CI gate: kernel-modeled bytes/step within ``tol`` of the
    engine's measured accounting, and the dense-anchored HLO count within
    ``tol`` of measured. Returns violation strings (empty = pass)."""
    out = []
    for r in recs:
        if abs(r["ratio"] - 1.0) > tol:
            out.append(f"{r['mode']}: kernel-modeled bytes/step "
                       f"{r['modeled_bytes']:.0f} vs measured "
                       f"{r['measured_bytes']:.0f} (ratio {r['ratio']:.3f} "
                       f"outside 1±{tol})")
        hr = (r["hlo_bytes"] / r["measured_bytes"] if r["measured_bytes"]
              else float("inf"))
        if abs(hr - 1.0) > tol:
            out.append(f"{r['mode']}: HLO-counted bytes/step "
                       f"{r['hlo_bytes']:.0f} vs measured "
                       f"{r['measured_bytes']:.0f} (ratio {hr:.3f} "
                       f"outside 1±{tol})")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("path", nargs="?", default="experiments/dryrun_results.jsonl")
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--serving", action="store_true",
                    help="serving decode bytes-per-step roofline "
                         "(fused kernels forced on)")
    ap.add_argument("--check", action="store_true",
                    help="with --serving: exit nonzero unless modeled / "
                         "measured / HLO bytes-per-step agree within --tol")
    ap.add_argument("--tol", type=float, default=0.15)
    ap.add_argument("--config", default="tiny-relu")
    args = ap.parse_args()
    if args.serving or args.check:
        recs = serving_records(args.config)
        print(serving_table(recs))
        if args.check:
            bad = check_serving(recs, args.tol)
            for v in bad:
                print("VIOLATION:", v, file=sys.stderr)
            if bad:
                sys.exit(1)
            print(f"roofline check OK (tol {args.tol})")
        return
    recs = load(args.path)
    print(table(recs, md=args.md))
    bad = [r for r in recs if "error" in r]
    over = [r for r in recs if "error" not in r
            and r["peak_bytes_per_chip"] > HBM_GB * 1e9]
    print(f"\n{len(recs)} cells: {len(recs) - len(bad)} compiled, "
          f"{len(bad)} errors, {len(over)} over {HBM_GB:.0f} GB HBM",
          file=sys.stderr)


if __name__ == "__main__":
    main()
