"""Roofline report generator: reads the dry-run JSONL records and emits the
EXPERIMENTS.md tables (per-cell three-term roofline, bottleneck, MODEL_FLOPS
ratio, memory fit).

  python -m repro.launch.roofline experiments/dryrun_results.jsonl [--md]

Hardware model (v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s ICI.
Terms (per chip, per step):
  compute    = HLO_FLOPs / peak          (trip-count-corrected, hlo_cost.py)
  memory     = HLO_bytes / HBM_bw        (post-fusion op traffic, bf16-scaled)
  collective = wire_bytes / ICI_bw       (ring multipliers, loop-aware)
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

HBM_GB = 16.0


def load(path: str) -> List[Dict]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def fmt_t(x: float) -> str:
    if x >= 1.0:
        return f"{x:8.2f}s "
    if x >= 1e-3:
        return f"{x * 1e3:8.2f}ms"
    return f"{x * 1e6:8.1f}us"


def table(recs: List[Dict], md: bool = False) -> str:
    rows = []
    hdr = ("cell", "sparse", "t_compute", "t_memory", "t_coll", "bound",
           "MF/HLO", "peak_GB", "fit", "step_est")
    rows.append(hdr)
    for r in recs:
        if "error" in r:
            rows.append((f"{r['arch']}/{r['shape']}", str(r.get("sparse", 0)),
                         "ERROR", "", "", "", "", "", "", ""))
            continue
        peak = r["peak_bytes_per_chip"] / 1e9
        cell = f"{r['arch']}/{r['shape']}"
        step = max(r["t_compute"], r["t_memory"], r["t_collective"])
        rows.append((
            cell, str(r.get("sparse", 0) or "-"),
            fmt_t(r["t_compute"]), fmt_t(r["t_memory"]),
            fmt_t(r["t_collective"]), r["bottleneck"][:4],
            f"{r.get('useful_flops_ratio', 0):.2f}",
            f"{peak:.1f}", "Y" if peak <= HBM_GB else "OVER",
            fmt_t(step),
        ))
    widths = [max(len(str(row[i])) for row in rows) for i in range(len(hdr))]
    sep = " | " if md else "  "
    lines = []
    for j, row in enumerate(rows):
        line = sep.join(str(c).ljust(w) for c, w in zip(row, widths))
        lines.append(("| " + line + " |") if md else line)
        if md and j == 0:
            lines.append("|" + "|".join("-" * (w + 2) for w in widths) + "|")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("path", nargs="?", default="experiments/dryrun_results.jsonl")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    recs = load(args.path)
    print(table(recs, md=args.md))
    bad = [r for r in recs if "error" in r]
    over = [r for r in recs if "error" not in r
            and r["peak_bytes_per_chip"] > HBM_GB * 1e9]
    print(f"\n{len(recs)} cells: {len(recs) - len(bad)} compiled, "
          f"{len(bad)} errors, {len(over)} over {HBM_GB:.0f} GB HBM",
          file=sys.stderr)


if __name__ == "__main__":
    main()
