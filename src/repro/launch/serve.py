"""Production serving launcher: prefill + decode steps on the pod mesh, with
the paper's sparse-inference config (relufied weights, tile capacities).

  python -m repro.launch.serve --arch deepseek-67b --shape decode_32k \
      --sparse-density 0.25 [--multi-pod]
  python -m repro.launch.serve --arch qwen3-4b --smoke --tokens 32   # CPU
  python -m repro.launch.serve --arch qwen3-4b --smoke --continuous  # CB path
  python -m repro.launch.serve --arch qwen3-4b --smoke --speculative # spec
  python -m repro.launch.serve --arch qwen3-4b --smoke \
      --predictor sign --target-recall 0.99                # predictor mode
  python -m repro.launch.serve --arch qwen3-4b --smoke \
      --prefill-chunk 16 --prefix-cache   # chunked prefill + prefix reuse
  python -m repro.launch.serve --arch qwen3-4b --smoke --continuous \
      --mesh 1,8    # tensor-parallel sharded serving on a (data,model) mesh
  python -m repro.launch.serve --arch mixtral-8x22b --smoke --continuous \
      # MoE through the engine: routed experts as structured sparsity
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--sparse-density", type=float, default=0.0,
                    help="FFN tile density; 0 = dense serving")
    ap.add_argument("--reuse-window", type=int, default=0)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--continuous", action="store_true",
                    help="smoke the continuous-batching paged-cache engine "
                         "(any family declaring the 'paged_decode' serving "
                         "capability: dense + moe)")
    ap.add_argument("--speculative", action="store_true",
                    help="smoke the engine's speculative mode: a 1-layer "
                         "draft proposes γ tokens per slot, the target "
                         "verifies each window in one forward (implies "
                         "--continuous)")
    ap.add_argument("--gamma", type=int, default=4,
                    help="draft length γ for --speculative")
    ap.add_argument("--predictor", choices=["none", "sign", "lowrank"],
                    default="none",
                    help="predictor serving mode: skip up+down projection "
                         "weight reads for neurons a calibrated activity "
                         "predictor marks inactive (implies --continuous; "
                         "relufies soft-activation archs first)")
    ap.add_argument("--target-recall", type=float, default=0.99,
                    help="calibration recall target for --predictor")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill: admit prompts one fixed-size "
                         "chunk per engine step, interleaved with decode "
                         "(0 = whole-prompt prefill; implies --continuous)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="reuse KV blocks across requests sharing a "
                         "block-aligned prompt prefix (the smoke workload "
                         "then shares a system prompt; implies "
                         "--prefill-chunk 16 unless set)")
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="serve the continuous-batching engine on a "
                         "(data, model) device mesh: weights TP-sharded "
                         "over 'model' via the serve-mode rules, paged KV "
                         "pool blocks over 'data' (implies --continuous; "
                         "RAISES if the shape needs more devices than "
                         "exist — no silent single-device fallback)")
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()
    if args.prefix_cache and args.prefill_chunk == 0:
        args.prefill_chunk = 16
    if (args.speculative or args.predictor != "none" or args.prefill_chunk
            or args.mesh):
        args.continuous = True
    mesh_shape = None
    if args.mesh:
        try:
            mesh_shape = tuple(int(x) for x in args.mesh.split(","))
            assert len(mesh_shape) == 2 and min(mesh_shape) >= 1
        except (ValueError, AssertionError):
            ap.error(f"--mesh expects DATA,MODEL (two positive ints), "
                     f"got {args.mesh!r}")
    if args.speculative and args.predictor != "none":
        ap.error("--speculative and --predictor are mutually exclusive "
                 "serving modes")
    if args.continuous and not args.smoke:
        ap.error("--continuous requires --smoke (the pod-mesh launcher "
                 "lowers the legacy decode cell)")

    import jax
    import jax.numpy as jnp

    from repro.configs import SHAPES, get_config, smoke_config
    from repro.core import relufication
    from repro.models import registry

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.sparse_density > 0:
        cfg = relufication.relufy_stage2(cfg)
        cfg = relufication.enable_sparse_serving(
            cfg, args.sparse_density, min(1.0, args.sparse_density * 3),
            reuse_window=args.reuse_window)

    if args.smoke and args.continuous:
        import numpy as np
        from repro.serving import ContinuousBatchingEngine, EngineConfig
        from repro.serving.spec_decode import spec_metrics
        if args.predictor != "none":
            from repro.core.activations import is_sparse_activation
            if not is_sparse_activation(cfg.activation):
                cfg = relufication.relufy_stage1(cfg)
            cfg = cfg.replace_sparsity(predictor=args.predictor,
                                       predictor_recall=args.target_recall)
        fam = registry.get_family(cfg)
        params = fam.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.RandomState(1)
        lengths = (8, 13, 21)
        if args.prefix_cache:
            # shared system prompt (two full 16-token blocks): request 1
            # prefills it cold, every later admission maps it from the trie
            system = rng.randint(0, cfg.vocab_size, 32)
            prompts = [np.concatenate([system,
                                       rng.randint(0, cfg.vocab_size, s)])
                       for s in lengths]
        else:
            prompts = [rng.randint(0, cfg.vocab_size, s) for s in lengths]
        max_bps = -(-(max(len(p) for p in prompts) + args.tokens) // 16)
        spec_kw = {}
        if args.prefill_chunk:
            spec_kw.update(prefill_chunk=args.prefill_chunk,
                           prefix_cache=args.prefix_cache)
        if args.speculative:
            dcfg = cfg.replace(name=f"{cfg.name}-draft", n_layers=1)
            spec_kw.update(draft_cfg=dcfg,
                           draft_params=fam.init_params(
                               jax.random.PRNGKey(2), dcfg),
                           gamma=args.gamma)
        if args.predictor != "none":
            from repro.predictor import calibrate_from_config
            calib = {"tokens": jax.random.randint(
                jax.random.PRNGKey(7), (4, 32), 0, cfg.vocab_size)}
            # tile=1 = exact row-skipping: observable savings on the tiny
            # smoke models (128-wide tiles are never all-zero at this size)
            spec_kw.update(predictor=calibrate_from_config(
                params, cfg, calib, tile=1))
        if mesh_shape is not None:
            from repro.launch.mesh import make_host_mesh
            # strict: an unsatisfiable --mesh shape is an operator error —
            # raise instead of quietly serving single-device
            spec_kw["mesh"] = make_host_mesh(*mesh_shape, strict=True)
        eng = ContinuousBatchingEngine(cfg, params, config=EngineConfig(
            n_slots=2, block_size=16, max_blocks_per_seq=max_bps,
            track_sparsity=True, **spec_kw))
        uids = [eng.submit(p, args.tokens, reuse_window=args.reuse_window)
                for p in prompts]
        res = eng.run()
        aggs = [eng.trackers[u].aggregated_sparsity() for u in uids]
        print(f"continuous batching served {len(uids)} requests "
              f"({sum(len(res[u].tokens) for u in uids)} tokens); "
              f"per-request aggregated FFN sparsity "
              f"{', '.join(f'{a:.3f}' for a in aggs)}; "
              f"weight I/O saved {eng.weight_io_saved():.1%}")
        if cfg.n_experts:
            print(f"moe routing: {cfg.top_k}/{cfg.n_experts} experts per "
                  f"token (expert I/O fraction "
                  f"{eng.expert_io_fraction():.3f}); activated-expert FFN "
                  f"weight read {eng.weight_io_bytes_per_step():.0f} B/step")
        if mesh_shape is not None:
            print(f"sharded serving on mesh {dict(eng.mesh.shape)}: "
                  f"TP={eng.tp}; per-device FFN weight read "
                  f"{eng.weight_io_bytes_per_step():.0f} B/step "
                  f"(= {eng.weight_io_bytes_per_step(per_device=False):.0f} "
                  f"B total x 1/{eng.ffn_tp})")
        if args.prefix_cache:
            print(f"prefix cache: hit rate {eng.prefix_hit_rate():.1%}; "
                  f"prefill tokens saved {eng.prefill_tokens_saved()} "
                  f"(chunked prefill, chunk={args.prefill_chunk})")
        if args.predictor != "none":
            print(f"predictor={args.predictor} "
                  f"(target recall {args.target_recall}): "
                  f"tile density {eng.predictor_density():.3f}; "
                  f"realized recall {eng.predictor_recall():.4f}; "
                  f"up+down weight I/O saved {eng.weight_io_saved():.1%}; "
                  f"per-request misses "
                  f"{', '.join(str(res[u].pred_misses) for u in uids)}")
        if args.speculative:
            ms = [spec_metrics(res[u], gamma=args.gamma, c=0.1,
                               s_agg=eng.s_agg_window()) for u in uids]
            print(f"speculative gamma={args.gamma}: "
                  f"alpha={np.mean([m.accept_rate for m in ms]):.3f}; "
                  f"target-call reduction "
                  f"{np.mean([m.target_call_reduction for m in ms]):.2f}x; "
                  f"window s_agg={eng.s_agg_window():.3f}; "
                  f"Thm1 sparse-verify speedup "
                  f"{np.mean([m.thm1_speedup for m in ms]):.3f}x")
        from repro.obs import format_statusz
        print("-- final observability snapshot --")
        print(format_statusz(eng), end="")
        return

    if args.smoke:
        from repro.serving.engine import ServeEngine
        fam = registry.get_family(cfg)
        params = fam.init_params(jax.random.PRNGKey(0), cfg)
        eng = ServeEngine(cfg, params, max_len=64 + args.tokens,
                          track_sparsity=True)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8),
                                              0, cfg.vocab_size)}
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (2, cfg.n_vision_tokens, cfg.d_model),
                jnp.dtype(cfg.compute_dtype))
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros((2, cfg.n_audio_frames, cfg.d_model),
                                        jnp.dtype(cfg.compute_dtype))
        res = eng.generate(batch, max_new=args.tokens,
                           reuse_window=args.reuse_window)
        agg = (res.aggregated.aggregated_sparsity()
               if res.aggregated is not None else float("nan"))
        print(f"generated {res.tokens.shape} tokens; aggregated FFN sparsity "
              f"{agg:.3f}")
        return

    from repro.launch import mesh as mesh_lib
    from repro.launch import specs as specs_lib
    shape = SHAPES[args.shape]
    mesh = mesh_lib.make_production_mesh(multi_pod=args.multi_pod)
    with mesh:
        jitted, specs = specs_lib.build_cell(cfg, shape, mesh)
        compiled = jitted.lower(*specs).compile()
    print("serve step compiled for", mesh.shape, "-",
          compiled.memory_analysis())


if __name__ == "__main__":
    main()
