"""HTTP/SSE front door for the async streaming serving engine.

A deliberately dependency-free server (stdlib asyncio only — the repo's
serving path must run from the ``repro[test]`` install) exposing
``AsyncServingEngine`` over:

  GET  /healthz          -> {"ok": true, "mode": ..., "steps": ...,
                             "uptime_s": ...}
  GET  /metrics          -> Prometheus text exposition of the engine's
                            observability registry (repro.obs): request /
                            token counters, step-phase + TTFT/TPOT/queue-
                            wait histograms, occupancy gauges. Unavailable
                            series (e.g. predictor recall with telemetry
                            off) are OMITTED, never rendered as zeros.
  GET  /statusz          -> human-readable engine snapshot: config,
                            occupancy, scalar metrics, latency
                            percentiles, live + recent requests
  GET  /profilez?ms=N    -> opt-in jax.profiler capture: traces the next
                            N ms into --profilez-dir (403 unless the flag
                            was given; one capture at a time)
  POST /v1/generate      -> token stream (SSE) or one JSON body

``--log-json PATH`` additionally streams one JSON object per request
lifecycle event (submit / admit / first_token / finish / api_finish) to
PATH ("-" = stderr) — the structured event log.

Request body (JSON)::

    {"prompt": [1, 2, 3],        # token ids (required)
     "max_new": 16,              # generation budget (required)
     "stream": true,             # default true: SSE; false: one JSON reply
     "temperature": 0.8,         # 0 = greedy (default)
     "top_k": 40, "top_p": 0.95,
     "seed": 7,                  # omit -> the engine's --base-seed
     "stop": [[5, 9]],           # stop sequences (token ids)
     "reuse_window": 0,          # γ-window weight reuse (plain mode)
     "priority": 0,              # scheduling class (higher = more urgent)
     "slo_ms": 500.0}            # TTFT target; graded, never scheduled on

This is schema v1: UNKNOWN fields are rejected with a 400 naming the
field (a typo'd "priorty" must not silently serve at default priority).
The terminal event carries the scheduling outcome — ``priority``,
``preemptions``, ``slo_met`` — alongside the token list and latency.

Streaming responses are standard SSE: one ``data: {json}`` line per token,
a terminal ``data:`` object with ``"done": true`` plus the finish reason,
full token list, and serving latency (ttft_s / total_s), then
``data: [DONE]``. A client that disconnects mid-stream cancels its
request — the engine slot is reclaimed for other traffic.

Run (tiny smoke model, f32)::

    python -m repro.launch.serve_api --arch tiny-relu --f32 --port 8151

The launcher prints one ``READY {...}`` JSON line to stdout once the
socket is bound — process supervisors (launch/serve_smoke_client.py, the
serve-smoke CI job) wait on it and read the bound port from it.
``build_engine(args)`` is importable so drivers can construct a
bit-identical offline reference engine for byte-identity checks.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import sys
import time
from typing import Optional


def parse_args(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="tiny-relu")
    ap.add_argument("--smoke", action="store_true",
                    help="shrink --arch via configs.smoke_config")
    ap.add_argument("--mode", choices=["plain", "spec", "predictor"],
                    default="plain")
    ap.add_argument("--gamma", type=int, default=4,
                    help="draft length γ (spec mode)")
    ap.add_argument("--prefill-chunk", type=int, default=0)
    ap.add_argument("--prefix-cache", action="store_true")
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--max-blocks", type=int, default=6)
    ap.add_argument("--f32", action="store_true",
                    help="force float32 compute (exactness smoke runs)")
    ap.add_argument("--init-seed", type=int, default=0,
                    help="PRNG seed for the (random) smoke weights")
    ap.add_argument("--base-seed", type=int, default=0,
                    help="engine base seed for unseeded sampled requests")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8151)
    ap.add_argument("--log-json", default=None, metavar="PATH",
                    help="append one JSON object per request lifecycle "
                         "event to PATH ('-' = stderr)")
    ap.add_argument("--profilez-dir", default=None, metavar="DIR",
                    help="enable GET /profilez?ms=N jax.profiler captures "
                         "into DIR (disabled when omitted)")
    return ap.parse_args(argv)


def build_engine(args: argparse.Namespace):
    """Construct the serving engine the launcher fronts. Deterministic in
    ``args`` (random weights keyed on --init-seed), so a driver calling
    this again gets a reference engine producing byte-identical greedy
    streams — the serve-smoke CI assertion."""
    import jax

    from repro.configs import get_config, smoke_config
    from repro.models import registry
    from repro.serving import ContinuousBatchingEngine, EngineConfig

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.f32:
        cfg = cfg.replace(compute_dtype="float32")
    fam = registry.get_family(cfg)
    params = fam.init_params(jax.random.PRNGKey(args.init_seed), cfg)
    kw = dict(n_slots=args.n_slots, block_size=args.block_size,
              max_blocks_per_seq=args.max_blocks,
              base_seed=args.base_seed)
    if args.prefill_chunk:
        kw.update(prefill_chunk=args.prefill_chunk,
                  prefix_cache=args.prefix_cache)
    if args.mode == "spec":
        dcfg = cfg.replace(name=f"{cfg.name}-draft", n_layers=1)
        kw.update(draft_cfg=dcfg, gamma=args.gamma,
                  draft_params=fam.init_params(jax.random.PRNGKey(2), dcfg))
    elif args.mode == "predictor":
        from repro.core import relufication
        from repro.core.activations import is_sparse_activation
        from repro.predictor import calibrate_from_config
        if not is_sparse_activation(cfg.activation):
            cfg = relufication.relufy_stage1(cfg)
            params = fam.init_params(jax.random.PRNGKey(args.init_seed), cfg)
        cfg = cfg.replace_sparsity(predictor="sign", predictor_recall=0.99)
        calib = {"tokens": jax.random.randint(
            jax.random.PRNGKey(7), (4, 32), 0, cfg.vocab_size)}
        # tile=1 = exact row-skipping, observable on the tiny smoke models
        kw.update(predictor=calibrate_from_config(params, cfg, calib,
                                                  tile=1))
    return ContinuousBatchingEngine(cfg, params,
                                    config=EngineConfig(**kw).validate())


# /v1/generate schema v1: the complete field set. Anything else is a 400
# naming the offender — a misspelled "priorty" must fail loudly, not
# silently serve at the default priority.
_SCHEMA_V1_FIELDS = frozenset({
    "prompt", "max_new", "stream", "temperature", "top_k", "top_p",
    "seed", "stop", "reuse_window", "priority", "slo_ms"})


def _sampling_from(body: dict):
    from repro.serving import SamplingParams
    return SamplingParams(
        temperature=float(body.get("temperature", 0.0)),
        top_k=int(body.get("top_k", 0)),
        top_p=float(body.get("top_p", 1.0)),
        seed=(int(body["seed"]) if body.get("seed") is not None else None),
        stop=tuple(tuple(int(t) for t in s) for s in body.get("stop", [])))


async def _read_request(reader) -> Optional[tuple]:
    """Minimal HTTP/1.1 request parse: (method, path, body-bytes)."""
    line = await reader.readline()
    if not line:
        return None
    try:
        method, path, _ = line.decode("latin-1").split(None, 2)
    except ValueError:
        return None
    length = 0
    while True:
        h = await reader.readline()
        if h in (b"\r\n", b"\n", b""):
            break
        name, _, value = h.decode("latin-1").partition(":")
        if name.strip().lower() == "content-length":
            length = int(value.strip())
    body = await reader.readexactly(length) if length else b""
    return method.upper(), path, body


def _response(status: str, body: bytes, ctype: str = "application/json",
              stream: bool = False) -> bytes:
    head = [f"HTTP/1.1 {status}", f"Content-Type: {ctype}",
            "Connection: close"]
    if not stream:
        head.append(f"Content-Length: {len(body)}")
    return ("\r\n".join(head) + "\r\n\r\n").encode() + body


class ApiServer:
    """One engine, one asyncio TCP server. Kept as a class so in-process
    tests can drive the exact wire path without a subprocess."""

    def __init__(self, api, mode: str = "plain",
                 profilez_dir: Optional[str] = None):
        self.api = api
        self.mode = mode
        self.profilez_dir = profilez_dir
        self._profiling = False  # one jax.profiler capture at a time
        self._t0 = time.monotonic()
        self._server: Optional[asyncio.AbstractServer] = None

    @property
    def port(self) -> int:
        return self._server.sockets[0].getsockname()[1]

    async def start(self, host: str = "127.0.0.1", port: int = 0) -> None:
        self._server = await asyncio.start_server(self._handle, host, port)

    async def aclose(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()

    async def _handle(self, reader, writer) -> None:
        try:
            req = await _read_request(reader)
            if req is None:
                return
            method, path, raw = req
            path, _, query = path.partition("?")
            if method == "GET" and path in ("/healthz", "/metrics",
                                            "/statusz", "/profilez"):
                await self._handle_get(writer, path, query)
                return
            if method != "POST" or path != "/v1/generate":
                writer.write(_response("404 Not Found",
                                       b'{"error": "not found"}'))
                await writer.drain()
                return
            try:
                body = json.loads(raw or b"{}")
                unknown = sorted(set(body) - _SCHEMA_V1_FIELDS)
                if unknown:
                    raise ValueError(
                        f"unknown field(s) {unknown}; schema v1 accepts "
                        f"{sorted(_SCHEMA_V1_FIELDS)}")
                prompt = [int(t) for t in body["prompt"]]
                max_new = int(body["max_new"])
                sampling = _sampling_from(body)
                reuse_window = int(body.get("reuse_window", 0))
                priority = int(body.get("priority", 0))
                slo_ms = (float(body["slo_ms"])
                          if body.get("slo_ms") is not None else None)
            except (KeyError, TypeError, ValueError) as e:
                writer.write(_response("400 Bad Request", json.dumps(
                    {"error": f"bad request: {e}"}).encode()))
                await writer.drain()
                return
            await self._generate(writer, prompt, max_new, sampling,
                                 reuse_window, priority, slo_ms,
                                 stream=body.get("stream", True))
        except (ConnectionResetError, BrokenPipeError, asyncio.IncompleteReadError):
            pass  # client went away; any in-flight uid is cancelled below
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    async def _handle_get(self, writer, path: str, query: str) -> None:
        """Observability endpoints — pure reads of the engine's obs hub
        (safe between steps: the serve loop and this handler share the
        event loop thread), except /profilez which runs a bounded
        jax.profiler capture."""
        engine = self.api.engine
        if path == "/healthz":
            writer.write(_response("200 OK", json.dumps(
                {"ok": True, "mode": self.mode, "steps": engine.t,
                 "uptime_s": round(time.monotonic() - self._t0, 3)}
            ).encode()))
        elif path == "/metrics":
            # unavailable series are simply absent from the registry —
            # never a 500, never a fabricated zero
            writer.write(_response("200 OK", engine.obs.render().encode(),
                                   ctype="text/plain; version=0.0.4"))
        elif path == "/statusz":
            from repro.obs import format_statusz
            writer.write(_response("200 OK",
                                   format_statusz(engine).encode(),
                                   ctype="text/plain; charset=utf-8"))
        else:  # /profilez
            await self._profilez(writer, query)
            return
        await writer.drain()

    async def _profilez(self, writer, query: str) -> None:
        """Opt-in jax.profiler capture: trace the next ``ms`` milliseconds
        of serving into --profilez-dir. The capture window overlaps live
        traffic — the point is profiling real steps, not a synthetic
        workload."""
        if self.profilez_dir is None:
            writer.write(_response("403 Forbidden", json.dumps(
                {"error": "profiling disabled: start the server with "
                          "--profilez-dir"}).encode()))
            await writer.drain()
            return
        params = dict(kv.split("=", 1) for kv in query.split("&")
                      if "=" in kv)
        try:
            ms = max(1, min(60_000, int(params.get("ms", "500"))))
        except ValueError:
            writer.write(_response("400 Bad Request",
                                   b'{"error": "ms must be an integer"}'))
            await writer.drain()
            return
        if self._profiling:
            writer.write(_response(
                "409 Conflict", b'{"error": "a capture is already running"}'))
            await writer.drain()
            return
        self._profiling = True
        try:
            import jax
            jax.profiler.start_trace(self.profilez_dir)
            try:
                await asyncio.sleep(ms / 1000.0)
            finally:
                jax.profiler.stop_trace()
        except Exception as e:  # capture failure must not kill the server
            writer.write(_response("500 Internal Server Error", json.dumps(
                {"error": f"profiler capture failed: {e}"}).encode()))
            await writer.drain()
            return
        finally:
            self._profiling = False
        writer.write(_response("200 OK", json.dumps(
            {"ok": True, "ms": ms, "dir": self.profilez_dir}).encode()))
        await writer.drain()

    async def _generate(self, writer, prompt, max_new, sampling,
                        reuse_window, priority, slo_ms,
                        stream: bool) -> None:
        try:
            uid = await self.api.submit(prompt, max_new, sampling=sampling,
                                        reuse_window=reuse_window,
                                        priority=priority, slo_ms=slo_ms)
        except Exception as e:  # validation errors surface as 400s
            writer.write(_response("400 Bad Request", json.dumps(
                {"error": str(e)}).encode()))
            await writer.drain()
            return
        print(f"serve_api: uid={uid} prompt_len={len(prompt)} "
              f"max_new={max_new} greedy={sampling.is_greedy} "
              f"stream={stream}", file=sys.stderr, flush=True)
        tokens, lps = [], []
        if stream:
            writer.write(_response("200 OK", b"", ctype="text/event-stream",
                                   stream=True))
        try:
            async for ev in self.api.events(uid):
                if ev.finished:
                    final = {"uid": uid, "done": True,
                             "n_tokens": len(ev.result.tokens),
                             "finish_reason": ev.finish_reason,
                             "tokens": [int(t) for t in ev.result.tokens],
                             "logprobs": [float(x)
                                          for x in ev.result.logprobs],
                             "ttft_s": ev.ttft_s, "total_s": ev.total_s,
                             "priority": ev.result.priority,
                             "preemptions": ev.result.preemptions,
                             "slo_met": ev.result.slo_met}
                    if stream:
                        writer.write(b"data: " + json.dumps(final).encode()
                                     + b"\n\ndata: [DONE]\n\n")
                    else:
                        writer.write(_response("200 OK",
                                               json.dumps(final).encode()))
                    await writer.drain()
                    return
                tokens.append(ev.token)
                lps.append(ev.logprob)
                if stream:
                    writer.write(b"data: " + json.dumps(
                        {"uid": uid, "index": ev.index, "token": ev.token,
                         "logprob": ev.logprob}).encode() + b"\n\n")
                    # drain per event: a disconnected client raises here,
                    # freeing its slot instead of decoding to a dead socket
                    await writer.drain()
        except (ConnectionResetError, BrokenPipeError):
            print(f"serve_api: uid={uid} client disconnected after "
                  f"{len(tokens)} tokens — cancelling", file=sys.stderr,
                  flush=True)
            self.api.cancel(uid)
            raise


def _json_event_writer(path: str):
    """Line-delimited JSON sink for --log-json ('-' = stderr). Line-
    buffered so a crashed server leaves a readable log behind."""
    stream = sys.stderr if path == "-" else open(path, "a", buffering=1)

    def write(event: dict) -> None:
        stream.write(json.dumps(event) + "\n")
    return write


async def _amain(args: argparse.Namespace) -> None:
    from repro.serving import AsyncServingEngine

    engine = build_engine(args)
    if args.log_json:
        engine.obs.log_event = _json_event_writer(args.log_json)
    async with AsyncServingEngine(engine) as api:
        server = ApiServer(api, mode=args.mode,
                           profilez_dir=args.profilez_dir)
        await server.start(args.host, args.port)
        print("READY " + json.dumps({"host": args.host, "port": server.port,
                                     "mode": args.mode}), flush=True)
        try:
            await asyncio.Event().wait()  # serve until killed
        finally:
            await server.aclose()


def main() -> None:
    args = parse_args()
    try:
        asyncio.run(_amain(args))
    except KeyboardInterrupt:
        pass


if __name__ == "__main__":
    main()
