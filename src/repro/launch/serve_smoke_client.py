"""serve-smoke driver: boot the API server, hammer it, verify exactness.

The CI serve-smoke job runs this module (launch/serve_api.py is the system
under test, spawned as a real subprocess speaking real HTTP):

  1. start ``python -m repro.launch.serve_api`` with the given mode,
     logging the server's stdout/stderr to ``--log``;
  2. drive 8 concurrent streaming clients — 5 greedy, 3 sampled (distinct
     seeds), one of which disconnects mid-stream;
  3. assert every completed greedy stream is byte-identical to an offline
     ``engine.run()`` over a reference engine built with the SAME args
     (serve_api.build_engine — same random weights, same config);
  4. assert the server survives the disconnect: /healthz still answers
     and a post-disconnect greedy request still matches the reference;
  5. scrape ``/metrics`` mid-run (availability under load) and again at
     the end, asserting the scraped request/token counters agree with the
     client-observed counts, ``/statusz`` renders, and ``/profilez`` is
     403 without its opt-in flag; the final scrape is written to
     ``--metrics-out`` (a ``.prom`` file CI uploads as an artifact).

Exit code 0 = pass. Any mismatch/timeout prints a diagnosis and exits 1;
the CI job uploads ``--log`` as an artifact on failure.

  python -m repro.launch.serve_smoke_client --mode plain --log server.log
"""
from __future__ import annotations

import argparse
import asyncio
import json
import shutil
import subprocess
import sys
import threading
import time
from typing import List, Optional, Tuple

import numpy as np

CLIENT_TIMEOUT_S = 420  # generous: first stream pays the jit compiles


def server_args(mode: str) -> List[str]:
    """CLI args shared by the server subprocess and the in-driver
    reference engine — byte-identity depends on them matching."""
    return ["--arch", "tiny-relu", "--f32", "--mode", mode,
            "--n-slots", "4", "--block-size", "8", "--max-blocks", "6",
            "--gamma", "3"]


def workload(vocab: int) -> List[dict]:
    """8 deterministic client requests: 5 greedy, 3 sampled; request 5
    (sampled) disconnects after 3 streamed tokens. Traffic is
    mixed-priority (schema v1): two interactive requests at priority 2
    with a generous SLO, one at priority 1, the rest default batch class —
    per-class TTFT series must land in /metrics."""
    rng = np.random.RandomState(0)
    reqs = []
    for i in range(8):
        prompt = [int(t) for t in rng.randint(0, vocab, 4 + 2 * i)]
        r = {"prompt": prompt, "max_new": 6 + (i % 3), "stream": True}
        if i in (2, 5, 7):  # the sampled cohort
            r.update(temperature=0.8 + 0.1 * i, top_k=50, top_p=0.95,
                     seed=i)
        if i in (1, 4):  # the interactive cohort (one greedy, one greedy)
            r.update(priority=2, slo_ms=120_000.0)
        elif i == 3:
            r.update(priority=1)
        reqs.append(r)
    return reqs


async def stream_client(port: int, body: dict,
                        disconnect_after: Optional[int] = None
                        ) -> Tuple[List[int], Optional[dict]]:
    """One SSE client; returns (streamed tokens, final event or None when
    it disconnected early)."""
    raw = json.dumps(body).encode()
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    writer.write(b"POST /v1/generate HTTP/1.1\r\nHost: smoke\r\n"
                 b"Content-Length: " + str(len(raw)).encode()
                 + b"\r\n\r\n" + raw)
    await writer.drain()
    tokens: List[int] = []
    final = None
    buf = b""
    try:
        while True:
            chunk = await reader.read(4096)
            if not chunk:
                break
            buf += chunk
            done = False
            while b"\n\n" in buf:
                frame, buf = buf.split(b"\n\n", 1)
                for line in frame.splitlines():
                    if not line.startswith(b"data: "):
                        continue
                    payload = line[6:]
                    if payload == b"[DONE]":
                        done = True
                        break
                    ev = json.loads(payload)
                    if ev.get("done"):
                        final = ev
                    else:
                        tokens.append(ev["token"])
                        if (disconnect_after is not None
                                and len(tokens) >= disconnect_after):
                            return tokens, None  # finally closes the socket
                if done:
                    break
            if done:
                break
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError):
            pass
    return tokens, final


async def http_get(port: int, path: str) -> Tuple[int, str]:
    """One GET request; returns (status code, body text)."""
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    writer.write(f"GET {path} HTTP/1.1\r\nHost: smoke\r\n\r\n".encode())
    await writer.drain()
    data = await reader.read()
    writer.close()
    try:
        await writer.wait_closed()
    except (ConnectionResetError, BrokenPipeError):
        pass
    head, _, body = data.partition(b"\r\n\r\n")
    status = int(head.split()[1]) if head.split() else 0
    return status, body.decode("utf-8", "replace")


async def healthz(port: int) -> bool:
    try:
        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        writer.write(b"GET /healthz HTTP/1.1\r\nHost: smoke\r\n\r\n")
        await writer.drain()
        data = await reader.read()
        writer.close()
        return b'"ok": true' in data
    except OSError:
        return False


def reference_streams(mode: str, reqs: List[dict]) -> dict:
    """Offline greedy ground truth from engine.run() on an identically
    built engine (greedy requests only — sampled exactness is pinned by
    the pytest tier; here we check the greedy byte-identity contract)."""
    from repro.launch.serve_api import build_engine, parse_args
    eng = build_engine(parse_args(server_args(mode)))
    uids = {}
    for i, r in enumerate(reqs):
        if "temperature" not in r:
            uids[i] = eng.submit(r["prompt"], r["max_new"])
    res = eng.run()
    return {i: [int(t) for t in res[u].tokens] for i, u in uids.items()}


async def drive(port: int, mode: str,
                metrics_out: Optional[str] = None) -> None:
    from repro.configs import get_config
    from repro.obs import parse_prometheus
    vocab = get_config("tiny-relu").vocab_size
    reqs = workload(vocab)
    ref = reference_streams(mode, reqs)
    failures: List[str] = []

    async def run_one(i: int):
        return await asyncio.wait_for(
            stream_client(port, reqs[i],
                          disconnect_after=3 if i == 5 else None),
            CLIENT_TIMEOUT_S)

    async def midrun_scrape():
        # scrape while the client streams are (very likely — the first
        # steps pay jit compiles) still in flight: /metrics must answer
        # under load, and the counters must already be consistent
        await asyncio.sleep(1.0)
        status, text = await http_get(port, "/metrics")
        if status != 200:
            failures.append(f"mid-run /metrics returned {status}")
            return
        m = parse_prometheus(text)
        submitted = m.get(("repro_requests_submitted_total", ""), 0.0)
        finished = sum(v for (name, _), v in m.items()
                       if name == "repro_requests_finished_total")
        if not (1 <= submitted <= len(reqs) and 0 <= finished <= submitted):
            failures.append(f"mid-run counters inconsistent: "
                            f"submitted={submitted} finished={finished}")

    results = (await asyncio.gather(*[run_one(i) for i in range(len(reqs))],
                                    midrun_scrape()))[:len(reqs)]
    for i, (tokens, final) in enumerate(results):
        if i == 5:
            if final is not None:
                failures.append(f"client {i}: expected mid-stream "
                                f"disconnect, got a final event")
            continue
        if final is None:
            failures.append(f"client {i}: stream ended without a final "
                            f"event (got {len(tokens)} tokens)")
            continue
        if tokens != final["tokens"]:
            failures.append(f"client {i}: streamed tokens {tokens} != "
                            f"final event tokens {final['tokens']}")
        if len(tokens) != reqs[i]["max_new"]:
            failures.append(f"client {i}: {len(tokens)} tokens, wanted "
                            f"max_new={reqs[i]['max_new']}")
        if i in ref and tokens != ref[i]:
            failures.append(f"client {i}: greedy stream {tokens} != "
                            f"offline engine.run() {ref[i]}")
        if final.get("ttft_s") is None:
            failures.append(f"client {i}: final event missing ttft_s")
        if final.get("priority") != reqs[i].get("priority", 0):
            failures.append(f"client {i}: final event priority "
                            f"{final.get('priority')} != submitted "
                            f"{reqs[i].get('priority', 0)}")
        if "slo_ms" in reqs[i] and final.get("slo_met") is not True:
            failures.append(f"client {i}: slo_met={final.get('slo_met')} "
                            f"under a {reqs[i]['slo_ms']}ms SLO nothing "
                            f"in this smoke run can miss")
    # the server must have survived client 5 vanishing mid-stream
    if not await healthz(port):
        failures.append("healthz failed after mid-stream disconnect")
    post = reqs[0]
    tokens, final = await asyncio.wait_for(stream_client(port, post),
                                           CLIENT_TIMEOUT_S)
    if tokens != ref[0]:
        failures.append(f"post-disconnect greedy stream {tokens} != "
                        f"reference {ref[0]}")

    # -- final /metrics scrape: counters must agree with what the clients
    # themselves observed (9 requests total: the 8-request workload + the
    # post-disconnect probe). The disconnected client saw 3 tokens; the
    # engine may have decoded up to its max_new before the cancel landed,
    # so its engine-side token count is bounded, not pinned.
    status, text = await http_get(port, "/metrics")
    if status != 200:
        failures.append(f"final /metrics returned {status}")
        text = ""
    m = parse_prometheus(text)

    def counter(name: str, labels: str = "") -> float:
        return m.get((name, labels), 0.0)

    n_expected = len(reqs) + 1
    for name in ("repro_requests_submitted_total",
                 "repro_requests_admitted_total"):
        if counter(name) != n_expected:
            failures.append(f"{name}={counter(name)} != {n_expected} "
                            f"client-submitted requests")
    by_reason = {lab: v for (name, lab), v in m.items()
                 if name == "repro_requests_finished_total"}
    if sum(by_reason.values()) != n_expected:
        failures.append(f"finished-by-reason {by_reason} does not sum to "
                        f"{n_expected}")
    if by_reason.get('reason="cancelled"', 0.0) > 1:
        failures.append(f"more than one cancelled request: {by_reason}")
    completed = (sum(len(t) for i, (t, _) in enumerate(results) if i != 5)
                 + len(tokens))
    gen = counter("repro_generated_tokens_total")
    lo, hi = completed + 3, completed + reqs[5]["max_new"]
    if not lo <= gen <= hi:
        failures.append(f"generated_tokens_total={gen} outside "
                        f"[{lo}, {hi}] (clients observed {completed} "
                        f"completed tokens + 3..{reqs[5]['max_new']} on "
                        f"the disconnected stream)")
    if counter("repro_request_ttft_seconds_count") != n_expected:
        failures.append(f"ttft histogram count "
                        f"{counter('repro_request_ttft_seconds_count')} != "
                        f"{n_expected}")
    # per-class TTFT (SLO scheduling): one labeled series per priority
    # class the workload used, counts partitioning the 9 requests —
    # priority 2: clients 1+4; priority 1: client 3; priority 0: the
    # remaining 5 workload clients + the post-disconnect probe
    for prio, n_class in (("2", 2), ("1", 1), ("0", n_expected - 3)):
        got = counter("repro_request_class_ttft_seconds_count",
                      f'priority="{prio}"')
        if got != n_class:
            failures.append(f"class ttft count for priority={prio} is "
                            f"{got}, expected {n_class}")
    if mode == "predictor" and counter(
            "repro_predictor_active_neurons_total") <= 0:
        failures.append("predictor mode served but recall telemetry "
                        "counters are absent from /metrics")
    # /statusz renders; /profilez is 403 without its opt-in flag
    s_status, s_text = await http_get(port, "/statusz")
    if s_status != 200 or "repro serving engine" not in s_text:
        failures.append(f"/statusz broken (status {s_status})")
    p_status, _ = await http_get(port, "/profilez?ms=10")
    if p_status != 403:
        failures.append(f"/profilez without --profilez-dir returned "
                        f"{p_status}, expected 403")
    if metrics_out and text:
        with open(metrics_out, "w") as f:
            f.write(text)

    if failures:
        raise AssertionError("serve-smoke failures:\n  "
                             + "\n  ".join(failures))
    n_sampled = sum(1 for i in range(len(reqs)) if i in (2, 7))
    print(f"serve-smoke PASS [{mode}]: {len(ref)} greedy streams "
          f"byte-identical to engine.run(), {n_sampled} sampled streams "
          f"completed, 1 mid-stream disconnect survived")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["plain", "spec", "predictor"],
                    default="plain")
    ap.add_argument("--log", default="serve_smoke_server.log")
    ap.add_argument("--metrics-out", default=None,
                    help="write the final /metrics scrape here "
                         "(default serve_smoke_metrics_<mode>.prom)")
    ap.add_argument("--boot-timeout", type=float, default=300.0)
    args = ap.parse_args()
    if args.metrics_out is None:
        args.metrics_out = f"serve_smoke_metrics_{args.mode}.prom"

    cmd = [sys.executable, "-u", "-m", "repro.launch.serve_api",
           "--port", "0"] + server_args(args.mode)
    log = open(args.log, "w")
    proc = subprocess.Popen(cmd, stdout=subprocess.PIPE, stderr=log,
                            text=True)
    port = None
    try:
        deadline = time.monotonic() + args.boot_timeout
        while time.monotonic() < deadline:
            line = proc.stdout.readline()
            if not line:
                raise RuntimeError(
                    f"server exited during boot (rc={proc.poll()}) — "
                    f"see {args.log}")
            log.write(line)
            log.flush()
            if line.startswith("READY "):
                port = json.loads(line[6:])["port"]
                break
        if port is None:
            raise RuntimeError(f"server did not print READY within "
                               f"{args.boot_timeout}s — see {args.log}")
        # keep draining server stdout into the log while clients run
        t = threading.Thread(target=shutil.copyfileobj,
                             args=(proc.stdout, log), daemon=True)
        t.start()
        asyncio.run(drive(port, args.mode, args.metrics_out))
    except BaseException as e:
        print(f"serve-smoke FAIL [{args.mode}]: {e}", file=sys.stderr)
        raise SystemExit(1)
    finally:
        proc.terminate()
        try:
            proc.wait(timeout=30)
        except subprocess.TimeoutExpired:
            proc.kill()
        log.close()


if __name__ == "__main__":
    main()
