"""ShapeDtypeStruct input specs + step builders for the dry-run.

``input_specs(cfg, shape)`` returns weak-type-correct, shardable stand-ins for
every model input — no device allocation anywhere (params come from
jax.eval_shape on init).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.models import registry
from repro.optim import adamw
from repro.sharding import rules
from repro.train.step import make_train_step

PyTree = Any
SDS = jax.ShapeDtypeStruct


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, SDS]:
    """Model inputs for one cell (batch dict for train/prefill; decode adds
    token/pos and the cache comes from cache_specs)."""
    B, S = shape.global_batch, shape.seq_len
    cdt = jnp.dtype(cfg.compute_dtype)
    if shape.kind in ("train", "prefill"):
        out = {"tokens": SDS((B, S), jnp.int32)}
        if cfg.family == "vlm":
            out["patches"] = SDS((B, cfg.n_vision_tokens, cfg.d_model), cdt)
        if cfg.family == "encdec":
            out["frames"] = SDS((B, cfg.n_audio_frames, cfg.d_model), cdt)
        return out
    # decode: one new token against a cache of length S
    out = {"token": SDS((B,), jnp.int32), "pos": SDS((B,), jnp.int32)}
    return out


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> PyTree:
    B = shape.global_batch
    if shape.kind in ("train", "prefill"):
        out = {"tokens": NamedSharding(mesh, rules.batch_pspec(B, mesh, 1))}
        if cfg.family == "vlm":
            out["patches"] = NamedSharding(mesh, rules.batch_pspec(B, mesh, 2))
        if cfg.family == "encdec":
            out["frames"] = NamedSharding(mesh, rules.batch_pspec(B, mesh, 2))
        return out
    bp = NamedSharding(mesh, rules.batch_pspec(B, mesh, 0))
    return {"token": bp, "pos": bp}


def params_spec(cfg: ModelConfig) -> PyTree:
    fam = registry.get_family(cfg)
    rng = SDS((2,), jnp.uint32)
    return jax.eval_shape(lambda r: fam.init_params(r, cfg), rng)


def cache_specs(cfg: ModelConfig, shape: ShapeConfig) -> PyTree:
    fam = registry.get_family(cfg)
    return jax.eval_shape(
        lambda: fam.init_cache(cfg, shape.global_batch, shape.seq_len))


def cache_shardings(cache_spec: PyTree, mesh: Mesh) -> PyTree:
    def f(path, leaf):
        name = rules._path_str(path)
        if leaf.ndim == 5:  # KV cache (L, b, S, kvp, hd)
            return NamedSharding(mesh, rules.cache_pspec(leaf.shape, mesh))
        return NamedSharding(mesh, rules.ssm_cache_pspec(leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(f, cache_spec)


# ---------------------------------------------------------------------------
# step builders: return (jittable_fn, example_args, in_shardings, out_shardings)


def build_train(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                tc: TrainConfig):
    rules.set_mesh(mesh)
    params_shape = params_spec(cfg)
    ps = rules.params_shardings(params_shape, mesh, "train")
    opt_shape = jax.eval_shape(adamw.init_opt_state, params_shape)
    rep = NamedSharding(mesh, P())
    opt_sh = adamw.OptState(step=rep, m=ps, v=ps)
    batch = input_specs(cfg, shape)
    bsh = batch_shardings(cfg, shape, mesh)

    step = make_train_step(cfg, tc)
    metrics_sh = {"loss": rep, "grad_norm": rep, "lr": rep, "step_ok": rep}
    jitted = jax.jit(
        step,
        in_shardings=(ps, opt_sh, bsh),
        out_shardings=(ps, opt_sh, metrics_sh),
        donate_argnums=(0, 1),
    )
    return jitted, (params_shape, opt_shape, batch)


def build_prefill(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    rules.set_mesh(mesh)
    params_shape = params_spec(cfg)
    ps = rules.params_shardings(params_shape, mesh, "serve")
    batch = input_specs(cfg, shape)
    bsh = batch_shardings(cfg, shape, mesh)
    fam = registry.get_family(cfg)

    def fn(params, batch):
        return fam.model_prefill(params, batch, cfg, shape.seq_len)

    csh = cache_shardings(jax.eval_shape(
        lambda p, b: fn(p, b)[1], params_shape, batch), mesh)
    lsh = NamedSharding(mesh, rules.logits_pspec(shape.global_batch, mesh, False))
    jitted = jax.jit(fn, in_shardings=(ps, bsh), out_shardings=(lsh, csh))
    return jitted, (params_shape, batch)


def build_decode(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    rules.set_mesh(mesh)
    params_shape = params_spec(cfg)
    ps = rules.params_shardings(params_shape, mesh, "serve")
    cache = cache_specs(cfg, shape)
    csh = cache_shardings(cache, mesh)
    inp = input_specs(cfg, shape)
    ish = batch_shardings(cfg, shape, mesh)
    fam = registry.get_family(cfg)

    def fn(params, cache, token, pos):
        return fam.model_decode(params, cache, token, pos, cfg)

    lsh = NamedSharding(mesh, rules.logits_pspec(shape.global_batch, mesh, False))
    jitted = jax.jit(fn, in_shardings=(ps, csh, ish["token"], ish["pos"]),
                     out_shardings=(lsh, csh), donate_argnums=(1,))
    return jitted, (params_shape, cache, inp["token"], inp["pos"])


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               tc: TrainConfig = None):
    if shape.kind == "train":
        return build_train(cfg, shape, mesh, tc or TrainConfig(
            num_microbatches=shape.num_microbatches, remat_policy="minimal"))
    if shape.kind == "prefill":
        return build_prefill(cfg, shape, mesh)
    return build_decode(cfg, shape, mesh)
