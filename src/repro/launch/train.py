"""Production training launcher.

On a real TPU pod each host runs this under its runtime; on this container
it runs reduced configs (--smoke) end-to-end. The pjit step, sharding rules,
checkpointing, and relufication stages are identical in both paths.

  python -m repro.launch.train --arch qwen2-7b --shape train_4k \
      --relufy-stage 2 --steps 30000 --ckpt /ckpt/qwen2-relu [--multi-pod]
  python -m repro.launch.train --arch qwen3-4b --smoke --steps 20   # CPU
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--relufy-stage", type=int, default=0, choices=[0, 1, 2])
    ap.add_argument("--shifted-relu", type=float, default=None,
                    help="use ReLU(x - b) with this shift")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on local devices (CPU)")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--lr", type=float, default=1.5e-5)  # paper's FT recipe
    args = ap.parse_args()

    import jax

    from repro.configs import SHAPES, TrainConfig, get_config, smoke_config
    from repro.core import relufication
    from repro.data.pipeline import DataConfig
    from repro.train.loop import Trainer

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.relufy_stage == 1:
        cfg = relufication.relufy_stage1(cfg)
    elif args.relufy_stage == 2:
        cfg = relufication.relufy_stage2(cfg)
    if args.shifted_relu is not None:
        cfg = relufication.shifted_relufy(cfg, args.shifted_relu)

    if args.smoke:
        dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, batch_size=4)
        tc = TrainConfig(learning_rate=5e-3, total_steps=args.steps,
                         warmup_steps=5, num_microbatches=1)
        tr = Trainer(cfg, tc, dc, ckpt_dir=args.ckpt)
        rep = tr.run(args.steps)
        print(f"done: {rep.steps} steps, final loss {rep.losses[-1]:.4f}, "
              f"skipped {rep.skipped_steps}, stragglers {rep.straggler_steps}")
        return

    # production pod path: build the sharded step on the 16x16 (or 2x16x16)
    # mesh. Requires the actual TPU runtime; here we validate the build.
    from repro.launch import mesh as mesh_lib
    from repro.launch import specs as specs_lib
    shape = SHAPES[args.shape]
    if args.microbatches:
        import dataclasses
        shape = dataclasses.replace(shape, num_microbatches=args.microbatches)
    mesh = mesh_lib.make_production_mesh(multi_pod=args.multi_pod)
    with mesh:
        jitted, specs = specs_lib.build_cell(
            cfg, shape, mesh,
            tc=TrainConfig(learning_rate=args.lr,
                           num_microbatches=shape.num_microbatches or 1,
                           remat_policy="minimal"))
        compiled = jitted.lower(*specs).compile()
    print("train step compiled for", mesh.shape, "-",
          compiled.memory_analysis())


if __name__ == "__main__":
    main()
