"""Shared model components.

Everything here is pure-jnp, mesh-agnostic (sharding is applied from the
outside via logical-axis rules), and eval_shape-friendly (init allocates only
through jax.random so the dry-run can stay on ShapeDtypeStructs).

TPU adaptation notes (see DESIGN.md §3):
  * attention for long sequences is an online-softmax chunked loop (flash
    attention algorithmically, pure XLA);
  * decode attention runs over a KV cache whose *sequence* axis may be sharded
    (GSPMD inserts the partial-softmax all-reduces — flash-decode for free);
  * the paper's row-skipping sparse matmul becomes *tile*-gathered matmul with
    static top-k capacity (`select_active_tiles` + `gathered_matmul`).

Head padding (probe: jit rejects uneven shardings, so the q-head axis must be
a multiple of the model-axis size 16). We use a *per-group padded layout*:
for GQA with H q-heads and K kv-heads, real group size r = H/K is padded to
g = Hp/K slots per kv group (Hp = round_up(H, 16); K | Hp holds for every
assigned arch since K is a power of two or equals H). Padded slots hold zero
weights and are masked after attention, so the math is exactly GQA. MHA archs
(K == H) pad K alongside H with zero K/V heads. Grouped attention einsums
then need no gather maps at all.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

PyTree = Any

# ---------------------------------------------------------------------------
# init helpers


def dense_init(rng, shape, in_axis_size, dtype):
    scale = 1.0 / math.sqrt(max(1, in_axis_size))
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


def embed_init(rng, shape, dtype):
    return (jax.random.normal(rng, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# normalization


def init_norm(cfg: ModelConfig, dim: int, dtype) -> PyTree:
    p = {"scale": jnp.ones((dim,), dtype)}
    if cfg.norm_kind == "layernorm":
        p["bias"] = jnp.zeros((dim,), dtype)
    return p


def apply_norm(p: PyTree, x: jnp.ndarray, cfg: ModelConfig, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm_kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_headdim(scale: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """qk-norm (qwen3): RMS-normalize the head_dim axis."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE


def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq) broadcastable."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    angles = angles[..., None, :]  # broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# padding geometry (DESIGN.md §4)

TP = 16  # model-axis size of the production mesh
VOCAB_MULTIPLE = 2048  # 16 shards x 128 lanes


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


class HeadGeometry:
    """Padded GQA/MHA layout for an arch (see module docstring)."""

    def __init__(self, n_heads: int, n_kv: int, head_dim: int, tp: int = TP):
        self.n_heads = n_heads
        self.head_dim = head_dim
        if n_kv == n_heads:  # MHA: pad kv alongside q
            self.hp = round_up(n_heads, tp)
            self.kvp = self.hp
            self.group = 1
            self.real_per_group = 1  # slot j==0 real iff kv head real
        else:
            self.hp = round_up(n_heads, tp)
            assert self.hp % n_kv == 0, (n_heads, n_kv)
            self.kvp = n_kv
            self.group = self.hp // n_kv
            self.real_per_group = n_heads // n_kv
        self.n_kv = n_kv

    def q_slot_mask(self) -> np.ndarray:
        """(hp,) 1.0 for real q-head slots in the per-group padded layout."""
        if self.group == 1:
            m = (np.arange(self.hp) < self.n_heads)
        else:
            j = np.arange(self.hp) % self.group
            m = j < self.real_per_group
        return m.astype(np.float32)

    def kv_slot_mask(self) -> np.ndarray:
        return (np.arange(self.kvp) < self.n_kv).astype(np.float32)

    def scatter_q(self, w_real: jnp.ndarray, axis: int) -> jnp.ndarray:
        """Place a real-head-indexed array into the padded layout (init only)."""
        shape = list(w_real.shape)
        shape[axis] = self.hp
        out = jnp.zeros(shape, w_real.dtype)
        if self.group == 1:
            return jax.lax.dynamic_update_slice_in_dim(out, w_real, 0, axis)
        # real head h = k*r + j  ->  padded slot k*g + j
        idx = (np.arange(self.n_heads) // self.real_per_group) * self.group + (
            np.arange(self.n_heads) % self.real_per_group)
        return out.at[tuple(slice(None) if a != axis else idx
                            for a in range(len(shape)))].set(w_real)


def padded_vocab(vocab: int) -> int:
    return round_up(vocab, VOCAB_MULTIPLE)


def vocab_logit_mask(vocab: int, vocab_p: int) -> jnp.ndarray:
    return jnp.where(jnp.arange(vocab_p) < vocab, 0.0, -1e9).astype(jnp.float32)


def _largest_divisor_leq(n: int, cap: int) -> int:
    for c in range(min(cap, n), 0, -1):
        if n % c == 0:
            return c
    return 1


# ---------------------------------------------------------------------------
# attention (train/prefill): online-softmax chunked == flash-attention in XLA


def flash_attention(
    q: jnp.ndarray,  # (b, s, kvp, g, d) per-group padded layout
    k: jnp.ndarray,  # (b, skv, kvp, d)
    v: jnp.ndarray,  # (b, skv, kvp, d)
    causal: bool = True,
    window: int = 0,  # sliding-window size; 0 = global
    q_chunk: int = 512,
    kv_chunk: int = 512,
    q_offset: int = 0,  # absolute position of q[0]
) -> jnp.ndarray:
    """Exact attention with O(s·chunk) memory and ~causal FLOPs.

    Outer python loop over q chunks (static slices); inner lax.scan over only
    the kv chunks a q chunk can see (causal / sliding window), with an online
    softmax (m, l, acc) carry in f32. Returns (b, s, kvp, g, d).
    """
    b, s, kvp, g, d = q.shape
    skv = k.shape[1]
    q_chunk = _largest_divisor_leq(s, min(q_chunk, s))
    kv_chunk = _largest_divisor_leq(skv, min(kv_chunk, skv))
    n_q = s // q_chunk
    scale = 1.0 / math.sqrt(d)

    outs = []
    for i in range(n_q):
        q0 = i * q_chunk
        cq = q_chunk
        # keep operands in compute dtype; accumulate in f32 via
        # preferred_element_type (avoids materializing f32 copies of q/k/v)
        qi = jax.lax.slice_in_dim(q, q0, q0 + cq, axis=1) * jnp.asarray(scale, q.dtype)
        q_pos = q0 + q_offset + jnp.arange(cq)

        # kv chunk range this q chunk can see (static, aligned bounds)
        hi = min(skv, q0 + q_offset + cq) if causal else skv
        lo = max(0, q0 + q_offset - window + 1) if window else 0
        lo = (lo // kv_chunk) * kv_chunk
        hi = min(skv, round_up(max(hi, lo + 1), kv_chunk))
        n_kv = (hi - lo) // kv_chunk

        base = lo + jnp.arange(n_kv, dtype=jnp.int32) * kv_chunk

        def body(carry, b0):
            # slice the kv chunk inside the body (no stacked operand copies)
            m, l, acc = carry
            kj = jax.lax.dynamic_slice_in_dim(k, b0, kv_chunk, axis=1)
            vj = jax.lax.dynamic_slice_in_dim(v, b0, kv_chunk, axis=1)
            logits = jnp.einsum("bqhgd,bkhd->bhgqk", qi, kj,
                                preferred_element_type=jnp.float32)
            kpos = b0 + jnp.arange(kv_chunk)
            mask = jnp.ones((cq, kv_chunk), bool)
            if causal:
                mask &= kpos[None, :] <= q_pos[:, None]
            if window:
                mask &= kpos[None, :] > q_pos[:, None] - window
            logits = jnp.where(mask[None, None, None], logits, -1e30)
            mj = jnp.maximum(m, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - mj[..., None])
            corr = jnp.exp(m - mj)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(q.dtype), vj,
                preferred_element_type=jnp.float32)
            return (mj, l, acc), None

        m0 = jnp.full((b, kvp, g, cq), -1e30, jnp.float32)
        l0 = jnp.zeros((b, kvp, g, cq), jnp.float32)
        a0 = jnp.zeros((b, kvp, g, cq, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), base)
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(o.transpose(0, 3, 1, 2, 4))  # (b, cq, kvp, g, d)
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


def window_attention(
    q: jnp.ndarray,  # (b, W, kvp, g, d) a W-token window per sequence
    k_cache: jnp.ndarray,  # (b, kvp, S, d) HEAD-MAJOR layout
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,  # (b, W) absolute position of each window token
    window: int = 0,
) -> jnp.ndarray:
    """Grouped attention for a W-token window over a (possibly seq-sharded)
    cache, causal WITHIN the window: query i attends to cache positions
    <= pos[:, i] (each window token's K/V is already written at its own
    position, so the window verifies in one pass — the speculative-decoding
    target forward). W == 1 is exactly single-token decode attention.

    The cache is head-major (b, kvp, S, d): both einsums consume it with
    (b, h) as batch dims and contract d / S directly — no transposed copies
    of the cache are ever materialized (this layout change removed ~2/3 of
    decode cache traffic, EXPERIMENTS.md §Perf).

    softmax reductions over the cache S axis are GSPMD-partitionable, so when
    the cache is sharded on S over the `model` axis this lowers to the
    flash-decode pattern (local partial max/sum + all-reduce) automatically.
    """
    b, W, kvp, g, d = q.shape
    S = k_cache.shape[2]
    scale = 1.0 / math.sqrt(d)
    qs = (q * jnp.asarray(scale, q.dtype)).astype(k_cache.dtype)
    logits = jnp.einsum("bqhgd,bhsd->bhgqs", qs, k_cache,
                        preferred_element_type=jnp.float32)
    valid = jnp.arange(S)[None, None, :] <= pos[:, :, None]  # (b, W, S)
    if window:
        valid &= jnp.arange(S)[None, None, :] > pos[:, :, None] - window
    logits = jnp.where(valid[:, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqs,bhsd->bqhgd", w.astype(k_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,  # (b, kvp, g, d) one new token per sequence
    k_cache: jnp.ndarray,  # (b, kvp, S, d) HEAD-MAJOR layout
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,  # (b,) index of the current (just-written) token
    window: int = 0,
) -> jnp.ndarray:
    """Single-step grouped attention — ``window_attention`` at W = 1."""
    return window_attention(q[:, None], k_cache, v_cache, pos[:, None],
                            window)[:, 0]


# ---------------------------------------------------------------------------
# paged (block-table) KV cache — continuous-batching serving
#
# A shared pool of fixed-size blocks (L, n_blocks, kvp, block_size, hd) holds
# the K/V of every in-flight request; each request owns an ordered list of
# block ids (its "block table" row). Sequences of different lengths coexist
# without padding the pool to max_len: a request only holds the blocks its
# current length needs, and retirement returns them to the allocator.
# Block 0 is reserved as a scratch block: idle batch slots and block-table
# padding point at it, so writes from inactive slots land harmlessly there.

SCRATCH_BLOCK = 0


def init_paged_cache(cfg: ModelConfig, n_blocks: int, block_size: int,
                     dtype=None, sharding=None) -> PyTree:
    """Block pool, head-major within a block (decode reads it untransposed).

    ``sharding`` (an optional jax Sharding, e.g. NamedSharding over the
    serving mesh from rules.paged_cache_pspec) allocates the pool directly
    into its distributed layout — a production pool is sized to fill HBM
    across the mesh and must never materialize on one device first. Still
    mesh-agnostic: the layout decision lives with the caller."""
    g = HeadGeometry(cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim)
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    shape = (cfg.n_layers, n_blocks, g.kvp, block_size, g.head_dim)
    kw = {} if sharding is None else {"device": sharding}
    return {"k": jnp.zeros(shape, dtype, **kw),
            "v": jnp.zeros(shape, dtype, **kw)}


def paged_gather(pages_l: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Assemble per-request contiguous K or V views from the pool.

    pages_l: (n_blocks, kvp, bs, hd) one layer's pool; table: (b, nb) block
    ids in sequence order. Returns (b, kvp, nb*bs, hd) — the head-major
    layout decode_attention consumes. Positions past a request's length hold
    stale/scratch data and must be masked by `pos` (decode_attention does).
    """
    b, nb = table.shape
    _, kvp, bs, hd = pages_l.shape
    gath = pages_l[table]  # (b, nb, kvp, bs, hd)
    return gath.transpose(0, 2, 1, 3, 4).reshape(b, kvp, nb * bs, hd)


def paged_write_window(pages: jnp.ndarray, layer, table: jnp.ndarray,
                       pos: jnp.ndarray, val: jnp.ndarray, block_size: int,
                       enable: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Scatter a W-token window's K or V per request through the block table.

    pages: (L, n_blocks, kvp, bs, hd); layer: scalar (may be traced);
    table: (b, nb); pos: (b, W) absolute write positions; val: (b, W, kvp,
    hd); enable: (b, W) bool — window tokens past a slot's valid window
    length (and every token of an idle slot) are routed to the scratch
    block, so a write can NEVER land outside the blocks a request owns. A
    true scatter — no full-layer rewrite rides the loop. Shared by the
    speculative verify window AND chunked prefill (transformer.py
    ``prefill_chunk_paged``), whose chunks resume at arbitrary block-
    aligned positions over possibly prefix-cache-shared tables.
    """
    nb = table.shape[1]
    blk = jnp.take_along_axis(table, jnp.clip(pos // block_size, 0, nb - 1),
                              axis=1)  # (b, W)
    if enable is not None:
        blk = jnp.where(enable, blk, SCRATCH_BLOCK)
    off = pos % block_size
    return pages.at[layer, blk, :, off, :].set(val.astype(pages.dtype))


def paged_write_token(pages: jnp.ndarray, layer, table: jnp.ndarray,
                      pos: jnp.ndarray, val: jnp.ndarray,
                      block_size: int) -> jnp.ndarray:
    """Scatter one token's K or V per request — ``paged_write_window`` at
    W = 1. pos: (b,); val: (b, kvp, hd)."""
    return paged_write_window(pages, layer, table, pos[:, None],
                              val[:, None], block_size)


def paged_write_prefill(pages: jnp.ndarray, kv: jnp.ndarray,
                        blocks: jnp.ndarray, block_size: int) -> jnp.ndarray:
    """Write a whole prompt's K or V into freshly allocated blocks.

    pages: (L, n_blocks, kvp, bs, hd); kv: (L, s, kvp, hd) from prefill;
    blocks: (nb,) with nb*bs >= s (tail zero-padded inside the last block).
    """
    L, s, kvp, hd = kv.shape
    nb = blocks.shape[0]
    pad = nb * block_size - s
    kv = jnp.pad(kv, ((0, 0), (0, pad), (0, 0), (0, 0)))
    tiles = kv.reshape(L, nb, block_size, kvp, hd).transpose(0, 1, 3, 2, 4)
    return pages.at[:, blocks].set(tiles.astype(pages.dtype))


# ---------------------------------------------------------------------------
# the paper's mechanism: tile-level activation sparsity (DESIGN.md §3)


def tile_scores(h: jnp.ndarray, tile: int) -> jnp.ndarray:
    """Per-tile activity score. h: (..., F) -> (..., F//tile)."""
    F = h.shape[-1]
    ht = jnp.abs(h).reshape(h.shape[:-1] + (F // tile, tile))
    return jnp.max(ht, axis=-1)


def select_active_tiles(
    scores: jnp.ndarray,  # (tokens, n_tiles) or (n_tiles,)
    density: float,
    n_groups: int = 1,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Static-capacity top-k tile selection, batch-union, group-balanced.

    Returns (idx, mask): idx (k_total,) int32 *global* tile indices, mask
    (k_total,) {0,1} marking tiles that were truly active (score > 0). Groups
    keep the selection balanced across TP shards so the gather stays
    shard-local when the weight's F axis is sharded n_groups-way.
    """
    if scores.ndim == 2:  # union over tokens (batch aggregated sparsity)
        scores = jnp.max(scores, axis=0)
    n_tiles = scores.shape[-1]
    gsz = n_tiles // n_groups
    k_g = max(1, int(math.ceil(density * gsz)))
    sg = scores.reshape(n_groups, gsz)
    top, idx_l = jax.lax.top_k(sg, k_g)  # (g, k_g) group-local indices
    idx = idx_l + (jnp.arange(n_groups) * gsz)[:, None]
    mask = (top > 0).astype(scores.dtype)
    return idx.reshape(-1).astype(jnp.int32), mask.reshape(-1)


def gathered_matmul(
    x: jnp.ndarray,  # (tokens, F) sparse-ish input
    w: jnp.ndarray,  # (F, D) weights
    idx: jnp.ndarray,  # (k,) active tile indices
    mask: jnp.ndarray,  # (k,) validity
    tile: int,
) -> jnp.ndarray:
    """y = x @ w computed only over the selected F tiles (XLA path).

    This is the paper's "skip zero rows" on TPU: only k·tile rows of w are
    read and multiplied. The Pallas kernel (kernels/sparse_matmul.py) is the
    deployment version; this gather+dot is mathematically identical and is
    what the dry-run lowers (cost_analysis reflects the FLOP/byte savings).
    """
    t, F = x.shape
    D = w.shape[1]
    k = idx.shape[0]
    xt = x.reshape(t, F // tile, tile)
    xg = jnp.take(xt, idx, axis=1) * mask[None, :, None].astype(x.dtype)
    wt = w.reshape(F // tile, tile, D)
    wg = jnp.take(wt, idx, axis=0)
    return jax.lax.dot_general(
        xg.reshape(t, k * tile), wg.reshape(k * tile, D),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    ).astype(x.dtype)


def pick_group_tile(F: int, n_groups: int) -> int:
    """Largest tile size dividing F/n_groups, sublane-aligned (%8), with at
    least ~4 tiles per group for useful top-k granularity."""
    per = F // n_groups
    cap = max(64, per // 4)
    for t in range(cap, 7, -1):
        if per % t == 0 and t % 8 == 0:
            return t
    for t in range(cap, 0, -1):
        if per % t == 0:
            return t
    return per


def ffn_gather_tile(cfg: ModelConfig) -> int:
    """The FFN weight-gather tile width: cfg.sparsity.tile_size when it
    divides d_ff (default 128 = TPU lane width), else the aligned fallback.
    The single source of truth for the granularity shared by the serving
    decode steps' tile-activity scores (models/transformer.py) and the
    activity predictors' masks (repro.predictor) — they must agree or
    predicted masks stop being weight-I/O plans."""
    ts = cfg.sparsity.tile_size
    return ts if cfg.d_ff % ts == 0 else pick_group_tile(cfg.d_ff, 1)


def grouped_sparse_matmul(x, w, density: float, n_groups: int):
    """Shard-local tile-gathered matmul (the §Perf optimization).

    The F axis is cut into `n_groups` groups aligned with the weight's
    sharding (n_groups = TP degree makes every gather shard-local: indices
    and weight slices live on the same chip, so GSPMD emits NO weight
    all-gather — only the usual small TP psum of the (t, D) output).
    Capacity is balanced per group, which also load-balances the TP shards.
    """
    t, F = x.shape
    D = w.shape[1]
    tile = pick_group_tile(F, n_groups)
    per = F // n_groups
    tiles_g = per // tile
    k_g = max(1, int(math.ceil(density * tiles_g)))

    xt = x.reshape(t, n_groups, tiles_g, tile)
    sc = jnp.max(jnp.abs(xt), axis=(0, 3))  # (G, tiles_g) union over tokens
    top, idx = jax.lax.top_k(sc, k_g)  # (G, k_g) group-local tile ids
    mask = (top > 0).astype(x.dtype)

    xg = jnp.take_along_axis(xt, idx[None, :, :, None], axis=2)  # (t,G,k,c)
    xg = xg * mask[None, :, :, None]
    w4 = w.reshape(n_groups, tiles_g, tile, D)
    wg = jnp.take_along_axis(w4, idx[:, :, None, None], axis=1)  # (G,k,c,D)
    return jnp.einsum("tgkc,gkcd->td", xg, wg,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def maybe_sparse_matmul(x, w, cfg: ModelConfig, density: float,
                        n_groups: int = 0):
    """Dense x@w, or tile-gathered if a sparse decode path is configured."""
    if density >= 1.0:
        return x @ w
    n_groups = n_groups or cfg.sparsity.n_groups
    if n_groups > 1 and x.shape[1] % n_groups == 0:
        return grouped_sparse_matmul(x, w, density, n_groups)
    sc = tile_scores(x, cfg.sparsity.tile_size)
    idx, mask = select_active_tiles(sc, density, 1)
    return gathered_matmul(x, w, idx, mask, cfg.sparsity.tile_size)


# ---------------------------------------------------------------------------
# sparsity instrumentation (paper Figs. 1/2/4; Table 1 sparsity columns)


def site_sparsity(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((x == 0).astype(jnp.float32))


def wrap_block(policy: str, block_fn):
    """Wrap a family block fn with the configured remat policy.

    "save_ars" saves the TP-collective outputs (attn_out / ffn_out) so the
    backward pass re-runs neither those matmuls nor their all-reduces —
    trades a little activation memory for ~1/3 of the TP collective volume
    (the §Perf lever for collective-bound training).
    """
    if policy in (None, "none"):
        return block_fn
    if policy == "save_ars":
        pol = jax.checkpoint_policies.save_only_these_names(
            "attn_out", "ffn_out")
    else:
        pol = (None if policy == "full"
               else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    def block(p, x, cfg, *, positions, stats, return_kv=False):
        assert not return_kv

        def inner(p_, x_, cfg_):
            return block_fn(p_, x_, cfg_, positions=positions, stats=stats)
        kw = {} if pol is None else {"policy": pol}
        return jax.checkpoint(inner, static_argnums=(2,), **kw)(p, x, cfg)

    return block


def cast_params(params: PyTree, cfg: ModelConfig) -> PyTree:
    """Mixed precision: cast f32 master params to the compute dtype at the
    model entry point (differentiable; grads accumulate back in f32)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    return jax.tree.map(
        lambda p: p.astype(cdt) if p.dtype == jnp.float32 else p, params)


class StatsCollector:
    """Accumulates per-site sparsity/preactivation stats during apply().

    Inactive (the default) it is free: `add` becomes a no-op so the dry-run
    HLO contains no instrumentation.
    """

    def __init__(self, active: bool = False, raw: bool = False):
        self.active = active
        self.raw = active and raw
        self.stats: Dict[str, jnp.ndarray] = {}

    def add(self, name: str, value: jnp.ndarray):
        if self.active:
            self.stats[name] = value

    def add_raw(self, name: str, x: jnp.ndarray):
        """Capture a full activation tensor (calibration runs only — e.g.
        the predictor harness needs per-layer FFN inputs, not summaries).
        No-op unless the collector was built with raw=True."""
        if self.raw:
            self.stats[name] = jax.lax.stop_gradient(x)

    def add_sparsity(self, name: str, x: jnp.ndarray):
        if self.active:
            self.stats[name] = site_sparsity(jax.lax.stop_gradient(x))

    def add_preact(self, name: str, x: jnp.ndarray):
        if self.active:
            xf = jax.lax.stop_gradient(x).astype(jnp.float32)
            self.stats[name + "/mean"] = jnp.mean(xf)
            self.stats[name + "/std"] = jnp.std(xf)
            self.stats[name + "/frac_neg"] = jnp.mean((xf < 0).astype(jnp.float32))
