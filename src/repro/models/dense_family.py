"""Registry adapter for the dense decoder family (batch-dict interface)."""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models import transformer as T


def init_params(rng, cfg: ModelConfig):
    return T.init_params(rng, cfg)


def model_forward(params, batch, cfg: ModelConfig, *, stats=None,
                  remat_policy="none"):
    return T.forward(params, batch["tokens"], cfg, stats=stats,
                     remat_block=cm.wrap_block(remat_policy, T.apply_block))


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    return T.init_cache(cfg, batch, max_len)


def model_prefill(params, batch, cfg: ModelConfig, max_len: int, stats=None):
    return T.prefill(params, batch["tokens"], cfg, max_len, stats=stats)


def model_decode(params, cache, token, pos, cfg: ModelConfig, stats=None,
                 ffn_masks=None):
    return T.decode_step(params, cache, token, pos, cfg, stats=stats,
                         ffn_masks=ffn_masks)


# -- continuous-batching (paged-cache) serving interface --------------------

def init_paged_cache(cfg: ModelConfig, n_blocks: int, block_size: int,
                     sharding=None):
    return cm.init_paged_cache(cfg, n_blocks, block_size, sharding=sharding)


def model_prefill_paged(params, batch, cfg: ModelConfig, pages, blocks,
                        block_size: int, true_len=None):
    return T.prefill_paged(params, batch["tokens"], cfg, pages, blocks,
                           block_size=block_size, true_len=true_len)


def model_prefill_chunk_paged(params, batch, cfg: ModelConfig, pages, table,
                              pos0, clen, ffn_masks, refresh,
                              block_size: int, fast_kernels: bool = False):
    return T.prefill_chunk_paged(params, pages, table, batch["tokens"],
                                 pos0, clen, cfg, ffn_masks, refresh,
                                 block_size=block_size,
                                 fast_kernels=fast_kernels)


def model_decode_paged(params, pages, table, token, pos, cfg: ModelConfig,
                       ffn_masks, refresh, block_size: int,
                       fast_kernels: bool = False):
    return T.decode_step_paged(params, pages, table, token, pos, cfg,
                               ffn_masks, refresh, block_size=block_size,
                               fast_kernels=fast_kernels)


def model_decode_paged_predicted(params, pages, table, token, pos,
                                 cfg: ModelConfig, ffn_masks, refresh,
                                 pred_params, kind: str, tile: int,
                                 k_tiles: int, block_size: int,
                                 measure: bool = True, shards: int = 1,
                                 fast_kernels: bool = False):
    return T.decode_step_paged_predicted(params, pages, table, token, pos,
                                         cfg, ffn_masks, refresh, pred_params,
                                         kind=kind, tile=tile,
                                         k_tiles=k_tiles,
                                         block_size=block_size,
                                         measure=measure, shards=shards,
                                         fast_kernels=fast_kernels)


def model_verify_window_paged(params, pages, table, tokens, pos0, wlen,
                              cfg: ModelConfig, ffn_masks, refresh,
                              block_size: int, fast_kernels: bool = False):
    return T.verify_window_paged(params, pages, table, tokens, pos0, wlen,
                                 cfg, ffn_masks, refresh,
                                 block_size=block_size,
                                 fast_kernels=fast_kernels)


def model_draft_gamma_paged(params, pages, table, token, pos0, wlen,
                            cfg: ModelConfig, gamma: int, block_size: int,
                            next_fn=None, fast_kernels: bool = False):
    return T.draft_gamma_paged(params, pages, table, token, pos0, wlen, cfg,
                               gamma=gamma, block_size=block_size,
                               next_fn=next_fn,
                               fast_kernels=fast_kernels)
