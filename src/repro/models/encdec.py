"""Whisper-style encoder-decoder family.

The conv frontend is a STUB: input_specs provides precomputed frame
embeddings (b, n_audio_frames, d_model). Encoder = bidirectional transformer
over frames + sinusoidal positions; decoder = causal self-attention +
cross-attention to the encoder output + FFN. Relufication applies to both
stacks' FFNs (GELU -> ReLU) and stage-2 post-norm ReLU.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models import transformer as T
from repro.sharding import rules

PyTree = Any


def sinusoid(n: int, d: int) -> np.ndarray:
    pos = np.arange(n)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * i / d))
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=-1).astype(np.float32)


def init_dec_block(rng, cfg: ModelConfig, dtype) -> PyTree:
    ks = jax.random.split(rng, 3)
    return {
        "ln1": cm.init_norm(cfg, cfg.d_model, dtype),
        "attn": T.init_attn(ks[0], cfg, dtype),
        "lnx": cm.init_norm(cfg, cfg.d_model, dtype),
        "xattn": T.init_attn(ks[1], cfg, dtype),
        "ln2": cm.init_norm(cfg, cfg.d_model, dtype),
        "ffn": T.init_ffn(ks[2], cfg, dtype),
    }


def init_params(rng, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    vp = cm.padded_vocab(cfg.vocab_size)
    ks = jax.random.split(rng, 5)
    enc = jax.vmap(lambda k: T.init_block(k, cfg, dtype))(
        jax.random.split(ks[0], cfg.n_encoder_layers))
    dec = jax.vmap(lambda k: init_dec_block(k, cfg, dtype))(
        jax.random.split(ks[1], cfg.n_layers))
    return {
        "embed": cm.embed_init(ks[2], (vp, cfg.d_model), dtype),
        "pos_embed": cm.embed_init(ks[3], (cfg.max_seq_len, cfg.d_model), dtype),
        "enc_layers": enc,
        "enc_norm": cm.init_norm(cfg, cfg.d_model, dtype),
        "dec_layers": dec,
        "final_norm": cm.init_norm(cfg, cfg.d_model, dtype),
    }


def encode(params, frames, cfg: ModelConfig, *, stats, remat_policy="none"):
    """frames: (b, n_frames, d) stub embeddings -> encoder output."""
    b, nf, d = frames.shape
    x = frames + jnp.asarray(sinusoid(nf, d), frames.dtype)
    x = rules.constrain(x, "dp", None, None)
    positions = jnp.broadcast_to(jnp.arange(nf), (b, nf))

    base = T.apply_block

    def enc_block(p, x, cfg_, *, positions, stats, return_kv=False):
        return base(p, x, cfg_, positions=positions, stats=stats,
                    causal=False)
    block = cm.wrap_block(remat_policy, enc_block)

    def body(x, pl_i):
        return block(pl_i, x, cfg, positions=positions, stats=stats), None
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return cm.apply_norm(params["enc_norm"], x, cfg)


def _cross_kv(p, enc_out, cfg: ModelConfig):
    """K/V from the encoder output with the cross-attn projections."""
    g = T.attn_geometry(cfg)
    b, se, d = enc_out.shape
    k = jnp.einsum("bsd,dkh->bskh", enc_out, p["wk"])
    v = jnp.einsum("bsd,dkh->bskh", enc_out, p["wv"])
    if cfg.qkv_bias:
        k = k + p["bk"]
        v = v + p["bv"]
    return k, v


def _cross_attend(p, h, kx, vx, cfg: ModelConfig, *, stats):
    """h: (b, s, d) decoder states; kx/vx: (b, se, kvp, hd)."""
    g = T.attn_geometry(cfg)
    b, s, d = h.shape
    q = jnp.einsum("bsd,dqh->bsqh", h, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    qg = q.reshape(b, s, g.kvp, g.group, g.head_dim)
    o = cm.flash_attention(qg, kx, vx, causal=False)
    return T._attn_out(p, o.reshape(b, s, g.hp, g.head_dim), cfg)


def apply_dec_block(p, x, cfg: ModelConfig, enc_out, *, positions, stats,
                    return_kv=False):
    h = T.post_norm(cm.apply_norm(p["ln1"], x, cfg), cfg)
    if return_kv:
        a, kv = T.apply_attn_full(p["attn"], h, cfg, positions=positions,
                                  stats=stats, return_kv=True)
    else:
        a = T.apply_attn_full(p["attn"], h, cfg, positions=positions, stats=stats)
    x = x + a
    h = T.post_norm(cm.apply_norm(p["lnx"], x, cfg), cfg)
    kx, vx = _cross_kv(p["xattn"], enc_out, cfg)
    x = x + _cross_attend(p["xattn"], h, kx, vx, cfg, stats=stats)
    h = T.post_norm(cm.apply_norm(p["ln2"], x, cfg), cfg)
    b, s, d = h.shape
    x = x + T.apply_ffn(p["ffn"], h.reshape(b * s, d), cfg,
                        stats=stats).reshape(b, s, d)
    return (x, kv) if return_kv else x


def model_forward(params, batch, cfg: ModelConfig, *, stats=None,
                  remat_policy="none"):
    stats = stats or cm.StatsCollector(False)
    params = cm.cast_params(params, cfg)
    enc_out = encode(params, batch["frames"], cfg, stats=stats,
                     remat_policy=remat_policy)
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = jnp.take(params["embed"], tokens, axis=0) \
        + jnp.take(params["pos_embed"], positions, axis=0)
    x = rules.constrain(x.astype(enc_out.dtype), "dp", None, None)

    def dec(p, x_, cfg_, *, positions, stats, return_kv=False):
        return apply_dec_block(p, x_, cfg_, enc_out, positions=positions,
                               stats=stats, return_kv=return_kv)
    block = cm.wrap_block(remat_policy, dec)

    def body(x, pl_i):
        return block(pl_i, x, cfg, positions=positions, stats=stats), None
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = cm.apply_norm(params["final_norm"], x, cfg)
    return T.logits_from(params, x, cfg)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    g = T.attn_geometry(cfg)
    L = cfg.n_layers
    return {  # head-major KV layout (see models/common.decode_attention)
        "k": jnp.zeros((L, batch, g.kvp, max_len, g.head_dim), dtype),
        "v": jnp.zeros((L, batch, g.kvp, max_len, g.head_dim), dtype),
        "xk": jnp.zeros((L, batch, g.kvp, cfg.n_audio_frames, g.head_dim), dtype),
        "xv": jnp.zeros((L, batch, g.kvp, cfg.n_audio_frames, g.head_dim), dtype),
    }


def model_prefill(params, batch, cfg: ModelConfig, max_len: int, stats=None):
    stats = stats or cm.StatsCollector(False)
    params_c = cm.cast_params(params, cfg)
    enc_out = encode(params_c, batch["frames"], cfg, stats=stats)
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = (jnp.take(params_c["embed"], tokens, axis=0)
         + jnp.take(params_c["pos_embed"], positions, axis=0)).astype(enc_out.dtype)

    def body(x, pl_i):
        kx, vx = _cross_kv(pl_i["xattn"], enc_out, cfg)
        x, kv = apply_dec_block(pl_i, x, cfg, enc_out, positions=positions,
                                stats=stats, return_kv=True)
        return x, (kv[0], kv[1], kx, vx)
    x, (k, v, xk, xv) = jax.lax.scan(body, x, params_c["dec_layers"])
    x = cm.apply_norm(params_c["final_norm"], x, cfg)
    logits = T.logits_from(params_c, x, cfg)
    k = k.transpose(0, 1, 3, 2, 4)  # head-major
    v = v.transpose(0, 1, 3, 2, 4)
    xk = xk.transpose(0, 1, 3, 2, 4)
    xv = xv.transpose(0, 1, 3, 2, 4)
    pad = max_len - k.shape[3]
    if pad > 0:
        zeros = jnp.zeros(k.shape[:3] + (pad,) + k.shape[4:], k.dtype)
        k = jnp.concatenate([k, zeros], axis=3)
        v = jnp.concatenate([v, zeros], axis=3)
    return logits[:, -1], {"k": k, "v": v, "xk": xk, "xv": xv}


def apply_dec_block_decode(p, x, cfg, kc, vc, xk, xv, pos, *, stats, layer):
    h = T.post_norm(cm.apply_norm(p["ln1"], x[:, None], cfg)[:, 0], cfg)
    a, kc, vc = T.apply_attn_decode(p["attn"], h, cfg, kc, vc, pos,
                                    stats=stats, layer=layer)
    x = x + a
    h = T.post_norm(cm.apply_norm(p["lnx"], x[:, None], cfg)[:, 0], cfg)
    g = T.attn_geometry(cfg)
    q = jnp.einsum("bd,dqh->bqh", h, p["xattn"]["wq"])
    if cfg.qkv_bias:
        q = q + p["xattn"]["bq"]
    xk_l = jax.lax.dynamic_index_in_dim(xk, layer, 0, keepdims=False)
    xv_l = jax.lax.dynamic_index_in_dim(xv, layer, 0, keepdims=False)
    se = xk_l.shape[2]  # head-major (b, kvp, se, hd)
    o = cm.decode_attention(q.reshape(-1, g.kvp, g.group, g.head_dim),
                            xk_l, xv_l,
                            jnp.full((x.shape[0],), se - 1, jnp.int32))
    xo = T._attn_out(p["xattn"],
                     o.reshape(o.shape[0], 1, g.hp, g.head_dim), cfg)[:, 0]
    x = x + xo
    h = T.post_norm(cm.apply_norm(p["ln2"], x[:, None], cfg)[:, 0], cfg)
    x = x + T.apply_ffn(p["ffn"], h, cfg, stats=stats, decode=True)
    return x, kc, vc


def model_decode(params, cache, token, pos, cfg: ModelConfig, stats=None):
    stats = stats or cm.StatsCollector(False)
    params = cm.cast_params(params, cfg)
    x = (jnp.take(params["embed"], token, axis=0)
         + jnp.take(params["pos_embed"], pos, axis=0))
    x = x.astype(jnp.dtype(cfg.compute_dtype))

    if stats.active:
        kc, vc = cache["k"], cache["v"]
        for i in range(cfg.n_layers):
            pl_i = jax.tree.map(lambda a: a[i], params["dec_layers"])
            x, kc, vc = apply_dec_block_decode(
                pl_i, x, cfg, kc, vc, cache["xk"], cache["xv"], pos,
                stats=stats, layer=i)
        new_cache = dict(cache, k=kc, v=vc)
    else:
        def body(carry, xs):
            x, kc, vc = carry
            pl_i, li = xs
            x, kc, vc = apply_dec_block_decode(
                pl_i, x, cfg, kc, vc, cache["xk"], cache["xv"], pos,
                stats=stats, layer=li)
            return (x, kc, vc), None
        (x, kc, vc), _ = jax.lax.scan(
            body, (x, cache["k"], cache["v"]),
            (params["dec_layers"], jnp.arange(cfg.n_layers)))
        new_cache = dict(cache, k=kc, v=vc)

    x = cm.apply_norm(params["final_norm"], x[:, None], cfg)[:, 0]
    return T.logits_from(params, x, cfg), new_cache
