"""Zamba-2 hybrid family: Mamba-2 (SSD) backbone + ONE shared attention+FFN
block applied every `attn_every` layers (13 applications over 81 layers).

Mamba-2 uses the SSD chunked algorithm (matmul form — MXU friendly):
intra-chunk quadratic attention-like matmuls with decay masks, inter-chunk
state recurrence via a cheap scan over chunks.

Relufication: the shared attention block's FFN relufies exactly like dense
(stages 1+2); the Mamba-2 gate (SiLU on z) relufies like falcon-mamba,
sparsifying the out_proj input (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import activations as acts
from repro.models import common as cm
from repro.models import transformer as T
from repro.sharding import rules

PyTree = Any


def init_mamba2(rng, cfg: ModelConfig, dtype) -> PyTree:
    d, di, st = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = cfg.n_ssm_heads
    k = cfg.ssm_conv
    ks = jax.random.split(rng, 3)
    return {
        "norm": cm.init_norm(cfg, d, dtype),
        "ssm": {
            # in_proj -> [z(di), x(di), B(st), C(st), dt(nh)]
            "in_proj": cm.dense_init(ks[0], (d, 2 * di + 2 * st + nh), d, dtype),
            "conv_w": cm.dense_init(ks[1], (k, di), k, dtype),
            "conv_b": jnp.zeros((di,), dtype),
            "A_log": jnp.zeros((nh,), dtype),  # A = -exp(A_log) = -1
            "D": jnp.ones((nh,), dtype),
            "dt_bias": jnp.full((nh,), -4.6, dtype),
            "gnorm": jnp.ones((di,), dtype),  # gated RMSNorm before out_proj
            "out_proj": cm.dense_init(ks[2], (di, d), di, dtype),
        },
    }


def _split_in_proj(p, h_in, cfg: ModelConfig):
    di, st, nh = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    zxbcdt = h_in @ p["in_proj"]
    return jnp.split(zxbcdt, [di, 2 * di, 2 * di + st, 2 * di + 2 * st], axis=-1)


def _gated_out(p, y, z, cfg, act, stats):
    """y, z: (..., di). Gated RMSNorm then (possibly sparse) out_proj."""
    stats.add_preact("gate_pre", z)
    g = y * act(z)
    gf = g.astype(jnp.float32)
    var = jnp.mean(jnp.square(gf), axis=-1, keepdims=True)
    g = (gf * jax.lax.rsqrt(var + 1e-6)).astype(y.dtype) * p["gnorm"]
    stats.add_sparsity("down_in", g)
    dens = cfg.sparsity.ffn_tile_density if cfg.sparsity.enabled else 1.0
    flat = g.reshape(-1, g.shape[-1])
    out = cm.maybe_sparse_matmul(flat, p["out_proj"], cfg,
                                 dens if g.ndim == 2 else 1.0)
    return out.reshape(g.shape[:-1] + (p["out_proj"].shape[-1],))


def apply_mamba2_block(p, x, cfg: ModelConfig, *, positions=None, stats,
                       return_kv=False):
    """SSD chunked scan. x: (b, s, d)."""
    assert not return_kv
    b, s, d = x.shape
    di, st, nh = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    hd = cfg.ssm_head_dim
    act = acts.get(cfg.activation, shift=cfg.sparsity.shift)
    Q = cm._largest_divisor_leq(s, cfg.ssm_chunk)
    nc = s // Q

    h_in = cm.apply_norm(p["norm"], x, cfg)
    if cfg.post_norm_relu:
        h_in = jax.nn.relu(h_in)
    stats.add_sparsity("qkv_in", h_in)
    z, xs, B, C, dt = _split_in_proj(p["ssm"], h_in, cfg)
    xs = rules.constrain(xs, "dp", None, "model")
    xs = act(jnp.pad(_causal_conv_seq(xs, p["ssm"]), ((0, 0), (0, 0), (0, 0))))
    dt = jax.nn.softplus(dt + p["ssm"]["dt_bias"]).astype(jnp.float32)  # (b,s,nh)
    A = -jnp.exp(p["ssm"]["A_log"].astype(jnp.float32))  # (nh,)
    la = dt * A  # (b, s, nh) log-decay per step

    xh = xs.reshape(b, nc, Q, nh, hd)
    lac = la.reshape(b, nc, Q, nh)
    Bc = B.reshape(b, nc, Q, st).astype(jnp.float32)
    Cc = C.reshape(b, nc, Q, st).astype(jnp.float32)
    cum = jnp.cumsum(lac, axis=2)  # (b, nc, Q, nh)

    # intra-chunk: y[i] = sum_{j<=i} exp(cum_i - cum_j) (C_i.B_j) x_j dt_j
    dtc = dt.reshape(b, nc, Q, nh)
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # (b,nc,Q,Q,nh)
    tri = jnp.tril(jnp.ones((Q, Q), jnp.float32))
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)
    w = scores[..., None] * decay * tri[None, None, :, :, None]
    y_intra = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", w,
                         dtc, xh.astype(jnp.float32))

    # chunk states: S_c = sum_j exp(cum_end - cum_j) dt_j B_j x_j^T
    dec_out = jnp.exp(cum[:, :, -1:, :] - cum)  # (b, nc, Q, nh)
    S = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Bc, dec_out * dtc,
                   xh.astype(jnp.float32))  # (b, nc, nh, st, hd)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (b, nc, nh)

    def chunk_scan(H, inp):
        S_c, dec_c = inp
        H_new = dec_c[:, :, None, None] * H + S_c
        return H_new, H

    S_t = S.transpose(1, 0, 2, 3, 4)
    d_t = chunk_decay.transpose(1, 0, 2)
    H_last, H_prefix = jax.lax.scan(
        chunk_scan, jnp.zeros((b, nh, st, hd), jnp.float32), (S_t, d_t))
    H_prefix = H_prefix.transpose(1, 0, 2, 3, 4)  # state BEFORE each chunk

    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp", Cc, jnp.exp(cum), H_prefix)
    y = (y_intra + y_inter).astype(x.dtype).reshape(b, s, nh, hd)
    y = y + p["ssm"]["D"][None, None, :, None] * xs.reshape(b, s, nh, hd)
    y = y.reshape(b, s, di)
    out = _gated_out(p["ssm"], y, z, cfg, act, stats)
    return x + rules.constrain(out, "dp", None, None)


def _causal_conv_seq(x, pssm):
    k = pssm["conv_w"].shape[0]
    out = x * pssm["conv_w"][k - 1]
    for i in range(1, k):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * pssm["conv_w"][k - 1 - i]
    return out + pssm["conv_b"]


def apply_mamba2_decode(p, x, cfg: ModelConfig, ssm_state, conv_state, *,
                        stats, layer):
    """One-token SSD step. ssm_state: (L,b,nh,st,hd); conv_state: (L,b,k-1,di)."""
    di, st, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    act = acts.get(cfg.activation, shift=cfg.sparsity.shift)
    h_in = cm.apply_norm(p["norm"], x[:, None], cfg)[:, 0]
    if cfg.post_norm_relu:
        h_in = jax.nn.relu(h_in)
    z, xs, B, C, dt = _split_in_proj(p["ssm"], h_in, cfg)

    conv_l = jax.lax.dynamic_index_in_dim(conv_state, layer, 0, keepdims=False)
    win = jnp.concatenate([conv_l, xs[:, None]], axis=1)  # (b, k, di)
    xs = act(jnp.einsum("bkd,kd->bd", win, p["ssm"]["conv_w"]) + p["ssm"]["conv_b"])
    conv_state = jax.lax.dynamic_update_slice(
        conv_state, win[None, :, 1:], (layer, 0, 0, 0))

    dt = jax.nn.softplus(dt + p["ssm"]["dt_bias"]).astype(jnp.float32)  # (b, nh)
    A = -jnp.exp(p["ssm"]["A_log"].astype(jnp.float32))
    dec = jnp.exp(dt * A)  # (b, nh)
    xh = xs.reshape(-1, nh, hd).astype(jnp.float32)
    h_l = jax.lax.dynamic_index_in_dim(ssm_state, layer, 0, keepdims=False)
    h_new = dec[:, :, None, None] * h_l.astype(jnp.float32) \
        + jnp.einsum("bn,bh,bhp->bhnp", B.astype(jnp.float32), dt, xh)
    ssm_state = jax.lax.dynamic_update_slice(
        ssm_state, h_new.astype(ssm_state.dtype)[None], (layer, 0, 0, 0, 0))

    y = jnp.einsum("bn,bhnp->bhp", C.astype(jnp.float32), h_new).astype(x.dtype)
    y = y + p["ssm"]["D"][None, :, None] * xs.reshape(-1, nh, hd)
    out = _gated_out(p["ssm"], y.reshape(-1, di), z, cfg, act, stats)
    return x + out, ssm_state, conv_state


# ---------------------------------------------------------------------------
# hybrid assembly: segments of mamba layers + the shared attention block


def _segments(cfg: ModelConfig) -> List[Tuple[int, int, bool]]:
    """[(start, end, attn_after)]: mamba layers [start:end), then maybe attn."""
    ae = cfg.attn_every or cfg.n_layers + 1
    out = []
    i = 0
    while i < cfg.n_layers:
        j = min(i + ae, cfg.n_layers)
        out.append((i, j, j - i == ae))
        i = j
    return out


def init_params(rng, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    vp = cm.padded_vocab(cfg.vocab_size)
    ks = jax.random.split(rng, 4)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    layers = jax.vmap(lambda k: init_mamba2(k, cfg, dtype))(layer_keys)
    return {"embed": cm.embed_init(ks[1], (vp, cfg.d_model), dtype),
            "layers": layers,
            "shared": T.init_block(ks[2], cfg, dtype),  # ONE shared attn+FFN
            "final_norm": cm.init_norm(cfg, cfg.d_model, dtype),
            "unembed": cm.embed_init(ks[3], (vp, cfg.d_model), dtype)}


def model_forward(params, batch, cfg: ModelConfig, *, stats=None,
                  remat_policy="none"):
    stats = stats or cm.StatsCollector(False)
    params = cm.cast_params(params, cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = T.embed_tokens(params, tokens, cfg, positions)
    x = rules.constrain(x, "dp", None, None)
    mblock = cm.wrap_block(remat_policy, apply_mamba2_block)
    ablock = cm.wrap_block(remat_policy, T.apply_block)

    for (i0, i1, attn_after) in _segments(cfg):
        seg = jax.tree.map(lambda a: a[i0:i1], params["layers"])

        def body(x, pl_i):
            return mblock(pl_i, x, cfg, positions=positions, stats=stats), None
        x, _ = jax.lax.scan(body, x, seg)
        if attn_after:
            x = ablock(params["shared"], x, cfg, positions=positions, stats=stats)

    x = cm.apply_norm(params["final_norm"], x, cfg)
    return T.logits_from(params, x, cfg)


def n_attn_applications(cfg: ModelConfig) -> int:
    return sum(1 for (_, _, a) in _segments(cfg) if a)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    L, di, st, k = cfg.n_layers, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    nh, hd = cfg.n_ssm_heads, cfg.ssm_head_dim
    g = T.attn_geometry(cfg)
    na = n_attn_applications(cfg)
    return {"ssm": jnp.zeros((L, batch, nh, st, hd), dtype),
            "conv": jnp.zeros((L, batch, k - 1, di), dtype),
            # head-major KV layout (see models/common.decode_attention)
            "k": jnp.zeros((na, batch, g.kvp, max_len, g.head_dim), dtype),
            "v": jnp.zeros((na, batch, g.kvp, max_len, g.head_dim), dtype)}


def model_prefill(params, batch, cfg: ModelConfig, max_len: int, stats=None):
    stats = stats or cm.StatsCollector(False)
    params_c = cm.cast_params(params, cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = T.embed_tokens(params_c, tokens, cfg, positions)

    ssm_states, conv_states, kvs = [], [], []
    for (i0, i1, attn_after) in _segments(cfg):
        seg = jax.tree.map(lambda a: a[i0:i1], params_c["layers"])

        def body(x, pl_i):
            x, (h_last, conv_tail) = _mamba2_with_state(pl_i, x, cfg, stats=stats)
            return x, (h_last, conv_tail)
        x, (hs, tails) = jax.lax.scan(body, x, seg)
        ssm_states.append(hs)
        conv_states.append(tails)
        if attn_after:
            x, kv = T.apply_block(params_c["shared"], x, cfg,
                                  positions=positions, stats=stats,
                                  return_kv=True)
            kvs.append(kv)

    x = cm.apply_norm(params_c["final_norm"], x, cfg)
    logits = T.logits_from(params_c, x, cfg)
    cdt = jnp.dtype(cfg.compute_dtype)
    ssm_states = [jnp.concatenate(ssm_states)]
    conv_states = [jnp.concatenate(conv_states)]
    k = jnp.stack([kv[0] for kv in kvs]) if kvs else jnp.zeros((0,))
    v = jnp.stack([kv[1] for kv in kvs]) if kvs else jnp.zeros((0,))
    if kvs:
        k = k.transpose(0, 1, 3, 2, 4)  # head-major
        v = v.transpose(0, 1, 3, 2, 4)
    pad = max_len - k.shape[3]
    if pad > 0:
        zeros = jnp.zeros(k.shape[:3] + (pad,) + k.shape[4:], k.dtype)
        k = jnp.concatenate([k, zeros], axis=3)
        v = jnp.concatenate([v, zeros], axis=3)
    return logits[:, -1], {"ssm": ssm_states[0].astype(cdt),
                           "conv": conv_states[0].astype(cdt),
                           "k": k.astype(cdt), "v": v.astype(cdt)}


def _mamba2_with_state(p, x, cfg, *, stats):
    """Full-seq SSD + final state extraction (for prefill)."""
    b, s, d = x.shape
    k = cfg.ssm_conv
    # final conv tail = last (k-1) pre-conv inputs
    h_in = cm.apply_norm(p["norm"], x, cfg)
    if cfg.post_norm_relu:
        h_in = jax.nn.relu(h_in)
    _, xs_raw, _, _, _ = _split_in_proj(p["ssm"], h_in, cfg)
    conv_tail = xs_raw[:, -(k - 1):]
    # rerun the chunked block for outputs + final state via the chunk scan
    x_out, h_last = _mamba2_scan_with_last(p, x, cfg, stats)
    return x_out, (h_last, conv_tail)


def _mamba2_scan_with_last(p, x, cfg, stats):
    """Same math as apply_mamba2_block but also returns the final SSD state."""
    b, s, d = x.shape
    di, st, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    act = acts.get(cfg.activation, shift=cfg.sparsity.shift)
    Q = cm._largest_divisor_leq(s, cfg.ssm_chunk)
    nc = s // Q
    h_in = cm.apply_norm(p["norm"], x, cfg)
    if cfg.post_norm_relu:
        h_in = jax.nn.relu(h_in)
    z, xs, B, C, dt = _split_in_proj(p["ssm"], h_in, cfg)
    xs = act(_causal_conv_seq(xs, p["ssm"]))
    dt = jax.nn.softplus(dt + p["ssm"]["dt_bias"]).astype(jnp.float32)
    A = -jnp.exp(p["ssm"]["A_log"].astype(jnp.float32))
    la = dt * A

    xh = xs.reshape(b, nc, Q, nh, hd)
    lac = la.reshape(b, nc, Q, nh)
    Bc = B.reshape(b, nc, Q, st).astype(jnp.float32)
    Cc = C.reshape(b, nc, Q, st).astype(jnp.float32)
    dtc = dt.reshape(b, nc, Q, nh)
    cum = jnp.cumsum(lac, axis=2)
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])
    tri = jnp.tril(jnp.ones((Q, Q), jnp.float32))
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)
    w = scores[..., None] * decay * tri[None, None, :, :, None]
    y_intra = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", w, dtc, xh.astype(jnp.float32))
    dec_out = jnp.exp(cum[:, :, -1:, :] - cum)
    S = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Bc, dec_out * dtc, xh.astype(jnp.float32))
    chunk_decay = jnp.exp(cum[:, :, -1, :])

    def chunk_scan(H, inp):
        S_c, dec_c = inp
        return dec_c[:, :, None, None] * H + S_c, H

    H_last, H_prefix = jax.lax.scan(
        chunk_scan, jnp.zeros((b, nh, st, hd), jnp.float32),
        (S.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    H_prefix = H_prefix.transpose(1, 0, 2, 3, 4)
    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp", Cc, jnp.exp(cum), H_prefix)
    y = (y_intra + y_inter).astype(x.dtype).reshape(b, s, nh, hd)
    y = y + p["ssm"]["D"][None, None, :, None] * xs.reshape(b, s, nh, hd)
    out = _gated_out(p["ssm"], y.reshape(b, s, di), z, cfg, act, stats)
    return x + out, H_last


def model_decode(params, cache, token, pos, cfg: ModelConfig, stats=None):
    stats = stats or cm.StatsCollector(False)
    params = cm.cast_params(params, cfg)
    x = T.embed_tokens(params, token[:, None], cfg, pos[:, None])[:, 0]
    ssm, conv = cache["ssm"], cache["conv"]
    kc, vc = cache["k"], cache["v"]

    attn_idx = 0
    for (i0, i1, attn_after) in _segments(cfg):
        seg = jax.tree.map(lambda a: a[i0:i1], params["layers"])

        def body(carry, xs_):
            x, ssm, conv = carry
            pl_i, li = xs_
            x, ssm, conv = apply_mamba2_decode(pl_i, x, cfg, ssm, conv,
                                               stats=stats, layer=li)
            return (x, ssm, conv), None
        (x, ssm, conv), _ = jax.lax.scan(
            body, (x, ssm, conv), (seg, jnp.arange(i0, i1)))
        if attn_after:
            x, kc, vc = T.apply_block_decode(params["shared"], x, cfg, kc, vc,
                                             pos, stats=stats, layer=attn_idx)
            attn_idx += 1

    x = cm.apply_norm(params["final_norm"], x[:, None], cfg)[:, 0]
    new_cache = {"ssm": ssm, "conv": conv, "k": kc, "v": vc}
    return T.logits_from(params, x, cfg), new_cache
