"""Mamba-1 family (falcon-mamba-7b) — attention-free selective SSM.

Train/prefill uses jax.lax.associative_scan over the sequence (parallel
prefix, O(log s) depth); decode is the O(1) recurrence with an SSM state +
conv ring buffer carried in the cache.

Relufication (DESIGN.md §5): mamba has no FFN, but the *gate* non-linearity
(SiLU on z) plays the same role — swapping it for ReLU makes the out_proj
input sparse, and the paper's row-skipping applies to out_proj exactly as it
does to a down projection. Stage-2 post-norm ReLU applies before in_proj.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import activations as acts
from repro.models import common as cm
from repro.sharding import rules

PyTree = Any


def dt_rank(cfg: ModelConfig) -> int:
    return max(1, cfg.d_model // 16)


def init_ssm(rng, cfg: ModelConfig, dtype) -> PyTree:
    d, di, st, k = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    dr = dt_rank(cfg)
    ks = jax.random.split(rng, 5)
    A = jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": cm.dense_init(ks[0], (d, 2 * di), d, dtype),
        "conv_w": cm.dense_init(ks[1], (k, di), k, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": cm.dense_init(ks[2], (di, dr + 2 * st), di, dtype),
        "dt_proj": cm.dense_init(ks[3], (dr, di), dr, dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype),  # softplus^-1(0.01)
        "A_log": jnp.log(A).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": cm.dense_init(ks[4], (di, d), di, dtype),
    }


def init_block(rng, cfg: ModelConfig, dtype) -> PyTree:
    return {"norm": cm.init_norm(cfg, cfg.d_model, dtype),
            "ssm": init_ssm(rng, cfg, dtype)}


def _causal_conv(x, w, b):
    """Depthwise causal conv via k shifted adds. x: (b, s, di); w: (k, di)."""
    k = w.shape[0]
    out = x * w[k - 1]
    for i in range(1, k):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * w[k - 1 - i]
    return out + b


def _ssm_inputs(p, h_in, cfg: ModelConfig, stats):
    """Shared between scan and step: project + conv + gate activations."""
    act = acts.get(cfg.activation, shift=cfg.sparsity.shift)
    dr, st = dt_rank(cfg), cfg.ssm_state
    xz = h_in @ p["in_proj"]
    x1, z = jnp.split(xz, 2, axis=-1)
    return x1, z, act, dr, st


def apply_block(p, x, cfg: ModelConfig, *, positions=None, stats,
                return_kv=False):
    """x: (b, s, d) -> (b, s, d). Full-sequence selective scan."""
    assert not return_kv
    b, s, d = x.shape
    di, st = cfg.d_inner, cfg.ssm_state
    h_in = cm.apply_norm(p["norm"], x, cfg)
    if cfg.post_norm_relu:  # stage-2 relufication
        h_in = jax.nn.relu(h_in)
    stats.add_sparsity("qkv_in", h_in)
    x1, z, act, dr, _ = _ssm_inputs(p["ssm"], h_in, cfg, stats)
    x1 = rules.constrain(x1, "dp", None, "model")
    x1 = act(_causal_conv(x1, p["ssm"]["conv_w"], p["ssm"]["conv_b"]))

    proj = x1 @ p["ssm"]["x_proj"]  # (b, s, dr + 2 st)
    dtr, B, C = jnp.split(proj, [dr, dr + st], axis=-1)
    dt = jax.nn.softplus(dtr @ p["ssm"]["dt_proj"] + p["ssm"]["dt_bias"])
    A = -jnp.exp(p["ssm"]["A_log"].astype(jnp.float32))  # (di, st)

    dA = jnp.exp(dt.astype(jnp.float32)[..., None] * A)  # (b, s, di, st)
    dBx = (dt * x1).astype(jnp.float32)[..., None] * B.astype(jnp.float32)[:, :, None, :]
    dA = rules.constrain(dA, "dp", None, "model", None)
    dBx = rules.constrain(dBx, "dp", None, "model", None)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    _, hs = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", hs.astype(x1.dtype), C) \
        + p["ssm"]["D"] * x1
    g = act(z)
    stats.add_preact("gate_pre", z)
    y = y * g
    stats.add_sparsity("down_in", y)
    y2 = y.reshape(b * s, di)
    out = cm.maybe_sparse_matmul(
        y2, p["ssm"]["out_proj"], cfg,
        1.0).reshape(b, s, d)
    return x + rules.constrain(out, "dp", None, None)


def apply_block_decode(p, x, cfg: ModelConfig, ssm_state, conv_state, pos, *,
                       stats, layer=None):
    """One-token step. ssm_state: (L, b, di, st); conv_state: (L, b, k-1, di)."""
    b, d = x.shape
    di, st, k = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    h_in = cm.apply_norm(p["norm"], x[:, None], cfg)[:, 0]
    if cfg.post_norm_relu:
        h_in = jax.nn.relu(h_in)
    x1, z, act, dr, _ = _ssm_inputs(p["ssm"], h_in, cfg, stats)

    conv_l = jax.lax.dynamic_index_in_dim(conv_state, layer, 0, keepdims=False)
    win = jnp.concatenate([conv_l, x1[:, None]], axis=1)  # (b, k, di)
    y1 = jnp.einsum("bkd,kd->bd", win, p["ssm"]["conv_w"]) + p["ssm"]["conv_b"]
    x1 = act(y1)
    conv_state = jax.lax.dynamic_update_slice(
        conv_state, win[None, :, 1:], (layer, 0, 0, 0))

    proj = x1 @ p["ssm"]["x_proj"]
    dtr, B, C = jnp.split(proj, [dr, dr + st], axis=-1)
    dt = jax.nn.softplus(dtr @ p["ssm"]["dt_proj"] + p["ssm"]["dt_bias"])
    A = -jnp.exp(p["ssm"]["A_log"].astype(jnp.float32))

    h_l = jax.lax.dynamic_index_in_dim(ssm_state, layer, 0, keepdims=False)
    dA = jnp.exp(dt.astype(jnp.float32)[..., None] * A)  # (b, di, st)
    dBx = (dt * x1).astype(jnp.float32)[..., None] * B.astype(jnp.float32)[:, None, :]
    h_new = dA * h_l.astype(jnp.float32) + dBx
    ssm_state = jax.lax.dynamic_update_slice(
        ssm_state, h_new.astype(ssm_state.dtype)[None], (layer, 0, 0, 0))

    y = jnp.einsum("bdn,bn->bd", h_new.astype(x1.dtype), C) + p["ssm"]["D"] * x1
    stats.add_preact("gate_pre", z)
    y = y * act(z)
    stats.add_sparsity("down_in", y)
    dens = cfg.sparsity.ffn_tile_density if cfg.sparsity.enabled else 1.0
    out = cm.maybe_sparse_matmul(y, p["ssm"]["out_proj"], cfg, dens)
    return x + out, ssm_state, conv_state


# ---------------------------------------------------------------------------
# family interface


def init_params(rng, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    vp = cm.padded_vocab(cfg.vocab_size)
    ks = jax.random.split(rng, 3)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    layers = jax.vmap(lambda k: init_block(k, cfg, dtype))(layer_keys)
    return {"embed": cm.embed_init(ks[1], (vp, cfg.d_model), dtype),
            "layers": layers,
            "final_norm": cm.init_norm(cfg, cfg.d_model, dtype),
            "unembed": cm.embed_init(ks[2], (vp, cfg.d_model), dtype)}


def model_forward(params, batch, cfg: ModelConfig, *, stats=None,
                  remat_policy="none"):
    from repro.models import transformer as T
    stats = stats or cm.StatsCollector(False)
    params = cm.cast_params(params, cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = T.embed_tokens(params, tokens, cfg, positions)
    x = rules.constrain(x, "dp", None, None)
    block = cm.wrap_block(remat_policy, apply_block)

    if stats.active:
        for i in range(cfg.n_layers):
            pl_i = jax.tree.map(lambda a: a[i], params["layers"])
            sub = cm.StatsCollector(True)
            x = block(pl_i, x, cfg, positions=positions, stats=sub)
            for k_, v_ in sub.stats.items():
                stats.stats[f"layer{i}/{k_}"] = v_
    else:
        def body(x, pl_i):
            return block(pl_i, x, cfg, positions=positions, stats=stats), None
        x, _ = jax.lax.scan(body, x, params["layers"])

    x = cm.apply_norm(params["final_norm"], x, cfg)
    return T.logits_from(params, x, cfg)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    L, di, st, k = cfg.n_layers, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    return {"ssm": jnp.zeros((L, batch, di, st), dtype),
            "conv": jnp.zeros((L, batch, k - 1, di), dtype)}


def model_prefill(params, batch, cfg: ModelConfig, max_len: int, stats=None):
    """Run the prompt through the scan and emit the final recurrent state.

    For the dry-run cells, prefill of an SSM is the full forward (state
    extraction uses the same scan); we recompute the final state per layer
    with a cheap second pass over the last ssm_conv tokens for the conv
    buffer and take the scan's final hidden state.
    """
    from repro.models import transformer as T
    stats = stats or cm.StatsCollector(False)
    params_c = cm.cast_params(params, cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = T.embed_tokens(params_c, tokens, cfg, positions)

    def body(x, pl_i):
        x2, (h_last, conv_last) = _apply_block_with_state(pl_i, x, cfg, stats=stats)
        return x2, (h_last, conv_last)

    x, (hs, convs) = jax.lax.scan(body, x, params_c["layers"])
    x = cm.apply_norm(params_c["final_norm"], x, cfg)
    logits = T.logits_from(params_c, x, cfg)
    cache = {"ssm": hs.astype(jnp.dtype(cfg.compute_dtype)),
             "conv": convs.astype(jnp.dtype(cfg.compute_dtype))}
    return logits[:, -1], cache


def _apply_block_with_state(p, x, cfg: ModelConfig, *, stats):
    """apply_block + return (final ssm state, conv tail) for the cache."""
    b, s, d = x.shape
    di, st, k = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    h_in = cm.apply_norm(p["norm"], x, cfg)
    if cfg.post_norm_relu:
        h_in = jax.nn.relu(h_in)
    x1, z, act, dr, _ = _ssm_inputs(p["ssm"], h_in, cfg, stats)
    x1c = act(_causal_conv(x1, p["ssm"]["conv_w"], p["ssm"]["conv_b"]))
    conv_tail = x1[:, -(k - 1):]  # pre-activation conv inputs

    proj = x1c @ p["ssm"]["x_proj"]
    dtr, B, C = jnp.split(proj, [dr, dr + st], axis=-1)
    dt = jax.nn.softplus(dtr @ p["ssm"]["dt_proj"] + p["ssm"]["dt_bias"])
    A = -jnp.exp(p["ssm"]["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt.astype(jnp.float32)[..., None] * A)
    dBx = (dt * x1c).astype(jnp.float32)[..., None] * B.astype(jnp.float32)[:, :, None, :]

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    _, hs = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", hs.astype(x1c.dtype), C) + p["ssm"]["D"] * x1c
    y = y * act(z)
    out = (y.reshape(b * s, di) @ p["ssm"]["out_proj"]).reshape(b, s, d)
    return x + out, (hs[:, -1], conv_tail)


def model_decode(params, cache, token, pos, cfg: ModelConfig, stats=None):
    from repro.models import transformer as T
    stats = stats or cm.StatsCollector(False)
    params = cm.cast_params(params, cfg)
    x = T.embed_tokens(params, token[:, None], cfg, pos[:, None])[:, 0]

    if stats.active:
        ssm, conv = cache["ssm"], cache["conv"]
        for i in range(cfg.n_layers):
            pl_i = jax.tree.map(lambda a: a[i], params["layers"])
            x, ssm, conv = apply_block_decode(pl_i, x, cfg, ssm, conv, pos,
                                              stats=stats, layer=i)
        new_cache = {"ssm": ssm, "conv": conv}
    else:
        def body(carry, xs):
            x, ssm, conv = carry
            pl_i, li = xs
            x, ssm, conv = apply_block_decode(pl_i, x, cfg, ssm, conv, pos,
                                              stats=stats, layer=li)
            return (x, ssm, conv), None
        (x, ssm, conv), _ = jax.lax.scan(
            body, (x, cache["ssm"], cache["conv"]),
            (params["layers"], jnp.arange(cfg.n_layers)))
        new_cache = {"ssm": ssm, "conv": conv}

    x = cm.apply_norm(params["final_norm"], x[:, None], cfg)[:, 0]
    return T.logits_from(params, x, cfg), new_cache
