"""Top-k Mixture-of-Experts family (Mixtral 8x22B, Phi-3.5-MoE).

Grouped one-hot dispatch (GSPMD-friendly Switch/GShard formulation): tokens
are split into groups of ~cfg.moe_group_size so the dispatch einsum stays a
few percent of expert compute; capacity = ceil(group·top_k·CF / E) with
priority-ordered slot assignment (k=0 routes before k=1).

Relufication (paper App. A): "MoE can be combined with relufication, having
sparsity inside FFN of each expert" — cfg.activation applies inside every
expert, and stage-2 post-norm ReLU sparsifies the router+expert input.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import activations as acts
from repro.models import common as cm
from repro.models import transformer as T
from repro.sharding import rules

PyTree = Any


def init_moe(rng, cfg: ModelConfig, dtype) -> PyTree:
    d, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(rng, 4)
    p = {
        "router": cm.dense_init(ks[0], (d, E), d, dtype),
        "wu": cm.dense_init(ks[1], (E, d, F), d, dtype),
        "wd": cm.dense_init(ks[2], (E, F, d), F, dtype),
    }
    if cfg.ffn_kind == "glu":
        p["wg"] = cm.dense_init(ks[3], (E, d, F), d, dtype)
    return p


def apply_moe(p, x, cfg: ModelConfig, *, stats: cm.StatsCollector,
              decode: bool = False):
    """x: (tokens, d) -> (tokens, d). Top-k routing with capacity."""
    t, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    act = acts.get(cfg.activation, shift=cfg.sparsity.shift)

    G = max(1, t // cfg.moe_group_size)
    while t % G:
        G -= 1
    tg = t // G
    cap = max(1, int(-(-tg * k * cfg.capacity_factor // E)))

    xg = x.reshape(G, tg, d)
    logits = jnp.einsum("gtd,de->gte", xg, p["router"]).astype(jnp.float32)
    gates_all = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates_all, k)  # (G, tg, k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)  # renormalize (mixtral)

    # priority slot assignment: k=0 claims capacity first
    dispatch = jnp.zeros((G, tg, E, cap), jnp.bool_)
    combine = jnp.zeros((G, tg, E, cap), jnp.float32)
    counts = jnp.zeros((G, E), jnp.int32)
    for kk in range(k):
        oh = jax.nn.one_hot(topi[..., kk], E, dtype=jnp.int32)  # (G, tg, E)
        pos = jnp.cumsum(oh, axis=1) - oh + counts[:, None, :]  # slot index
        ok = (pos < cap) & (oh > 0)
        slot = jax.nn.one_hot(jnp.where(ok, pos, cap), cap + 1,
                              dtype=jnp.float32)[..., :cap]  # (G, tg, E, cap)
        sel = slot * oh[..., None]
        dispatch = dispatch | (sel > 0)
        combine = combine + sel * topv[..., kk][..., None, None]
        counts = counts + jnp.sum(oh, axis=1)
    stats.add("moe_drop_frac", 1.0 - jnp.sum(dispatch) / (G * tg * k))
    stats.add("moe_load_cv", jnp.std(jnp.sum(combine, (1, 3)))
              / (jnp.mean(jnp.sum(combine, (1, 3))) + 1e-9))

    dd = dispatch.astype(x.dtype)
    xe = rules.constrain(jnp.einsum("gtec,gtd->gecd", dd, xg),
                         "dp", None, None, None)
    if cfg.ffn_kind == "glu":
        pre = jnp.einsum("gecd,edf->gecf", xe, p["wg"])
        stats.add_preact("moe_pre", pre)
        h = act(pre) * jnp.einsum("gecd,edf->gecf", xe, p["wu"])
    else:
        pre = jnp.einsum("gecd,edf->gecf", xe, p["wu"])
        stats.add_preact("moe_pre", pre)
        h = act(pre)
    stats.add_sparsity("down_in", h)
    h = rules.constrain(h, "dp", None, None, "model")
    ye = jnp.einsum("gecf,efd->gecd", h, p["wd"])
    # pin the output to token-parallel: GSPMD otherwise resolves the dp-axis
    # collision (groups vs wd's d_model FSDP dim) by replicating the einsum
    ye = rules.constrain(ye, "dp", None, None, None)
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), ye)
    return y.reshape(t, d)


def init_block(rng, cfg: ModelConfig, dtype) -> PyTree:
    ks = jax.random.split(rng, 2)
    return {
        "ln1": cm.init_norm(cfg, cfg.d_model, dtype),
        "attn": T.init_attn(ks[0], cfg, dtype),
        "ln2": cm.init_norm(cfg, cfg.d_model, dtype),
        "moe": init_moe(ks[1], cfg, dtype),
    }


def apply_block(p, x, cfg: ModelConfig, *, positions, stats, return_kv=False):
    h = T.post_norm(cm.apply_norm(p["ln1"], x, cfg), cfg)
    if return_kv:
        a, kv = T.apply_attn_full(p["attn"], h, cfg, positions=positions,
                                  stats=stats, return_kv=True)
    else:
        a = T.apply_attn_full(p["attn"], h, cfg, positions=positions, stats=stats)
    x = x + a
    h = T.post_norm(cm.apply_norm(p["ln2"], x, cfg), cfg)
    b, s, d = h.shape
    f = apply_moe(p["moe"], h.reshape(b * s, d), cfg, stats=stats).reshape(b, s, d)
    x = x + f
    return (x, kv) if return_kv else x


def apply_block_decode(p, x, cfg, k_cache, v_cache, pos, *, stats, layer=None):
    h = T.post_norm(cm.apply_norm(p["ln1"], x[:, None], cfg)[:, 0], cfg)
    a, k_cache, v_cache = T.apply_attn_decode(
        p["attn"], h, cfg, k_cache, v_cache, pos, stats=stats, layer=layer)
    x = x + a
    h = T.post_norm(cm.apply_norm(p["ln2"], x[:, None], cfg)[:, 0], cfg)
    x = x + apply_moe(p["moe"], h, cfg, stats=stats, decode=True)
    return x, k_cache, v_cache


# ---------------------------------------------------------------------------
# family interface (reuses the dense scaffolding with our block fns)


def init_params(rng, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    vp = cm.padded_vocab(cfg.vocab_size)
    ks = jax.random.split(rng, 4)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    layers = jax.vmap(lambda k: init_block(k, cfg, dtype))(layer_keys)
    p = {"embed": cm.embed_init(ks[1], (vp, cfg.d_model), dtype),
         "layers": layers,
         "final_norm": cm.init_norm(cfg, cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = cm.embed_init(ks[2], (vp, cfg.d_model), dtype)
    if not cfg.use_rope:
        p["pos_embed"] = cm.embed_init(ks[3], (cfg.max_seq_len, cfg.d_model), dtype)
    return p


def model_forward(params, batch, cfg: ModelConfig, *, stats=None,
                  remat_policy="none"):
    return T.forward(params, batch["tokens"], cfg, stats=stats,
                     remat_block=cm.wrap_block(remat_policy, apply_block))


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    return T.init_cache(cfg, batch, max_len)


def model_prefill(params, batch, cfg: ModelConfig, max_len: int, stats=None):
    stats = stats or cm.StatsCollector(False)
    logits, kv = T.forward(params, batch["tokens"], cfg, stats=stats,
                           return_kv=True, remat_block=apply_block)
    return logits[:, -1], T.finalize_prefill_cache(*kv, max_len)


def model_decode(params, cache, token, pos, cfg: ModelConfig, stats=None):
    stats = stats or cm.StatsCollector(False)
    params = cm.cast_params(params, cfg)
    x = T.embed_tokens(params, token[:, None], cfg, pos[:, None])[:, 0]

    if stats.active:
        kc, vc = cache["k"], cache["v"]
        for i in range(cfg.n_layers):
            pl_i = jax.tree.map(lambda a: a[i], params["layers"])
            x, kc, vc = apply_block_decode(pl_i, x, cfg, kc, vc, pos,
                                           stats=stats, layer=i)
        new_cache = {"k": kc, "v": vc}
    else:
        def body(carry, xs):
            x, kc, vc = carry
            pl_i, li = xs
            x, kc, vc = apply_block_decode(pl_i, x, cfg, kc, vc, pos,
                                           stats=stats, layer=li)
            return (x, kc, vc), None
        (x, kc, vc), _ = jax.lax.scan(
            body, (x, cache["k"], cache["v"]),
            (params["layers"], jnp.arange(cfg.n_layers)))
        new_cache = {"k": kc, "v": vc}

    x = cm.apply_norm(params["final_norm"], x[:, None], cfg)[:, 0]
    return T.logits_from(params, x, cfg), new_cache
