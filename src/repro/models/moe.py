"""Top-k Mixture-of-Experts family (Mixtral 8x22B, Phi-3.5-MoE).

Grouped one-hot dispatch (GSPMD-friendly Switch/GShard formulation): tokens
are split into groups of ~cfg.moe_group_size so the dispatch einsum stays a
few percent of expert compute; capacity = ceil(group·top_k·CF / E) with
priority-ordered slot assignment (k=0 routes before k=1).

Relufication (paper App. A): "MoE can be combined with relufication, having
sparsity inside FFN of each expert" — cfg.activation applies inside every
expert, and stage-2 post-norm ReLU sparsifies the router+expert input.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import activations as acts
from repro.models import common as cm
from repro.models import serving_protocol as sp
from repro.models import transformer as T
from repro.sharding import rules

PyTree = Any


def init_moe(rng, cfg: ModelConfig, dtype) -> PyTree:
    d, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(rng, 4)
    p = {
        "router": cm.dense_init(ks[0], (d, E), d, dtype),
        "wu": cm.dense_init(ks[1], (E, d, F), d, dtype),
        "wd": cm.dense_init(ks[2], (E, F, d), F, dtype),
    }
    if cfg.ffn_kind == "glu":
        p["wg"] = cm.dense_init(ks[3], (E, d, F), d, dtype)
    return p


def _route(p, x, cfg: ModelConfig, stats: cm.StatsCollector):
    """Top-k routing + grouped priority slot assignment for t flat tokens.

    Returns (xg (G, tg, d), dispatch (G, tg, E, cap) bool, combine
    (G, tg, E, cap) f32, (G, tg, cap)). Shared verbatim by the training /
    legacy path (``apply_moe``) and the paged serving path
    (``apply_moe_window``) so both route bit-identically. Under drop-free
    capacity (cap >= tg·top_k, i.e. capacity_factor >= n_experts) every
    token's experts get slots regardless of which other tokens share the
    batch — each slot's value is an EXACT copy of one token's row — which
    is what makes the serving path's different batch shapes byte-identical
    to the sequential legacy decode."""
    t, d = x.shape
    E, k = cfg.n_experts, cfg.top_k

    G = max(1, t // cfg.moe_group_size)
    while t % G:
        G -= 1
    tg = t // G
    cap = max(1, int(-(-tg * k * cfg.capacity_factor // E)))

    xg = x.reshape(G, tg, d)
    logits = jnp.einsum("gtd,de->gte", xg, p["router"]).astype(jnp.float32)
    gates_all = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates_all, k)  # (G, tg, k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)  # renormalize (mixtral)

    # priority slot assignment: k=0 claims capacity first
    dispatch = jnp.zeros((G, tg, E, cap), jnp.bool_)
    combine = jnp.zeros((G, tg, E, cap), jnp.float32)
    counts = jnp.zeros((G, E), jnp.int32)
    for kk in range(k):
        oh = jax.nn.one_hot(topi[..., kk], E, dtype=jnp.int32)  # (G, tg, E)
        pos = jnp.cumsum(oh, axis=1) - oh + counts[:, None, :]  # slot index
        ok = (pos < cap) & (oh > 0)
        slot = jax.nn.one_hot(jnp.where(ok, pos, cap), cap + 1,
                              dtype=jnp.float32)[..., :cap]  # (G, tg, E, cap)
        sel = slot * oh[..., None]
        dispatch = dispatch | (sel > 0)
        combine = combine + sel * topv[..., kk][..., None, None]
        counts = counts + jnp.sum(oh, axis=1)
    stats.add("moe_drop_frac", 1.0 - jnp.sum(dispatch) / (G * tg * k))
    stats.add("moe_load_cv", jnp.std(jnp.sum(combine, (1, 3)))
              / (jnp.mean(jnp.sum(combine, (1, 3))) + 1e-9))
    return xg, dispatch, combine, (G, tg, cap)


def apply_moe(p, x, cfg: ModelConfig, *, stats: cm.StatsCollector,
              decode: bool = False):
    """x: (tokens, d) -> (tokens, d). Top-k routing with capacity."""
    act = acts.get(cfg.activation, shift=cfg.sparsity.shift)
    xg, dispatch, combine, _ = _route(p, x, cfg, stats)

    dd = dispatch.astype(x.dtype)
    xe = rules.constrain(jnp.einsum("gtec,gtd->gecd", dd, xg),
                         "dp", None, None, None)
    if cfg.ffn_kind == "glu":
        pre = jnp.einsum("gecd,edf->gecf", xe, p["wg"])
        stats.add_preact("moe_pre", pre)
        h = act(pre) * jnp.einsum("gecd,edf->gecf", xe, p["wu"])
    else:
        pre = jnp.einsum("gecd,edf->gecf", xe, p["wu"])
        stats.add_preact("moe_pre", pre)
        h = act(pre)
    stats.add_sparsity("down_in", h)
    h = rules.constrain(h, "dp", None, None, "model")
    ye = jnp.einsum("gecf,efd->gecd", h, p["wd"])
    # pin the output to token-parallel: GSPMD otherwise resolves the dp-axis
    # collision (groups vs wd's d_model FSDP dim) by replicating the einsum
    ye = rules.constrain(ye, "dp", None, None, None)
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), ye)
    return y.reshape(x.shape)


def init_block(rng, cfg: ModelConfig, dtype) -> PyTree:
    ks = jax.random.split(rng, 2)
    return {
        "ln1": cm.init_norm(cfg, cfg.d_model, dtype),
        "attn": T.init_attn(ks[0], cfg, dtype),
        "ln2": cm.init_norm(cfg, cfg.d_model, dtype),
        "moe": init_moe(ks[1], cfg, dtype),
    }


def apply_block(p, x, cfg: ModelConfig, *, positions, stats, return_kv=False):
    h = T.post_norm(cm.apply_norm(p["ln1"], x, cfg), cfg)
    if return_kv:
        a, kv = T.apply_attn_full(p["attn"], h, cfg, positions=positions,
                                  stats=stats, return_kv=True)
    else:
        a = T.apply_attn_full(p["attn"], h, cfg, positions=positions, stats=stats)
    x = x + a
    h = T.post_norm(cm.apply_norm(p["ln2"], x, cfg), cfg)
    b, s, d = h.shape
    f = apply_moe(p["moe"], h.reshape(b * s, d), cfg, stats=stats).reshape(b, s, d)
    x = x + f
    return (x, kv) if return_kv else x


def apply_block_decode(p, x, cfg, k_cache, v_cache, pos, *, stats, layer=None):
    h = T.post_norm(cm.apply_norm(p["ln1"], x[:, None], cfg)[:, 0], cfg)
    a, k_cache, v_cache = T.apply_attn_decode(
        p["attn"], h, cfg, k_cache, v_cache, pos, stats=stats, layer=layer)
    x = x + a
    h = T.post_norm(cm.apply_norm(p["ln2"], x[:, None], cfg)[:, 0], cfg)
    x = x + apply_moe(p["moe"], h, cfg, stats=stats, decode=True)
    return x, k_cache, v_cache


# ---------------------------------------------------------------------------
# family interface (reuses the dense scaffolding with our block fns)


def init_params(rng, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    vp = cm.padded_vocab(cfg.vocab_size)
    ks = jax.random.split(rng, 4)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    layers = jax.vmap(lambda k: init_block(k, cfg, dtype))(layer_keys)
    p = {"embed": cm.embed_init(ks[1], (vp, cfg.d_model), dtype),
         "layers": layers,
         "final_norm": cm.init_norm(cfg, cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = cm.embed_init(ks[2], (vp, cfg.d_model), dtype)
    if not cfg.use_rope:
        p["pos_embed"] = cm.embed_init(ks[3], (cfg.max_seq_len, cfg.d_model), dtype)
    return p


def model_forward(params, batch, cfg: ModelConfig, *, stats=None,
                  remat_policy="none"):
    return T.forward(params, batch["tokens"], cfg, stats=stats,
                     remat_block=cm.wrap_block(remat_policy, apply_block))


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    return T.init_cache(cfg, batch, max_len)


def model_prefill(params, batch, cfg: ModelConfig, max_len: int, stats=None):
    stats = stats or cm.StatsCollector(False)
    logits, kv = T.forward(params, batch["tokens"], cfg, stats=stats,
                           return_kv=True, remat_block=apply_block)
    return logits[:, -1], T.finalize_prefill_cache(*kv, max_len)


def model_decode(params, cache, token, pos, cfg: ModelConfig, stats=None):
    stats = stats or cm.StatsCollector(False)
    params = cm.cast_params(params, cfg)
    x = T.embed_tokens(params, token[:, None], cfg, pos[:, None])[:, 0]

    if stats.active:
        kc, vc = cache["k"], cache["v"]
        for i in range(cfg.n_layers):
            pl_i = jax.tree.map(lambda a: a[i], params["layers"])
            x, kc, vc = apply_block_decode(pl_i, x, cfg, kc, vc, pos,
                                           stats=stats, layer=i)
        new_cache = {"k": kc, "v": vc}
    else:
        def body(carry, xs):
            x, kc, vc = carry
            pl_i, li = xs
            x, kc, vc = apply_block_decode(pl_i, x, cfg, kc, vc, pos,
                                           stats=stats, layer=li)
            return (x, kc, vc), None
        (x, kc, vc), _ = jax.lax.scan(
            body, (x, cache["k"], cache["v"]),
            (params["layers"], jnp.arange(cfg.n_layers)))
        new_cache = {"k": kc, "v": vc}

    x = cm.apply_norm(params["final_norm"], x[:, None], cfg)[:, 0]
    return T.logits_from(params, x, cfg), new_cache


# ---------------------------------------------------------------------------
# continuous-batching serving: the full paged interface (serving_protocol
# caps: paged_decode + chunked_prefill + spec_verify)
#
# Router top-k IS structured activation sparsity at expert granularity: a
# token reads top_k/n_experts of the FFN weights before any within-expert
# γ-masking applies, so the serving density telemetry composes both layers
# (density = expert fraction × within-expert eff density) and the engine's
# ``weight_io_bytes_per_step`` — density × dense-ALL-experts bytes — reports
# activated-expert bytes.
#
# Exactness: all serving configs use drop-free capacity (capacity_factor >=
# n_experts ⇒ cap >= tg·top_k). Then per-token routing results do not depend
# on co-batched tokens (each expert slot is an exact copy of one token's
# row; extra slots only add exact zeros / ×1.0 terms), so the engine's
# slot-batched, scratch-padded windows are byte-identical at f32 to the
# legacy sequential ``model_decode`` — the same invariance that makes
# chunked prefill's zero-padded windows safe. With droppable capacity the
# paths stay correct but dropped tokens may differ between batch shapes.


def apply_moe_window(p, x, cfg: ModelConfig, *, mask, refresh, valid):
    """Decode MoE-FFN over a W-token window with per-request γ-window reuse,
    batched over slots. x: (b, W, d); mask: (b, F) bool γ-window rows;
    refresh: (b,); valid: (b, W) real window tokens.

    Returns (out (b, W, d),
             act (b, F) union within-expert activity over valid tokens,
             scores (b, F//tile) window-union tile activity,
             density (b,) mean per-token fraction of expert FFN weights
                 read = routed-expert fraction × within-expert eff density,
             union_density (b,) fraction of the (E, F) expert-unit grid in
                 the window's read union = 1 − s_agg at expert granularity).
    """
    act_fn = acts.get(cfg.activation, shift=cfg.sparsity.shift)
    b, W, d = x.shape
    E, F = cfg.n_experts, cfg.d_ff
    stats = cm.StatsCollector(False)
    xg, dispatch, combine, (G, tg, cap) = _route(p, x.reshape(b * W, d),
                                                 cfg, stats)

    dd = dispatch.astype(x.dtype)
    # serve mesh: expert dim over "model" (sharding/rules.py serve map) —
    # each device computes its experts' slots; identity without a mesh
    xe = rules.constrain(jnp.einsum("gtec,gtd->gecd", dd, xg),
                         None, "model", None, None)
    if cfg.ffn_kind == "glu":
        h = act_fn(jnp.einsum("gecd,edf->gecf", xe, p["wg"])) \
            * jnp.einsum("gecd,edf->gecf", xe, p["wu"])
    else:
        h = act_fn(jnp.einsum("gecd,edf->gecf", xe, p["wu"]))
    h = rules.constrain(h, None, "model", None, None)

    # γ-window gate, dispatched to expert-slot space: each slot's eff row is
    # an exact copy of its token's slot-level eff (drop-free), so gating
    # here equals gating per token — and is ×1.0 (bit-exact) under refresh
    eff = mask | refresh[:, None]  # (b, F)
    eff_tok = jnp.broadcast_to(eff[:, None, :], (b, W, F)).reshape(G, tg, F)
    eff_slots = jnp.einsum("gtec,gtf->gecf", dd, eff_tok.astype(h.dtype))
    h = h * eff_slots

    ye = jnp.einsum("gecf,efd->gecd", h, p["wd"])
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), ye)
    out = y.reshape(b, W, d)

    # telemetry: per-token within-expert activity unioned over the token's
    # routed experts (the slot-level γ-mask is shared across experts)
    hq = (h != 0).astype(jnp.float32)  # (G, E, cap, F)
    act_tok = (jnp.einsum("gtec,gecf->gtf", dd.astype(jnp.float32), hq)
               .reshape(b, W, F) > 0)
    act_tok = act_tok & valid[:, :, None]
    act = jnp.any(act_tok, axis=1)  # (b, F)
    from repro.kernels.fused_ffn import window_tile_activity
    scores = window_tile_activity(act_tok.astype(jnp.float32),
                                  cm.ffn_gather_tile(cfg))

    texp = jnp.any(dispatch, axis=3).reshape(b, W, E)  # token's experts
    efrac = jnp.mean(texp.astype(jnp.float32), -1)  # (b, W)
    tok_density = efrac * jnp.mean(eff.astype(jnp.float32), -1)[:, None]
    vf = valid.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(vf, 1), 1.0)
    density = jnp.sum(tok_density * vf, 1) / denom  # (b,)

    read = (texp[:, :, :, None] & eff[:, None, None, :]
            & valid[:, :, None, None])  # (b, W, E, F)
    union_density = jnp.mean(jnp.any(read, 1).astype(jnp.float32), (1, 2))
    return out, act, scores, density, union_density


def apply_block_window_paged(p, x, cfg: ModelConfig, k_pages, v_pages, table,
                             pos, valid, *, layer, block_size: int, mask,
                             refresh):
    stats = cm.StatsCollector(False)
    h = T.post_norm(cm.apply_norm(p["ln1"], x, cfg), cfg)
    a, k_pages, v_pages = T.apply_attn_window_paged(
        p["attn"], h, cfg, k_pages, v_pages, table, pos, valid, layer=layer,
        block_size=block_size, stats=stats)
    x = x + a
    h = T.post_norm(cm.apply_norm(p["ln2"], x, cfg), cfg)
    f, act, scores, density, udens = apply_moe_window(
        p["moe"], h, cfg, mask=mask, refresh=refresh, valid=valid)
    x = x + f
    return x, k_pages, v_pages, act, scores, density, udens


def init_paged_cache(cfg: ModelConfig, n_blocks: int, block_size: int,
                     sharding=None):
    return cm.init_paged_cache(cfg, n_blocks, block_size, sharding=sharding)


def model_prefill_paged(params, batch, cfg: ModelConfig, pages, blocks,
                        block_size: int, true_len=None):
    """Whole-prompt prefill into freshly allocated pool blocks (the dense
    family's contract; see transformer.prefill_paged). Zero-padding to a
    block multiple is routing-safe under drop-free capacity (module note)."""
    li = None if true_len is None else true_len - 1
    logits, kv = T.forward(params, batch["tokens"], cfg, return_kv=True,
                           last_index=li, remat_block=apply_block)
    k, v = kv  # (L, 1, s, kvp, hd)
    kp = cm.paged_write_prefill(pages["k"], k[:, 0], blocks, block_size)
    vp = cm.paged_write_prefill(pages["v"], v[:, 0], blocks, block_size)
    return logits[:, -1], {"k": kp, "v": vp}


def model_verify_window_paged(params, pages, table, tokens, pos0, wlen,
                              cfg: ModelConfig, ffn_masks, refresh,
                              block_size: int, fast_kernels: bool = False):
    """W-token window per slot over the shared page pool — the speculative
    verification target step, MoE edition (same contract as
    transformer.verify_window_paged; aux density/union_density measure the
    EXPERT-weighted fractions). fast_kernels is accepted for interface
    parity but MoE uses the documented XLA dispatch fallback
    (kernels/fused_decode.py module note)."""
    del fast_kernels

    def layer_fn(pl_i, li, x, kp, vp, fm, pos, valid):
        x, kp, vp, act, scores, density, udens = apply_block_window_paged(
            pl_i, x, cfg, kp, vp, table, pos, valid, layer=li,
            block_size=block_size, mask=fm, refresh=refresh)
        return x, kp, vp, (act, scores, density, udens)

    return sp.window_step_core(params, pages, tokens, pos0, wlen, cfg,
                               ffn_masks, refresh, layer_fn=layer_fn,
                               embed_fn=T.embed_tokens,
                               logits_fn=T.logits_from)


def model_prefill_chunk_paged(params, batch, cfg: ModelConfig, pages, table,
                              pos0, clen, ffn_masks, refresh,
                              block_size: int, fast_kernels: bool = False):
    """One fixed-shape prefill chunk IS a window step (the dense family's
    delegation, transformer.prefill_chunk_paged): chunk tokens write K/V at
    their own positions, tokens past clen scratch-route, and the window's
    union activity seeds the warm γ-mask."""
    return model_verify_window_paged(params, pages, table, batch["tokens"],
                                     pos0, clen, cfg, ffn_masks, refresh,
                                     block_size, fast_kernels=fast_kernels)


def model_decode_paged(params, pages, table, token, pos, cfg: ModelConfig,
                       ffn_masks, refresh, block_size: int,
                       fast_kernels: bool = False):
    """Plain continuous-batching decode = the W == 1 window step. Unlike the
    dense family (whose decode keeps a hand-specialized bf16-frozen
    lowering), MoE serves at f32-pinned exactness from day one, so the
    window path with wlen == 1 IS the decode step — aux drops the window's
    union_density to match the engine's 3-tuple decode contract."""
    logits, pages, new_masks, (act, scores, density, _udens) = \
        model_verify_window_paged(params, pages, table, token[:, None], pos,
                                  jnp.ones_like(pos), cfg, ffn_masks,
                                  refresh, block_size,
                                  fast_kernels=fast_kernels)
    return logits[:, 0], pages, new_masks, (act, scores, density)
