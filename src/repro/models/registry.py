"""Architecture-family registry.

Uniform interface per family (duck-typed module):
  init_params(rng, cfg) -> params
  model_forward(params, batch, cfg, *, stats=None, remat_block=None)
      -> logits aligned with batch["tokens"] (b, s, vocab_p)
  init_cache(cfg, batch, max_len) -> cache pytree
  model_prefill(params, batch, cfg, max_len, stats=None) -> (logits_b, cache)
  model_decode(params, cache, token, pos, cfg, stats=None) -> (logits, cache)

batch is a dict: {"tokens": (b, s) int32} plus optional modality-stub inputs
("patches" for vlm, "frames" for encdec).
"""
from __future__ import annotations

from typing import Any, Dict

from repro.configs.base import ModelConfig

_FAMILIES: Dict[str, Any] = {}


def register_family(name: str, module) -> None:
    _FAMILIES[name] = module


def get_family(cfg_or_name) -> Any:
    name = cfg_or_name if isinstance(cfg_or_name, str) else cfg_or_name.family
    if name not in _FAMILIES:
        _load_builtin(name)
    return _FAMILIES[name]


def _load_builtin(name: str) -> None:
    if name in ("dense",):
        from repro.models import dense_family
        register_family("dense", dense_family)
    elif name == "vlm":
        from repro.models import vlm
        register_family("vlm", vlm)
    elif name == "moe":
        from repro.models import moe
        register_family("moe", moe)
    elif name == "mamba":
        from repro.models import mamba
        register_family("mamba", mamba)
    elif name == "hybrid":
        from repro.models import hybrid
        register_family("hybrid", hybrid)
    elif name == "encdec":
        from repro.models import encdec
        register_family("encdec", encdec)
    else:
        raise KeyError(f"unknown model family {name!r}")
