"""Architecture-family registry.

Uniform interface per family (duck-typed module):
  init_params(rng, cfg) -> params
  model_forward(params, batch, cfg, *, stats=None, remat_block=None)
      -> logits aligned with batch["tokens"] (b, s, vocab_p)
  init_cache(cfg, batch, max_len) -> cache pytree
  model_prefill(params, batch, cfg, max_len, stats=None) -> (logits_b, cache)
  model_decode(params, cache, token, pos, cfg, stats=None) -> (logits, cache)

batch is a dict: {"tokens": (b, s) int32} plus optional modality-stub inputs
("patches" for vlm, "frames" for encdec).

Paged serving is opt-in per family via a declared capability set
(``serving_protocol.ServingCaps``): ``register_family(name, module,
caps=...)`` validates at registration time that the module defines every
function the declared capabilities promise, and the serving engine gates
each mode on ``serving_caps(cfg).require(cap, family)`` — never on
``hasattr`` probes.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable

from repro.configs.base import ModelConfig
from repro.models.serving_protocol import ServingCaps, validate_caps

_FAMILIES: Dict[str, Any] = {}
_CAPS: Dict[str, ServingCaps] = {}


def register_family(name: str, module, caps: Iterable[str] = ()) -> None:
    caps = ServingCaps(caps)
    validate_caps(name, module, caps)
    _FAMILIES[name] = module
    _CAPS[name] = caps


def get_family(cfg_or_name) -> Any:
    name = cfg_or_name if isinstance(cfg_or_name, str) else cfg_or_name.family
    if name not in _FAMILIES:
        _load_builtin(name)
    return _FAMILIES[name]


def serving_caps(cfg_or_name) -> ServingCaps:
    """The declared paged-serving capability set for a family (empty set for
    families that have not been routed through the serving protocol yet)."""
    name = cfg_or_name if isinstance(cfg_or_name, str) else cfg_or_name.family
    if name not in _FAMILIES:
        _load_builtin(name)
    return _CAPS[name]


def _load_builtin(name: str) -> None:
    if name in ("dense",):
        from repro.models import dense_family
        register_family("dense", dense_family,
                        caps=("paged_decode", "chunked_prefill",
                              "spec_verify", "spec_draft", "predictor"))
    elif name == "vlm":
        from repro.models import vlm
        register_family("vlm", vlm)
    elif name == "moe":
        from repro.models import moe
        register_family("moe", moe,
                        caps=("paged_decode", "chunked_prefill",
                              "spec_verify"))
    elif name == "mamba":
        from repro.models import mamba
        register_family("mamba", mamba)
    elif name == "hybrid":
        from repro.models import hybrid
        register_family("hybrid", hybrid)
    elif name == "encdec":
        from repro.models import encdec
        register_family("encdec", encdec)
    else:
        raise KeyError(f"unknown model family {name!r}")
