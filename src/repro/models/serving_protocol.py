"""Family-agnostic paged-serving protocol.

Two things live here, and both exist so the serving engine never has to
know which model family it is driving:

1. **Declared capabilities** (``ServingCaps``): every family registered in
   models/registry.py declares which paged-serving entry points it
   implements, as a set of capability names from ``CAP_FUNCS``. Declaring a
   capability is validated EARLY (at registration: the named module
   functions must exist), and the engine checks requirements with ONE
   uniform error message (``ServingCaps.require``) instead of scattered
   ``hasattr(fam, "model_decode_paged")`` probes — an unsupported-family
   error always names the missing capability and what the family does
   declare.

2. **The shared paged-decode skeleton**: every family's paged serving entry
   points are the same sandwich — embed → per-layer scan carrying the paged
   K/V pool (attention through the block table, then the family's FFN
   dispatch) → γ-window mask refresh → final norm + logits head. The
   transformer and MoE families previously each spelled this out;
   ``decode_step_core`` / ``window_step_core`` hold it once, parameterized
   by the family's per-layer block function (``layer_fn``) and its
   embed/logits callables. The cores are pure structural plumbing: a family
   delegating to them emits the SAME jaxpr as the hand-written loop it
   replaces, so the dense family's bit-frozen serving lowerings (bf16
   exactness pins) are unchanged.

Family hooks with defaults (resolved here so the engine itself contains no
``hasattr``/``getattr`` family probes):

* ``prompt_token_offset(cfg) -> int`` — extra non-text positions a family
  prepends to the prompt (vision patches for vlm); the legacy ServeEngine
  offsets decode positions by it. Default 0.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.sharding import rules

PyTree = Any

# capability name -> module functions a family must define to declare it.
# The engine requires:
#   paged_decode    — any ContinuousBatchingEngine at all
#   chunked_prefill — prefill_chunk > 0 (and prefix_cache, which needs it)
#   spec_verify     — speculative mode's TARGET family
#   spec_draft      — speculative mode's DRAFT family
#   predictor       — predictor serving mode
CAP_FUNCS: Dict[str, Tuple[str, ...]] = {
    "paged_decode": ("init_paged_cache", "model_prefill_paged",
                     "model_decode_paged"),
    "chunked_prefill": ("model_prefill_chunk_paged",),
    "spec_verify": ("model_verify_window_paged",),
    "spec_draft": ("model_draft_gamma_paged",),
    "predictor": ("model_decode_paged_predicted",),
}


class ServingCaps(frozenset):
    """A family's declared paged-serving capability set (names from
    ``CAP_FUNCS``). Frozen so it can be declared once at registration and
    shared; ``require`` is the engine's single capability gate."""

    def require(self, cap: str, family: str) -> None:
        """Raise the uniform unsupported-capability ValueError unless this
        family declared ``cap``."""
        if cap not in CAP_FUNCS:
            raise KeyError(f"unknown serving capability {cap!r} "
                           f"(known: {sorted(CAP_FUNCS)})")
        if cap not in self:
            declared = ", ".join(sorted(self)) if self else "none"
            raise ValueError(
                f"family {family!r} does not support the {cap!r} serving "
                f"capability (declared capabilities: {declared})")


def validate_caps(name: str, module, caps: ServingCaps) -> None:
    """Early registration-time check: every declared capability's functions
    must exist on the family module — a typo'd declaration fails at
    register_family(), not at first serve."""
    for cap in caps:
        if cap not in CAP_FUNCS:
            raise ValueError(f"family {name!r} declares unknown serving "
                             f"capability {cap!r} (known: "
                             f"{sorted(CAP_FUNCS)})")
        missing = [f for f in CAP_FUNCS[cap] if not hasattr(module, f)]
        if missing:
            raise ValueError(
                f"family {name!r} declares capability {cap!r} but is "
                f"missing {missing}")


def prompt_token_offset(fam, cfg) -> int:
    """The family's extra prompt-position offset (default 0). Families with
    non-text prefix positions (vlm vision patches) define
    ``prompt_token_offset(cfg)``; resolved here so engines stay free of
    per-family probes."""
    hook = getattr(fam, "prompt_token_offset", None)
    return 0 if hook is None else int(hook(cfg))


# ---------------------------------------------------------------------------
# shared paged-decode skeleton


def refresh_union_masks(ffn_masks, act, refresh):
    """γ-window mask update shared by every paged step: slots flagged
    ``refresh`` replace their mask row with this step's (union) activity,
    others keep the window's mask. Constrained d_ff-over-"model" for TP
    serving (identity without a mesh)."""
    return rules.constrain(
        jnp.where(refresh[None, :, None], act, ffn_masks),
        None, "dp", "model")


def scan_layers_paged(params, pages, cfg, x, layer_fn: Callable,
                      extra_xs: Tuple = ()):
    """The per-layer paged scan: carry (x, k_pages, v_pages) through the
    stacked layers; each layer writes its K/V through the block table and
    returns its FFN telemetry as the scan's stacked ys.

    layer_fn(pl_i, li, x, k_pages, v_pages, ffn_mask, *extras)
        -> (x, k_pages, v_pages, aux_tuple)

    Returns ((x, k_pages, v_pages), aux) with every aux leaf stacked on a
    leading layer axis."""
    def body(carry, xs):
        x, kp, vp = carry
        pl_i, li, fm = xs[:3]
        x, kp, vp, aux = layer_fn(pl_i, li, x, kp, vp, fm, *xs[3:])
        return (x, kp, vp), aux

    xs = (params["layers"], jnp.arange(cfg.n_layers)) + extra_xs
    return jax.lax.scan(body, (x, pages["k"], pages["v"]), xs)


def decode_step_core(params, pages, token, pos, cfg, ffn_masks, refresh, *,
                     layer_fn: Callable, embed_fn: Callable,
                     logits_fn: Callable, extra_xs: Tuple = ()):
    """Generic single-token paged decode: embed → scan_layers_paged →
    mask refresh → final norm + logits. token/pos/refresh: (b,);
    ffn_masks: (L, b, F). layer_fn's aux tuple must lead with the (b, F)
    FFN activity (it feeds the mask refresh); the whole stacked aux tuple
    is returned untouched.

    Returns (logits (b, vocab_p), pages, new_masks (L, b, F), aux)."""
    params = cm.cast_params(params, cfg)
    x = embed_fn(params, token[:, None], cfg, pos[:, None])[:, 0]
    x = rules.constrain(x, "dp", None)

    (x, kp, vp), aux = scan_layers_paged(params, pages, cfg, x, layer_fn,
                                         (ffn_masks,) + extra_xs)
    new_masks = refresh_union_masks(ffn_masks, aux[0], refresh)

    x = cm.apply_norm(params["final_norm"], x[:, None], cfg)[:, 0]
    logits = logits_fn(params, x, cfg)
    return logits, {"k": kp, "v": vp}, new_masks, aux


def window_step_core(params, pages, tokens, pos0, wlen, cfg, ffn_masks,
                     refresh, *, layer_fn: Callable, embed_fn: Callable,
                     logits_fn: Callable):
    """Generic W-token paged window step (speculative verify / chunked
    prefill; W == 1 is a plain decode step). tokens: (b, W); pos0/wlen/
    refresh: (b,). layer_fn additionally receives the window's per-token
    write positions pos (b, W) and validity valid (b, W); its aux tuple must
    lead with the (b, F) window-union FFN activity.

    Returns (logits (b, W, vocab_p), pages, new_masks (L, b, F), aux)."""
    params = cm.cast_params(params, cfg)
    b, W = tokens.shape
    pos = pos0[:, None] + jnp.arange(W, dtype=pos0.dtype)[None, :]
    valid = jnp.arange(W)[None, :] < wlen[:, None]
    x = rules.constrain(embed_fn(params, tokens, cfg, pos), "dp", None, None)

    def wrapped(pl_i, li, x, kp, vp, fm):
        return layer_fn(pl_i, li, x, kp, vp, fm, pos, valid)

    (x, kp, vp), aux = scan_layers_paged(params, pages, cfg, x, wrapped,
                                         (ffn_masks,))
    new_masks = refresh_union_masks(ffn_masks, aux[0], refresh)

    x = cm.apply_norm(params["final_norm"], x, cfg)
    logits = logits_fn(params, x, cfg)
    return logits, {"k": kp, "v": vp}, new_masks, aux
