"""Dense decoder-only transformer family.

Covers: OPT (layernorm, learned positions, ReLU-MLP), Llama/DeepSeek (rmsnorm,
RoPE, SwiGLU), Falcon (layernorm, GELU-MLP), Qwen2 (QKV bias), Qwen3
(qk-norm), StarCoder2 (GQA+GELU), and the InternLM2 backbone of InternVL2.

Layers are stacked on a leading axis and iterated with lax.scan so the HLO is
one layer body regardless of depth (95-layer deepseek compiles as fast as a
2-layer toy). The attention / FFN builders here are reused by moe.py,
hybrid.py and encdec.py.

Relufication hooks (paper Sec. 4):
  * stage 1 = cfg.activation == "relu" (or "shifted_relu")
  * stage 2 = cfg.post_norm_relu: ReLU is applied to the output of each
    pre-attention / pre-FFN norm, sparsifying QKV and up-projection inputs.
Sparse decode (paper Sec. 4.2/5, DESIGN.md §3): tile-gathered matmuls with
static capacities cfg.sparsity.{ffn_tile_density, input_tile_density}.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import activations as acts
from repro.models import common as cm
from repro.models import serving_protocol as sp
from jax import ad_checkpoint
from repro.sharding import rules

PyTree = Any

# ---------------------------------------------------------------------------
# attention sub-module (shared by every family with attention)


def attn_geometry(cfg: ModelConfig) -> cm.HeadGeometry:
    return cm.HeadGeometry(cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim)


def init_attn(rng, cfg: ModelConfig, dtype) -> PyTree:
    g = attn_geometry(cfg)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(rng, 4)
    # init real heads, scatter into the padded per-group layout (zeros padded)
    wq = g.scatter_q(cm.dense_init(ks[0], (d, cfg.n_heads, hd), d, dtype), axis=1)
    if g.kvp == g.n_kv:
        wk = cm.dense_init(ks[1], (d, g.kvp, hd), d, dtype)
        wv = cm.dense_init(ks[2], (d, g.kvp, hd), d, dtype)
    else:  # MHA padding: zero K/V for padded kv heads
        wk = jnp.zeros((d, g.kvp, hd), dtype).at[:, : g.n_kv].set(
            cm.dense_init(ks[1], (d, g.n_kv, hd), d, dtype))
        wv = jnp.zeros((d, g.kvp, hd), dtype).at[:, : g.n_kv].set(
            cm.dense_init(ks[2], (d, g.n_kv, hd), d, dtype))
    wo = g.scatter_q(cm.dense_init(ks[3], (cfg.n_heads, hd, d), cfg.n_heads * hd, dtype),
                     axis=0)
    p = {"wq": wq, "wk": wk, "wv": wv, "wo": wo}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((g.hp, hd), dtype)
        p["bk"] = jnp.zeros((g.kvp, hd), dtype)
        p["bv"] = jnp.zeros((g.kvp, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _qkv(p, x, cfg: ModelConfig, positions, *, stats: cm.StatsCollector,
         input_density: float = 1.0):
    """x: (b, s, d) -> q (b,s,kvp,g,hd), k/v (b,s,kvp,hd). RoPE applied."""
    g = attn_geometry(cfg)
    stats.add_sparsity("qkv_in", x)
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    wq = p["wq"].reshape(d, g.hp * g.head_dim)
    wk = p["wk"].reshape(d, g.kvp * g.head_dim)
    wv = p["wv"].reshape(d, g.kvp * g.head_dim)
    dens = input_density if cfg.sparsity.enabled else 1.0
    q = cm.maybe_sparse_matmul(x2, wq, cfg, dens).reshape(b, s, g.hp, g.head_dim)
    k = cm.maybe_sparse_matmul(x2, wk, cfg, dens).reshape(b, s, g.kvp, g.head_dim)
    v = cm.maybe_sparse_matmul(x2, wv, cfg, dens).reshape(b, s, g.kvp, g.head_dim)
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = cm.rms_norm_headdim(p["q_norm"], q)
        k = cm.rms_norm_headdim(p["k_norm"], k)
    if cfg.use_rope:
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        k = cm.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v  # q flat (b, s, hp, hd); k/v (b, s, kvp, hd)


def _attn_out(p, o, cfg: ModelConfig):
    """o: (b, s, hp, hd) -> (b, s, d), padded head slots masked."""
    g = attn_geometry(cfg)
    b, s = o.shape[:2]
    o = o * jnp.asarray(g.q_slot_mask(), o.dtype)[None, None, :, None]
    return jnp.einsum("bshd,hde->bse", o, p["wo"])


def apply_attn_full(
    p, x, cfg: ModelConfig, *, positions, causal=True, stats: cm.StatsCollector,
    return_kv=False, kv_override=None, q_offset: int = 0,
):
    """Full-sequence attention (train / prefill). Optionally returns K,V for
    the cache, or attends to externally supplied K,V (cross-attention).

    For GQA with kv < 16, K/V activations are replication-padded to 16 heads
    (each kv head repeated 16/kv times — exactly GQA, since every q head
    still sees a copy of its own kv head) so the attention einsums shard
    16-way over the `model` axis. Weights and the cache stay unpadded.
    """
    g = attn_geometry(cfg)
    b, s = x.shape[:2]
    q, k, v = _qkv(p, x, cfg, positions, stats=stats)
    # the copy stored into the prefill cache is SEQ-sharded over `model`
    # (matching the decode cache layout) so the stacked (L, b, S, kvp, hd)
    # buffer never materializes replicated on any chip
    kv_for_cache = (rules.constrain(k, "dp", "model", None, None),
                    rules.constrain(v, "dp", "model", None, None))
    if kv_override is not None:
        k, v = kv_override
        causal = False
    r = 1 if g.kvp % cm.TP == 0 else cm.TP // g.kvp
    if r > 1:
        k = jnp.repeat(k, r, axis=2)
        v = jnp.repeat(v, r, axis=2)
    kv_eff = g.kvp * r
    g_eff = g.hp // kv_eff
    qg = q.reshape(b, s, kv_eff, g_eff, g.head_dim)
    qg = rules.constrain(qg, "dp", None, "model", None, None)
    k = rules.constrain(k, "dp", None, "model", None)
    v = rules.constrain(v, "dp", None, "model", None)
    o = cm.flash_attention(qg, k, v, causal=causal, window=cfg.sliding_window,
                           q_offset=q_offset)
    out = _attn_out(p, o.reshape(b, s, g.hp, g.head_dim), cfg)
    if return_kv:
        return out, kv_for_cache
    return out


def apply_attn_decode(
    p, x, cfg: ModelConfig, k_cache, v_cache, pos, *, stats: cm.StatsCollector,
    cross: bool = False, layer=None,
):
    """One-token attention against a cache.

    x: (b, d); pos: (b,) write position. When ``layer`` is given, k_cache /
    v_cache are the FULL stacked (L, b, S, kvp, hd) buffers and only the
    single-token slice for this layer is written (the whole stack is carried
    through the layer scan so decode traffic is one cache read + an O(1)
    write — NOT a full rewrite). Otherwise they are per-layer (b, S, kvp, hd).
    cross=True skips the write (encoder K/V are static).
    Returns (out (b, d), k_cache, v_cache).
    """
    g = attn_geometry(cfg)
    q, k, v = _qkv(p, x[:, None, :], cfg, pos[:, None],
                   stats=stats, input_density=cfg.sparsity.input_tile_density)
    q = q.reshape(q.shape[0], 1, g.kvp, g.group, g.head_dim)
    if not cross:
        # uniform-position fast path: dynamic_update_slice is a single cheap
        # in-place update (positions are equal across the batch in the
        # dry-run serve step; the engine uses per-seq scatter instead).
        # cache is head-major: write (b, kvp, 1, hd) at position pos.
        kt = k.transpose(0, 2, 1, 3).astype(k_cache.dtype)  # (b, kvp, 1, hd)
        vt = v.transpose(0, 2, 1, 3).astype(v_cache.dtype)
        if layer is not None:
            k_cache = jax.lax.dynamic_update_slice(
                k_cache, kt[None], (layer, 0, 0, pos[0], 0))
            v_cache = jax.lax.dynamic_update_slice(
                v_cache, vt[None], (layer, 0, 0, pos[0], 0))
        else:
            k_cache = jax.lax.dynamic_update_slice(k_cache, kt, (0, 0, pos[0], 0))
            v_cache = jax.lax.dynamic_update_slice(v_cache, vt, (0, 0, pos[0], 0))
    if layer is not None:
        kl = jax.lax.dynamic_index_in_dim(k_cache, layer, 0, keepdims=False)
        vl = jax.lax.dynamic_index_in_dim(v_cache, layer, 0, keepdims=False)
    else:
        kl, vl = k_cache, v_cache
    o = cm.decode_attention(q[:, 0], kl, vl, pos, window=cfg.sliding_window)
    out = _attn_out(p, o.reshape(o.shape[0], 1, g.hp, g.head_dim), cfg)[:, 0]
    return out, k_cache, v_cache


# ---------------------------------------------------------------------------
# FFN sub-module (the paper's main stage — sparsity lives here)


def init_ffn(rng, cfg: ModelConfig, dtype, d_ff: Optional[int] = None) -> PyTree:
    d, F = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    p = {"wu": cm.dense_init(ks[0], (d, F), d, dtype),
         "wd": cm.dense_init(ks[1], (F, d), F, dtype)}
    if cfg.ffn_kind == "glu":
        p["wg"] = cm.dense_init(ks[2], (d, F), d, dtype)
    return p


def apply_ffn(p, x, cfg: ModelConfig, *, stats: cm.StatsCollector,
              decode: bool = False, ffn_mask: Optional[jnp.ndarray] = None):
    """x: (tokens, d) -> (tokens, d). ffn_mask (d_ff,) emulates γ-window
    weight reuse (paper Fig. 7c): only previously-loaded rows participate."""
    act = acts.get(cfg.activation, shift=cfg.sparsity.shift)
    stats.add_sparsity("up_in", x)
    stats.add_raw("ffn_x", x)  # predictor-calibration capture (raw=True only)
    x = rules.constrain(x, "dp", None)
    dens_in = cfg.sparsity.input_tile_density if (cfg.sparsity.enabled and decode) else 1.0
    if cfg.ffn_kind == "glu":
        pre = cm.maybe_sparse_matmul(x, p["wg"], cfg, dens_in)
        stats.add_preact("ffn_pre", pre)
        h = act(pre) * cm.maybe_sparse_matmul(x, p["wu"], cfg, dens_in)
    else:
        pre = cm.maybe_sparse_matmul(x, p["wu"], cfg, dens_in)
        stats.add_preact("ffn_pre", pre)
        h = act(pre)
    if ffn_mask is not None:
        h = h * ffn_mask.astype(h.dtype)
    stats.add_sparsity("down_in", h)
    if stats.active:  # unit-level activity for aggregated-sparsity tracking
        stats.add("down_act", jnp.any(h != 0, axis=0))
    h = rules.constrain(h, "dp", "model")
    dens_ffn = cfg.sparsity.ffn_tile_density if (cfg.sparsity.enabled and decode) else 1.0
    return rules.constrain(
        cm.maybe_sparse_matmul(h, p["wd"], cfg, dens_ffn), "dp", None)


def post_norm(x, cfg: ModelConfig):
    """Relufication stage 2: ReLU after the normalization layer."""
    return jax.nn.relu(x) if cfg.post_norm_relu else x


# ---------------------------------------------------------------------------
# dense decoder blocks


def init_block(rng, cfg: ModelConfig, dtype) -> PyTree:
    ks = jax.random.split(rng, 2)
    return {
        "ln1": cm.init_norm(cfg, cfg.d_model, dtype),
        "attn": init_attn(ks[0], cfg, dtype),
        "ln2": cm.init_norm(cfg, cfg.d_model, dtype),
        "ffn": init_ffn(ks[1], cfg, dtype),
    }


def apply_block(p, x, cfg: ModelConfig, *, positions, stats, return_kv=False,
                causal=True):
    h = post_norm(cm.apply_norm(p["ln1"], x, cfg), cfg)
    if return_kv:
        a, kv = apply_attn_full(p["attn"], h, cfg, positions=positions,
                                stats=stats, return_kv=True, causal=causal)
    else:
        a = apply_attn_full(p["attn"], h, cfg, positions=positions,
                            stats=stats, causal=causal)
    a = ad_checkpoint.checkpoint_name(a, "attn_out")  # TP all-reduce output
    x = x + a
    h = post_norm(cm.apply_norm(p["ln2"], x, cfg), cfg)
    b, s, d = h.shape
    f = apply_ffn(p["ffn"], h.reshape(b * s, d), cfg, stats=stats).reshape(b, s, d)
    f = ad_checkpoint.checkpoint_name(f, "ffn_out")  # TP all-reduce output
    x = x + f
    if cfg.sp_residuals:
        x = rules.constrain(x, "dp", None, "model")
    return (x, kv) if return_kv else x


def apply_block_decode(p, x, cfg: ModelConfig, k_cache, v_cache, pos, *, stats,
                       layer=None, ffn_mask=None):
    h = post_norm(cm.apply_norm(p["ln1"], x[:, None], cfg)[:, 0], cfg)
    a, k_cache, v_cache = apply_attn_decode(
        p["attn"], h, cfg, k_cache, v_cache, pos, stats=stats, layer=layer)
    x = x + a
    h = post_norm(cm.apply_norm(p["ln2"], x[:, None], cfg)[:, 0], cfg)
    f = apply_ffn(p["ffn"], h, cfg, stats=stats, decode=True, ffn_mask=ffn_mask)
    x = x + f
    return x, k_cache, v_cache


# ---------------------------------------------------------------------------
# whole model


def init_params(rng, cfg: ModelConfig) -> PyTree:
    dtype = jnp.dtype(cfg.param_dtype)
    vp = cm.padded_vocab(cfg.vocab_size)
    ks = jax.random.split(rng, 4)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    layers = jax.vmap(lambda k: init_block(k, cfg, dtype))(layer_keys)
    p = {
        "embed": cm.embed_init(ks[1], (vp, cfg.d_model), dtype),
        "layers": layers,
        "final_norm": cm.init_norm(cfg, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = cm.embed_init(ks[2], (vp, cfg.d_model), dtype)
    if not cfg.use_rope:
        p["pos_embed"] = cm.embed_init(ks[3], (cfg.max_seq_len, cfg.d_model), dtype)
    return p


def embed_tokens(params, tokens, cfg: ModelConfig, positions):
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.compute_dtype))
    if not cfg.use_rope:
        pe = jnp.take(params["pos_embed"], positions, axis=0)
        x = x + pe.astype(x.dtype)
    return x


def logits_from(params, x, cfg: ModelConfig):
    u = params.get("unembed", params["embed"])
    out = jnp.einsum("...d,vd->...v", x, u.astype(x.dtype))
    return out + cm.vocab_logit_mask(cfg.vocab_size, u.shape[0]).astype(out.dtype)


def forward(params, tokens, cfg: ModelConfig, *, stats: Optional[cm.StatsCollector] = None,
            extra_embeds: Optional[jnp.ndarray] = None, return_kv: bool = False,
            remat_block=None, last_index=None):
    """Full-sequence forward. tokens: (b, s) -> logits (b, s_total, vocab_p).

    extra_embeds (b, n, d): modality-frontend stubs (vision patches / audio
    frames) prepended to the token embeddings (internvl2).

    last_index: optional TRACED scalar — with return_kv, take the prefill
    logits from this position instead of s-1 (tokens beyond it are padding;
    causality keeps the earlier positions exact).
    """
    stats = stats or cm.StatsCollector(False)
    params = cm.cast_params(params, cfg)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = embed_tokens(params, tokens, cfg, positions)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
        s = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    x = rules.constrain(x, "dp", None, None)
    block = remat_block or apply_block

    if stats.active:  # unrolled so per-layer stats stay distinguishable
        kvs = []
        layers = params["layers"]
        for i in range(cfg.n_layers):
            pl_i = jax.tree.map(lambda a: a[i], layers)
            sub = cm.StatsCollector(True, raw=stats.raw)
            if return_kv:
                x, kv = block(pl_i, x, cfg, positions=positions, stats=sub,
                              return_kv=True)
                kvs.append(kv)
            else:
                x = block(pl_i, x, cfg, positions=positions, stats=sub)
            for k_, v_ in sub.stats.items():
                stats.stats[f"layer{i}/{k_}"] = v_
        kv_stack = (jax.tree.map(lambda *a: jnp.stack(a), *kvs) if kvs else None)
    else:
        def body(x, pl_i):
            if return_kv:
                x, kv = block(pl_i, x, cfg, positions=positions, stats=stats,
                              return_kv=True)
                return x, kv
            return block(pl_i, x, cfg, positions=positions, stats=stats), None
        x, kv_stack = jax.lax.scan(body, x, params["layers"])

    x = cm.apply_norm(params["final_norm"], x, cfg)
    if return_kv:
        # prefill: only the last position's logits are needed -> avoid the
        # (b, s, vocab_p) buffer entirely
        xl = (x[:, -1:] if last_index is None
              else jax.lax.dynamic_slice_in_dim(x, last_index, 1, axis=1))
        logits = logits_from(params, xl, cfg)
        return logits, kv_stack
    return logits_from(params, x, cfg)


# ---------------------------------------------------------------------------
# serving entry points


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> PyTree:
    g = attn_geometry(cfg)
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    # head-major layout: decode einsums read it without transposing
    shape = (cfg.n_layers, batch, g.kvp, max_len, g.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def finalize_prefill_cache(k, v, max_len: int):
    """(L, b, s, kvp, hd) scan output -> head-major padded cache dict."""
    k = k.transpose(0, 1, 3, 2, 4)  # -> head-major (L, b, kvp, s, hd)
    v = v.transpose(0, 1, 3, 2, 4)
    pad = max_len - k.shape[3]
    if pad > 0:
        zeros = jnp.zeros(k.shape[:3] + (pad,) + k.shape[4:], k.dtype)
        k = jnp.concatenate([k, zeros], axis=3)
        v = jnp.concatenate([v, zeros], axis=3)
    return {"k": k, "v": v}


def prefill(params, tokens, cfg: ModelConfig, max_len: int,
            stats: Optional[cm.StatsCollector] = None):
    """Run the full prompt, return (last-token logits, cache at max_len)."""
    logits, kv = forward(params, tokens, cfg, stats=stats, return_kv=True)
    # logits are last-position only (b, 1, V)
    return logits[:, -1], finalize_prefill_cache(*kv, max_len)


def decode_step(params, cache, token, pos, cfg: ModelConfig,
                stats: Optional[cm.StatsCollector] = None,
                ffn_masks: Optional[jnp.ndarray] = None):
    """One decode step. token: (b,) int32; pos: (b,) write position.

    ffn_masks (L, d_ff): γ-window weight-reuse masks (paper Fig. 7c).
    Returns (logits (b, vocab_p), new cache). The cache S axis may be sharded
    (long-context flash-decode, DESIGN.md §3).
    """
    stats = stats or cm.StatsCollector(False)
    params = cm.cast_params(params, cfg)
    b = token.shape[0]
    x = embed_tokens(params, token[:, None], cfg, pos[:, None])[:, 0]

    if stats.active:
        kc, vc = cache["k"], cache["v"]
        for i in range(cfg.n_layers):
            pl_i = jax.tree.map(lambda a: a[i], params["layers"])
            sub = cm.StatsCollector(True)
            x, kc, vc = apply_block_decode(
                pl_i, x, cfg, kc, vc, pos, stats=sub, layer=i,
                ffn_mask=None if ffn_masks is None else ffn_masks[i])
            for k_, v_ in sub.stats.items():
                stats.stats[f"layer{i}/{k_}"] = v_
        new_cache = {"k": kc, "v": vc}
    else:
        # the FULL stacked cache rides in the scan carry: per step each layer
        # reads its slice for attention and writes one token in place (no
        # per-layer full-slice rewrites through scan ys).
        def body(carry, xs):
            x, kc, vc = carry
            if ffn_masks is None:
                pl_i, li = xs
                fm = None
            else:
                pl_i, li, fm = xs
            x, kc, vc = apply_block_decode(pl_i, x, cfg, kc, vc, pos,
                                           stats=stats, layer=li, ffn_mask=fm)
            return (x, kc, vc), None
        xs = ((params["layers"], jnp.arange(cfg.n_layers)) if ffn_masks is None
              else (params["layers"], jnp.arange(cfg.n_layers), ffn_masks))
        (x, kc, vc), _ = jax.lax.scan(
            body, (x, cache["k"], cache["v"]), xs)
        new_cache = {"k": kc, "v": vc}

    x = cm.apply_norm(params["final_norm"], x[:, None], cfg)[:, 0]
    logits = logits_from(params, x, cfg)
    return logits, new_cache


# ---------------------------------------------------------------------------
# continuous-batching serving: paged cache + per-request γ-window masks
#
# Unlike decode_step above (uniform positions, contiguous per-batch cache),
# these entry points serve a *slot* batch whose requests were admitted at
# different times: every slot has its own write position, its own block-table
# row into the shared page pool, and its own γ-window FFN mask + refresh
# phase. The whole stack is written for a W-token WINDOW per slot — W = γ+1
# is the speculative-verification target forward (all window tokens in ONE
# pass, causal within the window), W = 1 is the plain decode step. Everything
# is computed in-graph — one trace, no host round-trips.


def _ffn_tile(cfg: ModelConfig) -> int:
    return cm.ffn_gather_tile(cfg)


def apply_attn_window_paged(p, x, cfg: ModelConfig, k_pages, v_pages, table,
                            pos, valid, *, layer, block_size: int,
                            stats: cm.StatsCollector,
                            fast_kernels: bool = False):
    """W-token windowed attention against the paged pool. x: (b, W, d);
    pos: (b, W) per-slot write positions (NOT uniform); valid: (b, W) real
    window tokens — K/V of invalid ones is routed to the scratch block;
    table: (b, nb) block ids. Causal within the window: token i attends to
    cache positions <= pos[:, i]. Returns (out (b, W, d), k_pages, v_pages).

    ``fast_kernels`` reads the pool THROUGH the block table inside a Pallas
    kernel (kernels/paged_attention.py) instead of materializing the
    ``paged_gather`` copy — same math (streams match at f32), half the
    cache traffic.
    """
    g = attn_geometry(cfg)
    b, W, _ = x.shape
    q, k, v = _qkv(p, x, cfg, pos, stats=stats,
                   input_density=cfg.sparsity.input_tile_density)
    q = q.reshape(b, W, g.kvp, g.group, g.head_dim)
    k_pages = cm.paged_write_window(k_pages, layer, table, pos, k,
                                    block_size, valid)
    v_pages = cm.paged_write_window(v_pages, layer, table, pos, v,
                                    block_size, valid)
    kl = jax.lax.dynamic_index_in_dim(k_pages, layer, 0, keepdims=False)
    vl = jax.lax.dynamic_index_in_dim(v_pages, layer, 0, keepdims=False)
    if fast_kernels:
        from repro.kernels import paged_attention as kpa
        o = kpa.paged_window_attention(q, kl, vl, table, pos,
                                       window=cfg.sliding_window)
    else:
        kg = cm.paged_gather(kl, table)
        vg = cm.paged_gather(vl, table)
        o = cm.window_attention(q, kg, vg, pos, window=cfg.sliding_window)
    out = _attn_out(p, o.reshape(b, W, g.hp, g.head_dim), cfg)
    return out, k_pages, v_pages


def apply_ffn_window(p, x, cfg: ModelConfig, *, mask, refresh, valid,
                     fast_kernels: bool = False):
    """Decode FFN over a W-token window with per-request γ-window weight
    reuse, batched over slots. x: (b, W, d); mask: (b, F) bool — the rows
    loaded in each request's current window; refresh: (b,) bool — slots
    starting a new window this step (they run dense and record fresh
    activity); valid: (b, W) bool — real window tokens (idle slots and
    window padding are excluded from activity/scores).

    Returns (out (b, W, d),
             act (b, F) union activity over the window's valid tokens,
             scores (b, F//tile) window-union tile activity,
             density (b,) fraction of down-proj rows READ (refresh ⇒ 1.0)
                 — the Fig. 7c γ-reuse weight-I/O metric,
             union_density (b,) fraction of rows in the window's activity
                 union = 1 − s_agg(W) — the Sec. 5.2 sparse-verification
                 I/O metric).

    ``fast_kernels`` makes the union I/O saving PHYSICAL: the
    down-projection runs as a per-row tile gather (sparse_matmul_tokens)
    over each slot's window-union tile list, so only union-active wd tiles
    are read — exactly the density the union_density metric reports. The up
    projection stays dense (the union is only known after it runs)."""
    from repro.kernels.fused_ffn import window_tile_activity

    act_fn = acts.get(cfg.activation, shift=cfg.sparsity.shift)
    b, W, d = x.shape
    x2 = x.reshape(b * W, d)
    dens_in = (cfg.sparsity.input_tile_density if cfg.sparsity.enabled
               else 1.0)
    if cfg.ffn_kind == "glu":
        pre = cm.maybe_sparse_matmul(x2, p["wg"], cfg, dens_in)
        h = act_fn(pre) * cm.maybe_sparse_matmul(x2, p["wu"], cfg, dens_in)
    else:
        h = act_fn(cm.maybe_sparse_matmul(x2, p["wu"], cfg, dens_in))
    h = h.reshape(b, W, h.shape[-1])
    # TP serving: window activations / union masks live on shard-local d_ff
    # slices (no-op single-device — constrain is identity without a mesh)
    h = rules.constrain(h, "dp", None, "model")
    eff = mask | refresh[:, None]  # refresh ⇒ all rows participate
    h = h * eff[:, None, :].astype(h.dtype)
    hv = h * valid[:, :, None].astype(h.dtype)
    act = jnp.any(hv != 0, axis=1)  # (b, F) union over the window
    scores = window_tile_activity(hv, _ffn_tile(cfg))
    density = jnp.mean(eff.astype(jnp.float32), axis=-1)
    union_density = jnp.mean(act.astype(jnp.float32), axis=-1)
    dens_ffn = (cfg.sparsity.ffn_tile_density if cfg.sparsity.enabled
                else 1.0)
    if fast_kernels and dens_in >= 1.0 and dens_ffn >= 1.0:
        from repro.kernels import sparse_matmul as ksm
        from repro.predictor import predictors as preds
        tile = _ffn_tile(cfg)
        n_tiles = h.shape[-1] // tile
        # per-slot window-union tile list at full capacity: valid rows'
        # support is inside their slot's union, so gathering only union
        # tiles is exact for every row the caller reads (invalid window
        # rows may differ — their outputs are discarded by construction)
        idx, nvalid = preds.pack_tile_indices(scores > 0, n_tiles)
        out = ksm.sparse_matmul_tokens(
            h.reshape(b * W, -1).astype(p["wd"].dtype), p["wd"],
            jnp.repeat(idx, W, axis=0), jnp.repeat(nvalid, W),
            tile=tile).astype(x.dtype)
    else:
        out = cm.maybe_sparse_matmul(h.reshape(b * W, -1), p["wd"], cfg,
                                     dens_ffn)
    return out.reshape(b, W, d), act, scores, density, union_density


def apply_block_window_paged(p, x, cfg: ModelConfig, k_pages, v_pages, table,
                             pos, valid, *, layer, block_size: int, mask,
                             refresh, fast_kernels: bool = False):
    stats = cm.StatsCollector(False)
    h = post_norm(cm.apply_norm(p["ln1"], x, cfg), cfg)
    a, k_pages, v_pages = apply_attn_window_paged(
        p["attn"], h, cfg, k_pages, v_pages, table, pos, valid, layer=layer,
        block_size=block_size, stats=stats, fast_kernels=fast_kernels)
    x = x + a
    h = post_norm(cm.apply_norm(p["ln2"], x, cfg), cfg)
    f, act, scores, density, udens = apply_ffn_window(
        p["ffn"], h, cfg, mask=mask, refresh=refresh, valid=valid,
        fast_kernels=fast_kernels)
    x = x + f
    return x, k_pages, v_pages, act, scores, density, udens


def verify_window_paged(params, pages, table, tokens, pos0, wlen,
                        cfg: ModelConfig, ffn_masks, refresh, *,
                        block_size: int, fast_kernels: bool = False):
    """Run a W-token window per slot in ONE forward over the shared page
    pool — the speculative-verification target step (paper Sec. 5.2): every
    window token's K/V is written at its own position, attention is causal
    within the window, and the FFN activity comes back as the window's
    aggregated (union) mask. W == 1 is exactly the plain continuous-batching
    decode step (see ``decode_step_paged``).

    tokens: (b, W) = [current token, draft proposals...]; pos0: (b,) write
    position of tokens[:, 0]; wlen: (b,) valid window length per slot —
    tokens at index >= wlen (and every token of an idle slot, wlen == 0)
    write to the scratch block and are excluded from activity, so no
    speculative write can land outside a request's blocks; table: (b, nb);
    ffn_masks: (L, b, F) bool γ-window masks; refresh: (b,).

    Returns (logits (b, W, vocab_p), pages, new_masks (L, b, F), aux) with
    aux = (act (L, b, F) window-union FFN activity, scores (L, b, F//tile)
    window-union tile activity, density (L, b) fraction of rows read,
    union_density (L, b) = 1 − s_agg of each slot's window).

    Structure (embed → layer scan → mask refresh → head) lives in the
    family-agnostic ``serving_protocol.window_step_core``; this wrapper
    only supplies the dense block — the delegated trace is op-for-op the
    historical lowering."""
    def layer_fn(pl_i, li, x, kp, vp, fm, pos, valid):
        x, kp, vp, act, scores, density, udens = apply_block_window_paged(
            pl_i, x, cfg, kp, vp, table, pos, valid, layer=li,
            block_size=block_size, mask=fm, refresh=refresh,
            fast_kernels=fast_kernels)
        return x, kp, vp, (act, scores, density, udens)

    return sp.window_step_core(params, pages, tokens, pos0, wlen, cfg,
                               ffn_masks, refresh, layer_fn=layer_fn,
                               embed_fn=embed_tokens, logits_fn=logits_from)


def prefill_chunk_paged(params, pages, table, tokens, pos0, clen,
                        cfg: ModelConfig, ffn_masks, refresh, *,
                        block_size: int, fast_kernels: bool = False):
    """One fixed-shape CHUNK of paged prefill, batched over slots — the
    admission path that replaces stop-the-world whole-prompt prefill.

    A prefill chunk IS a W-token window step, so this delegates to
    ``verify_window_paged``: every chunk token's K/V is scattered at its own
    position through the block table (``paged_write_window``), attention is
    causal within the chunk and over everything already in the cache
    (earlier chunks AND prefix-cache blocks written by other requests), and
    tokens at index >= clen are scratch-routed. The scheduler interleaves
    one chunk per engine step with decode, so admission costs ONE compiled
    shape (n_slots × chunk) with bounded per-step latency — instead of one
    whole-prompt executable per prompt-block count, each stalling every
    active decode for its full duration.

    tokens: (b, C) the next C prompt tokens per slot (zero-padded past
    clen); pos0: (b,) each slot's prefill resume position — block-aligned
    for a prefix-cache hit's cold suffix; clen: (b,) valid chunk lengths
    (0 = slot not prefilling this step).

    Returns (logits (b, C, vocab_p), pages, new_masks, aux): on a request's
    final chunk, logits[i, clen_i - 1] seed its first generated token; aux's
    union FFN activity / tile scores are the free per-chunk harvest that
    warms the request's first γ-window mask and predictor telemetry
    (new_masks picks it up wherever ``refresh`` is set)."""
    return verify_window_paged(params, pages, table, tokens, pos0, clen,
                               cfg, ffn_masks, refresh,
                               block_size=block_size,
                               fast_kernels=fast_kernels)


def _ffn_decode_predicted(pf, h, cfg: ModelConfig, pred_l, *, kind: str,
                          tile: int, k_tiles: int, mask, refresh,
                          measure: bool = True, shards: int = 1,
                          fast_kernels: bool = False):
    """Predictor-gathered decode FFN (predictor serving mode): the
    activity predictor (repro.predictor) names each token's active tiles
    BEFORE any FFN weight is read, and both the up- and down-projections
    run as tile-gathered matmuls (kernels/sparse_matmul.py) over exactly
    those tiles — the paper's "up to 3x" headroom applied to the full FFN
    weight I/O, not just the down-projection.

    h: (B, d) post-norm FFN input; pred_l: this layer's predictor-param
    slice; mask (B, F) / refresh (B,): the γ-window machinery — between
    refreshes the window's rows are composed INTO the predicted set
    (cheap recall insurance: recently-active rows stay computable even if
    the probe misses them).

    A recall miss is a correctness event, so with measure=True (the
    measurement-repo default) it is counted in-graph: a dense gate
    pre-activation — telemetry only, its product never feeds the residual
    stream — re-reads the gate weight each step. measure=False drops that
    probe (n_active/n_miss come back 0), making the gathered reads the
    ONLY FFN weight traffic — the production-serving configuration.

    ``shards`` (the engine passes its mesh's TP degree; 1 = today's
    single-device lowering, bit-frozen) makes the packed tile lists
    model-axis-local: each TP shard packs its own capacity from its local
    d_ff slice (predictors.pack_tile_indices n_groups), the probe /
    union-mask composition runs on "model"-sharded (B, F) tensors, and
    the per-token density/recall telemetry is reduced across shards once
    per step by the returned sums — no host round-trips.

    Returns (f (B, d), act (B, F), scores (B, F // _ffn_tile),
             density (B,) fraction of weight tiles READ (up AND down),
             n_active (B,), n_miss (B,))."""
    from repro.kernels import sparse_matmul as ksm
    from repro.kernels.fused_ffn import tile_activity
    from repro.predictor import predictors as preds

    act_fn = acts.get(cfg.activation, shift=cfg.sparsity.shift)
    n_tiles = cfg.d_ff // tile
    unit_pred = preds.predict_units(kind, pred_l, h)  # (B, F)
    unit_pred = rules.constrain(unit_pred, "dp", "model")
    eff_units = unit_pred | (mask & ~refresh[:, None])
    tile_mask = preds.units_to_tiles(eff_units, tile)
    idx, nvalid = preds.pack_tile_indices(tile_mask, k_tiles,
                                          n_groups=shards)
    cov_units = preds.tiles_to_units(
        preds.covered_tiles(idx, nvalid, n_tiles), tile)  # (B, F)

    gate_w = pf["wg"] if cfg.ffn_kind == "glu" else pf["wu"]
    if fast_kernels:
        # fused gather-up -> act -> scatter-down: one pass over the tile
        # list, same per-tile dots / accumulation order as the unfused pair
        # below (bit-equal — tests/test_fused_decode.py). cov_units is all
        # ones inside every gathered tile, so the in-kernel activation needs
        # no covered-mask; non-gathered tiles are exact zeros by omission.
        from repro.kernels import fused_decode as kfd
        f32, compact = kfd.fused_sparse_ffn(
            h, gate_w, pf["wd"], idx, nvalid,
            w_up=pf["wu"] if cfg.ffn_kind == "glu" else None,
            activation=cfg.activation, shift=cfg.sparsity.shift, tile=tile)
        hh = kfd.scatter_compact(compact, idx, nvalid, n_tiles)
        f = f32.astype(h.dtype)
    else:
        pre = ksm.sparse_up_matmul(h, gate_w, idx, nvalid, tile=tile)
        # mask to the covered tiles so skipped tiles are EXACTLY zero even
        # for activations with f(0) != 0 (e.g. negative shifted_relu shift)
        hh = act_fn(pre) * cov_units.astype(pre.dtype)
        if cfg.ffn_kind == "glu":
            hh = hh * ksm.sparse_up_matmul(h, pf["wu"], idx, nvalid,
                                           tile=tile)
        f = ksm.sparse_matmul_tokens(hh.astype(pf["wd"].dtype), pf["wd"],
                                     idx, nvalid, tile=tile).astype(h.dtype)
    act = hh != 0
    scores = tile_activity(hh, _ffn_tile(cfg))
    density = nvalid.astype(jnp.float32) / n_tiles

    if measure:
        thr = acts.firing_threshold(cfg.activation, cfg.sparsity.shift)
        true_act = (h @ gate_w).astype(jnp.float32) > thr  # telemetry only
        n_active = jnp.sum(true_act.astype(jnp.int32), axis=-1)
        n_miss = jnp.sum((true_act & ~cov_units).astype(jnp.int32), axis=-1)
    else:
        n_active = jnp.zeros(h.shape[0], jnp.int32)
        n_miss = jnp.zeros(h.shape[0], jnp.int32)
    return f, act, scores, density, n_active, n_miss


def apply_block_decode_paged(p, x, cfg: ModelConfig, k_pages, v_pages, table,
                             pos, *, layer, block_size: int, mask, refresh,
                             pred=None, pred_kind: Optional[str] = None,
                             pred_tile: int = 128, k_tiles: int = 0,
                             pred_measure: bool = True, pred_shards: int = 1,
                             fast_kernels: bool = False):
    """Single-token specialization of ``apply_block_window_paged``.

    Mathematically the W = 1 case, but kept as its own lowering: the decode
    step is the latency-critical path (it should carry no window machinery),
    and its bf16 rounding placement is FROZEN — re-deriving it from the
    window code changes where XLA rounds, which changes greedy outputs of
    bf16 models across engines (exactness tests pin the current numerics).

    ``pred`` (a per-layer predictor-param slice; None = off, identical
    trace to before) switches the FFN to the predictor-gathered path
    (``_ffn_decode_predicted``), which appends (n_active, n_miss) recall
    telemetry to the return tuple.
    """
    stats = cm.StatsCollector(False)
    h = post_norm(cm.apply_norm(p["ln1"], x[:, None], cfg)[:, 0], cfg)
    g = attn_geometry(cfg)
    q, k, v = _qkv(p["attn"], h[:, None, :], cfg, pos[:, None],
                   stats=stats, input_density=cfg.sparsity.input_tile_density)
    q = q.reshape(q.shape[0], g.kvp, g.group, g.head_dim)
    k_pages = cm.paged_write_token(k_pages, layer, table, pos, k[:, 0],
                                   block_size)
    v_pages = cm.paged_write_token(v_pages, layer, table, pos, v[:, 0],
                                   block_size)
    kl = jax.lax.dynamic_index_in_dim(k_pages, layer, 0, keepdims=False)
    vl = jax.lax.dynamic_index_in_dim(v_pages, layer, 0, keepdims=False)
    if fast_kernels:
        from repro.kernels import paged_attention as kpa
        o = kpa.paged_decode_attention(q, kl, vl, table, pos,
                                       window=cfg.sliding_window)
    else:
        kg = cm.paged_gather(kl, table)
        vg = cm.paged_gather(vl, table)
        o = cm.decode_attention(q, kg, vg, pos, window=cfg.sliding_window)
    a = _attn_out(p["attn"], o.reshape(o.shape[0], 1, g.hp, g.head_dim),
                  cfg)[:, 0]
    x = x + a

    from repro.kernels.fused_ffn import tile_activity
    h = post_norm(cm.apply_norm(p["ln2"], x[:, None], cfg)[:, 0], cfg)
    if pred is not None:
        f, act, scores, density, n_active, n_miss = _ffn_decode_predicted(
            p["ffn"], h, cfg, pred, kind=pred_kind, tile=pred_tile,
            k_tiles=k_tiles, mask=mask, refresh=refresh,
            measure=pred_measure, shards=pred_shards,
            fast_kernels=fast_kernels)
        x = x + f
        return x, k_pages, v_pages, act, scores, density, n_active, n_miss
    act_fn = acts.get(cfg.activation, shift=cfg.sparsity.shift)
    dens_in = (cfg.sparsity.input_tile_density if cfg.sparsity.enabled
               else 1.0)
    dens_ffn = (cfg.sparsity.ffn_tile_density if cfg.sparsity.enabled
                else 1.0)
    pf = p["ffn"]
    eff = mask | refresh[:, None]  # refresh ⇒ all rows participate
    if fast_kernels and dens_in >= 1.0 and dens_ffn >= 1.0:
        # AR fast path: the γ-window eff mask IS a per-token active set, so
        # the whole FFN runs through the fused kernel over eff's tile list
        # at full capacity — up- AND down-projection reads of fully-masked
        # tiles are physically skipped; masked-off units inside gathered
        # tiles are zeroed in-kernel (unit_mask), matching hh * eff.
        from repro.kernels import fused_decode as kfd
        from repro.predictor import predictors as preds
        tile = _ffn_tile(cfg)
        n_tiles = cfg.d_ff // tile
        idx, nvalid = preds.pack_tile_indices(
            preds.units_to_tiles(eff, tile), n_tiles)
        f32, compact = kfd.fused_sparse_ffn(
            h, pf["wg"] if cfg.ffn_kind == "glu" else pf["wu"], pf["wd"],
            idx, nvalid,
            w_up=pf["wu"] if cfg.ffn_kind == "glu" else None, unit_mask=eff,
            activation=cfg.activation, shift=cfg.sparsity.shift, tile=tile)
        hh = kfd.scatter_compact(compact, idx, nvalid, n_tiles)
        f = f32.astype(h.dtype)
    else:
        if cfg.ffn_kind == "glu":
            pre = cm.maybe_sparse_matmul(h, pf["wg"], cfg, dens_in)
            hh = act_fn(pre) * cm.maybe_sparse_matmul(h, pf["wu"], cfg,
                                                      dens_in)
        else:
            hh = act_fn(cm.maybe_sparse_matmul(h, pf["wu"], cfg, dens_in))
        # TP serving (rules.use_mesh installed): keep the hidden activation
        # and the γ-mask composition sharded on each shard's d_ff slice;
        # no-op (and bit-frozen lowering) single-device
        hh = rules.constrain(hh, "dp", "model")
        hh = hh * eff.astype(hh.dtype)
        f = cm.maybe_sparse_matmul(hh, pf["wd"], cfg, dens_ffn)
    act = hh != 0
    scores = tile_activity(hh, _ffn_tile(cfg))
    density = jnp.mean(eff.astype(jnp.float32), axis=-1)
    x = x + f
    return x, k_pages, v_pages, act, scores, density


def decode_step_paged(params, pages, table, token, pos, cfg: ModelConfig,
                      ffn_masks, refresh, *, block_size: int,
                      fast_kernels: bool = False):
    """One continuous-batching decode step over the shared page pool — the
    W = 1 case of ``verify_window_paged``, specialized (see
    ``apply_block_decode_paged`` for why it is not a wrapper).

    token/pos/refresh: (b,) per slot; table: (b, nb); ffn_masks: (L, b, F)
    bool γ-window masks. Idle slots point at the scratch block and are
    simply ignored by the caller. Returns (logits (b, vocab_p), pages,
    new_masks (L, b, F), aux) where aux = (act (L, b, F), scores
    (L, b, F//tile), density (L, b)).

    Structure lives in ``serving_protocol.decode_step_core``; this wrapper
    supplies the dense decode block (same jaxpr as the historical inline
    loop)."""
    def layer_fn(pl_i, li, x, kp, vp, fm):
        x, kp, vp, act, scores, density = apply_block_decode_paged(
            pl_i, x, cfg, kp, vp, table, pos, layer=li,
            block_size=block_size, mask=fm, refresh=refresh,
            fast_kernels=fast_kernels)
        return x, kp, vp, (act, scores, density)

    return sp.decode_step_core(params, pages, token, pos, cfg, ffn_masks,
                               refresh, layer_fn=layer_fn,
                               embed_fn=embed_tokens, logits_fn=logits_from)


def decode_step_paged_predicted(params, pages, table, token, pos, cfg: ModelConfig,
                                ffn_masks, refresh, pred_params, *,
                                kind: str, tile: int, k_tiles: int,
                                block_size: int, measure: bool = True,
                                shards: int = 1, fast_kernels: bool = False):
    """Predictor-mode continuous-batching decode step: like
    ``decode_step_paged`` but every layer's FFN runs tile-gathered over the
    activity predictor's per-token mask (up- AND down-projection reads are
    skipped — see ``_ffn_decode_predicted``). pred_params is the stacked
    (leading layer axis) predictor pytree; kind / tile / k_tiles are static
    so the step compiles ONCE (fixed-K padded tile indices, no retracing).
    measure=False drops the in-graph recall probe (and its dense gate-weight
    re-read) — the production configuration.

    Returns (logits (b, vocab_p), pages, new_masks (L, b, F), aux) with
    aux = (act (L, b, F), scores (L, b, F//tile'), density (L, b) fraction
    of FFN weight tiles read, n_active (L, b), n_miss (L, b) in-graph
    recall telemetry; zeros when measure=False).

    ``shards`` (static; the engine's mesh TP degree) switches the per-token
    packed tile lists to model-axis-local packing — see
    ``_ffn_decode_predicted``. 1 keeps the frozen single-device lowering."""
    def layer_fn(pl_i, li, x, kp, vp, fm, pred_l):
        x, kp, vp, act, scores, density, n_act, n_miss = \
            apply_block_decode_paged(
                pl_i, x, cfg, kp, vp, table, pos, layer=li,
                block_size=block_size, mask=fm, refresh=refresh,
                pred=pred_l, pred_kind=kind, pred_tile=tile, k_tiles=k_tiles,
                pred_measure=measure, pred_shards=shards,
                fast_kernels=fast_kernels)
        return x, kp, vp, (act, scores, density, n_act, n_miss)

    return sp.decode_step_core(params, pages, token, pos, cfg, ffn_masks,
                               refresh, layer_fn=layer_fn,
                               embed_fn=embed_tokens, logits_fn=logits_from,
                               extra_xs=(pred_params,))


def draft_gamma_paged(params, pages, table, token, pos0, wlen,
                      cfg: ModelConfig, *, gamma: int, block_size: int,
                      next_fn=None, fast_kernels: bool = False):
    """Draft γ tokens per slot in one jitted scan over the paged pool —
    the proposer half of speculative decoding, batched across slots with
    NO host round-trips.

    token: (b,) each slot's current (verified) token; pos0: (b,) its write
    position; wlen: (b,) the slot's verification window length W_s — draft
    step g writes position pos0+g only while g < W_s (out-of-window and
    idle-slot writes go to the scratch block). The scan runs γ+1 steps so
    the final proposal's own K/V is already in the draft cache when every
    draft is accepted (no hole to back-fill next round); the extra step's
    logits are discarded.

    next_fn(logits (b, vocab_p), g) -> (b,) int32 selects each step's
    proposal from the step's logits — the logits-out hook the serving
    engine uses to draft with per-slot sampling (sampling head + the
    shared key schedule). None keeps the frozen greedy argmax lowering.

    Returns (proposals (b, γ), pages)."""
    b = token.shape[0]
    masks = jnp.zeros((cfg.n_layers, b, cfg.d_ff), bool)
    refresh = jnp.ones((b,), bool)

    def step(carry, g):
        tok, pages = carry
        wl = (g < wlen).astype(wlen.dtype)  # 0/1: write-enable as W_s
        logits, pages, _, _ = verify_window_paged(
            params, pages, table, tok[:, None], pos0 + g, wl, cfg,
            masks, refresh, block_size=block_size,
            fast_kernels=fast_kernels)
        if next_fn is None:
            nxt = jnp.argmax(logits[:, 0, : cfg.vocab_size],
                             -1).astype(jnp.int32)
        else:
            nxt = next_fn(logits[:, 0], g)
        return (nxt, pages), nxt

    (_, pages), props = jax.lax.scan(
        step, (token, pages), jnp.arange(gamma + 1, dtype=wlen.dtype))
    return props[:gamma].T, pages


def prefill_paged(params, tokens, cfg: ModelConfig, pages, blocks,
                  *, block_size: int, true_len=None):
    """Prefill one request's prompt into freshly allocated pool blocks.

    tokens: (1, s); blocks: (nb,) with nb*block_size >= s. Returns
    (last-token logits (1, vocab_p), pages).

    true_len (traced scalar): real prompt length when `tokens` is
    zero-padded to a block multiple — the engine pads so compiles are keyed
    on block count (<= max_blocks_per_seq shapes), not raw prompt length.
    K/V written for pad positions is masked by `pos` until decode overwrites
    it in place."""
    li = None if true_len is None else true_len - 1
    logits, kv = forward(params, tokens, cfg, return_kv=True, last_index=li)
    k, v = kv  # (L, 1, s, kvp, hd)
    kp = cm.paged_write_prefill(pages["k"], k[:, 0], blocks, block_size)
    vp = cm.paged_write_prefill(pages["v"], v[:, 0], blocks, block_size)
    return logits[:, -1], {"k": kp, "v": vp}
