"""InternVL2 family: InternLM2-style backbone + STUB ViT frontend.

The assignment specifies the transformer backbone only; `input_specs()`
provides precomputed patch embeddings (b, n_vision_tokens, d_model) which are
prepended to the token embeddings. Relufication applies to the backbone FFNs
exactly as for the dense family.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models import transformer as T


def init_params(rng, cfg: ModelConfig):
    return T.init_params(rng, cfg)


def prompt_token_offset(cfg: ModelConfig) -> int:
    """Serving-protocol hook: text decode positions start after the vision
    patch positions the prefill consumed (serving_protocol.py; default 0
    for text-only families)."""
    return cfg.n_vision_tokens


def model_forward(params, batch, cfg: ModelConfig, *, stats=None,
                  remat_policy="none"):
    logits = T.forward(params, batch["tokens"], cfg, stats=stats,
                       extra_embeds=batch["patches"],
                       remat_block=cm.wrap_block(remat_policy, T.apply_block))
    return logits[:, batch["patches"].shape[1]:]  # align with tokens


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    return T.init_cache(cfg, batch, max_len)


def model_prefill(params, batch, cfg: ModelConfig, max_len: int, stats=None):
    """Prompt = vision patches ++ tokens; cache covers both."""
    logits, kv = T.forward(params, batch["tokens"], cfg, stats=stats,
                           extra_embeds=batch["patches"], return_kv=True)
    return logits[:, -1], T.finalize_prefill_cache(*kv, max_len)


def model_decode(params, cache, token, pos, cfg: ModelConfig, stats=None):
    return T.decode_step(params, cache, token, pos, cfg, stats=stats)
