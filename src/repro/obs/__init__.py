"""Observability for the serving stack: a dependency-free metrics
registry (obs/metrics.py) and engine step/request tracing (obs/tracing.py).

Import surface is deliberately jax-free — the host-only scheduler hooks
into ``EngineObs`` and must stay importable without a device runtime.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, Registry,
                               hist_quantile, label_str, merge_snapshots,
                               parse_prometheus, render_prometheus,
                               snapshot_quantile)
from repro.obs.tracing import (PHASES, EngineObs, RequestSpan, StepTrace,
                               format_statusz)

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry",
    "hist_quantile", "label_str", "merge_snapshots", "parse_prometheus",
    "render_prometheus", "snapshot_quantile",
    "PHASES", "EngineObs", "RequestSpan", "StepTrace", "format_statusz",
]
