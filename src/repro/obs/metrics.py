"""Dependency-free in-process metrics: Counters, Gauges, and log-bucketed
Histograms with labeled series, mergeable snapshots, and Prometheus text
exposition.

Pure stdlib on purpose: the scheduler (serving/scheduler.py) is host-only
with no jax import, and the serving path must run from the bare ``repro``
install — so this module must not pull in numpy, jax, or any client
library. Everything is plain dicts and floats.

Model
-----
A ``Registry`` owns named metrics; each metric owns labeled *series*
(one per distinct label set, keyed by the canonical Prometheus label
string ``k1="v1",k2="v2"``). Three kinds:

* ``Counter`` — monotonically non-decreasing sum (``inc``).
* ``Gauge`` — last-written value (``set``).
* ``Histogram`` — geometric (log-spaced) buckets: bucket *i* counts
  observations ``<= lo * factor**i``, plus a +Inf overflow bucket, plus
  exact sum/count/min/max. Log buckets hold constant *relative* error, the
  right shape for latencies spanning µs prefills to multi-second
  compile-warm first steps.

``Registry.snapshot()`` returns a plain JSON-able dict. Snapshots MERGE
(``merge_snapshots``): counters and histogram buckets add, gauges take the
right operand, min/max widen — associative, so per-engine (or per-process)
snapshots can be combined in any grouping into one fleet view. Quantiles
(``hist_quantile``) are answered from bucket counts: the returned value is
the upper edge of the bucket holding the q-th observation, clamped to the
observed [min, max] — so it always lies within that bucket's bounds
(tests/test_obs.py holds these properties under hypothesis).

``render_prometheus`` emits the text exposition format (``/metrics``).
Metrics with no series yet are omitted entirely — an unavailable series
(e.g. predictor recall with telemetry off) simply never appears, it does
not render as a fake zero.
"""
from __future__ import annotations

import json
import math
import threading
from typing import Dict, List, Optional, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry",
    "merge_snapshots", "render_prometheus", "hist_quantile", "label_str",
]


def label_str(labels: Dict[str, str]) -> str:
    """Canonical label-set key: sorted ``k="v"`` pairs joined by commas
    (exactly what goes inside ``{}`` in the Prometheus exposition)."""
    if not labels:
        return ""
    return ",".join(f'{k}="{_escape(str(v))}"'
                    for k, v in sorted(labels.items()))


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str, unit: str = ""):
        self.name = name
        self.help = help
        self.unit = unit
        self.series: Dict[str, object] = {}

    def _meta(self) -> dict:
        return {"kind": self.kind, "help": self.help, "unit": self.unit}


class Counter(_Metric):
    """Monotonically non-decreasing labeled sum."""
    kind = "counter"

    def inc(self, value: float = 1.0, **labels) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name}: negative inc {value}")
        key = label_str(labels)
        self.series[key] = self.series.get(key, 0.0) + value

    def value(self, **labels) -> float:
        return float(self.series.get(label_str(labels), 0.0))

    def snapshot(self) -> dict:
        return {**self._meta(), "series": dict(self.series)}


class Gauge(_Metric):
    """Last-written labeled value."""
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self.series[label_str(labels)] = float(value)

    def value(self, **labels) -> Optional[float]:
        return self.series.get(label_str(labels))

    def snapshot(self) -> dict:
        return {**self._meta(), "series": dict(self.series)}


# geometric bucket edges shared by every histogram series of a metric.
# Defaults cover 10 µs .. ~160 s at 2x resolution — wide enough for both
# a sub-ms host-sync phase and a compile-dominated first step.
_DEF_LO = 1e-5
_DEF_FACTOR = 2.0
_DEF_N = 24


class Histogram(_Metric):
    """Log-bucketed labeled histogram. Bucket ``i`` counts observations
    ``<= bounds[i]``; one extra overflow bucket counts the rest (+Inf)."""
    kind = "histogram"

    def __init__(self, name: str, help: str, unit: str = "",
                 lo: float = _DEF_LO, factor: float = _DEF_FACTOR,
                 n_buckets: int = _DEF_N):
        super().__init__(name, help, unit)
        if lo <= 0 or factor <= 1 or n_buckets < 1:
            raise ValueError("histogram needs lo > 0, factor > 1, "
                             "n_buckets >= 1")
        self.bounds: List[float] = [lo * factor ** i
                                    for i in range(n_buckets)]

    def _new_series(self) -> dict:
        return {"buckets": [0] * (len(self.bounds) + 1), "sum": 0.0,
                "count": 0, "min": math.inf, "max": -math.inf}

    def observe(self, value: float, **labels) -> None:
        key = label_str(labels)
        s = self.series.get(key)
        if s is None:
            s = self.series[key] = self._new_series()
        i = _bucket_index(self.bounds, value)
        s["buckets"][i] += 1
        s["sum"] += value
        s["count"] += 1
        if value < s["min"]:
            s["min"] = value
        if value > s["max"]:
            s["max"] = value

    def quantile(self, q: float, **labels) -> Optional[float]:
        s = self.series.get(label_str(labels))
        if s is None or not s["count"]:
            return None
        return hist_quantile({"bounds": self.bounds, **s}, q)

    def count(self, **labels) -> int:
        s = self.series.get(label_str(labels))
        return int(s["count"]) if s else 0

    def snapshot(self) -> dict:
        return {**self._meta(), "bounds": list(self.bounds),
                "series": {k: {"buckets": list(v["buckets"]),
                               "sum": v["sum"], "count": v["count"],
                               "min": v["min"], "max": v["max"]}
                           for k, v in self.series.items()}}


def _bucket_index(bounds: List[float], value: float) -> int:
    """First bucket whose upper edge admits ``value`` (bisect over the
    geometric edges; the list is tiny, linear would do — bisect keeps it
    O(log n) even for fine-grained custom histograms)."""
    lo, hi = 0, len(bounds)
    while lo < hi:
        mid = (lo + hi) // 2
        if value <= bounds[mid]:
            hi = mid
        else:
            lo = mid + 1
    return lo  # == len(bounds) -> overflow bucket


def hist_quantile(series: dict, q: float) -> Optional[float]:
    """Quantile estimate from one histogram series snapshot (needs the
    metric's ``bounds`` spliced in, as ``Histogram.quantile`` and the
    snapshot helpers do). Returns the upper edge of the bucket containing
    the ceil(q*count)-th observation, clamped to the observed [min, max] —
    always within the true quantile's bucket, never outside the observed
    range. None when the series is empty."""
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile {q} outside [0, 1]")
    count = series["count"]
    if not count:
        return None
    rank = max(1, math.ceil(q * count))
    bounds = series["bounds"]
    acc = 0
    for i, c in enumerate(series["buckets"]):
        acc += c
        if acc >= rank:
            upper = bounds[i] if i < len(bounds) else math.inf
            return float(min(max(upper, series["min"]), series["max"]))
    return float(series["max"])  # pragma: no cover - acc always reaches


class Registry:
    """Named metrics, get-or-create. Creation is idempotent (same name →
    the existing metric, kind mismatch raises); a lock guards creation so
    the asyncio serve loop and a benchmark thread can share one registry,
    while the hot inc/observe path stays lock-free (CPython dict ops are
    atomic and every writer is the single engine/serve-loop thread)."""

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, help: str, unit: str, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, unit, **kw)
            elif not isinstance(m, cls):
                raise ValueError(f"metric {name} already registered as "
                                 f"{m.kind}, not {cls.kind}")
            return m

    def counter(self, name: str, help: str = "", unit: str = "") -> Counter:
        return self._get(Counter, name, help, unit)

    def gauge(self, name: str, help: str = "", unit: str = "") -> Gauge:
        return self._get(Gauge, name, help, unit)

    def histogram(self, name: str, help: str = "", unit: str = "",
                  **kw) -> Histogram:
        return self._get(Histogram, name, help, unit, **kw)

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    def snapshot(self) -> dict:
        """Plain JSON-able dict of every metric with at least one series."""
        return {name: m.snapshot() for name, m in self._metrics.items()
                if m.series}

    def render(self) -> str:
        return render_prometheus(self.snapshot())

    def reset(self) -> None:
        """Drop every series (metric definitions survive). For benchmark
        harnesses that warm an engine and then measure it: NOT part of the
        serving path — a live server's counters stay monotone."""
        for m in self._metrics.values():
            m.series.clear()


# ---------------------------------------------------------------------------
# snapshot-level operations (merge + exposition) — pure functions over the
# plain-dict snapshot format, so remote snapshots (JSON over the wire) are
# first-class citizens


def merge_snapshots(*snaps: dict) -> dict:
    """Merge snapshots into one: counters and histogram buckets ADD, gauges
    take the rightmost value, histogram min/max widen. Associative (and,
    for counters/histograms, commutative) — fold per-engine snapshots in
    any grouping; bucket/observation counts and min/max are exactly
    grouping-independent, float sums up to ulp rounding. Kind/bucket-
    geometry mismatches for a shared name raise."""
    out: dict = {}
    for snap in snaps:
        for name, m in snap.items():
            if name not in out:
                out[name] = json.loads(json.dumps(m))  # deep copy
                continue
            dst = out[name]
            if dst["kind"] != m["kind"]:
                raise ValueError(f"merge: {name} is {dst['kind']} vs "
                                 f"{m['kind']}")
            if m["kind"] == "gauge":
                dst["series"].update(m["series"])
            elif m["kind"] == "counter":
                for k, v in m["series"].items():
                    dst["series"][k] = dst["series"].get(k, 0.0) + v
            else:  # histogram
                if dst["bounds"] != m["bounds"]:
                    raise ValueError(f"merge: {name} bucket bounds differ")
                for k, s in m["series"].items():
                    d = dst["series"].get(k)
                    if d is None:
                        dst["series"][k] = json.loads(json.dumps(s))
                        continue
                    d["buckets"] = [a + b for a, b in zip(d["buckets"],
                                                          s["buckets"])]
                    d["sum"] += s["sum"]
                    d["count"] += s["count"]
                    d["min"] = min(d["min"], s["min"])
                    d["max"] = max(d["max"], s["max"])
    return out


def snapshot_quantile(snap: dict, name: str, q: float,
                      labels: str = "") -> Optional[float]:
    """Quantile from a (possibly merged) snapshot; None when absent."""
    m = snap.get(name)
    if m is None or m["kind"] != "histogram":
        return None
    s = m["series"].get(labels)
    if s is None or not s["count"]:
        return None
    return hist_quantile({"bounds": m["bounds"], **s}, q)


def _fmt(v: float) -> str:
    if v != v or v in (math.inf, -math.inf):  # NaN/Inf guards
        return {math.inf: "+Inf", -math.inf: "-Inf"}.get(v, "NaN")
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def render_prometheus(snap: dict) -> str:
    """Prometheus text exposition (version 0.0.4) of a snapshot. Series
    are ordered by label string so scrapes diff cleanly."""
    lines: List[str] = []
    for name in sorted(snap):
        m = snap[name]
        if not m["series"]:
            continue
        if m["help"]:
            lines.append(f"# HELP {name} {m['help']}")
        lines.append(f"# TYPE {name} {m['kind']}")
        if m["kind"] in ("counter", "gauge"):
            for key in sorted(m["series"]):
                lab = f"{{{key}}}" if key else ""
                lines.append(f"{name}{lab} {_fmt(m['series'][key])}")
            continue
        bounds = m["bounds"]
        for key in sorted(m["series"]):
            s = m["series"][key]
            acc = 0
            for i, c in enumerate(s["buckets"]):
                acc += c
                le = _fmt(bounds[i]) if i < len(bounds) else "+Inf"
                lab = f'{key},le="{le}"' if key else f'le="{le}"'
                lines.append(f"{name}_bucket{{{lab}}} {acc}")
            lab = f"{{{key}}}" if key else ""
            lines.append(f"{name}_sum{lab} {_fmt(s['sum'])}")
            lines.append(f"{name}_count{lab} {s['count']}")
    return "\n".join(lines) + ("\n" if lines else "")


def parse_prometheus(text: str) -> Dict[Tuple[str, str], float]:
    """Inverse of ``render_prometheus`` for scrape clients (the serve-smoke
    driver): maps (metric_name, label_string) -> value. Histogram bucket /
    sum / count lines appear under their suffixed names."""
    out: Dict[Tuple[str, str], float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        head, _, val = line.rpartition(" ")
        if not head:
            continue
        if "{" in head:
            name, _, rest = head.partition("{")
            labels = rest.rstrip("}")
        else:
            name, labels = head, ""
        try:
            out[(name, labels)] = float(val)
        except ValueError:
            continue
    return out
