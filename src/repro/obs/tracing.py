"""Step-phase tracing and per-request spans for the serving engine.

``EngineObs`` is the observability hub one ``ContinuousBatchingEngine``
(and its scheduler + async front) feeds:

* ``StepTrace`` — wall-clock phase timer for one ``engine.step()``:
  admit / prefill / dispatch / host_sync / sample, bracketed with
  ``perf_counter`` context managers. The engine hands the trace to
  ``step_end`` together with values it ALREADY holds on the host
  (occupancy, pool use, the step's measured density from the arrays
  ``_account()`` fetched) — observability adds zero device syncs.

* ``RequestSpan`` — one request's lifecycle (queued → prefilled →
  decoding → finished/cancelled), driven by scheduler state transitions
  (``serving/scheduler.py`` calls the ``req_*`` hooks at submit / admit /
  seed / record / retire / cancel). Terminal spans feed the TTFT / TPOT /
  queue-wait / e2e histograms behind the latency percentiles
  (`benchmarks/serving_throughput.py`, ROADMAP item 2).

Disabled observability (``EngineObs.disabled()``) turns every hook into
an early return and ``step_start`` into a shared null trace whose
``phase()`` is a no-op — the house invariant that f32 greedy streams are
byte-identical with observability on or off is pinned by tests/test_obs.py,
and ``self_time_s`` (accumulated inside the hooks themselves) bounds the
per-step bookkeeping cost.

The scheduler is host-only with no jax import; so is this module — hooks
must stay stdlib-only (see obs/metrics.py).
"""
from __future__ import annotations

import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Callable, Deque, Dict, Optional

from repro.obs.metrics import Registry

__all__ = ["StepTrace", "RequestSpan", "EngineObs", "format_statusz",
           "PHASES"]

# engine.step() phase names, in execution order
PHASES = ("admit", "prefill", "dispatch", "host_sync", "sample")

_pc = time.perf_counter


class StepTrace:
    """Per-phase wall-clock accumulator for one engine step."""
    __slots__ = ("t0", "phases")

    def __init__(self):
        self.t0 = _pc()
        self.phases: Dict[str, float] = {}

    @contextmanager
    def phase(self, name: str):
        t = _pc()
        try:
            yield
        finally:
            self.phases[name] = self.phases.get(name, 0.0) + (_pc() - t)


class _NullTrace:
    """Shared no-op trace handed out when observability is disabled, so
    the engine's ``with st.phase(...)`` brackets cost one empty context
    manager and nothing else."""
    __slots__ = ()

    @contextmanager
    def phase(self, name: str):
        yield


_NULL_TRACE = _NullTrace()


@dataclass
class RequestSpan:
    """One request's serving lifecycle, timestamped with ``perf_counter``.

    States: queued (submitted) → prefilling (admitted) → decoding (first
    token) → finished. Latency derivations:

    * queue wait = t_admitted − t_queued (slot + block allocation wait)
    * TTFT       = t_first − t_queued (engine-side: submit → first token)
    * TPOT       = (t_last − t_first) / (n_tokens − 1), needs ≥ 2 tokens
    * e2e        = t_finished − t_queued
    """
    uid: int
    prompt_len: int
    max_new: int
    t_queued: float
    t_admitted: Optional[float] = None
    t_first: Optional[float] = None
    t_last: Optional[float] = None
    t_finished: Optional[float] = None
    n_tokens: int = 0
    cached_tokens: int = 0
    state: str = "queued"
    finish_reason: Optional[str] = None
    # -- SLO scheduling (PR 10) --
    priority: int = 0
    slo_ms: Optional[float] = None
    preemptions: int = 0
    slo_met: Optional[bool] = None

    def queue_wait_s(self) -> Optional[float]:
        if self.t_admitted is None:
            return None
        return self.t_admitted - self.t_queued

    def ttft_s(self) -> Optional[float]:
        if self.t_first is None:
            return None
        return self.t_first - self.t_queued

    def tpot_s(self) -> Optional[float]:
        if self.t_first is None or self.t_last is None or self.n_tokens < 2:
            return None
        return (self.t_last - self.t_first) / (self.n_tokens - 1)

    def e2e_s(self) -> Optional[float]:
        if self.t_finished is None:
            return None
        return self.t_finished - self.t_queued


class EngineObs:
    """Observability hub for one serving engine: a metrics registry, live
    + recently finished request spans, an optional structured-event sink
    (``log_event`` receives one plain dict per lifecycle event — the
    ``--log-json`` stream), and a self-time accumulator bounding the cost
    of the bookkeeping itself."""

    def __init__(self, registry: Optional[Registry] = None, *,
                 enabled: bool = True,
                 log_event: Optional[Callable[[dict], None]] = None,
                 max_finished_spans: int = 64):
        self.enabled = enabled
        self.registry = registry if registry is not None else Registry()
        self.log_event = log_event
        self.spans: Dict[int, RequestSpan] = {}
        self.finished_spans: Deque[RequestSpan] = deque(
            maxlen=max_finished_spans)
        self.self_time_s = 0.0  # wall time spent inside these hooks
        self.steps = 0          # steps that did work (mirrors the counter)
        r = self.registry
        # -- engine step metrics --------------------------------------------
        self.c_steps = r.counter(
            "repro_engine_steps_total", "engine steps that did work")
        self.h_step = r.histogram(
            "repro_engine_step_seconds", "wall time of one engine step",
            unit="seconds")
        self.h_phase = r.histogram(
            "repro_engine_step_phase_seconds",
            "wall time per step phase (admit/prefill/dispatch/host_sync/"
            "sample)", unit="seconds")
        self.g_active = r.gauge(
            "repro_slots_active", "slots decoding this step")
        self.g_occupancy = r.gauge(
            "repro_batch_occupancy_ratio", "active slots / n_slots")
        self.g_queue = r.gauge(
            "repro_queue_depth", "requests waiting for admission")
        self.g_pool_used = r.gauge(
            "repro_pool_blocks_used", "KV pool blocks allocated")
        self.g_pool_total = r.gauge(
            "repro_pool_blocks_total", "allocatable KV pool blocks")
        self.h_density = r.histogram(
            "repro_step_ffn_density",
            "measured FFN weight-read fraction per step (mean over active "
            "slots)", unit="ratio", lo=1e-3, factor=1.26, n_buckets=25)
        self.h_bytes = r.histogram(
            "repro_step_ffn_bytes",
            "modeled per-device FFN weight bytes read this step "
            "(density x dense bytes / TP)", unit="bytes",
            lo=1024.0, factor=4.0, n_buckets=16)
        # -- request lifecycle ----------------------------------------------
        self.c_submitted = r.counter(
            "repro_requests_submitted_total", "requests accepted by submit()")
        self.c_admitted = r.counter(
            "repro_requests_admitted_total", "requests admitted to a slot")
        self.c_finished = r.counter(
            "repro_requests_finished_total",
            "terminal requests by finish reason")
        self.c_tokens = r.counter(
            "repro_generated_tokens_total", "tokens emitted to requests")
        self.c_prefill = r.counter(
            "repro_prefill_tokens_total", "prompt tokens admitted")
        self.c_prefill_cached = r.counter(
            "repro_prefill_tokens_cached_total",
            "prompt tokens served from the prefix cache")
        self.h_ttft = r.histogram(
            "repro_request_ttft_seconds",
            "submit to first token (engine-side)", unit="seconds")
        # per-priority-class TTFT lives in its OWN histogram: the percentile
        # keys derived from h_ttft predate priorities and must keep their
        # unlabeled series
        self.h_class_ttft = r.histogram(
            "repro_request_class_ttft_seconds",
            "submit to first token by priority class", unit="seconds")
        self.c_preempted = r.counter(
            "repro_requests_preempted_total",
            "decode slots preempted under pool/priority pressure")
        self.c_resumed = r.counter(
            "repro_requests_resumed_total",
            "preempted requests re-admitted (resume via chunked prefill)")
        self.h_tpot = r.histogram(
            "repro_request_tpot_seconds",
            "mean inter-token time per finished request", unit="seconds")
        self.h_queue_wait = r.histogram(
            "repro_request_queue_wait_seconds",
            "submit to slot admission", unit="seconds")
        self.h_e2e = r.histogram(
            "repro_request_e2e_seconds", "submit to terminal event",
            unit="seconds")
        # -- mode-specific (series appear only when the mode produces them) --
        self.c_draft_proposed = r.counter(
            "repro_draft_tokens_proposed_total",
            "draft tokens submitted for verification (speculative mode)")
        self.c_draft_accepted = r.counter(
            "repro_draft_tokens_accepted_total",
            "draft tokens the target accepted (speculative mode)")
        self.c_pred_active = r.counter(
            "repro_predictor_active_neurons_total",
            "active FFN neurons measured in-graph (predictor telemetry)")
        self.c_pred_miss = r.counter(
            "repro_predictor_missed_neurons_total",
            "active neurons the predictor's tiles missed (recall events)")
        # -- API front-door latency (serving/api.py terminal events) ---------
        self.h_api_ttft = r.histogram(
            "repro_api_ttft_seconds",
            "API submit to first streamed token", unit="seconds")
        self.h_api_total = r.histogram(
            "repro_api_request_seconds", "API submit to terminal event",
            unit="seconds")
        self.g_info = r.gauge(
            "repro_engine_info",
            "static engine configuration (value is always 1)")

    @classmethod
    def disabled(cls) -> "EngineObs":
        """A no-op hub for metrics-off serving (the byte-identity and
        overhead baselines in tests/test_obs.py)."""
        return cls(enabled=False)

    # -- event sink ----------------------------------------------------------
    def _event(self, kind: str, **fields) -> None:
        if self.log_event is not None:
            self.log_event({"event": kind, "ts": time.time(), **fields})

    # -- engine hooks --------------------------------------------------------
    def set_engine_info(self, **labels) -> None:
        if not self.enabled:
            return
        self.g_info.set(1.0, **{k: str(v) for k, v in labels.items()})

    def step_start(self):
        if not self.enabled:
            return _NULL_TRACE
        return StepTrace()

    def step_end(self, st, *, worked: bool, slots_active: int, n_slots: int,
                 queue_depth: int, pool_used: int, pool_total: int,
                 density: Optional[float] = None,
                 tiles: Optional[float] = None,
                 ffn_bytes: Optional[float] = None) -> None:
        """Close one step trace. Gauges always update (an idle engine still
        reports its occupancy truthfully); step/phase histograms only count
        steps that did work, so percentiles aren't diluted by idle polls."""
        if not self.enabled:
            return
        t = _pc()
        self.g_active.set(slots_active)
        self.g_occupancy.set(slots_active / max(1, n_slots))
        self.g_queue.set(queue_depth)
        self.g_pool_used.set(pool_used)
        self.g_pool_total.set(pool_total)
        if worked:
            self.steps += 1
            self.c_steps.inc()
            self.h_step.observe(t - st.t0)
            for name, dt in st.phases.items():
                self.h_phase.observe(dt, phase=name)
            if density is not None:
                self.h_density.observe(density)
            if tiles is not None:
                self.h_density.observe(tiles, granularity="tile")
            if ffn_bytes is not None:
                self.h_bytes.observe(ffn_bytes)
        self.self_time_s += _pc() - t

    def predictor_counts(self, n_active: int, n_miss: int) -> None:
        """Per-step in-graph recall telemetry sums (predictor mode with
        ``predictor_telemetry=True`` only — the series never exists
        otherwise, and /metrics omits it rather than faking a zero)."""
        if not self.enabled:
            return
        t = _pc()
        self.c_pred_active.inc(n_active)
        self.c_pred_miss.inc(n_miss)
        self.self_time_s += _pc() - t

    # -- scheduler (request lifecycle) hooks ---------------------------------
    def req_submitted(self, uid: int, prompt_len: int, max_new: int,
                      priority: int = 0,
                      slo_ms: Optional[float] = None) -> None:
        if not self.enabled:
            return
        t = _pc()
        self.spans[uid] = RequestSpan(uid=uid, prompt_len=prompt_len,
                                      max_new=max_new, t_queued=t,
                                      priority=priority, slo_ms=slo_ms)
        self.c_submitted.inc()
        self._event("submit", uid=uid, prompt_len=prompt_len,
                    max_new=max_new, priority=priority, slo_ms=slo_ms)
        self.self_time_s += _pc() - t

    def req_admitted(self, uid: int, cached_tokens: int = 0) -> None:
        if not self.enabled:
            return
        t = _pc()
        self.c_admitted.inc()
        span = self.spans.get(uid)
        if span is not None:
            span.t_admitted = t
            span.cached_tokens = cached_tokens
            span.state = "prefilling"
            self.c_prefill.inc(span.prompt_len)
            if cached_tokens:
                self.c_prefill_cached.inc(cached_tokens)
            self.h_queue_wait.observe(span.queue_wait_s())
            self._event("admit", uid=uid, queue_wait_s=span.queue_wait_s(),
                        cached_tokens=cached_tokens)
        self.self_time_s += _pc() - t

    def req_preempted(self, uid: int, n_tokens: int,
                      priority: int = 0) -> None:
        """Slot evicted under pressure: its KV blocks returned to the pool,
        the request (prompt + ``n_tokens`` generated so far) requeued."""
        if not self.enabled:
            return
        t = _pc()
        self.c_preempted.inc(priority=str(priority))
        span = self.spans.get(uid)
        if span is not None:
            span.state = "preempted"
            span.preemptions += 1
        self._event("preempt", uid=uid, n_tokens=n_tokens,
                    priority=priority)
        self.self_time_s += _pc() - t

    def req_resumed(self, uid: int, cached_tokens: int = 0) -> None:
        """Preempted request re-admitted to a slot; its prefix resumes via
        chunked prefill (``cached_tokens`` of it straight from the trie)."""
        if not self.enabled:
            return
        t = _pc()
        self.c_resumed.inc()
        span = self.spans.get(uid)
        if span is not None:
            span.state = "prefilling"
            if cached_tokens:
                span.cached_tokens = cached_tokens
                self.c_prefill_cached.inc(cached_tokens)
        self._event("resume", uid=uid, cached_tokens=cached_tokens)
        self.self_time_s += _pc() - t

    def req_tokens(self, uid: int, n: int) -> None:
        """``n`` tokens just emitted to ``uid`` (seed / decode / accepted
        speculative window). The first call marks prefill complete."""
        if not self.enabled:
            return
        t = _pc()
        self.c_tokens.inc(n)
        span = self.spans.get(uid)
        if span is not None:
            if span.t_first is None:
                span.t_first = t
                span.state = "decoding"
                self.h_ttft.observe(span.ttft_s())
                self.h_class_ttft.observe(span.ttft_s(),
                                          priority=str(span.priority))
                self._event("first_token", uid=uid, ttft_s=span.ttft_s())
            elif span.state == "preempted":
                span.state = "decoding"
            span.t_last = t
            span.n_tokens += n
        self.self_time_s += _pc() - t

    def req_finished(self, result) -> None:
        """Terminal transition (retire_finished, or a queued-cancel's
        synthesized result). ``result`` is a scheduler RequestResult."""
        if not self.enabled:
            return
        t = _pc()
        reason = result.finish_reason
        self.c_finished.inc(reason=reason)
        if result.draft_proposed:
            self.c_draft_proposed.inc(result.draft_proposed)
            self.c_draft_accepted.inc(result.draft_accepted)
        span = self.spans.pop(result.uid, None)
        if span is not None:
            span.t_finished = t
            span.state = "finished"
            span.finish_reason = reason
            span.preemptions = getattr(result, "preemptions",
                                       span.preemptions)
            span.slo_met = getattr(result, "slo_met", None)
            self.h_e2e.observe(span.e2e_s())
            tpot = span.tpot_s()
            if tpot is not None:
                self.h_tpot.observe(tpot)
            self.finished_spans.append(span)
            self._event("finish", uid=result.uid, reason=reason,
                        n_tokens=span.n_tokens, ttft_s=span.ttft_s(),
                        tpot_s=tpot, e2e_s=span.e2e_s(),
                        priority=span.priority,
                        preemptions=span.preemptions, slo_met=span.slo_met)
        self.self_time_s += _pc() - t

    # -- API front-door hooks ------------------------------------------------
    def api_request_done(self, uid: int, ttft_s: Optional[float],
                         total_s: Optional[float], n_tokens: int) -> None:
        """Stamped by serving/api.py on each terminal TokenEvent: the
        client-visible latency, measured at the async boundary (includes
        loop scheduling — the engine-side span histograms do not)."""
        if not self.enabled:
            return
        t = _pc()
        if ttft_s is not None:
            self.h_api_ttft.observe(ttft_s)
        if total_s is not None:
            self.h_api_total.observe(total_s)
        self._event("api_finish", uid=uid, ttft_s=ttft_s, total_s=total_s,
                    n_tokens=n_tokens)
        self.self_time_s += _pc() - t

    # -- read side -----------------------------------------------------------
    def quantile(self, name: str, q: float, **labels) -> Optional[float]:
        m = self.registry.get(name)
        if m is None or m.kind != "histogram":
            return None
        return m.quantile(q, **labels)

    def snapshot(self) -> dict:
        return self.registry.snapshot()

    def render(self) -> str:
        return self.registry.render()

    def reset(self) -> None:
        """Clear every series and span (benchmark warm-up isolation — see
        Registry.reset; never used on a live server)."""
        self.registry.reset()
        self.spans.clear()
        self.finished_spans.clear()
        self.self_time_s = 0.0
        self.steps = 0


# ---------------------------------------------------------------------------
# /statusz rendering


def _ms(v: Optional[float]) -> str:
    return "-" if v is None else f"{v * 1e3:.2f}ms"


def format_statusz(engine) -> str:
    """Human-readable snapshot of a ContinuousBatchingEngine: config,
    occupancy, the scalar engine metrics (None-valued ones omitted — the
    satellite convention), latency percentiles from the span histograms,
    and live / recently finished requests. Pure read — safe to render
    between steps from the serve loop."""
    obs = engine.obs
    sched = engine.scheduler
    mode = ("spec" if engine.spec
            else "predictor" if engine.predictor is not None else "plain")
    lines = [
        f"repro serving engine — arch={engine.cfg.name} mode={mode} "
        f"steps={engine.t}",
        f"config: n_slots={sched.n_slots} block_size={sched.block_size} "
        f"max_blocks_per_seq={sched.max_blocks_per_seq} "
        f"prefill_chunk={engine.prefill_chunk} tp={engine.tp} "
        f"fast_kernels={engine.fast_kernels} "
        f"observability={'on' if obs.enabled else 'off'}",
        f"occupancy: {len(sched.active_indices())}/{sched.n_slots} slots "
        f"decoding, {len(sched.prefill_indices())} prefilling, "
        f"{len(sched.queue)} queued, pool "
        f"{sched.allocator.allocated}/{sched.allocator.n_blocks - 1} blocks, "
        f"{sched.preemption_count} preemptions",
    ]
    snap = engine.metrics_snapshot()
    lines.append("engine metrics: " + (", ".join(
        f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
        for k, v in sorted(snap.items())) or "(none)"))
    if obs.enabled:
        lines.append("latency (p50/p99): " + ", ".join(
            f"{label} {_ms(obs.quantile(name, 0.5))}/"
            f"{_ms(obs.quantile(name, 0.99))}"
            for label, name in (
                ("ttft", "repro_request_ttft_seconds"),
                ("tpot", "repro_request_tpot_seconds"),
                ("queue_wait", "repro_request_queue_wait_seconds"),
                ("step", "repro_engine_step_seconds"))))
        live = sorted(obs.spans.values(), key=lambda s: s.uid)
        lines.append(f"live requests ({len(live)}):")
        for s in live[:32]:
            lines.append(f"  uid={s.uid} {s.state} prio={s.priority} "
                         f"tokens={s.n_tokens}/{s.max_new} "
                         f"prompt={s.prompt_len} "
                         f"queue_wait={_ms(s.queue_wait_s())} "
                         f"ttft={_ms(s.ttft_s())}")
        lines.append(f"recently finished ({len(obs.finished_spans)}):")
        for s in list(obs.finished_spans)[-8:]:
            lines.append(f"  uid={s.uid} {s.finish_reason} "
                         f"tokens={s.n_tokens} ttft={_ms(s.ttft_s())} "
                         f"tpot={_ms(s.tpot_s())} e2e={_ms(s.e2e_s())}")
        lines.append(f"obs self-time: {obs.self_time_s * 1e3:.2f}ms over "
                     f"{obs.steps} steps")
    return "\n".join(lines) + "\n"
