"""AdamW with decoupled weight decay (paper's fine-tuning recipe: AdamW +
ZeRO-1 sharded optimizer states).

Pure-pytree implementation (no optax dependency). Moment tensors inherit the
parameter shardings, which in train mode are FSDP(+TP)-sharded — i.e. the
optimizer state is sharded across the mesh exactly as ZeRO prescribes; no
device holds a replicated copy of m/v for any sharded parameter.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig

PyTree = Any


class OptState(NamedTuple):
    step: jnp.ndarray  # () int32
    m: PyTree
    v: PyTree


def init_opt_state(params: PyTree) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                    v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> Tuple[PyTree, jnp.ndarray]:
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn


def adamw_update(
    grads: PyTree, state: OptState, params: PyTree, lr: jnp.ndarray,
    tc: TrainConfig,
) -> Tuple[PyTree, OptState]:
    step = state.step + 1
    b1, b2, eps, wd = tc.b1, tc.b2, tc.eps, tc.weight_decay
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * jnp.square(gf)
        update = (m2 / c1) / (jnp.sqrt(v2 / c2) + eps)
        # decoupled weight decay on matrices only (ndim >= 2), like the usual
        # no-decay-on-norms/bias convention.
        if p.ndim >= 2:
            update = update + wd * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), m2, v2

    flat = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, OptState(step=step, m=new_m, v=new_v)
