"""Gradient compression for the data-parallel reduction: int8 quantization
with error feedback (EF-SGD style). The wire format is int8 (4x fewer bytes
than f32 grads); the quantization error is carried in an error-feedback
buffer so convergence is preserved (tested in tests/test_compression.py).

Used by the shard_map DDP step (train/ddp.py) — with pjit+GSPMD the grad
psum is fused into the backward pass and cannot be intercepted, so the
compressed path is an explicit-collective variant.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_ef_state(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum_mean(grads: PyTree, ef: PyTree, axis_name: str):
    """All-reduce-mean of grads with int8 wire + error feedback.

    Inside shard_map over `axis_name`. Implementation: quantize (g + ef) to
    int8, all_gather the int8 payload (8-bit wire), sum + dequantize locally;
    the residual goes back into the EF buffer.
    """
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = quantize_int8(gf)
        new_e = gf - dequantize_int8(q, scale)
        qs = jax.lax.all_gather(q, axis_name)          # int8 on the wire
        ss = jax.lax.all_gather(scale, axis_name)
        n = qs.shape[0]
        total = jnp.sum(qs.astype(jnp.float32)
                        * ss.reshape((n,) + (1,) * g.ndim), axis=0)
        return (total / n).astype(g.dtype), new_e

    flat = jax.tree.map(one, grads, ef)
    g_out = jax.tree.map(lambda t: t[0], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    e_out = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    return g_out, e_out
