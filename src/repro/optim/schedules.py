"""LR schedules (pure fns of the int32 step)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import TrainConfig


def learning_rate(step: jnp.ndarray, tc: TrainConfig) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (s + 1.0) / max(1, tc.warmup_steps))
    if tc.schedule == "constant":
        post = 1.0
    elif tc.schedule == "linear":
        frac = jnp.clip((s - tc.warmup_steps)
                        / max(1, tc.total_steps - tc.warmup_steps), 0.0, 1.0)
        post = 1.0 - 0.9 * frac
    else:  # cosine
        frac = jnp.clip((s - tc.warmup_steps)
                        / max(1, tc.total_steps - tc.warmup_steps), 0.0, 1.0)
        post = 0.1 + 0.45 * (1.0 + jnp.cos(jnp.pi * frac))
    return tc.learning_rate * warm * post
