"""Activation-sparsity predictor subsystem (paper Sec. 5 headroom).

Predict which FFN neurons fire *before* reading their weights, so the
serving engine can gather only the predicted-active up- AND down-projection
tiles (serving/engine.py ``predictor=`` mode). See predictors.py for the
sign / low-rank predictors and calibration.py for the offline fitting
harness + serialization.
"""
from repro.predictor.calibration import (calibrate, calibrate_from_config,
                                         collect_ffn_inputs, load_predictor,
                                         save_predictor)
from repro.predictor.predictors import (LayerReport, Predictor,
                                        pack_tile_indices, sign_predictor)

__all__ = [
    "LayerReport",
    "Predictor",
    "calibrate",
    "calibrate_from_config",
    "collect_ffn_inputs",
    "load_predictor",
    "pack_tile_indices",
    "save_predictor",
    "sign_predictor",
]
