"""Offline predictor calibration: fit thresholds (and low-rank factors) on a
calibration batch to hit a target recall, with per-layer precision / recall /
density reports and checkpoint-manager serialization.

The harness runs the model ONCE over the calibration batch with raw
activation capture (models.common.StatsCollector(raw=True) stores each
layer's FFN input), then fits everything offline in numpy:

* true activity: a unit fires iff its gate pre-activation exceeds the
  activation's firing threshold (core.activations.firing_threshold);
* sign predictor: probe = X @ W_lp at the chosen probe dtype; only the
  threshold tau is fitted;
* lowrank predictor: reduced-rank regression of the pre-activations on the
  inputs. With Z = X @ W the rank-r minimizer of ||X A B - Z||_F is the
  truncated SVD of Z: B = V_r^T, A = W V_r — data-weighted (directions that
  matter on real activations are kept), computed per layer from the
  calibration batch;
* tau per layer: the highest threshold keeping calibration recall >= the
  target (highest = most tiles skipped). target_recall >= 1 additionally
  clamps the sign predictor's tau to the firing threshold, making
  recall 1.0 *structural* when the probe is full-precision — the exactness
  anchor the serving tests pin.

Serialization: CheckpointManager (checkpoint/manager.py) — params as the
array payload, everything else (kind, tau already in params, reports,
knobs) in the JSON extras, so a fitted predictor round-trips through the
same atomic-write / keep-k machinery as model checkpoints.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models import registry
from repro.predictor.predictors import (LayerReport, Predictor, ffn_tile,
                                        firing_threshold, gate_weight_key,
                                        probe)


def collect_ffn_inputs(params, batch: Dict, cfg: ModelConfig) -> np.ndarray:
    """One instrumented forward over the calibration batch; returns the
    per-layer FFN inputs, stacked (L, N, d) f32 (N = batch * seq tokens)."""
    stats = cm.StatsCollector(True, raw=True)
    fam = registry.get_family(cfg)
    fam.model_forward(params, batch, cfg, stats=stats)
    xs = []
    for i in range(cfg.n_layers):
        key = f"layer{i}/ffn_x"
        if key not in stats.stats:
            raise ValueError(f"no FFN capture for layer {i} — family "
                             f"{cfg.family!r} lacks predictor support")
        xs.append(np.asarray(stats.stats[key], np.float32))
    return np.stack(xs)


def _fit_tau(probe_act: np.ndarray, target_recall: float) -> float:
    """Highest tau with calibration recall >= target: allow
    floor((1-target)*n) misses, set tau just below the first kept probe."""
    n = probe_act.size
    if n == 0:
        return 0.0
    allowed = int(np.floor((1.0 - min(target_recall, 1.0)) * n))
    srt = np.sort(probe_act)  # ascending
    anchor = srt[min(allowed, n - 1)]
    eps = 1e-6 * max(1.0, abs(float(anchor)))
    return float(anchor) - eps


def _layer_report(layer: int, tau: float, probe: np.ndarray,
                  active: np.ndarray, tile: int) -> LayerReport:
    pred = probe > tau
    n_act = max(1, int(active.sum()))
    n_pred = max(1, int(pred.sum()))
    N, F = pred.shape
    pred_tiles = pred.reshape(N, F // tile, tile).any(-1)
    covered = np.repeat(pred_tiles, tile, axis=-1)
    return LayerReport(
        layer=layer,
        tau=float(tau),
        recall=float((pred & active).sum() / n_act),
        tile_recall=float((covered & active).sum() / n_act),
        precision=float((pred & active).sum() / n_pred),
        unit_density=float(pred.mean()),
        tile_density=float(pred_tiles.mean()),
    )


def calibrate(params, cfg: ModelConfig, batch: Dict, *,
              kind: str = "sign", target_recall: float = 0.99,
              rank: int = 8, probe_dtype: str = "bfloat16",
              tile: Optional[int] = None,
              k_tiles: Optional[int] = None) -> Predictor:
    """Fit a predictor of the given kind on one calibration batch.

    Returns a Predictor whose per-layer reports record the calibration
    recall / precision / density at the fitted thresholds. tile defaults to
    the config's gather granularity (128 on TPU-shaped configs; tiny CPU
    models can pass 1 for exact row-skipping). k_tiles (static serving
    gather capacity) defaults to the full tile count — density savings come
    from nvalid, never from silent truncation.
    """
    thr = firing_threshold(cfg)
    tile = ffn_tile(cfg) if tile is None else tile
    if cfg.d_ff % tile:
        raise ValueError(f"d_ff={cfg.d_ff} is not a multiple of tile={tile}")
    X = collect_ffn_inputs(params, batch, cfg)  # (L, N, d)
    W = np.asarray(params["layers"]["ffn"][gate_weight_key(cfg)], np.float32)
    L = cfg.n_layers
    n_tiles = cfg.d_ff // tile

    taus, reports = [], []
    a_l, b_l, w_lp = [], [], []
    for layer in range(L):
        x, w = X[layer], W[layer]
        pre = x @ w  # (N, F) true gate pre-activation (f32 reference)
        active = pre > thr
        # probes go through predictors.probe — the SAME jnp computation
        # (including its output rounding at low probe dtypes) the serving
        # decode step runs, so the fitted tau binds serving-time values
        if kind == "sign":
            lp = jnp.asarray(w).astype(jnp.dtype(probe_dtype))
            w_lp.append(lp)
            pr = np.asarray(probe("sign", {"w": lp}, jnp.asarray(x)))
        elif kind == "lowrank":
            # reduced-rank regression: truncated SVD of the calibration
            # pre-activations gives the data-weighted rank-r factorization
            _, _, vt = np.linalg.svd(pre, full_matrices=False)
            v_r = vt[: min(rank, vt.shape[0])].T  # (F, r)
            a = jnp.asarray(w @ v_r, jnp.float32)  # (d, r)
            b = jnp.asarray(v_r.T, jnp.float32)  # (r, F)
            a_l.append(a)
            b_l.append(b)
            pr = np.asarray(probe("lowrank", {"a": a, "b": b},
                                  jnp.asarray(x)))
        else:
            raise ValueError(f"unknown predictor kind {kind!r}")
        tau = _fit_tau(pr[active], target_recall)
        if kind == "sign" and target_recall >= 1.0:
            # structural recall: a full-precision probe IS the
            # pre-activation, and every firing unit exceeds thr
            tau = min(tau, thr)
        taus.append(tau)
        reports.append(_layer_report(layer, tau, pr, active, tile))

    tau_arr = jnp.asarray(np.asarray(taus, np.float32))
    if kind == "sign":
        p = {"w": jnp.stack(w_lp), "tau": tau_arr}
    else:
        p = {"a": jnp.stack(a_l), "b": jnp.stack(b_l), "tau": tau_arr}
    return Predictor(
        kind=kind, params=p, n_tiles=n_tiles,
        k_tiles=n_tiles if k_tiles is None else min(k_tiles, n_tiles),
        tile=tile, target_recall=target_recall, probe_dtype=probe_dtype,
        reports=reports)


def calibrate_from_config(params, cfg: ModelConfig, batch: Dict,
                          **overrides) -> Predictor:
    """Calibrate using the SparsityConfig predictor knobs: kind =
    cfg.sparsity.predictor, target recall, rank, and probe dtype all come
    from the config (a deployment is a config — configs/base.py), with
    keyword overrides for experiments."""
    if cfg.sparsity.predictor == "none":
        raise ValueError("cfg.sparsity.predictor is 'none' — set it to "
                         "'sign' or 'lowrank' (or call calibrate directly)")
    kw = dict(kind=cfg.sparsity.predictor,
              target_recall=cfg.sparsity.predictor_recall,
              rank=cfg.sparsity.predictor_rank,
              probe_dtype=cfg.sparsity.probe_dtype)
    kw.update(overrides)
    return calibrate(params, cfg, batch, **kw)


# ---------------------------------------------------------------------------
# serialization (checkpoint/manager.py format)


def save_predictor(pred: Predictor, directory: str, step: int = 0) -> None:
    """Atomic-write the predictor under `directory` (numpy has no bf16, so
    array payloads are stored f32 and re-cast to probe_dtype on load)."""
    mgr = CheckpointManager(directory, keep=2, async_save=False)
    tree = {k: jnp.asarray(v, jnp.float32) for k, v in pred.params.items()}
    extras = {
        "kind": pred.kind,
        "n_tiles": pred.n_tiles,
        "k_tiles": pred.k_tiles,
        "tile": pred.tile,
        "target_recall": pred.target_recall,
        "probe_dtype": pred.probe_dtype,
        "reports": [dataclasses.asdict(r) for r in pred.reports],
    }
    mgr.save(step, tree, extras=extras, block=True)


def load_predictor(directory: str, step: Optional[int] = None) -> Predictor:
    mgr = CheckpointManager(directory, async_save=False)
    step = mgr.latest_step() if step is None else step
    if step is None:
        raise FileNotFoundError(f"no predictor checkpoints in {directory}")
    with open(os.path.join(directory, f"step_{step:010d}",
                           "manifest.json")) as f:
        extras = json.load(f)["extras"]
    template = ({"w": 0, "tau": 0} if extras["kind"] == "sign"
                else {"a": 0, "b": 0, "tau": 0})
    tree, extras = mgr.restore(template, step=step)
    # probe_dtype governs only the sign probe's weight; low-rank factors and
    # thresholds are f32
    pd = jnp.dtype(extras["probe_dtype"])
    params = {k: (v.astype(pd) if k == "w" else v.astype(jnp.float32))
              for k, v in tree.items()}
    return Predictor(
        kind=extras["kind"], params=params, n_tiles=extras["n_tiles"],
        k_tiles=extras["k_tiles"], tile=extras["tile"],
        target_recall=extras["target_recall"],
        probe_dtype=extras["probe_dtype"],
        reports=[LayerReport(**r) for r in extras["reports"]])
