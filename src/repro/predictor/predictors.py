"""Activation-sparsity predictors: know which FFN neurons fire BEFORE
paying for their weights (paper Sec. 5 headroom; SparseInfer 2411.12692,
ReLU^2-Wins 2402.03804).

Two predictor families, one contract. Given the FFN input x (the post-norm
block activation), a predictor produces a per-token *probe* approximating
the gate pre-activation ``x @ W_gate``; units whose probe exceeds the
layer's calibrated threshold are predicted to fire. Unit predictions are
rounded up to 128-lane tiles — the granularity the tile-gathered kernels
(kernels/sparse_matmul.py) read weights at — so a predicted mask is
directly a weight-I/O plan for BOTH the up- and down-projections.

* ``sign`` — training-free (SparseInfer-style): the probe is the sign-
  faithful low-precision product ``x @ W_lp`` where W_lp is the model's own
  gate weight cast to ``probe_dtype``. At probe_dtype == compute dtype the
  probe IS the pre-activation, so threshold = the activation's firing
  threshold gives recall 1.0 by construction (the exactness anchor).
* ``lowrank`` — learned: rank-r factors (A, B) distilled per layer from
  calibration activations (reduced-rank regression via SVD of the
  calibration pre-activations, predictor/calibration.py), probe =
  ``(x @ A) @ B`` — O(d*r + r*F) instead of O(d*F) probe flops.

Thresholds live per layer (``tau`` (L,)); calibration picks them to hit a
target recall. Everything is stacked on a leading layer axis so the
serving decode step scans over layers with no per-layer retracing.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import activations as acts

PyTree = Any

TILE = 128  # default lane-width tile (the TPU DMA granularity)


def ffn_tile(cfg: ModelConfig) -> int:
    """The weight-gather tile width (models.common.ffn_gather_tile — the
    single source of truth shared with the serving decode steps). tile=1
    degenerates to the paper's exact row-skipping — useful on CPU-sized
    models where 128-wide tiles are never all-zero."""
    from repro.models.common import ffn_gather_tile
    return ffn_gather_tile(cfg)


@dataclasses.dataclass
class LayerReport:
    """Per-layer calibration metrics (predictor quality at the fitted tau)."""

    layer: int
    tau: float
    recall: float          # active units whose probe cleared tau
    tile_recall: float     # active units whose TILE was predicted (>= recall)
    precision: float       # predicted units that were truly active
    unit_density: float    # fraction of units predicted active
    tile_density: float    # fraction of 128-tiles predicted active (the I/O)


@dataclasses.dataclass
class Predictor:
    """A fitted predictor: stacked per-layer params + static serving knobs.

    params (leading axis = layer):
      sign:    {"w": (L, d, F) probe_dtype, "tau": (L,) f32}
      lowrank: {"a": (L, d, r), "b": (L, r, F), "tau": (L,) f32}

    ``k_tiles`` is the STATIC gather capacity per token: predicted tile
    lists are padded/truncated to exactly k_tiles indices so the jitted
    decode step never retraces (truncation is a recorded recall event).
    """

    kind: str  # "sign" | "lowrank"
    params: Dict[str, jnp.ndarray]
    n_tiles: int
    k_tiles: int
    tile: int = TILE
    target_recall: float = 1.0
    probe_dtype: str = "float32"
    reports: List[LayerReport] = dataclasses.field(default_factory=list)

    def layer_tau(self, layer: int) -> float:
        return float(self.params["tau"][layer])

    def mean_report(self) -> Dict[str, float]:
        if not self.reports:
            return {}
        keys = ("recall", "tile_recall", "precision", "unit_density",
                "tile_density")
        n = len(self.reports)
        return {k: sum(getattr(r, k) for r in self.reports) / n for k in keys}

    def describe(self) -> str:
        m = self.mean_report()
        extra = ("" if not m else
                 f" recall={m['recall']:.3f} tile_density="
                 f"{m['tile_density']:.3f}")
        return (f"{self.kind}-predictor(k_tiles={self.k_tiles}/"
                f"{self.n_tiles}, target_recall={self.target_recall})"
                + extra)


# ---------------------------------------------------------------------------
# in-graph probe + mask machinery (called from the jitted decode step)


def probe(kind: str, pred_l: PyTree, x: jnp.ndarray) -> jnp.ndarray:
    """Per-layer probe. x: (T, d) -> (T, F) f32 approximate pre-activation.

    kind is STATIC (bakes into the trace); pred_l is this layer's slice of
    the stacked predictor params.
    """
    if kind == "sign":
        w = pred_l["w"]
        return (x.astype(w.dtype) @ w).astype(jnp.float32)
    if kind == "lowrank":
        a, b = pred_l["a"], pred_l["b"]
        return ((x.astype(a.dtype) @ a) @ b).astype(jnp.float32)
    raise ValueError(f"unknown predictor kind {kind!r}")


def predict_units(kind: str, pred_l: PyTree, x: jnp.ndarray) -> jnp.ndarray:
    """(T, d) -> (T, F) bool predicted-active units (probe > layer tau)."""
    return probe(kind, pred_l, x) > pred_l["tau"].astype(jnp.float32)


def units_to_tiles(units: jnp.ndarray, tile: int = TILE) -> jnp.ndarray:
    """(T, F) unit mask -> (T, F // tile) tile mask (any unit in the tile)."""
    T, F = units.shape
    return jnp.any(units.reshape(T, F // tile, tile), axis=-1)


def tiles_to_units(tiles: jnp.ndarray, tile: int = TILE) -> jnp.ndarray:
    """(T, nT) tile mask -> (T, nT * tile) unit-resolution coverage mask."""
    return jnp.repeat(tiles, tile, axis=-1)


def pack_tile_indices(tile_mask: jnp.ndarray, k: int, n_groups: int = 1):
    """Fixed-capacity packing: (T, nT) bool -> (idx (T, k') int32,
    nvalid (T,) int32) with k' = k (n_groups rounds it up to a multiple).

    Active tiles come first (ascending tile id); padding repeats each row's
    first entry so every index stays in [0, nT) and padded DMAs revisit an
    already-fetched block. If a row has more than k active tiles the excess
    is dropped — a *recorded* recall event, never an out-of-range index.

    ``n_groups > 1`` makes the packing MODEL-AXIS-LOCAL for a TP-sharded
    FFN: the tile axis is cut into n_groups contiguous shard slices and
    each group selects (and truncates) its own ceil(k / n_groups) capacity
    from its local slice — so every shard's gather touches only tiles it
    owns, and truncation is load-balanced across shards instead of biased
    toward low tile ids. Because groups are contiguous ascending ranges,
    the valid-first flattened index list is still globally ascending: at
    full capacity (k == nT) the packed set — and the f32 accumulation
    order of the gathered matmuls — is identical to n_groups == 1, which
    is what keeps sharded-engine streams byte-identical to single-device.
    """
    T, nT = tile_mask.shape
    k = min(k, nT)
    if n_groups <= 1:
        # top_k on {0,1} scores is stable: equal scores keep ascending index
        # order, so actives (1.0) land first, each group id-ordered.
        _, idx = jax.lax.top_k(tile_mask.astype(jnp.float32), k)
        nvalid = jnp.minimum(jnp.sum(tile_mask.astype(jnp.int32), axis=-1),
                             k).astype(jnp.int32)
        pad = idx[:, :1]  # row's first selected tile (always in range)
        idx = jnp.where(jnp.arange(k)[None, :] < nvalid[:, None], idx, pad)
        return idx.astype(jnp.int32), nvalid
    if nT % n_groups:
        raise ValueError(f"n_tiles={nT} not divisible by "
                         f"n_groups={n_groups} shards")
    gsz = nT // n_groups
    k_g = min(gsz, -(-k // n_groups))
    mg = tile_mask.reshape(T, n_groups, gsz).astype(jnp.float32)
    _, idx_l = jax.lax.top_k(mg, k_g)  # (T, G, k_g) group-local, stable
    idx = idx_l + (jnp.arange(n_groups) * gsz)[None, :, None]  # global ids
    ng = jnp.minimum(jnp.sum(mg.astype(jnp.int32), axis=-1), k_g)  # (T, G)
    valid = jnp.arange(k_g)[None, None, :] < ng[:, :, None]
    # compact valid-first across groups (kernels expect actives, then pads);
    # stable top_k keeps group-major = globally ascending order
    kt = n_groups * k_g
    _, order = jax.lax.top_k(valid.reshape(T, kt).astype(jnp.float32), kt)
    idx = jnp.take_along_axis(idx.reshape(T, kt), order, axis=-1)
    nvalid = jnp.sum(ng, axis=-1).astype(jnp.int32)
    pad = idx[:, :1]
    idx = jnp.where(jnp.arange(kt)[None, :] < nvalid[:, None], idx, pad)
    return idx.astype(jnp.int32), nvalid


def covered_tiles(idx: jnp.ndarray, nvalid: jnp.ndarray,
                  n_tiles: int) -> jnp.ndarray:
    """Invert packing: which tiles will actually be gathered. (T, k), (T,)
    -> (T, n_tiles) bool. Differs from the input mask only when packing
    truncated (more actives than k)."""
    T, k = idx.shape
    valid = jnp.arange(k)[None, :] < nvalid[:, None]
    out = jnp.zeros((T, n_tiles), bool)
    return out.at[jnp.arange(T)[:, None], idx].max(valid)


# ---------------------------------------------------------------------------
# training-free construction


def gate_weight_key(cfg: ModelConfig) -> str:
    """The FFN weight whose pre-activation the activation gates: the gate
    projection for GLU FFNs, the single up projection otherwise."""
    return "wg" if cfg.ffn_kind == "glu" else "wu"


def firing_threshold(cfg: ModelConfig) -> float:
    thr = acts.firing_threshold(cfg.activation, cfg.sparsity.shift)
    if thr is None:
        raise ValueError(
            f"activation {cfg.activation!r} has no exact firing threshold; "
            "the predictor subsystem needs a ReLU-family activation "
            "(relu / shifted_relu / fatrelu)")
    return thr


def sign_predictor(params, cfg: ModelConfig, *,
                   probe_dtype: str = "bfloat16",
                   tau: Optional[float] = None,
                   tile: Optional[int] = None,
                   k_tiles: Optional[int] = None) -> Predictor:
    """Training-free sign predictor straight from the model weights — no
    calibration pass. tau defaults to the activation's firing threshold
    (exact at probe_dtype == compute dtype; calibrate for margin at lower
    probe precision)."""
    thr = firing_threshold(cfg)
    w = params["layers"]["ffn"][gate_weight_key(cfg)]
    L = w.shape[0]
    tile = ffn_tile(cfg) if tile is None else tile
    if cfg.d_ff % tile:
        raise ValueError(f"d_ff={cfg.d_ff} is not a multiple of tile={tile}")
    n_tiles = cfg.d_ff // tile
    tau = thr if tau is None else float(tau)
    return Predictor(
        kind="sign",
        params={"w": w.astype(jnp.dtype(probe_dtype)),
                "tau": jnp.full((L,), tau, jnp.float32)},
        n_tiles=n_tiles,
        k_tiles=n_tiles if k_tiles is None else min(k_tiles, n_tiles),
        tile=tile,
        target_recall=1.0,
        probe_dtype=probe_dtype,
    )
