"""Serving stack: continuous-batching engine over a paged KV cache (with a
first-class speculative-decoding mode), the async streaming API layer with
per-request sampling, the legacy single-batch engine, scheduler,
speculative-decoding metrics, and the observability hub (repro.obs)."""
from repro.obs import EngineObs, format_statusz  # noqa: F401
from repro.serving.api import AsyncServingEngine, TokenEvent  # noqa: F401
from repro.serving.config import EngineConfig  # noqa: F401
from repro.serving.engine import (  # noqa: F401
    ContinuousBatchingEngine, GenerationResult, ServeEngine,
)
from repro.serving.sampling import SamplingParams  # noqa: F401
from repro.serving.scheduler import (  # noqa: F401
    BlockAllocator, PrefixCache, Request, RequestQueue, RequestResult,
    Scheduler,
)
from repro.serving.spec_decode import SpecResult, spec_metrics  # noqa: F401
