"""Serving stack: continuous-batching engine over a paged KV cache, the
legacy single-batch engine, scheduler, and speculative decoding."""
from repro.serving.engine import (  # noqa: F401
    ContinuousBatchingEngine, GenerationResult, ServeEngine,
)
from repro.serving.scheduler import (  # noqa: F401
    BlockAllocator, Request, RequestQueue, RequestResult, Scheduler,
)
