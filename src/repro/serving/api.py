"""Async streaming serving layer over ``ContinuousBatchingEngine``.

``AsyncServingEngine`` turns the engine's step-wise API (submit / step /
cancel) into an online server: an asyncio background task drives
``engine.step()`` in a worker thread (the event loop keeps ingesting
requests and feeding client streams while a jitted step runs on device),
and every request gets its own ``stream()`` async generator yielding
``TokenEvent``s the moment the step that produced them completes. Works
with all three serving modes — plain/γ-reuse, speculative (which can emit
several tokens per event batch), predictor — and with per-request
``SamplingParams`` (serving/sampling.py).

Concurrency contract: the engine and its scheduler are NOT thread-safe
and are touched only from the serve-loop task, between steps — client
submits and cancels are buffered and applied there. The only work shipped
off the loop thread is the blocking ``engine.step()`` call itself.

The HTTP/SSE front door over this class lives in launch/serve_api.py;
in-process callers (tests, benchmarks) use it directly:

    async with AsyncServingEngine(engine) as api:
        async for ev in api.stream(prompt, max_new=32,
                                   sampling=SamplingParams(temperature=0.8,
                                                           seed=7)):
            ...

Greedy streams are byte-identical to the offline ``engine.run()`` results
for the same prompts — the API changes WHEN tokens surface, never which
tokens (tests/test_api_server.py pins this in all three modes).
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from collections import deque
from typing import AsyncIterator, Dict, Optional

from repro.serving.engine import ContinuousBatchingEngine
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import RequestResult


@dataclasses.dataclass
class TokenEvent:
    """One streamed token — or, with ``finished=True``, the request's
    terminal event carrying the full ``RequestResult`` plus serving
    latency (ttft_s: submit → first token; total_s: submit → finish)."""
    uid: int
    index: int  # generated-token index (0 = the prompt-seeded token)
    token: int = -1
    logprob: float = 0.0
    finished: bool = False
    finish_reason: Optional[str] = None
    result: Optional[RequestResult] = None
    ttft_s: Optional[float] = None
    total_s: Optional[float] = None


@dataclasses.dataclass
class _Session:
    queue: asyncio.Queue
    t_submit: float
    t_first: Optional[float] = None
    n_sent: int = 0  # tokens already published to the queue
    closed: bool = False  # terminal event published


class AsyncServingEngine:
    """Asyncio front for a ``ContinuousBatchingEngine`` — see the module
    docstring. ``start()``/``aclose()`` bracket the serve loop; the async
    context manager form is preferred."""

    def __init__(self, engine: ContinuousBatchingEngine):
        self.engine = engine
        # pending: (prompt, max_new, rw, sp, priority, slo_ms, future)
        self._pending: deque = deque()
        self._cancels: deque = deque()  # uids to cancel
        self._sessions: Dict[int, _Session] = {}
        self._wake: Optional[asyncio.Event] = None
        self._task: Optional[asyncio.Task] = None
        self._running = False

    # -- lifecycle -----------------------------------------------------------
    async def start(self) -> None:
        if self._task is not None:
            raise RuntimeError("serve loop already started")
        self._wake = asyncio.Event()
        self._running = True
        self._task = asyncio.get_running_loop().create_task(
            self._serve_loop(), name="repro-serve-loop")

    async def aclose(self) -> None:
        self._running = False
        if self._wake is not None:
            self._wake.set()
        if self._task is not None:
            await self._task
            self._task = None

    async def __aenter__(self) -> "AsyncServingEngine":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    # -- client API ----------------------------------------------------------
    async def submit(self, prompt, max_new: int, *,
                     sampling: Optional[SamplingParams] = None,
                     reuse_window: int = 0, priority: int = 0,
                     slo_ms: Optional[float] = None) -> int:
        """Enqueue a request; resolves to its uid once the serve loop has
        accepted it (malformed requests raise here, exactly like
        ``engine.submit``). ``priority``/``slo_ms`` pass straight through
        to the SLO scheduler (engine.submit). Pair with ``events(uid)`` —
        or use ``stream``, which fuses both."""
        if self._task is None:
            raise RuntimeError("serve loop not started")
        fut = asyncio.get_running_loop().create_future()
        self._pending.append((prompt, max_new, reuse_window, sampling,
                              priority, slo_ms, fut))
        self._wake.set()
        return await fut

    def cancel(self, uid: int) -> None:
        """Abandon a request (idempotent; safe for finished uids). Its
        stream terminates with finish_reason "cancelled"."""
        self._cancels.append(uid)
        if self._wake is not None:
            self._wake.set()

    async def events(self, uid: int) -> AsyncIterator[TokenEvent]:
        """Yield ``uid``'s TokenEvents as the engine produces them; the
        ``finished`` event is always last. Closing the iterator mid-stream
        cancels the request (the mid-stream-disconnect path)."""
        sess = self._sessions[uid]
        try:
            while True:
                ev = await sess.queue.get()
                if isinstance(ev, BaseException):
                    raise ev
                yield ev
                if ev.finished:
                    return
        finally:
            if not sess.closed:
                self.cancel(uid)

    async def stream(self, prompt, max_new: int, *,
                     sampling: Optional[SamplingParams] = None,
                     reuse_window: int = 0, priority: int = 0,
                     slo_ms: Optional[float] = None
                     ) -> AsyncIterator[TokenEvent]:
        """submit + events in one async generator — one call per client
        session."""
        uid = await self.submit(prompt, max_new, sampling=sampling,
                                reuse_window=reuse_window,
                                priority=priority, slo_ms=slo_ms)
        async for ev in self.events(uid):
            yield ev

    async def generate(self, prompt, max_new: int, *,
                       sampling: Optional[SamplingParams] = None,
                       reuse_window: int = 0, priority: int = 0,
                       slo_ms: Optional[float] = None) -> TokenEvent:
        """Non-streaming convenience: the terminal event (with .result)."""
        ev = None
        async for ev in self.stream(prompt, max_new, sampling=sampling,
                                    reuse_window=reuse_window,
                                    priority=priority, slo_ms=slo_ms):
            pass
        return ev

    # -- serve loop ----------------------------------------------------------
    async def _serve_loop(self) -> None:
        loop = asyncio.get_running_loop()
        try:
            while True:
                self._apply_control()
                if not self._running and not self._sessions:
                    return
                if not self.engine.scheduler.has_work():
                    if not self._running:
                        return
                    # fully idle: sleep until a submit/cancel/close arrives
                    await self._wake.wait()
                    self._wake.clear()
                    continue
                progressed = await loop.run_in_executor(None,
                                                        self.engine.step)
                self._publish()
                if not progressed and self.engine.scheduler.has_work():
                    # queue head can never be admitted (engine.drain would
                    # raise here) — fail those streams instead of spinning
                    self._fail_queued()
        except BaseException as e:  # surface loop crashes to every client
            for sess in self._sessions.values():
                if not sess.closed:
                    sess.closed = True
                    sess.queue.put_nowait(e)
            raise

    def _apply_control(self) -> None:
        """Apply buffered submits/cancels on the loop thread, between
        engine steps (the engine is not thread-safe)."""
        while self._pending:
            (prompt, max_new, rw, sp, priority, slo_ms,
             fut) = self._pending.popleft()
            try:
                uid = self.engine.submit(prompt, max_new, reuse_window=rw,
                                         sampling=sp, priority=priority,
                                         slo_ms=slo_ms)
            except Exception as e:
                if not fut.cancelled():
                    fut.set_exception(e)
                continue
            self._sessions[uid] = _Session(queue=asyncio.Queue(),
                                           t_submit=time.monotonic())
            if not fut.cancelled():
                fut.set_result(uid)
        while self._cancels:
            self.engine.cancel(self._cancels.popleft())
        # a cancel of a queued request synthesizes its result immediately
        self._publish(slots=False)

    def _publish(self, slots: bool = True) -> None:
        """Flush newly produced tokens (and terminal results) to the
        per-request queues. Runs after every step: in-flight slots first
        (so clients see tokens the step they are made, not at retirement),
        then retirement + terminal events."""
        now = time.monotonic()
        if slots:
            for slot in self.engine.scheduler.slots:
                if slot is not None:
                    self._emit(slot.request.uid, slot.out, slot.lps, now)
        self.engine.scheduler.retire_finished(self.engine.t)
        for uid, res in list(self.engine.scheduler.results.items()):
            sess = self._sessions.get(uid)
            if sess is None or sess.closed:
                continue
            self._emit(uid, res.tokens, res.logprobs, now)
            sess.closed = True
            ttft_s = (sess.t_first - sess.t_submit
                      if sess.t_first is not None else None)
            total_s = now - sess.t_submit
            # API-boundary latency span (includes loop scheduling, unlike
            # the engine-side scheduler spans) — feeds repro_api_* series
            self.engine.obs.api_request_done(uid, ttft_s, total_s,
                                             len(res.tokens))
            sess.queue.put_nowait(TokenEvent(
                uid=uid, index=sess.n_sent, finished=True,
                finish_reason=res.finish_reason, result=res,
                ttft_s=ttft_s, total_s=total_s))

    def _emit(self, uid: int, tokens, lps, now: float) -> None:
        sess = self._sessions.get(uid)
        if sess is None or sess.closed:
            return
        while sess.n_sent < len(tokens):
            i = sess.n_sent
            if sess.t_first is None:
                sess.t_first = now
            sess.queue.put_nowait(TokenEvent(uid=uid, index=i,
                                             token=int(tokens[i]),
                                             logprob=float(lps[i])))
            sess.n_sent += 1

    def _fail_queued(self) -> None:
        alloc = self.engine.scheduler.allocator
        err = RuntimeError(
            f"serving deadlock: queued requests "
            f"{self.engine.scheduler.queue.uids()} can never be admitted "
            f"({alloc.available}/{alloc.n_blocks - 1} pool blocks free, "
            f"every slot idle)")
        for uid in list(self.engine.scheduler.queue.uids()):
            self.engine.cancel(uid)
            sess = self._sessions.get(uid)
            if sess is not None and not sess.closed:
                sess.closed = True
                sess.queue.put_nowait(err)
