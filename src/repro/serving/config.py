"""EngineConfig: the validated configuration surface for the
continuous-batching engine.

``ContinuousBatchingEngine(cfg, params, config=EngineConfig(...))`` is the
constructor surface; the historical kwarg sprawl
(``ContinuousBatchingEngine(cfg, params, n_slots=4, prefill_chunk=8, ...)``)
still works through a deprecation shim that warns once per process and
round-trips exactly onto an ``EngineConfig`` (same fields, same defaults,
same validation) — see ``EngineConfig.from_legacy_kwargs``.

``validate()`` owns every rule that is decidable from the config alone:
geometry/pool sizing, the chunked-prefill prerequisites of prefix caching
and warm masks, speculative/predictor mutual exclusion, and the scheduling
knobs (aging, preemption, prefill budget). Rules that need the model config
or runtime environment (family capabilities, d_ff coverage, vocab match,
mesh axes, backend autodetect) stay in the engine, which calls
``validate()`` first.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional

_LEGACY_KWARGS_WARNED = False


@dataclasses.dataclass
class EngineConfig:
    """Everything that shapes a ``ContinuousBatchingEngine`` besides the
    model config and params. Field semantics are documented on the engine
    class (they are the former constructor kwargs, names unchanged).

    Scheduling fields (SLO-aware scheduler):

    aging_steps: engine steps a queued request waits before its EFFECTIVE
        priority rises by one class. Aging bounds both starvation (a
        low-priority request eventually outranks the traffic passing it)
        and the admission skip-ahead (a stuck queue head that has waited
        ``aging_steps`` becomes a hard barrier — nothing may be admitted
        around it until it fits). 0 disables aging AND the barrier:
        admission is pure priority-then-FIFO with unbounded skip-ahead.
    preemption: allow admission to preempt a running slot whose RAW
        priority is strictly below the candidate's when no free slot /
        blocks remain. The preempted request keeps its generated prefix:
        its blocks are parked in the prefix trie (when enabled) and it
        re-enters the queue, resuming later via chunked prefill of the
        cold suffix — f32 greedy streams are byte-identical across a
        preempt/resume cycle (tests/test_slo_scheduler.py). With every
        request at the same priority (the default), preemption never
        triggers.
    prefill_budget: cap on the TOTAL prompt tokens prefilled per engine
        step across all prefilling slots (chunked mode only) — trades
        admission latency (TTFT) against decode TPOT for already-running
        requests. 0 = unlimited (every prefilling slot advances one full
        chunk per step).
    """

    n_slots: int = 4
    block_size: int = 16
    max_blocks_per_seq: int = 8
    n_blocks: Optional[int] = None
    track_sparsity: bool = False
    draft_cfg: Any = None
    draft_params: Any = None
    gamma: int = 4
    predictor: Any = None
    predictor_telemetry: bool = True
    prefill_chunk: int = 0
    prefix_cache: bool = False
    warm_masks: bool = False
    mesh: Any = None
    base_seed: int = 0
    fast_kernels: Optional[bool] = None
    obs: Any = None
    # -- SLO-aware scheduling (PR 10) --
    prefill_budget: int = 0
    preemption: bool = True
    aging_steps: int = 32

    @property
    def resolved_n_blocks(self) -> int:
        """Pool size with the full-residency default applied."""
        if self.n_blocks is None:
            return 1 + self.n_slots * self.max_blocks_per_seq
        return self.n_blocks

    def validate(self) -> "EngineConfig":
        """Raise ValueError on any self-contained rule violation; returns
        self so ``EngineConfig(...).validate()`` chains."""
        if self.n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        if self.block_size < 1:
            raise ValueError("block_size must be >= 1")
        if self.max_blocks_per_seq < 1:
            raise ValueError("max_blocks_per_seq must be >= 1")
        if self.resolved_n_blocks - 1 < self.max_blocks_per_seq:
            raise ValueError("pool smaller than one request's worst case")
        if self.prefill_chunk < 0:
            raise ValueError("prefill_chunk must be >= 0")
        if self.prefill_budget < 0:
            raise ValueError("prefill_budget must be >= 0")
        if self.aging_steps < 0:
            raise ValueError("aging_steps must be >= 0")
        if self.prefix_cache and not self.prefill_chunk:
            raise ValueError(
                "prefix_cache requires chunked prefill (prefill_chunk > 0): "
                "a cache hit prefills only the cold suffix, which resumes "
                "mid-prompt against cached blocks — the whole-prompt "
                "executable always starts at position 0")
        if self.warm_masks and not self.prefill_chunk:
            raise ValueError("warm_masks requires chunked prefill "
                             "(prefill_chunk > 0): the warm γ-mask is "
                             "harvested from the prefill chunks")
        if self.predictor is not None and self.draft_cfg is not None:
            raise ValueError("predictor and speculative modes are "
                             "mutually exclusive serving modes")
        if self.draft_cfg is not None and self.gamma < 1:
            raise ValueError("speculative mode needs gamma >= 1")
        if self.preemption and not self.prefill_chunk:
            # resume re-prefills the prompt+generated prefix from an
            # arbitrary mid-sequence position, which only the chunked
            # path can lower — whole-prompt prefill always starts at 0.
            # Allowed but inert: the engine downgrades to preemption=False
            # (the default-on knob must not break prefill_chunk=0 users).
            pass
        return self

    @staticmethod
    def from_legacy_kwargs(**kwargs) -> "EngineConfig":
        """Build an EngineConfig from the pre-PR-10 constructor kwargs.
        Warns once per process; unknown names raise TypeError just like
        the old keyword signature did."""
        global _LEGACY_KWARGS_WARNED
        if not _LEGACY_KWARGS_WARNED:
            _LEGACY_KWARGS_WARNED = True
            warnings.warn(
                "ContinuousBatchingEngine(cfg, params, **kwargs) is "
                "deprecated: pass config=EngineConfig(...) instead "
                "(serving/config.py; field names match the old kwargs "
                "one to one)", DeprecationWarning, stacklevel=3)
        known = {f.name for f in dataclasses.fields(EngineConfig)}
        unknown = sorted(set(kwargs) - known)
        if unknown:
            raise TypeError(
                f"unexpected engine keyword(s) {unknown}; EngineConfig "
                f"fields are {sorted(known)}")
        return EngineConfig(**kwargs)
