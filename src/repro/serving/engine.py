"""Serving engine: batched prefill + decode with the paper's sparse-inference
features — tile-gathered sparse FFN, aggregated-sparsity tracking (Sec. 5.1),
and γ-window weight reuse (Fig. 7c).

Works with any registered family; sparsity tracking / reuse use the dense
family's instrumented decode (the paper's OPT/Llama/Falcon experiments are
dense models).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.sparsity import AggregatedTracker
from repro.models import common as cm
from repro.models import registry


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray  # (b, n_new)
    logprobs: Optional[np.ndarray]
    site_sparsity: Dict[str, float]
    aggregated: Optional[AggregatedTracker]
    steps: int


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_len: int = 256,
                 track_sparsity: bool = False):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.fam = registry.get_family(cfg)
        self.track = track_sparsity
        self._decode_jit = jax.jit(
            lambda p, c, t, pos: self.fam.model_decode(p, c, t, pos, cfg))

    # -- basic API ----------------------------------------------------------
    def prefill(self, batch: Dict[str, jnp.ndarray]):
        return self.fam.model_prefill(self.params, batch, self.cfg, self.max_len)

    def decode(self, cache, token, pos, ffn_masks=None, stats=None):
        if (stats is not None and stats.active) or ffn_masks is not None:
            kw = {}
            if ffn_masks is not None:
                kw["ffn_masks"] = ffn_masks
            return self.fam.model_decode(self.params, cache, token, pos,
                                         self.cfg, stats=stats, **kw)
        return self._decode_jit(self.params, cache, token, pos)

    # -- generation with the paper's machinery ------------------------------
    def generate(self, batch: Dict[str, jnp.ndarray], max_new: int,
                 reuse_window: int = 0) -> GenerationResult:
        """Greedy generation. reuse_window=γ enables the paper's Fig. 7c
        strategy: between mask refreshes, only FFN rows already loaded in
        the current window participate (no new weight I/O)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        offset = cfg.n_vision_tokens if cfg.family == "vlm" else 0
        last, cache = self.prefill(batch)
        out: List[np.ndarray] = []
        lps: List[np.ndarray] = []
        tracker = (AggregatedTracker(cfg.n_layers, cfg.d_ff)
                   if self.track and cfg.d_ff else None)
        site_acc: Dict[str, List[float]] = {}
        masks = None

        nxt = jnp.argmax(last[:, : cfg.vocab_size], -1).astype(jnp.int32)
        for step in range(max_new):
            out.append(np.asarray(nxt))
            lp = jax.nn.log_softmax(last[:, : cfg.vocab_size].astype(jnp.float32))
            lps.append(np.asarray(jnp.take_along_axis(lp, nxt[:, None], 1)[:, 0]))
            pos = jnp.full((b,), offset + s + step, jnp.int32)

            need_stats = self.track or (
                reuse_window > 0 and step % max(1, reuse_window) == 0)
            if need_stats:
                stats = cm.StatsCollector(True)
                logits, cache = self.decode(cache, nxt, pos, stats=stats)
                step_masks = _collect_down_act(stats, cfg)
                if tracker is not None and step_masks is not None:
                    tracker.update(step_masks)
                for k, v in stats.stats.items():
                    if k.endswith(("down_in", "up_in", "qkv_in")):
                        site_acc.setdefault(k.split("/")[-1], []).append(float(v))
                if reuse_window > 0 and step_masks is not None:
                    masks = jnp.asarray(step_masks)
            else:
                logits, cache = self.decode(cache, nxt, pos, ffn_masks=masks)
            last = logits
            nxt = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)

        sites = {k: float(np.mean(v)) for k, v in site_acc.items()}
        return GenerationResult(tokens=np.stack(out, 1),
                                logprobs=np.stack(lps, 1),
                                site_sparsity=sites, aggregated=tracker,
                                steps=max_new)

    def score(self, batch: Dict[str, jnp.ndarray]) -> float:
        """Mean NLL of batch['tokens'] (perplexity = exp(score))."""
        from repro.train.step import lm_loss
        loss, _ = lm_loss(self.params, batch, self.cfg)
        return float(loss)


def _collect_down_act(stats: cm.StatsCollector, cfg: ModelConfig):
    masks = []
    for i in range(cfg.n_layers):
        key = f"layer{i}/down_act"
        if key in stats.stats:
            masks.append(np.asarray(stats.stats[key]))
    return np.stack(masks) if masks else None
