"""Serving engines for the paper's sparse-inference machinery.

Two tiers:

* ``ContinuousBatchingEngine`` — the production path. Requests are admitted
  and retired mid-decode by a scheduler (serving/scheduler.py); K/V lives in
  a paged block pool shared across the batch (models/common.py) so
  mixed-length sequences coexist without padding to max_len; a SINGLE jitted
  decode step serves every slot, carrying per-request γ-window FFN masks
  (paper Fig. 7c) and per-request tile-activity scores (kernels/fused_ffn)
  through the batch dimension. One trace, no host round-trips in the loop —
  the only per-step host traffic is the (B,) next-token / logprob fetch the
  scheduler needs. Admission can run CHUNKED (``prefill_chunk``): one
  fixed-shape prompt chunk per step interleaved with decode, with shared
  prompt prefixes mapped from a refcounted KV-block cache
  (``prefix_cache``) so identical system prompts are prefilled once.

* ``ServeEngine`` — the legacy single-batch path (fixed max_len contiguous
  cache, per-token python loop), kept as the compatibility surface for
  ``generate()``/``score()`` callers (tests, launch/serve.py) and for the
  instrumented sparsity-measurement runs that want batch-union masks.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs.base import ModelConfig
from repro.core.sparsity import AggregatedTracker
from repro.models import common as cm
from repro.models import registry
from repro.models import serving_protocol as sp
from repro.obs import EngineObs
from repro.serving import sampling as smp
from repro.serving.config import EngineConfig
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import Request, RequestResult, Scheduler
from repro.sharding import rules


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray  # (b, n_new)
    logprobs: Optional[np.ndarray]
    site_sparsity: Dict[str, float]
    aggregated: Optional[AggregatedTracker]
    steps: int


# ---------------------------------------------------------------------------
# continuous batching


def _place_serve_params(params, mesh):
    """Distribute a param pytree per the serve-mode logical-axis rules
    (weights TP-resident over "model"; sharding/rules.py)."""
    shapes = jax.eval_shape(lambda: params)
    return jax.device_put(params,
                          rules.params_shardings(shapes, mesh, "serve"))


class ContinuousBatchingEngine:
    """Continuous-batching sparse serving over a paged KV cache.

    Constructed as ``ContinuousBatchingEngine(cfg, params,
    config=EngineConfig(...))`` (serving/config.py — a validated dataclass
    holding every field below plus the SLO-scheduling knobs
    ``prefill_budget`` / ``preemption`` / ``aging_steps``). The historical
    keyword form ``ContinuousBatchingEngine(cfg, params, n_slots=4, ...)``
    still works through a deprecation shim that warns once per process and
    round-trips exactly onto an EngineConfig.

    Parameters (= EngineConfig fields)
    ----------------------------------
    n_slots: max concurrently decoding requests (the jitted batch width).
    block_size: tokens per KV block.
    n_blocks: shared pool size (block 0 is scratch). Defaults to full
        residency (every slot can hold max_blocks_per_seq blocks).
    max_blocks_per_seq: static block-table width; bounds prompt+generation
        length to max_blocks_per_seq * block_size tokens.
    track_sparsity: keep a per-request AggregatedTracker (paper Sec. 5.1)
        fed from the in-graph FFN activity (costs one extra host fetch per
        step).
    draft_cfg / draft_params: enable SPECULATIVE mode (paper Sec. 5.2): the
        draft proposes γ tokens per slot (one jitted scan, no host
        round-trips), the target verifies every slot's γ+1-token window in
        ONE jitted forward (causal within the window), and the scheduler
        keeps the longest accepted prefix + the target's correction — so
        greedy output is exactly the autoregressive stream. The verify
        forward's FFN activity comes back unioned per window: its density is
        1 − s_agg(γ), the sparse-verification weight I/O of Thm 1. Requests'
        ``reuse_window`` is ignored in this mode (the verify window IS the
        γ-window; every window refreshes its own union mask).
    gamma: draft length γ per verify window (speculative mode only).
    predictor: enable PREDICTOR mode (the third serving mode): a fitted
        activity predictor (repro.predictor) names each token's active FFN
        tiles BEFORE any FFN weight is read, and the jitted decode step
        gathers ONLY those tiles for both the up- and down-projections
        (kernels/sparse_matmul.py) — fixed-K padded tile indices, so one
        trace serves every step. The predicted mask is composed with the
        γ-window union mask (rows from the current window stay computable),
        and every step measures predicted density + realized recall
        in-graph: a recall miss (masked-out-but-active neuron) is a
        correctness event recorded on RequestResult. Mutually exclusive
        with speculative mode.
    predictor_telemetry: measure realized recall in-graph (predictor mode).
        The probe re-reads the gate weight densely each step — right for
        this measurement repo, wrong for a memory-bound deployment: set
        False in production so the gathered tiles are the ONLY FFN weight
        traffic (recall telemetry then reads 0 and predictor_recall()
        raises instead of reporting a fake 1.0).
    prefill_chunk: > 0 enables CHUNKED PREFILL: admission runs the prompt
        through a fixed (n_slots, prefill_chunk) paged window step
        (transformer.prefill_chunk_paged), ONE chunk per engine step,
        interleaved with decode — bounded per-step admission latency and a
        single compiled prefill shape instead of one per prompt-block
        count. 0 (default) keeps the whole-prompt prefill executable, whose
        bf16 rounding placement is frozen (cross-engine exactness tests pin
        it); at f32 the two paths produce identical greedy streams
        (tests/test_chunked_prefill.py). Composes with all three serving
        modes (the draft pool is chunk-prefilled through the same windows).
    base_seed: PRNG seed behind requests that sample (temperature > 0)
        without their own ``SamplingParams.seed``. Greedy requests never
        consume randomness. See serving/sampling.py for the key-schedule
        contract (restart-deterministic, admission-order independent).
    prefix_cache: reuse KV blocks across requests sharing a token-aligned
        full-block prompt prefix (system prompts, few-shot headers): the
        scheduler's prefix trie maps the shared blocks at admission
        (refcount++), only the cold suffix is prefilled, and retirement
        drops references instead of freeing — cached prefixes persist until
        pool pressure evicts them (LRU, unshared-only). Requires
        prefill_chunk > 0 (the cold suffix resumes mid-prompt, which only
        the chunked path can lower). A cache-hit request's greedy stream is
        byte-identical to a cold prefill of the same prompt.
    warm_masks: with chunked prefill, seed each request's first γ-window
        FFN mask from the prefill chunks' harvested union activity and skip
        the age-0 dense refresh — the request starts decoding with a warm
        mask and one less full weight read (approximation, exactly like any
        other γ-window; off by default so γ phase semantics match the
        whole-prompt path bit for bit).
    mesh: a ("data", "model") jax Mesh makes the engine MESH-NATIVE
        (tensor-parallel sharded serving): params (target, draft, and
        predictor probes) are placed via the serve-mode logical-axis rules
        (sharding/rules.py — FFN wu/wg/wd, attention heads and the vocab
        all split over "model"), the paged KV pool is allocated sharded
        (blocks over "data", kv heads over "model"), the per-slot γ-mask /
        activity buffers split d_ff over "model", and every jitted paged
        step traces under the mesh so its NamedSharding constraints keep
        the sparse FFN machinery shard-local (predictor tile lists pack
        per model shard; telemetry is all-reduced once per step). The
        memory-bound decode reads shrink multiplicatively: sparsity x
        1/TP per device — see ``weight_io_bytes_per_step``. None (the
        default) is today's single-device engine, whose jitted lowerings
        are bit-frozen (bf16 exactness pins); at f32 the sharded engine's
        greedy streams are byte-identical to it in all three serving
        modes (tests/test_sharded_serving.py).
    obs: an ``EngineObs`` observability hub (repro.obs). None (default)
        creates an enabled one per engine: step-phase tracing, per-request
        spans, and labeled counters/histograms feed the ``/metrics`` and
        ``/statusz`` endpoints (launch/serve_api.py). Hooks only touch
        host-side values the step already fetched — zero added device
        syncs, and f32 greedy streams are byte-identical with
        observability on or off (tests/test_obs.py). Pass
        ``EngineObs.disabled()`` to turn every hook into an early return.
    """

    def __init__(self, cfg: ModelConfig, params,
                 config: Optional[EngineConfig] = None, **legacy_kw):
        if config is None:
            config = (EngineConfig.from_legacy_kwargs(**legacy_kw)
                      if legacy_kw else EngineConfig())
        elif legacy_kw:
            raise TypeError(
                "pass either config=EngineConfig(...) or legacy keyword "
                f"arguments, not both (got {sorted(legacy_kw)})")
        config.validate()
        self.config = config
        n_slots = config.n_slots
        block_size = config.block_size
        max_blocks_per_seq = config.max_blocks_per_seq
        n_blocks = config.resolved_n_blocks
        track_sparsity = config.track_sparsity
        draft_cfg = config.draft_cfg
        draft_params = config.draft_params
        gamma = config.gamma
        predictor = config.predictor
        predictor_telemetry = config.predictor_telemetry
        prefill_chunk = config.prefill_chunk
        prefix_cache = config.prefix_cache
        warm_masks = config.warm_masks
        mesh = config.mesh
        base_seed = config.base_seed
        fast_kernels = config.fast_kernels
        obs = config.obs
        fam = registry.get_family(cfg)
        # every serving-mode gate below goes through the family's DECLARED
        # capability set (models/serving_protocol.py) — one uniform error
        # naming the missing capability, zero hasattr probes
        caps = registry.serving_caps(cfg)
        caps.require("paged_decode", cfg.family)
        if not cfg.d_ff:
            raise ValueError("continuous batching requires an FFN (d_ff > 0)")
        if prefill_chunk:
            caps.require("chunked_prefill", cfg.family)
        self.mesh = mesh
        self.tp = rules.tp_size(mesh)
        # effective TP of the FFN weights: the divisibility guard REPLICATES
        # wu/wg/wd over "model" when d_ff does not divide, and then every
        # device reads the full weight — per-device I/O accounting must not
        # claim a 1/TP split that physically did not happen. MoE shards the
        # EXPERT axis over "model" (sharding/rules.py serve map), so its
        # divisor holds when n_experts divides; d_ff is the fallback axis.
        tp = max(1, self.tp)
        if cfg.n_experts:
            self.ffn_tp = tp if (cfg.n_experts % tp == 0
                                 or cfg.d_ff % tp == 0) else 1
        else:
            self.ffn_tp = tp if cfg.d_ff % tp == 0 else 1
        # fused Pallas decode kernels (kernels/fused_decode.py,
        # kernels/paged_attention.py): None autodetects — compiled kernels
        # on an accelerator, the frozen XLA lowerings on CPU (where the
        # kernels would run in interpret mode: correct but slow, so CPU CI
        # keeps the frozen paths unless a test forces fast_kernels=True).
        if fast_kernels is None:
            fast_kernels = jax.default_backend() != "cpu"
        if fast_kernels and cfg.n_experts:
            import warnings
            warnings.warn(
                "fast_kernels is not wired for MoE serving yet: the fused "
                "decode kernel has no expert-offset variant, so MoE uses "
                "the documented XLA dispatch fallback "
                "(kernels/fused_decode.py); the standalone expert gather "
                "kernels live in kernels/sparse_matmul.py", stacklevel=2)
            fast_kernels = False
        if fast_kernels and mesh is not None:
            import warnings
            warnings.warn(
                "fast_kernels is not available under a mesh: GSPMD cannot "
                "partition pallas_call — falling back to the sharded XLA "
                "serving path", stacklevel=2)
            fast_kernels = False
        self.fast_kernels = bool(fast_kernels)
        fk = self.fast_kernels
        if mesh is not None:
            missing = {"data", "model"} - set(mesh.axis_names)
            if missing:
                raise ValueError("serving mesh needs ('data', 'model') "
                                 f"axes; missing {sorted(missing)}")
            params = _place_serve_params(params, mesh)
        self.cfg = cfg
        self.params = params
        self.fam = fam
        self.block_size = block_size
        self.track = track_sparsity
        self.prefill_chunk = prefill_chunk
        self.warm_masks = warm_masks
        self.prefill_budget = config.prefill_budget
        self.obs = obs if obs is not None else EngineObs()
        # preemption resumes a request mid-sequence via chunked prefill of
        # its prompt+generated prefix — without the chunked path the knob
        # is inert (downgraded, not an error: it defaults on)
        self.scheduler = Scheduler(n_slots, n_blocks, block_size,
                                   max_blocks_per_seq,
                                   prefix_cache=prefix_cache, obs=self.obs,
                                   preemption=(config.preemption
                                               and prefill_chunk > 0),
                                   aging_steps=config.aging_steps)
        self.pages = fam.init_paged_cache(
            cfg, n_blocks, block_size,
            sharding=self._pool_sharding(cfg, n_blocks))
        self.masks = jnp.zeros((cfg.n_layers, n_slots, cfg.d_ff), bool,
                               **self._masks_alloc_kw(n_slots))
        self.trackers: Dict[int, AggregatedTracker] = {}
        self.t = 0  # engine step counter
        self._uid = 0
        # weight-I/O accounting, per (active slot, step): autoregressive mode
        # sums the fraction of down-proj rows actually read under γ-reuse
        # (refresh steps count 1.0); speculative mode sums the window's
        # UNION-active fraction = 1 − s_agg (the Sec. 5.2 verification I/O).
        # _tiles_sum tracks active d_ff tiles (kernels/fused_ffn granularity).
        self._dens_sum = 0.0
        self._tiles_sum = 0.0
        self._dens_n = 0
        # predictor-mode recall accounting (in-graph miss counts)
        self._pred_active = 0
        self._pred_miss = 0
        # the current step's mean measured density / tile activity over
        # active slots — stashed by _account() from the SAME numpy arrays
        # it already fetched, so obs.step_end costs no extra device sync
        self._step_density: Optional[float] = None
        self._step_tiles: Optional[float] = None

        vocab = cfg.vocab_size
        self.base_seed = base_seed

        # one jitted sampling head for every closure below: per-slot
        # temperature / top-k / top-p / PRNG keys arrive as TRACED arrays,
        # so mixing greedy and sampled requests in a batch never retraces,
        # and temperature-0 rows reproduce the historical greedy outputs
        # bit for bit (sampling.sample_head's greedy branch is the old
        # argmax + log_softmax formula verbatim)
        def head(logits, temps, tks, tps, keys):
            """(..., vocab_p) -> next token + its logprob per position."""
            return smp.sample_head(logits, vocab, temps, tks, tps, keys)

        def decode(params, pages, table, token, pos, masks, refresh,
                   temps, tks, tps, keys, gen):
            logits, pages, new_masks, (act, scores, density) = \
                fam.model_decode_paged(params, pages, table, token, pos, cfg,
                                       masks, refresh, block_size,
                                       fast_kernels=fk)
            nxt, lp = head(logits, temps, tks, tps,
                           smp.position_keys(keys, gen))
            # per-request fraction of active d_ff tiles this step — the
            # granularity the tile-gathered kernels load weights at
            tiles = jnp.mean((scores > 0).astype(jnp.float32), axis=(0, 2))
            return nxt, lp, pages, new_masks, tiles, jnp.mean(density, 0), act

        def prefill(params, tokens, pages, blocks, true_len,
                    temps, tks, tps, keys):
            last, pages = fam.model_prefill_paged(params, {"tokens": tokens},
                                                  cfg, pages, blocks,
                                                  block_size,
                                                  true_len=true_len)
            # the prompt-seeded token is generated index 0 of the schedule
            nxt, lp = head(last, temps, tks, tps,
                           smp.position_keys(keys, jnp.zeros((1,),
                                                             jnp.int32)))
            return nxt[0], lp[0], pages

        # donate the page pool + masks: decode/prefill update them in place
        # instead of copying the whole pool every token
        self._decode = self._jit(decode, donate_argnums=(1, 5))
        # prompts are padded to block multiples, so prefill compiles at most
        # max_blocks_per_seq distinct shapes (admission-path latency bound)
        self._prefill = self._jit(prefill, donate_argnums=(2,))

        if prefill_chunk:
            def prefill_chunk_step(params, pages, table, tokens, pos0, clen,
                                   masks, refresh, keep, temps, tks, tps,
                                   keys, gen):
                (logits, pages, new_masks,
                 (act, _, _, _)) = fam.model_prefill_chunk_paged(
                    params, {"tokens": tokens}, cfg, pages, table, pos0,
                    clen, masks, refresh, block_size, fast_kernels=fk)
                # warm-mask harvest accumulates over a request's chunks:
                # the first chunk REPLACES the slot's row (clearing any
                # stale previous occupant — via new_masks' refresh path),
                # every later chunk ORs its union activity in, so the
                # final mask covers the whole cold suffix
                new_masks = jnp.where(keep[None, :, None], masks | act,
                                      new_masks)
                # every chunk position samples with the slot's CURRENT
                # generated-index key (gen=0 for a fresh prompt; a resumed
                # preempted slot continues its key schedule at len(out)) —
                # only clen-1 (the seed token) is read on the host
                B, C = logits.shape[:2]
                k0 = smp.position_keys(keys, gen)
                nxt, lp = head(logits,
                               jnp.broadcast_to(temps[:, None], (B, C)),
                               jnp.broadcast_to(tks[:, None], (B, C)),
                               jnp.broadcast_to(tps[:, None], (B, C)),
                               jnp.broadcast_to(k0[:, None, :], (B, C, 2)))
                return nxt, lp, pages, new_masks

            self._prefill_chunk = self._jit(prefill_chunk_step,
                                            donate_argnums=(1, 6))

        # -- predictor mode --------------------------------------------------
        self.predictor = predictor
        self.predictor_telemetry = predictor_telemetry
        if predictor is not None:
            caps.require("predictor", cfg.family)
            if predictor.n_tiles * predictor.tile != cfg.d_ff:
                raise ValueError(
                    f"predictor geometry {predictor.n_tiles}x"
                    f"{predictor.tile} does not cover d_ff={cfg.d_ff}")
            if mesh is not None:
                # place the probe weights alongside the FFN weights they
                # shadow (d_ff over "model"); never mutate the caller's
                # Predictor — it may drive other (single-device) engines
                predictor = dataclasses.replace(
                    predictor, params=jax.device_put(
                        predictor.params,
                        rules.predictor_shardings(predictor.params, mesh)))
                self.predictor = predictor
            kind, tile_w = predictor.kind, predictor.tile
            k_tiles = predictor.k_tiles
            # model-axis-local tile packing: each TP shard packs its own
            # capacity from its local d_ff slice (exact at full capacity)
            pred_shards = (self.tp
                           if (cfg.d_ff // tile_w) % self.tp == 0 else 1)
            if self.tp > 1 and pred_shards == 1:
                import warnings
                warnings.warn(
                    f"predictor tile count {cfg.d_ff // tile_w} is not "
                    f"divisible by the {self.tp}-way model axis: packed "
                    "tile lists fall back to GLOBAL packing, so predictor "
                    "gathers will cross shards (correct, but the "
                    "shard-local weight-I/O property is lost)",
                    stacklevel=2)

            def decode_pred(params, pages, table, token, pos, masks, refresh,
                            pred_params, temps, tks, tps, keys, gen):
                logits, pages, new_masks, (act, scores, density, n_act,
                                           n_miss) = \
                    fam.model_decode_paged_predicted(
                        params, pages, table, token, pos, cfg, masks,
                        refresh, pred_params, kind, tile_w, k_tiles,
                        block_size, predictor_telemetry, pred_shards,
                        fast_kernels=fk)
                nxt, lp = head(logits, temps, tks, tps,
                               smp.position_keys(keys, gen))
                tiles = jnp.mean((scores > 0).astype(jnp.float32),
                                 axis=(0, 2))
                return (nxt, lp, pages, new_masks, tiles,
                        jnp.mean(density, 0), act,
                        jnp.sum(n_act, 0), jnp.sum(n_miss, 0))

            self._decode_pred = self._jit(decode_pred, donate_argnums=(1, 5))

        # -- speculative mode ------------------------------------------------
        self.spec = draft_cfg is not None
        self.gamma = gamma
        if self.spec:
            if draft_cfg.vocab_size != cfg.vocab_size:
                raise ValueError("draft and target must share a vocabulary")
            caps.require("spec_verify", cfg.family)
            dfam = registry.get_family(draft_cfg)
            registry.serving_caps(draft_cfg).require("spec_draft",
                                                     draft_cfg.family)
            if mesh is not None:
                draft_params = _place_serve_params(draft_params, mesh)
            self.draft_cfg = draft_cfg
            self.draft_params = draft_params
            self.dfam = dfam
            # the draft shares the slots' block TABLES but has its own pool
            # (its layer count / head geometry differ from the target's)
            self.draft_pages = dfam.init_paged_cache(
                draft_cfg, n_blocks, block_size,
                sharding=self._pool_sharding(draft_cfg, n_blocks))

            def draft(dparams, dpages, table, token, pos0, wlen,
                      temps, tks, tps, keys, gen0):
                # the draft proposes with the SAME per-position key schedule
                # the verify step samples with (key-coupled acceptance —
                # see sampling.py): proposal g uses the key of generated
                # index gen0 + g. Greedy slots fall through to the frozen
                # argmax inside the head.
                def next_fn(logits, g):
                    nxt, _ = smp.sample_head(
                        logits, vocab, temps, tks, tps,
                        smp.position_keys(keys, gen0 + g))
                    return nxt

                return dfam.model_draft_gamma_paged(
                    dparams, dpages, table, token, pos0, wlen, draft_cfg,
                    gamma, block_size, next_fn=next_fn, fast_kernels=fk)

            def verify(params, pages, table, window, pos0, wlen, masks,
                       temps, tks, tps, keys, gen0):
                refresh = jnp.ones((n_slots,), bool)
                logits, pages, new_masks, (act, scores, density, udens) = \
                    fam.model_verify_window_paged(
                        params, pages, table, window, pos0, wlen, cfg,
                        masks, refresh, block_size, fast_kernels=fk)
                B, W = logits.shape[:2]
                nxt, lp = head(logits,  # both (b, W)
                               jnp.broadcast_to(temps[:, None], (B, W)),
                               jnp.broadcast_to(tks[:, None], (B, W)),
                               jnp.broadcast_to(tps[:, None], (B, W)),
                               smp.window_keys(keys, gen0, W))
                tiles = jnp.mean((scores > 0).astype(jnp.float32),
                                 axis=(0, 2))
                return (nxt, lp, pages, new_masks, tiles,
                        jnp.mean(udens, 0), act)

            def prefill_draft(dparams, tokens, dpages, blocks, true_len):
                _, dpages = dfam.model_prefill_paged(
                    dparams, {"tokens": tokens}, draft_cfg, dpages, blocks,
                    block_size, true_len=true_len)
                return dpages

            self._draft = self._jit(draft, donate_argnums=(1,))
            self._verify = self._jit(verify, donate_argnums=(1, 6))
            self._prefill_draft = self._jit(prefill_draft, donate_argnums=(2,))

            if prefill_chunk:
                def prefill_chunk_draft(dparams, dpages, table, tokens,
                                        pos0, clen):
                    # the draft needs the prompt K/V in ITS pool too. Its
                    # own γ-masks never persist (the returned masks are
                    # discarded), but refresh MUST be on: refresh off with
                    # zero masks silently zeroes the FFN (eff = mask |
                    # refresh), corrupting the drafted prompt K/V — exact
                    # output either way, but acceptance would collapse
                    dmasks = jnp.zeros((draft_cfg.n_layers, n_slots,
                                        draft_cfg.d_ff), bool)
                    drefresh = jnp.ones((n_slots,), bool)
                    _, dpages, _, _ = dfam.model_verify_window_paged(
                        dparams, dpages, table, tokens, pos0, clen,
                        draft_cfg, dmasks, drefresh, block_size,
                        fast_kernels=fk)
                    return dpages

                self._prefill_chunk_draft = self._jit(prefill_chunk_draft,
                                                      donate_argnums=(1,))

        self.obs.set_engine_info(
            arch=cfg.name,
            mode=("spec" if self.spec
                  else "predictor" if self.predictor is not None
                  else "plain"),
            n_slots=n_slots, block_size=block_size,
            prefill_chunk=prefill_chunk, tp=self.tp,
            fast_kernels=self.fast_kernels, family=cfg.family,
            n_experts=cfg.n_experts)

    # -- mesh plumbing -------------------------------------------------------
    def _jit(self, fn, **kw):
        """jax.jit whose *calls* run under the engine's mesh: constraints in
        the paged steps (rules.constrain) bind at trace time, so the mesh
        must be installed exactly while a sharded engine traces — and never
        while a single-device engine does (mesh=None skips the wrapper
        entirely: the frozen lowerings stay byte-identical)."""
        jf = jax.jit(fn, **kw)
        if self.mesh is None:
            return jf
        mesh = self.mesh

        def call(*args):
            with rules.use_mesh(mesh):
                return jf(*args)
        return call

    def _pool_sharding(self, cfg_: ModelConfig, n_blocks: int):
        """NamedSharding for a paged KV pool (None single-device): blocks
        over "data", kv heads over "model" — allocated in place, a
        production pool must never materialize on one device first."""
        if self.mesh is None:
            return None
        g = cm.HeadGeometry(cfg_.n_heads, cfg_.n_kv_heads,
                            cfg_.resolved_head_dim)
        shape = (cfg_.n_layers, n_blocks, g.kvp, self.block_size, g.head_dim)
        return NamedSharding(self.mesh,
                             rules.paged_cache_pspec(shape, self.mesh))

    def _masks_alloc_kw(self, n_slots: int) -> Dict:
        """Allocation kwargs for the (L, n_slots, d_ff) γ-mask buffer:
        d_ff over "model" so mask updates stay shard-local."""
        if self.mesh is None:
            return {}
        shape = (self.cfg.n_layers, n_slots, self.cfg.d_ff)
        return {"device": NamedSharding(
            self.mesh, rules.serve_masks_pspec(shape, self.mesh))}

    # -- request API --------------------------------------------------------
    def submit(self, prompt, max_new: int, reuse_window: int = 0,
               sampling: Optional[SamplingParams] = None, *,
               priority: int = 0,
               slo_ms: Optional[float] = None) -> int:
        """Enqueue a request; returns its uid. Admission happens inside
        step() when a slot and enough KV blocks are free.

        ``sampling`` (None = greedy) selects this request's decoding
        distribution and stop sequences. A sampled request's PRNG key is
        derived here from (seed, request fingerprint) — never from the
        uid, slot, or admission order — so its stream replays identically
        whatever else is co-scheduled (serving/sampling.py).

        ``priority`` (higher = more urgent; default 0) orders admission
        and selects preemption victims; aging (EngineConfig.aging_steps)
        keeps low classes from starving. ``slo_ms`` is this request's
        time-to-first-token target: it never changes scheduling, it is
        judged (RequestResult.slo_met, /metrics) — the scheduler works on
        priorities, the SLO grades the outcome."""
        self._uid += 1
        key = None
        if sampling is not None and not sampling.is_greedy:
            key = smp.request_prng_key(prompt, sampling, self.base_seed)
        req = Request(uid=self._uid,
                      tokens=np.asarray(prompt, np.int32).reshape(-1),
                      max_new=max_new, reuse_window=reuse_window,
                      sampling=sampling, key=key,
                      priority=priority, slo_ms=slo_ms)
        self.scheduler.submit(req, self.t)
        return self._uid

    def cancel(self, uid: int) -> bool:
        """Abandon a request (client disconnect). Queued requests are
        withdrawn immediately; in-flight ones finish this step and retire
        with their partial output and finish_reason "cancelled". Returns
        False for unknown/finished uids."""
        return self.scheduler.cancel(uid)

    def _admit(self, st=None) -> bool:
        """Retire finished requests, admit queued ones, and advance prefill
        (into the draft's page pool too, in speculative mode). ``st`` is
        the step's phase trace; standalone callers (tests driving prefill
        chunk-by-chunk) may omit it and get a throwaway one.

        Whole-prompt mode (prefill_chunk == 0): every newly admitted
        request is prefilled to completion right here — the frozen legacy
        lowering. Chunked mode: ONE fixed-shape (n_slots, prefill_chunk)
        window step advances EVERY prefilling slot by one chunk, so
        admission work is interleaved with (and latency-bounded like) the
        decode step; slots whose prompt completes are seeded from that
        chunk's logits. Returns True when any prefill work ran.

        ``st`` is the step's StepTrace: retirement + admission time under
        "admit", all prefill work (whole-prompt or one chunk, including its
        host fetches) under "prefill"."""
        if st is None:
            st = self.obs.step_start()  # throwaway trace, never reported
        sched = self.scheduler
        with st.phase("admit"):
            sched.retire_finished(self.t)
            newly = sched.admit(self.t)
            if self.track:
                for _, slot in newly:
                    # a resumed (preempted) slot keeps its tracker: the
                    # union statistics span the whole logical request
                    if slot.request.uid not in self.trackers:
                        self.trackers[slot.request.uid] = AggregatedTracker(
                            self.cfg.n_layers, self.cfg.d_ff)
        if not self.prefill_chunk:
            if not newly:
                return False
            with st.phase("prefill"):
                self._prefill_whole(newly)
            return True
        if not sched.prefill_indices():
            return False
        with st.phase("prefill"):
            self._prefill_one_chunk()
        return True

    def _prefill_whole(self, newly) -> None:
        """Whole-prompt prefill of every newly admitted slot (the frozen
        legacy lowering — prefill_chunk == 0)."""
        sched = self.scheduler
        for _, slot in newly:
            s = slot.prefill_len
            nb_eff = -(-s // self.block_size)  # blocks the prompt holds
            toks = np.zeros((1, nb_eff * self.block_size), np.int32)
            toks[0, :s] = slot.prefill_tokens
            jt = jnp.asarray(toks)
            blocks = jnp.asarray(slot.blocks[:nb_eff], jnp.int32)
            true_len = jnp.asarray(s, jnp.int32)
            sp = slot.request.sampling or smp.GREEDY
            rkey = (slot.request.key if slot.request.key is not None
                    else np.zeros((2,), np.uint32))
            nxt, lp, self.pages = self._prefill(
                self.params, jt, self.pages, blocks, true_len,
                jnp.asarray([sp.temperature], jnp.float32),
                jnp.asarray([sp.top_k], jnp.int32),
                jnp.asarray([sp.top_p], jnp.float32),
                jnp.asarray(rkey[None, :]))
            if self.spec:
                self.draft_pages = self._prefill_draft(
                    self.draft_params, jt, self.draft_pages, blocks,
                    true_len)
            sched.seed(slot, int(nxt), float(lp), step=self.t)

    def _prefill_one_chunk(self) -> None:
        """One fixed-shape chunked-prefill window step (see _admit)."""
        sched = self.scheduler
        (tokens, pos0, table, clen,
         first) = sched.prefill_batch(self.prefill_chunk,
                                      self.prefill_budget)
        temps, tks, tps, skeys, gen = sched.sampling_arrays()
        # prefilling slots run DENSE (refresh on): the chunk records fresh
        # union activity into their mask rows — the warm-mask harvest, and
        # harmless otherwise (an age-0 decode refresh overwrites it).
        # Decoding slots keep refresh off so their live γ-masks survive
        # the shared (L, B, F) mask update; continuing chunks (keep) OR
        # into the running union instead of replacing it.
        refresh = clen > 0
        keep = refresh & ~first
        jt = jnp.asarray(table)
        jtok, jp, jc = (jnp.asarray(tokens), jnp.asarray(pos0),
                        jnp.asarray(clen))
        nxt, lp, self.pages, self.masks = self._prefill_chunk(
            self.params, self.pages, jt, jtok, jp, jc, self.masks,
            jnp.asarray(refresh), jnp.asarray(keep), jnp.asarray(temps),
            jnp.asarray(tks), jnp.asarray(tps), jnp.asarray(skeys),
            jnp.asarray(gen))
        if self.spec:
            self.draft_pages = self._prefill_chunk_draft(
                self.draft_params, self.draft_pages, jt, jtok, jp, jc)
        sched.record_prefill(np.asarray(nxt), np.asarray(lp), clen,
                             warm=self.warm_masks, step=self.t)

    def _account(self, active, dens_np, tiles_np, act) -> None:
        """Per-(active slot, step) weight-I/O + sparsity-tracker updates.
        Also stashes the step means for obs.step_end — derived from the
        numpy arrays this call already received, not a new fetch."""
        self.scheduler.record_io(active, dens_np)
        for i in active:
            self._dens_sum += float(dens_np[i])
            self._tiles_sum += float(tiles_np[i])
            self._dens_n += 1
        if active:
            self._step_density = float(np.mean(dens_np[active]))
            self._step_tiles = float(np.mean(tiles_np[active]))
        if self.track:
            act_np = np.asarray(act)  # (L, B, F)
            for i in active:
                uid = self.scheduler.slots[i].request.uid
                self.trackers[uid].update(act_np[:, i, :])

    def step(self) -> bool:
        """Retire finished requests, admit queued ones, advance prefill by
        one chunk (chunked mode), then advance every active slot: one
        decoded token each (autoregressive mode) or one drafted-and-verified
        γ-window each (speculative mode). Returns False when NO work ran —
        neither a prefill chunk nor a decode."""
        st = self.obs.step_start()
        self._step_density = self._step_tiles = None
        prefilled = self._admit(st)
        active = self.scheduler.active_indices()
        if active:
            if self.spec:
                self._advance_spec(active, st)
            elif self.predictor is not None:
                self._advance_pred(active, st)
            else:
                self._advance(active, st)
        elif not prefilled:
            self._obs_step_end(st, False, active)
            return False
        self.t += 1
        self._obs_step_end(st, True, active)
        return True

    def _obs_step_end(self, st, worked: bool, active) -> None:
        """Close the step's trace with host-side state only (occupancy,
        pool, queue, and the density _account() already stashed)."""
        if not self.obs.enabled:
            return
        sched = self.scheduler
        dens = self._step_density
        self.obs.step_end(
            st, worked=worked, slots_active=len(active),
            n_slots=sched.n_slots, queue_depth=len(sched.queue),
            pool_used=sched.allocator.allocated,
            pool_total=sched.allocator.n_blocks - 1,
            density=dens, tiles=self._step_tiles,
            ffn_bytes=(None if dens is None
                       else dens * self._mode_ffn_bytes() / self.ffn_tp))

    def _advance(self, active, st) -> None:
        """Decode one token for every active slot."""
        sched = self.scheduler
        with st.phase("dispatch"):
            tokens, pos, table, refresh = sched.batch_arrays()
            temps, tks, tps, keys, gen = sched.sampling_arrays()
            nxt, lp, self.pages, self.masks, tiles, dens, act = self._decode(
                self.params, self.pages, jnp.asarray(table),
                jnp.asarray(tokens), jnp.asarray(pos), self.masks,
                jnp.asarray(refresh), jnp.asarray(temps), jnp.asarray(tks),
                jnp.asarray(tps), jnp.asarray(keys), jnp.asarray(gen))
        with st.phase("host_sync"):
            dens_np, tiles_np = np.asarray(dens), np.asarray(tiles)
            nxt_np, lp_np = np.asarray(nxt), np.asarray(lp)
        with st.phase("sample"):
            self._account(active, dens_np, tiles_np, act)
            sched.record(nxt_np, lp_np)

    def _advance_pred(self, active, st) -> None:
        """Predictor-mode decode: per-token predicted tile masks drive
        gathered up+down FFN matmuls inside the single jitted decode step;
        density / recall telemetry comes back with the batch."""
        sched = self.scheduler
        with st.phase("dispatch"):
            tokens, pos, table, refresh = sched.batch_arrays()
            temps, tks, tps, keys, gen = sched.sampling_arrays()
            (nxt, lp, self.pages, self.masks, tiles, dens, act, n_act,
             n_miss) = self._decode_pred(
                self.params, self.pages, jnp.asarray(table),
                jnp.asarray(tokens), jnp.asarray(pos), self.masks,
                jnp.asarray(refresh), self.predictor.params,
                jnp.asarray(temps), jnp.asarray(tks), jnp.asarray(tps),
                jnp.asarray(keys), jnp.asarray(gen))
        with st.phase("host_sync"):
            dens_np, tiles_np = np.asarray(dens), np.asarray(tiles)
            na, nm = np.asarray(n_act), np.asarray(n_miss)
            nxt_np, lp_np = np.asarray(nxt), np.asarray(lp)
        with st.phase("sample"):
            self._account(active, dens_np, tiles_np, act)
            step_act = step_miss = 0
            for i in active:
                step_act += int(na[i])
                step_miss += int(nm[i])
            self._pred_active += step_act
            self._pred_miss += step_miss
            if self.predictor_telemetry:
                self.obs.predictor_counts(step_act, step_miss)
            sched.record(nxt_np, lp_np, pred_density=dens_np,
                         pred_active=na, pred_miss=nm)

    def _advance_spec(self, active, st) -> None:
        """Speculative decode, batched across slots: γ draft tokens per
        slot from ONE jitted draft scan, then every slot's whole γ+1
        window through ONE jitted target forward. The only host traffic is
        the (B, γ) proposal fetch and the (B, W) target-token/logprob fetch
        the acceptance bookkeeping needs — no per-token round-trips. Both
        the draft scan and the verify head consume the slots' shared
        per-position key schedule, so sampled requests come out identical
        to their autoregressive sampled streams (key-coupled acceptance —
        serving/sampling.py)."""
        sched = self.scheduler
        with st.phase("dispatch"):
            tokens, pos0, table, wlen = sched.spec_batch(self.gamma + 1)
            temps, tks, tps, keys, gen0 = sched.sampling_arrays()
            jt = jnp.asarray(table)
            jp, jw = jnp.asarray(pos0), jnp.asarray(wlen)
            jtemps, jtks, jtps = (jnp.asarray(temps), jnp.asarray(tks),
                                  jnp.asarray(tps))
            jkeys, jgen = jnp.asarray(keys), jnp.asarray(gen0)
            props, self.draft_pages = self._draft(
                self.draft_params, self.draft_pages, jt, jnp.asarray(tokens),
                jp, jw, jtemps, jtks, jtps, jkeys, jgen)
            # the (B, γ) proposal fetch is pipeline-necessary (the verify
            # window is built from it), so it stays in "dispatch"
            window = np.concatenate([tokens[:, None], np.asarray(props)],
                                    axis=1)
            (target, lp, self.pages, self.masks, tiles, udens,
             act) = self._verify(
                self.params, self.pages, jt, jnp.asarray(window), jp, jw,
                self.masks, jtemps, jtks, jtps, jkeys, jgen)
        with st.phase("host_sync"):
            udens_np, tiles_np = np.asarray(udens), np.asarray(tiles)
            target_np, lp_np = np.asarray(target), np.asarray(lp)
        with st.phase("sample"):
            self._account(active, udens_np, tiles_np, act)
            sched.record_spec(window, target_np, lp_np, wlen)

    def drain(self, max_steps: int = 1_000_000) -> Dict[int, RequestResult]:
        """Drive step() until every submitted request has finished.

        Never drops work silently: if step() makes no progress while
        requests remain queued (a head that can never be admitted — which
        submit()'s validation should have rejected), or max_steps runs out
        with work outstanding, this RAISES instead of returning a results
        dict with uids quietly missing."""
        for _ in range(max_steps):
            progressed = self.step()
            if not self.scheduler.has_work():
                break
            if not progressed:
                # step() already retired + attempted admission: with no
                # active slot, no prefill chunk, and the queue head still
                # stuck, no internal event can ever unblock it
                alloc = self.scheduler.allocator
                raise RuntimeError(
                    f"serving deadlock: queued requests "
                    f"{self.scheduler.queue.uids()} can never be admitted "
                    f"({alloc.available}/{alloc.n_blocks - 1} pool blocks "
                    f"free, every slot idle)")
        else:
            if self.scheduler.has_work():
                raise RuntimeError(
                    f"drain(max_steps={max_steps}) exhausted with "
                    f"{len(self.scheduler.queue)} request(s) still queued "
                    f"or in flight")
        self.scheduler.retire_finished(self.t)
        return dict(self.scheduler.results)

    def run(self, max_steps: int = 1_000_000) -> Dict[int, RequestResult]:
        """Offline convenience: submit everything first, then run to
        completion. A thin wrapper over ``drain`` — the online serving
        layer (serving/api.py) interleaves submit()/cancel() with step()
        instead and never calls this."""
        return self.drain(max_steps)

    # -- metrics ------------------------------------------------------------
    # Scalar-helper convention (and the one metrics_snapshot()/the /metrics
    # endpoint rely on to OMIT series instead of faking them):
    #   * cumulative work ratios that are well-defined as "nothing saved
    #     yet" return 0.0 on a fresh engine (weight_io_saved,
    #     prefix_hit_rate);
    #   * mode-gated or measurement-gated metrics return None when the
    #     serving mode / telemetry doesn't produce them OR no step has
    #     measured them yet (predictor_density, predictor_recall,
    #     s_agg_window, tile_activity_rate) — never a fake 1.0 and never
    #     a raise, so status surfaces can render any engine uniformly.

    def weight_io_saved(self) -> float:
        """Fraction of FFN weight reads skipped, averaged over (active
        slot, step). Autoregressive mode: down-projection rows skipped by
        γ-window reuse (0.0 for dense serving). Speculative mode: skipped
        by verifying with only the window's union-active rows — the
        measured s_agg(γ) of paper Sec. 5.2 / Thm 1. Predictor mode:
        up- AND down-projection tiles skipped because the predictor never
        gathered them (1 − mean predicted tile density)."""
        if not self._dens_n:
            return 0.0
        return 1.0 - self._dens_sum / self._dens_n

    def _mode_ffn_bytes(self) -> int:
        """Per-layer-pass FFN weight bytes in the serving mode's SKIPPABLE
        scope, per token, dense: the down-projection for γ-reuse /
        speculative serving (their density metric covers wd rows), up-,
        gate- AND down-projection for predictor serving (the predictor
        gathers all of them). With ``fast_kernels`` the autoregressive
        step ALSO runs its up/gate projections through the fused
        tile-gathered kernel (kernels/fused_decode.py) over the γ-mask's
        tile list, widening the skippable scope to every projection — the
        speculative window's up projection stays dense (the union is only
        known after it runs), so its scope is unchanged.

        MoE: the dense scope covers ALL experts (× n_experts) — routing is
        itself structured activation sparsity, so the top-k gather is part
        of the measured density (the family reports density =
        activated/total experts × within-expert density), and
        ``weight_io_bytes_per_step`` = density × this dense-all-experts
        figure is the activated-expert bytes actually read."""
        itemsize = jnp.dtype(self.cfg.compute_dtype).itemsize
        proj = self.cfg.d_ff * self.cfg.d_model * itemsize
        proj *= max(1, self.cfg.n_experts)
        n_all = 3 if self.cfg.ffn_kind == "glu" else 2
        if self.predictor is not None:
            return self.cfg.n_layers * n_all * proj
        if self.fast_kernels and not self.spec:
            return self.cfg.n_layers * n_all * proj
        return self.cfg.n_layers * proj

    def weight_io_bytes_per_step(self, per_device: bool = True) -> float:
        """Mean FFN weight bytes actually READ per (active slot, step) over
        the mode's skippable projections (``_mode_ffn_bytes``). With a mesh
        the default is the PER-DEVICE figure: TP shards the d_ff axis of
        exactly the tiles the sparsity machinery masks, so each device
        reads measured_density x dense_bytes / TP — the multiplicative
        sparsity x 1/TP shrink of the memory-bound decode step. The
        divisor is ``ffn_tp``, NOT the raw mesh TP: when d_ff does not
        divide the model axis the guard replicated the FFN weights and
        every device really reads them whole. per_device=False reports the
        mesh-wide total (== the single-device engine's figure at equal
        density)."""
        dens = 1.0 if not self._dens_n else self._dens_sum / self._dens_n
        total = dens * self._mode_ffn_bytes()
        return total / self.ffn_tp if per_device else total

    def predictor_density(self) -> Optional[float]:
        """Mean fraction of FFN weight tiles gathered per (active slot,
        step, layer) in predictor mode — the up+down weight-I/O actually
        paid. None outside predictor mode or before any measured step."""
        if self.predictor is None or not self._dens_n:
            return None
        return self._dens_sum / self._dens_n

    def predictor_recall(self) -> Optional[float]:
        """Realized recall, measured in-graph across every served token:
        1 − (active neurons the predictor's gathered tiles missed) /
        (active neurons). A miss is a correctness event — at recall 1.0 the
        predictor-mode stream is the dense greedy stream. None when recall
        was never measured: outside predictor mode, with
        ``predictor_telemetry=False`` (the in-graph probe reads 0 — a fake
        1.0 would hide that nothing was checked), or before any decode
        step."""
        if (self.predictor is None or not self.predictor_telemetry
                or not self._dens_n):
            return None
        if not self._pred_active:
            return 1.0  # measured: no neuron fired, so none was missed
        return 1.0 - self._pred_miss / self._pred_active

    def s_agg_window(self) -> Optional[float]:
        """Measured mean aggregated sparsity per verify window (speculative
        mode): 1 − mean fraction of FFN units active anywhere in a γ-window.
        None outside speculative mode or before any verify window ran."""
        if not self.spec or not self._dens_n:
            return None
        return self.weight_io_saved()

    def tile_activity_rate(self) -> Optional[float]:
        """Mean fraction of d_ff tiles with any live activation, per (active
        slot, step) — what a tile-gathered down-projection would load.
        None before any measured step."""
        if not self._dens_n:
            return None
        return self._tiles_sum / self._dens_n

    def expert_io_fraction(self) -> Optional[float]:
        """Fraction of expert FFN weights a token's routing activates:
        top_k / n_experts — the coarse-grained layer of the activated-
        expert byte accounting (``weight_io_bytes_per_step`` multiplies it
        by the measured within-expert density via the family's density
        telemetry). Exact under drop-free capacity (every token reads
        exactly its top-k experts' tiles; dropped slots only read less, so
        this is the upper bound actually provisioned for). None for
        non-MoE families."""
        if not self.cfg.n_experts:
            return None
        return self.cfg.top_k / self.cfg.n_experts

    def prefix_hit_rate(self) -> float:
        """Fraction of admitted prompt tokens served from the prefix cache
        (their prefill — compute AND KV writes — was skipped entirely).
        0.0 when the cache is off or nothing was admitted yet."""
        s = self.scheduler
        if not s.prefill_tokens_total:
            return 0.0
        return s.prefill_tokens_saved / s.prefill_tokens_total

    def prefill_tokens_saved(self) -> int:
        """Total prompt tokens whose prefill was skipped via cached prefix
        blocks, across every admitted request."""
        return self.scheduler.prefill_tokens_saved

    def metrics_snapshot(self) -> Dict[str, float]:
        """Every scalar engine metric that is currently AVAILABLE (the
        None-valued ones — wrong mode, telemetry off, nothing measured yet
        — are omitted, per the convention above). The /statusz endpoint,
        launch/serve.py's final report, and tests consume this instead of
        probing helpers one by one."""
        out = {
            "steps": float(self.t),
            "weight_io_saved": self.weight_io_saved(),
            "weight_io_bytes_per_step": self.weight_io_bytes_per_step(),
            "tile_activity_rate": self.tile_activity_rate(),
            "prefix_hit_rate": self.prefix_hit_rate(),
            "prefill_tokens_saved": float(self.prefill_tokens_saved()),
            "predictor_density": self.predictor_density(),
            "predictor_recall": self.predictor_recall(),
            "s_agg_window": self.s_agg_window(),
            "expert_io_fraction": self.expert_io_fraction(),
        }
        return {k: v for k, v in out.items() if v is not None}


# ---------------------------------------------------------------------------
# legacy single-batch path (compatibility: generate()/score() callers)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_len: int = 256,
                 track_sparsity: bool = False):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.fam = registry.get_family(cfg)
        self.track = track_sparsity
        self._decode_jit = jax.jit(
            lambda p, c, t, pos: self.fam.model_decode(p, c, t, pos, cfg))

    # -- basic API ----------------------------------------------------------
    def prefill(self, batch: Dict[str, jnp.ndarray]):
        return self.fam.model_prefill(self.params, batch, self.cfg, self.max_len)

    def decode(self, cache, token, pos, ffn_masks=None, stats=None):
        if (stats is not None and stats.active) or ffn_masks is not None:
            kw = {}
            if ffn_masks is not None:
                kw["ffn_masks"] = ffn_masks
            return self.fam.model_decode(self.params, cache, token, pos,
                                         self.cfg, stats=stats, **kw)
        return self._decode_jit(self.params, cache, token, pos)

    # -- generation with the paper's machinery ------------------------------
    def generate(self, batch: Dict[str, jnp.ndarray], max_new: int,
                 reuse_window: int = 0) -> GenerationResult:
        """Greedy generation. reuse_window=γ enables the paper's Fig. 7c
        strategy: between mask refreshes, only FFN rows already loaded in
        the current window participate (no new weight I/O)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        offset = sp.prompt_token_offset(self.fam, cfg)
        last, cache = self.prefill(batch)
        out: List[np.ndarray] = []
        lps: List[np.ndarray] = []
        tracker = (AggregatedTracker(cfg.n_layers, cfg.d_ff)
                   if self.track and cfg.d_ff else None)
        site_acc: Dict[str, List[float]] = {}
        masks = None

        nxt = jnp.argmax(last[:, : cfg.vocab_size], -1).astype(jnp.int32)
        for step in range(max_new):
            out.append(np.asarray(nxt))
            lp = jax.nn.log_softmax(last[:, : cfg.vocab_size].astype(jnp.float32))
            lps.append(np.asarray(jnp.take_along_axis(lp, nxt[:, None], 1)[:, 0]))
            pos = jnp.full((b,), offset + s + step, jnp.int32)

            need_stats = self.track or (
                reuse_window > 0 and step % max(1, reuse_window) == 0)
            if need_stats:
                stats = cm.StatsCollector(True)
                logits, cache = self.decode(cache, nxt, pos, stats=stats)
                step_masks = _collect_down_act(stats, cfg)
                if tracker is not None and step_masks is not None:
                    tracker.update(step_masks)
                for k, v in stats.stats.items():
                    if k.endswith(("down_in", "up_in", "qkv_in")):
                        site_acc.setdefault(k.split("/")[-1], []).append(float(v))
                if reuse_window > 0 and step_masks is not None:
                    masks = jnp.asarray(step_masks)
            else:
                logits, cache = self.decode(cache, nxt, pos, ffn_masks=masks)
            last = logits
            nxt = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)

        sites = {k: float(np.mean(v)) for k, v in site_acc.items()}
        return GenerationResult(tokens=np.stack(out, 1),
                                logprobs=np.stack(lps, 1),
                                site_sparsity=sites, aggregated=tracker,
                                steps=max_new)

    def score(self, batch: Dict[str, jnp.ndarray]) -> float:
        """Mean NLL of batch['tokens'] (perplexity = exp(score))."""
        from repro.train.step import lm_loss
        loss, _ = lm_loss(self.params, batch, self.cfg)
        return float(loss)


def _collect_down_act(stats: cm.StatsCollector, cfg: ModelConfig):
    masks = []
    for i in range(cfg.n_layers):
        key = f"layer{i}/down_act"
        if key in stats.stats:
            masks.append(np.asarray(stats.stats[key]))
    return np.stack(masks) if masks else None
