"""Batched sampling head for the continuous-batching engine.

One jitted computation serves every request in the slot batch, whatever its
sampling configuration: temperature / top-k / top-p arrive as per-slot
ARRAYS (never as static python values), so the decode / verify / prefill
executables compile ONCE and a greedy request can sit next to a
temperature-1.2 top-p-0.9 request in the same step. ``temperature == 0``
rows take a dedicated greedy branch computed with exactly the formula the
engine always used (argmax + full-softmax logprob), so all-greedy traffic
through the sampling head is byte-identical to the historical greedy path.

Determinism (the serving contract)
----------------------------------
Every request owns a PRNG key derived from ``(seed, request fingerprint)``
— see ``request_prng_key``. The fingerprint hashes the prompt tokens and
the distribution-shaping params (temperature / top-k / top-p), NOT the
request uid, slot index, admission order, or ``max_new``:

* the same seeded request replays the same stream regardless of
  co-scheduled traffic (slot assignment and admission order do not touch
  the key), across engine restarts and processes;
* extending ``max_new`` extends the stream instead of reshuffling it (the
  shorter stream is a prefix of the longer one).

The g-th GENERATED token of a request (g = 0 is the token seeded from the
prompt's last logits) is sampled with ``fold_in(request_key, g)`` — the
"key schedule". Speculative mode samples the verify window's position j
with the key of generated index ``len(out) + j``, and the draft proposes
with the SAME schedule: acceptance keeps a proposal only while it equals
the target's own sample at that position (``scheduler.record_spec``), so
every emitted token is exactly the target's scheduled sample — the
sampled stream is identical to the autoregressive sampled stream, for ANY
draft, and the greedy-acceptance rule is recovered at temperature 0. (This
key-coupled acceptance trades a slightly lower accept rate for imperfect
drafts than ratio-test rejection sampling, in exchange for draft-invariant,
replayable streams — the property the serving tests pin.)
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# temperature floor for the sampling branch: rows at/below 0 take the greedy
# branch, so this only guards the discarded lane against inf/nan
_MIN_TEMP = 1e-6


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration.

    temperature: 0 (default) = greedy decoding — byte-identical to the
        engine's historical greedy path. > 0 samples from the (filtered,
        renormalized) softmax of logits / temperature.
    top_k: keep only the k highest-probability tokens (0 = off).
    top_p: nucleus sampling — keep the smallest prefix of the
        probability-sorted vocabulary whose cumulative mass reaches top_p
        (1.0 = off). Composes with top_k (intersection of both supports).
    seed: PRNG seed for this request's key schedule. None uses the
        engine's ``base_seed`` — identical unseeded requests then replay
        identical streams (full determinism is a feature of this repo;
        pass a fresh seed per request for varied completions).
    stop: stop sequences, each a tuple of token ids. Generation halts as
        soon as the produced stream ends with any of them (the stop tokens
        are included in the output); ``RequestResult.finish_reason``
        becomes "stop".
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: Optional[int] = None
    stop: Tuple[Tuple[int, ...], ...] = ()

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        # normalize stop to hashable nested tuples (callers pass lists)
        object.__setattr__(self, "stop",
                           tuple(tuple(int(t) for t in s) for s in self.stop))

    @property
    def is_greedy(self) -> bool:
        return self.temperature <= 0.0


GREEDY = SamplingParams()


# ---------------------------------------------------------------------------
# request key schedule (host side)


def request_fingerprint(tokens, sp: SamplingParams) -> int:
    """Stable 64-bit fingerprint of WHAT is being sampled: the prompt and
    the distribution-shaping params. Deliberately excludes uid / slot /
    admission order (replay must not depend on co-scheduled traffic),
    ``max_new`` (a longer budget extends the stream instead of reshuffling
    it) and ``stop`` (stopping truncates, it does not change the
    distribution). blake2b, not ``hash()`` — python's is salted per
    process, which would break restart determinism."""
    h = hashlib.blake2b(digest_size=8)
    h.update(np.ascontiguousarray(np.asarray(tokens, np.int32)).tobytes())
    h.update(np.float64(sp.temperature).tobytes())
    h.update(np.int64(sp.top_k).tobytes())
    h.update(np.float64(sp.top_p).tobytes())
    return int.from_bytes(h.digest(), "little")


def request_prng_key(tokens, sp: SamplingParams,
                     base_seed: int = 0) -> np.ndarray:
    """The request's root PRNG key: PRNGKey(seed) folded with the request
    fingerprint (two 31-bit folds — fold_in data must fit an int32).
    Returns a host (2,) uint32 array the scheduler stores per slot."""
    seed = sp.seed if sp.seed is not None else base_seed
    fp = request_fingerprint(tokens, sp)
    key = jax.random.PRNGKey(int(seed))
    key = jax.random.fold_in(key, fp & 0x7FFFFFFF)
    key = jax.random.fold_in(key, (fp >> 31) & 0x7FFFFFFF)
    return np.asarray(key, np.uint32)


# ---------------------------------------------------------------------------
# in-graph key derivation


def position_keys(keys, gen):
    """Per-slot key for one generated index. keys (B, 2) uint32 request
    root keys; gen (B,) int32 generated-token indices -> (B, 2)."""
    return jax.vmap(jax.random.fold_in)(keys, gen)


def window_keys(keys, gen0, W: int):
    """Keys for a W-token verify window: position j of slot b gets the key
    of generated index gen0[b] + j. Returns (B, W, 2)."""
    offs = jnp.arange(W, dtype=gen0.dtype)

    def per_slot(k, g0):
        return jax.vmap(lambda j: jax.random.fold_in(k, g0 + j))(offs)

    return jax.vmap(per_slot)(keys, gen0)


# ---------------------------------------------------------------------------
# the jitted sampling head


def filter_logits(logits, top_k, top_p, temperature):
    """Temperature-scale then mask logits outside the top-k / top-p
    support with -inf. logits (B, V) f32; per-row top_k (B,) int32
    (0 = off), top_p (B,) f32, temperature (B,) f32. The resulting rows
    renormalize over the surviving support (log_softmax of the output).

    Ties at the k-th / nucleus-boundary value keep every tied token (the
    support can only grow, never lose the argmax)."""
    V = logits.shape[-1]
    t = jnp.maximum(temperature, _MIN_TEMP)[:, None]
    scaled = logits / t
    svals = jnp.sort(scaled, axis=-1)[:, ::-1]  # descending
    # top-k: threshold at the k-th largest value (k=0 -> keep everything)
    k = jnp.clip(jnp.where(top_k > 0, top_k, V), 1, V)
    kth = jnp.take_along_axis(svals, (k - 1)[:, None], axis=-1)
    keep_k = scaled >= kth
    # top-p: smallest sorted prefix whose cumulative mass reaches top_p —
    # keep positions whose PRECEDING cumulative mass is < top_p (the first
    # position always survives, so the support is never empty)
    probs = jax.nn.softmax(svals, axis=-1)
    csum = jnp.cumsum(probs, axis=-1)
    n_keep = jnp.sum((csum - probs) < top_p[:, None], axis=-1)
    # top_p >= 1 must be EXACTLY off: f32 cumsum saturates at 1.0 once the
    # head holds all the mass, which would silently drop underflowed tail
    # tokens from the support
    n_keep = jnp.where(top_p >= 1.0, V, n_keep)
    pth = jnp.take_along_axis(svals, (n_keep - 1)[:, None], axis=-1)
    keep_p = scaled >= pth
    return jnp.where(keep_k & keep_p, scaled, -jnp.inf)


def _sample_rows(logits, vocab: int, temperature, top_k, top_p, keys):
    """(B, vocab_p) logits -> (next (B,), logprob (B,)). The greedy branch
    is bit-for-bit the engine's historical greedy computation; sampled rows
    report the logprob under the FILTERED, renormalized distribution."""
    lv = logits[..., :vocab].astype(jnp.float32)
    greedy_nxt = jnp.argmax(lv, axis=-1).astype(jnp.int32)
    greedy_lp = jnp.take_along_axis(jax.nn.log_softmax(lv, axis=-1),
                                    greedy_nxt[..., None], -1)[..., 0]
    filt = filter_logits(lv, top_k, top_p, temperature)
    samp = jax.vmap(jax.random.categorical)(keys, filt).astype(jnp.int32)
    samp_lp = jnp.take_along_axis(jax.nn.log_softmax(filt, axis=-1),
                                  samp[..., None], -1)[..., 0]
    g = temperature <= 0.0
    return jnp.where(g, greedy_nxt, samp), jnp.where(g, greedy_lp, samp_lp)


def sample_head(logits, vocab: int, temperature, top_k, top_p, keys):
    """The engine's one jitted sampling head. logits (..., vocab_p) with
    any leading batch shape (slot batch, or slot x window); temperature /
    top_k / top_p must carry that same batch shape (callers broadcast the
    per-slot arrays over a window axis); keys (..., 2) uint32 per-position
    PRNG keys from ``position_keys`` / ``window_keys``.

    Everything is a traced array, so one trace serves every mix of greedy
    and sampled requests — the decode step never retraces on sampling
    config. Returns (next_token (...,) int32, logprob (...,) f32)."""
    batch = logits.shape[:-1]
    nxt, lp = _sample_rows(logits.reshape((-1,) + logits.shape[-1:]), vocab,
                           jnp.asarray(temperature).reshape(-1),
                           jnp.asarray(top_k).reshape(-1),
                           jnp.asarray(top_p).reshape(-1),
                           keys.reshape(-1, 2))
    return nxt.reshape(batch), lp.reshape(batch)
