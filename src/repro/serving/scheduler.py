"""Continuous-batching scheduler: request queue, refcounted block allocator,
prompt-prefix cache, and slot bookkeeping for the paged KV cache
(models/common.py).

Pure host-side logic — no jax — so admission/retirement policy is unit-
testable without a model. The engine (serving/engine.py) owns the device
state (page pool, γ-window masks) and calls into this scheduler every step:

  1. retire slots whose requests finished, dropping their block references;
  2. admit queued requests into free slots while blocks last, highest
     EFFECTIVE priority first (priority + waiting-time aging), skipping
     entries whose block demand cannot currently be met — bounded by the
     aging barrier — and preempting strictly-lower-priority slots when the
     candidate cannot fit otherwise;
  3. advance chunked prefill for admitted-but-not-yet-decoding slots;
  4. build the fixed-shape slot batch the jitted decode step consumes.

A request is admitted only if its *entire* lifetime block need fits now
(ceil((prompt + max_new) / block_size)), so decode never stalls mid-flight
on allocation failure.

Admission state machine (one request's lifecycle)
-------------------------------------------------

    submit()            queued      validated against max_blocks_per_seq AND
       |                            the pool itself (a request the pool could
       v                            never hold is rejected, not starved)
    admit()             prefilling  highest effective priority first
       |                            (priority, then FIFO; queued entries age
       |                            one class per ``aging_steps`` waited); a
       |                            free slot + the full lifetime block need,
       |                            with any cached full-block prompt prefix
       |                            mapped from the prefix trie (refcount++,
       |                            prefilled jumps to the cached length) and
       |                            only the cold suffix left to compute. An
       |                            entry that does not fit is SKIPPED (not a
       |                            hard stop) until it has aged, after which
       |                            it becomes a barrier nothing passes.
       v
    record_prefill()    prefilling  one fixed-shape chunk per engine step,
       | (xN chunks)                interleaved with the decode step, until
       |                            ``prefilled == prefill_len``; whole-
       |                            prompt mode (prefill_chunk=0) collapses
       |                            this to a single jump
       v
    seed()              decoding    first generated token recorded from the
       |                            final chunk's logits; the prompt's full
       |                            blocks are registered in the prefix trie
       v
    record()/record_spec()  ...     one token (or one accepted window) per
       |                            step; ``age`` drives the γ-refresh phase
       |
       |   preempt()    queued      under slot/pool pressure a strictly
       |                            higher-priority admission may EVICT the
       |                            slot TO RECOMPUTE: its written full
       |                            blocks are parked in the prefix trie,
       |                            every block reference is dropped, and
       |                            the request re-enters the queue carrying
       |                            its generated prefix. Re-admission maps
       |                            the parked blocks back from the trie and
       |                            chunk-prefills only the cold suffix of
       |                            prompt+generated — f32 greedy streams
       |                            are byte-identical across the cycle.
       v
    retire_finished()   retired     block refcounts dropped — blocks shared
                                    with the trie or other slots survive;
                                    RequestResult lands in ``results``
"""
from __future__ import annotations

import dataclasses
import time
from typing import TYPE_CHECKING, Dict, List, Optional, Tuple

import numpy as np

from repro.models.common import SCRATCH_BLOCK

if TYPE_CHECKING:  # scheduler stays host-only; sampling.py pulls in jax
    from repro.serving.sampling import SamplingParams


@dataclasses.dataclass
class Request:
    uid: int
    tokens: np.ndarray  # (s,) int32 prompt
    max_new: int
    # γ-window weight reuse (paper Fig. 7c): refresh the FFN mask every γ
    # decoded tokens; 0 = dense (refresh every step, mask never binds).
    reuse_window: int = 0
    # per-request sampling config (None = greedy) and the request's root
    # PRNG key ((2,) uint32, sampling.request_prng_key) — derived from
    # (seed, request fingerprint), never from uid/slot/admission order
    sampling: Optional["SamplingParams"] = None
    key: Optional[np.ndarray] = None
    # scheduling class: higher admits first and may preempt strictly lower.
    # 0 (default) keeps today's FIFO behavior for homogeneous traffic.
    priority: int = 0
    # TTFT service-level objective in milliseconds (None = no SLO):
    # informational — the scheduler never drops a request for missing it,
    # but RequestResult.slo_met reports the outcome per request
    slo_ms: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])


@dataclasses.dataclass
class RequestResult:
    uid: int
    tokens: np.ndarray  # (max_new,) int32
    logprobs: np.ndarray  # (max_new,) f32
    prompt_len: int
    admitted_step: int
    finished_step: int
    # speculative-decoding accounting (zero when served autoregressively)
    draft_proposed: int = 0  # draft tokens submitted for verification
    draft_accepted: int = 0  # of those, accepted by the target
    # verify windows this request cost; prefill is NOT included here (the
    # reporting layer, spec_decode.spec_metrics, adds it as +1)
    target_calls: int = 0
    # predictor-mode telemetry (zero-information defaults otherwise)
    predicted_density: float = 1.0  # mean fraction of FFN weight tiles read
    realized_recall: float = 1.0    # 1 - misses/actives, measured in-graph
    pred_misses: int = 0            # masked-out-but-active neurons (count)
    # prompt tokens served from the prefix cache (prefill skipped for them)
    cached_prompt_tokens: int = 0
    # mean fraction of the mode's skippable FFN weights this request's steps
    # actually read (1.0 = dense) — the per-request half of the engine's
    # weight_io_bytes_per_step() per-device accounting
    ffn_read_fraction: float = 1.0
    # why generation ended: "length" (max_new budget), "stop" (a stop
    # sequence matched) or "cancelled" (client abandoned the request)
    finish_reason: str = "length"
    # -- SLO-aware scheduling outcomes --
    priority: int = 0
    slo_ms: Optional[float] = None
    # times this request was preempted (evicted to recompute and requeued)
    preemptions: int = 0
    # TTFT SLO verdict: None when the request carried no slo_ms, else
    # whether wall-clock submit→first-token beat it
    slo_met: Optional[bool] = None
    # engine-step stamps for deterministic (wall-clock-free) latency
    # accounting: TTFT in steps = first_token_step - submit_step
    submit_step: int = -1
    first_token_step: int = -1

    @property
    def accept_rate(self) -> float:
        """Measured α: accepted / proposed drafts (NOT a tokens-per-call
        ratio — see spec_decode.spec_metrics)."""
        return self.draft_accepted / max(1, self.draft_proposed)


@dataclasses.dataclass
class _QueueEntry:
    """One queued admission candidate: a fresh request, or a preempted
    slot re-entering with its generated prefix (``resume`` carries the
    live _Slot so no progress is lost)."""
    req: Request
    seq: int            # submission order — the FIFO tiebreak
    submit_step: int    # engine step when (re)queued — drives aging
    t_submit: float     # wall clock when first submitted (SLO accounting)
    resume: Optional["_Slot"] = None


class RequestQueue:
    """Priority admission queue with aging.

    Order = (effective priority DESC, submission seq ASC) where effective
    priority is ``req.priority`` plus one class per ``aging_steps`` engine
    steps waited (aging_steps=0 disables aging → raw priority, then FIFO).
    With homogeneous priorities this degenerates to exactly the historical
    FIFO. Starvation is bounded two ways: a waiting low-priority request
    ages into higher classes, and once an entry has waited ``aging_steps``
    without fitting it becomes an admission BARRIER (Scheduler.admit stops
    skipping past it)."""

    def __init__(self):
        self._q: List[_QueueEntry] = []
        self._seq = 0

    @staticmethod
    def effective_priority(entry: _QueueEntry, step: int,
                           aging_steps: int) -> int:
        aged = (max(0, step - entry.submit_step) // aging_steps
                if aging_steps > 0 else 0)
        return entry.req.priority + aged

    def ordered(self, step: int = 0,
                aging_steps: int = 0) -> List[_QueueEntry]:
        """Entries in admission order for this step."""
        return sorted(self._q, key=lambda e: (
            -self.effective_priority(e, step, aging_steps), e.seq))

    def push(self, req: Request, step: int = 0,
             resume: Optional["_Slot"] = None,
             t_submit: Optional[float] = None) -> _QueueEntry:
        entry = _QueueEntry(req, self._seq, step,
                            time.monotonic() if t_submit is None
                            else t_submit, resume)
        self._seq += 1
        self._q.append(entry)
        return entry

    def peek(self) -> Optional[Request]:
        """Head of the admission order (raw priority, no aging)."""
        return self.ordered()[0].req if self._q else None

    def pop(self) -> Request:
        entry = self.ordered()[0]
        self._q.remove(entry)
        return entry.req

    def remove_entry(self, entry: _QueueEntry) -> None:
        self._q.remove(entry)

    def uids(self) -> List[int]:
        return [e.req.uid for e in self.ordered()]

    def remove(self, uid: int) -> Optional[_QueueEntry]:
        """Withdraw a queued entry (cancellation before (re)admission)."""
        for e in self._q:
            if e.req.uid == uid:
                self._q.remove(e)
                return e
        return None

    def __len__(self) -> int:
        return len(self._q)


class BlockAllocator:
    """Refcounted free-list over the shared page pool. Block 0
    (SCRATCH_BLOCK) is never handed out — idle slots and table padding point
    at it.

    ``alloc`` hands a block out with one reference; requests sharing a
    cached prompt prefix and the prefix trie each take an extra reference
    (``ref``). ``free`` DROPS one reference and returns the block to the
    free list only when the last one is gone, so a shared prefix block
    survives the request that prefilled it. Double-frees and negative
    refcounts trip assertions instead of corrupting the pool.
    """

    def __init__(self, n_blocks: int):
        self.n_blocks = n_blocks
        self._free: List[int] = list(range(n_blocks - 1, SCRATCH_BLOCK, -1))
        self._refs: Dict[int, int] = {}

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def allocated(self) -> int:
        """Distinct blocks currently held (any refcount > 0)."""
        return len(self._refs)

    def refcount(self, block: int) -> int:
        return self._refs.get(block, 0)

    def alloc(self, n: int) -> Optional[List[int]]:
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            self._refs[b] = 1
        return out

    def ref(self, blocks: List[int]) -> None:
        """Take an extra reference on already-allocated blocks."""
        for b in blocks:
            assert self._refs.get(b, 0) > 0, f"ref of unallocated block {b}"
            self._refs[b] += 1

    def free(self, blocks: List[int]) -> None:
        for b in blocks:
            assert b != SCRATCH_BLOCK
            n = self._refs.get(b, 0)
            assert n > 0, f"double free of block {b}"
            if n == 1:
                del self._refs[b]
                self._free.append(b)
            else:
                self._refs[b] = n - 1


class _TrieNode:
    __slots__ = ("key", "block", "children", "parent", "last_used")

    def __init__(self, key, block: int, parent: Optional["_TrieNode"]):
        self.key = key
        self.block = block
        self.children: Dict[tuple, "_TrieNode"] = {}
        self.parent = parent
        self.last_used = 0


class PrefixCache:
    """Prompt-prefix → KV-block trie, keyed on token-aligned FULL blocks.

    Each node caches one full block of prompt K/V, keyed by that block's
    ``block_size`` tokens at depth = block index, so a root path spells a
    prompt prefix. Full prompt blocks are immutable once prefilled (decode
    writes start at ``prompt_len``, inside the first partial block), which
    is what makes them shareable. Nodes hold their own allocator reference:
    cached blocks survive the requests that wrote them and are reclaimed
    LRU-leaf-first (``evict``) only under pool pressure — and only when no
    live request still shares them (refcount == 1).
    """

    def __init__(self):
        self._children: Dict[tuple, _TrieNode] = {}
        self._clock = 0
        self.lookups = 0
        self.hits = 0

    @staticmethod
    def _keys(tokens, n_full: int, block_size: int) -> List[tuple]:
        toks = np.asarray(tokens)
        return [tuple(int(t) for t in toks[i * block_size:(i + 1) * block_size])
                for i in range(n_full)]

    @staticmethod
    def _shareable_blocks(prompt_len: int, block_size: int) -> int:
        """Full blocks of a prompt that may be cached/matched. Capped one
        token short of the prompt so at least one token always prefills
        cold — the final chunk's logits seed the first generated token."""
        return (prompt_len - 1) // block_size

    def lookup(self, tokens, block_size: int) -> List[int]:
        """Longest cached full-block prefix of ``tokens`` (strictly shorter
        than the prompt). Returns block ids in sequence order; the caller
        takes its own reference on them before using or evicting."""
        self._clock += 1
        self.lookups += 1
        children = self._children
        blocks: List[int] = []
        for key in self._keys(tokens, self._shareable_blocks(len(tokens),
                                                             block_size),
                              block_size):
            node = children.get(key)
            if node is None:
                break
            node.last_used = self._clock
            blocks.append(node.block)
            children = node.children
        if blocks:
            self.hits += 1
        return blocks

    def insert(self, tokens, blocks: List[int], block_size: int,
               allocator: BlockAllocator) -> None:
        """Register a fully prefilled prompt's full blocks. Insert-if-absent:
        an existing node keeps its block (two identical prompts admitted
        concurrently both prefill cold; the loser's copy stays private and
        is freed at retirement). New nodes take a trie reference."""
        self._clock += 1
        children = self._children
        parent: Optional[_TrieNode] = None
        keys = self._keys(tokens, self._shareable_blocks(len(tokens),
                                                         block_size),
                          block_size)
        for i, key in enumerate(keys):
            node = children.get(key)
            if node is None:
                node = _TrieNode(key, blocks[i], parent)
                allocator.ref([node.block])
                children[key] = node
            node.last_used = self._clock
            parent = node
            children = node.children

    def evict(self, allocator: BlockAllocator, n_needed: int) -> int:
        """Return up to ``n_needed`` cached blocks to the pool, dropping
        LRU leaves no live request shares. Leaves-first keeps every
        surviving root path dense (a partial path would be unmatchable)."""
        freed = 0
        while freed < n_needed:
            leaf = self._lru_unshared_leaf(allocator)
            if leaf is None:
                break
            siblings = (leaf.parent.children if leaf.parent is not None
                        else self._children)
            del siblings[leaf.key]
            allocator.free([leaf.block])
            freed += 1
        return freed

    def _iter_nodes(self):
        stack = list(self._children.values())
        while stack:
            node = stack.pop()
            yield node
            stack.extend(node.children.values())

    def _lru_unshared_leaf(self, allocator: BlockAllocator):
        best = None
        for node in self._iter_nodes():
            if node.children or allocator.refcount(node.block) != 1:
                continue  # interior, or a live request still shares it
            if best is None or node.last_used < best.last_used:
                best = node
        return best

    def blocks(self) -> List[int]:
        """Every block id the trie currently holds a reference on."""
        return [n.block for n in self._iter_nodes()]

    def __len__(self) -> int:
        return len(self.blocks())


@dataclasses.dataclass
class _Slot:
    request: Request
    blocks: List[int]
    admitted_step: int
    # generated tokens whose K/V is written (drives the γ phase and
    # next_pos). Maintained as len(out) - 1 while decoding — seed() pins
    # that equality so a preempted slot resumes at the exact γ phase and
    # write position it would have reached unpreempted.
    age: int = 0
    # prefill tokens whose K/V is already in the pool: starts at the cached
    # prefix length, advances chunk by chunk, reaches prefill_len at seed()
    prefilled: int = 0
    cached_tokens: int = 0  # of those, mapped from the prefix cache
    warm: bool = False  # γ-mask seeded from the prefill activity harvest
    out: List[int] = dataclasses.field(default_factory=list)
    lps: List[float] = dataclasses.field(default_factory=list)
    # speculative-decoding bookkeeping
    draft_proposed: int = 0
    draft_accepted: int = 0
    target_calls: int = 0
    # predictor-mode accumulators (per decoded token)
    pred_dens_sum: float = 0.0
    pred_steps: int = 0
    pred_active: int = 0
    pred_miss: int = 0
    # per-step FFN weight-read fraction (all modes; engine._account feeds it)
    io_dens_sum: float = 0.0
    io_steps: int = 0
    # early-finish marker ("stop" / "cancelled"); None = run to max_new
    finish: Optional[str] = None
    # -- SLO-aware scheduling state --
    preemptions: int = 0
    # set at preempt(): the prompt + everything generated so far, frozen as
    # the token sequence the NEXT admission must prefill (via the trie's
    # parked blocks + a chunked prefill of the cold tail). None = never
    # preempted: prefill covers just the prompt.
    resume_tokens: Optional[np.ndarray] = None
    submit_step: int = -1       # engine step of the original submit()
    t_submit: float = 0.0       # wall clock of the original submit()
    first_token_step: int = -1  # engine step of the first generated token
    t_first: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.finish is not None or len(self.out) >= self.request.max_new

    @property
    def prefill_tokens(self) -> np.ndarray:
        """Token sequence the current prefill pass must cover: the prompt,
        or prompt + generated prefix when resuming from a preemption."""
        return (self.resume_tokens if self.resume_tokens is not None
                else self.request.tokens)

    @property
    def prefill_len(self) -> int:
        return int(self.prefill_tokens.shape[0])

    @property
    def prefilling(self) -> bool:
        return self.prefilled < self.prefill_len

    @property
    def next_pos(self) -> int:
        """Write position of the current token (prompt occupies 0..s-1)."""
        return self.request.prompt_len + self.age

    @property
    def remaining(self) -> int:
        return self.request.max_new - len(self.out)


class Scheduler:
    """Admission/retirement policy over the slot batch — see the module
    docstring for the request state machine this drives."""

    def __init__(self, n_slots: int, n_blocks: int, block_size: int,
                 max_blocks_per_seq: int, prefix_cache: bool = False,
                 obs=None, preemption: bool = True, aging_steps: int = 32):
        self.n_slots = n_slots
        self.block_size = block_size
        self.max_blocks_per_seq = max_blocks_per_seq
        self.allocator = BlockAllocator(n_blocks)
        self.queue = RequestQueue()
        self.slots: List[Optional[_Slot]] = [None] * n_slots
        self.results: Dict[int, RequestResult] = {}
        self.prefix: Optional[PrefixCache] = (PrefixCache() if prefix_cache
                                              else None)
        # SLO-aware scheduling knobs (see EngineConfig for semantics):
        # preemption lets admission evict strictly-lower-priority slots;
        # aging_steps bounds both starvation and admission skip-ahead
        self.preemption = preemption
        self.aging_steps = aging_steps
        self.preemption_count = 0
        # prompt-token accounting behind the engine's prefix_hit_rate()
        self.prefill_tokens_total = 0
        self.prefill_tokens_saved = 0
        # observability hub (repro.obs.EngineObs or None): per-request span
        # hooks fire at the state transitions below. The scheduler stays
        # host-only / jax-free, and so must the hub — standalone Scheduler
        # unit tests run with obs=None at zero cost.
        self.obs = obs

    # -- lifecycle ----------------------------------------------------------
    def blocks_needed(self, req: Request) -> int:
        return -(-(req.prompt_len + req.max_new) // self.block_size)

    def submit(self, req: Request, step: int = 0) -> None:
        """Validate and enqueue. ``step`` is the engine step of submission:
        it stamps RequestResult.submit_step and starts the aging clock."""
        # reject malformed requests here, before any slot/block state exists:
        # a prefill failure mid-admission would leave a zombie slot behind
        if req.prompt_len == 0:
            raise ValueError(f"request {req.uid}: empty prompt")
        if req.max_new < 1:
            raise ValueError(f"request {req.uid}: max_new must be >= 1")
        need = self.blocks_needed(req)
        if need > self.max_blocks_per_seq:
            raise ValueError(
                f"request {req.uid}: needs {need} blocks > "
                f"max_blocks_per_seq={self.max_blocks_per_seq}")
        # also validate against the pool itself: a request bigger than every
        # allocatable block combined would sit at the head of the FIFO
        # forever (admit() breaks on it, run() sees no progress) — reject it
        # here instead of silently starving it and everything behind it
        if need > self.allocator.n_blocks - 1:
            raise ValueError(
                f"request {req.uid}: needs {need} blocks but the pool holds "
                f"only {self.allocator.n_blocks - 1} allocatable blocks — "
                f"it could never be admitted")
        self.queue.push(req, step)
        if self.obs is not None:  # span starts only for ACCEPTED requests
            self.obs.req_submitted(req.uid, req.prompt_len, req.max_new,
                                   priority=req.priority, slo_ms=req.slo_ms)

    def _result(self, slot: _Slot, step: int) -> RequestResult:
        """Terminal RequestResult from a slot's accumulated state."""
        req = slot.request
        slo_met = None
        if req.slo_ms is not None:
            slo_met = (slot.t_first is not None
                       and (slot.t_first - slot.t_submit) * 1e3 <= req.slo_ms)
        return RequestResult(
            uid=req.uid,
            tokens=np.asarray(slot.out, np.int32),
            logprobs=np.asarray(slot.lps, np.float32),
            prompt_len=req.prompt_len,
            admitted_step=slot.admitted_step,
            finished_step=step,
            draft_proposed=slot.draft_proposed,
            draft_accepted=slot.draft_accepted,
            target_calls=slot.target_calls,
            predicted_density=(slot.pred_dens_sum / slot.pred_steps
                               if slot.pred_steps else 1.0),
            realized_recall=(1.0 - slot.pred_miss / slot.pred_active
                             if slot.pred_active else 1.0),
            pred_misses=slot.pred_miss,
            cached_prompt_tokens=slot.cached_tokens,
            ffn_read_fraction=(slot.io_dens_sum / slot.io_steps
                               if slot.io_steps else 1.0),
            finish_reason=slot.finish or "length",
            priority=req.priority,
            slo_ms=req.slo_ms,
            preemptions=slot.preemptions,
            slo_met=slo_met,
            submit_step=slot.submit_step,
            first_token_step=slot.first_token_step,
        )

    def retire_finished(self, step: int) -> List[int]:
        """Free the blocks of finished slots; returns retired request uids."""
        retired = []
        for i, slot in enumerate(self.slots):
            if slot is not None and slot.done:
                self.allocator.free(slot.blocks)
                self.results[slot.request.uid] = self._result(slot, step)
                if self.obs is not None:
                    self.obs.req_finished(self.results[slot.request.uid])
                retired.append(slot.request.uid)
                self.slots[i] = None
        return retired

    def preempt(self, i: int, step: int) -> None:
        """Evict slot ``i`` to recompute and requeue it with its progress.

        The slot's fully WRITTEN full blocks (prompt + generated prefix K/V)
        are parked in the prefix trie (when enabled) so re-admission maps
        them back with zero prefill; every block reference the slot holds is
        dropped (parked blocks survive on the trie's reference and stay
        reclaimable by LRU eviction if pressure demands); the request
        re-enters the queue carrying the SAME _Slot — output, sampling
        schedule position and γ phase intact. Resume is then an ordinary
        admission whose prefill covers ``prompt + generated`` (the cold
        tail only, under a trie hit), and the final chunk's logits re-derive
        the next token exactly where decode left off: f32 greedy streams
        are byte-identical across the cycle."""
        s = self.slots[i]
        assert s is not None and not s.done, "preempting idle/finished slot"
        resume = np.concatenate(
            [s.request.tokens, np.asarray(s.out, np.int32)]
        ) if s.out else s.request.tokens
        if self.prefix is not None:
            # only positions < written hold valid K/V (the latest generated
            # token's K/V is written when it is FED, not when it is emitted;
            # a mid-prefill slot has written exactly `prefilled`), so park
            # exactly the full blocks below that bound: trie keys are capped
            # one token short of the sequence passed
            written = s.prefilled if s.prefilling else s.next_pos
            self.prefix.insert(resume[:written + 1], s.blocks,
                               self.block_size, self.allocator)
        self.allocator.free(s.blocks)
        s.blocks = []
        s.resume_tokens = resume
        s.preemptions += 1
        s.prefilled = 0
        s.cached_tokens = 0
        s.warm = False
        self.slots[i] = None
        self.preemption_count += 1
        self.queue.push(s.request, step, resume=s, t_submit=s.t_submit)
        if self.obs is not None:
            self.obs.req_preempted(s.request.uid, len(s.out),
                                   priority=s.request.priority)

    def _try_alloc(self, tokens, need: int
                   ) -> Optional[Tuple[List[int], List[int]]]:
        """(cached, cold) blocks for a sequence needing ``need`` total, or
        None without side effects. Cached blocks come pinned (refcount++);
        under pressure, LRU trie blocks nobody shares are evicted first."""
        cached: List[int] = []
        if self.prefix is not None:
            cached = self.prefix.lookup(tokens, self.block_size)
            if cached:
                # pin before any eviction below can consider them
                self.allocator.ref(cached)
        cold = self.allocator.alloc(need - len(cached))
        if cold is None and self.prefix is not None:
            self.prefix.evict(self.allocator, need - len(cached)
                              - self.allocator.available)
            cold = self.allocator.alloc(need - len(cached))
        if cold is None:
            if cached:
                self.allocator.free(cached)  # drop our pins
            return None
        return cached, cold

    def _pick_victim(self, req: Request,
                     protect: frozenset = frozenset()) -> Optional[int]:
        """Slot to preempt so ``req`` can be admitted: strictly lower RAW
        priority only (aging never makes queued work evict running work),
        lowest class first, least progress first (cheapest recompute).
        ``protect`` holds slot indices admitted earlier in the SAME admit()
        call — that order was already committed by effective priority, so
        a later candidate may not churn it back out within the call (an
        aged entry's admission would otherwise be reversed immediately by
        any queued higher-raw-priority entry, re-starving it).
        Returns None when preemption is off, no slot qualifies, or evicting
        every qualifying slot still could not cover the block need (blocks
        shared with other live slots stay allocated — preempting for an
        admission that then fails would churn victims for nothing)."""
        if not self.preemption:
            return None
        victims = [i for i, s in enumerate(self.slots)
                   if s is not None and not s.done and i not in protect
                   and s.request.priority < req.priority]
        if not victims:
            return None
        reclaimable = self.allocator.available + sum(
            sum(1 for b in self.slots[i].blocks
                if self.allocator.refcount(b) == 1)
            for i in victims)
        if self.prefix is not None:
            # unshared trie blocks are reclaimable via _try_alloc's eviction
            reclaimable += sum(
                1 for b in self.prefix.blocks()
                if self.allocator.refcount(b) == 1)
        if reclaimable < self.blocks_needed(req):
            return None
        return min(victims, key=lambda i: (
            self.slots[i].request.priority,
            self.slots[i].prefilled + len(self.slots[i].out)))

    def _try_admit(self, entry: _QueueEntry, step: int,
                   protect: frozenset = frozenset()
                   ) -> Optional[Tuple[int, _Slot]]:
        """Place one queued entry: free slot + blocks, preempting strictly
        lower-priority slots (outside ``protect``) while that is what
        admission is missing. Returns the (slot_index, slot) needing
        prefill, or None."""
        req = entry.req
        need = self.blocks_needed(req)
        tokens = (entry.resume.prefill_tokens if entry.resume is not None
                  else req.tokens)
        while True:
            slot_i = next((i for i, s in enumerate(self.slots)
                           if s is None), None)
            if slot_i is not None:
                got = self._try_alloc(tokens, need)
                if got is not None:
                    cached, cold = got
                    break
            victim = self._pick_victim(req, protect)
            if victim is None:
                return None
            self.preempt(victim, step)
        self.queue.remove_entry(entry)
        n_cached = len(cached) * self.block_size
        if entry.resume is not None:
            slot = entry.resume
            slot.blocks = cached + cold
            slot.prefilled = n_cached
            slot.cached_tokens = n_cached
        else:
            slot = _Slot(request=req, blocks=cached + cold,
                         admitted_step=step, prefilled=n_cached,
                         cached_tokens=n_cached,
                         submit_step=entry.submit_step,
                         t_submit=entry.t_submit)
        self.prefill_tokens_total += slot.prefill_len
        self.prefill_tokens_saved += n_cached
        self.slots[slot_i] = slot
        if self.obs is not None:
            if entry.resume is not None:
                self.obs.req_resumed(req.uid, n_cached)
            else:
                self.obs.req_admitted(req.uid, n_cached)
        return slot_i, slot

    def admit(self, step: int) -> List[Tuple[int, _Slot]]:
        """Fill free slots from the queue while blocks last, highest
        effective priority first (aging promotes waiting entries one class
        per ``aging_steps``; ties admit FIFO). An entry whose block demand
        cannot currently be met is SKIPPED — later entries may admit around
        it — unless it has already waited ``aging_steps``, at which point
        it becomes a hard barrier (the historical head-of-line guarantee,
        now bounded instead of immediate). When the candidate outranks a
        running slot and nothing else fits, admission preempts (see
        ``preempt``) — but never a slot admitted earlier in this same
        call: the call's own effective-priority order is final.

        With a prefix cache, the entry's longest cached full-block prefix
        is mapped from the trie (refcount++ — no prefill, no new blocks)
        and only the cold suffix is allocated; under pool pressure, LRU
        cached prefixes nobody currently shares are evicted first.
        Returns (slot_index, slot) pairs needing (suffix) prefill."""
        admitted = []
        placed = True
        while placed:
            placed = False
            protect = frozenset(i for i, _ in admitted)
            for entry in self.queue.ordered(step, self.aging_steps):
                got = self._try_admit(entry, step, protect)
                if got is not None:
                    admitted.append(got)
                    placed = True
                    break  # queue/slots changed: recompute the order
                if (self.aging_steps > 0
                        and step - entry.submit_step >= self.aging_steps):
                    return admitted  # aged stuck entry: hard barrier
        return admitted

    def cancel(self, uid: int) -> bool:
        """Abandon a request. Queued: withdrawn immediately (an empty
        "cancelled" RequestResult is synthesized so waiters always observe
        a terminal result). Slotted and unfinished: marked finished — the
        next ``retire_finished`` frees its blocks and emits its partial
        output with ``finish_reason="cancelled"``. A PREEMPTED request
        (queued for resume) is withdrawn with the partial output it already
        generated. Returns False if the uid is unknown or already
        finished."""
        entry = self.queue.remove(uid)
        if entry is not None:
            if entry.resume is not None:  # preempted: blocks already freed
                entry.resume.finish = "cancelled"
                self.results[uid] = self._result(entry.resume, -1)
            else:
                self.results[uid] = RequestResult(
                    uid=uid, tokens=np.zeros((0,), np.int32),
                    logprobs=np.zeros((0,), np.float32),
                    prompt_len=entry.req.prompt_len, admitted_step=-1,
                    finished_step=-1, finish_reason="cancelled",
                    priority=entry.req.priority, slo_ms=entry.req.slo_ms,
                    slo_met=(None if entry.req.slo_ms is None else False),
                    submit_step=entry.submit_step)
            if self.obs is not None:  # terminal even without admission
                self.obs.req_finished(self.results[uid])
            return True
        for s in self.slots:
            if s is not None and s.request.uid == uid and not s.done:
                s.finish = "cancelled"
                return True
        return False

    @staticmethod
    def _hits_stop(out: List[int], stop) -> bool:
        return any(s and len(out) >= len(s) and tuple(out[-len(s):]) == s
                   for s in stop)

    def _check_stop(self, slot: _Slot) -> bool:
        """Mark the slot finished if its output now ends with one of the
        request's stop sequences (the stop tokens stay in the output)."""
        sp = slot.request.sampling
        if (slot.finish is None and sp is not None and sp.stop
                and self._hits_stop(slot.out, sp.stop)):
            slot.finish = "stop"
        return slot.finish is not None

    def seed(self, slot: _Slot, token: int, logprob: float,
             step: int = 0) -> None:
        """Record the next generated token (from the prefill logits),
        marking prefill complete and registering the prefilled sequence's
        full blocks in the prefix cache. For a fresh slot this is the FIRST
        token; for a preempted slot resuming, it is the token decode would
        have produced next — either way ``age = len(out) - 1`` afterwards,
        so next_pos and the γ-refresh phase continue exactly."""
        slot.prefilled = slot.prefill_len
        slot.out.append(int(token))
        slot.lps.append(float(logprob))
        slot.age = len(slot.out) - 1
        if slot.t_first is None:  # the span's first token (TTFT edge)
            slot.t_first = time.monotonic()
            slot.first_token_step = step
        if self.obs is not None:
            self.obs.req_tokens(slot.request.uid, 1)
        self._check_stop(slot)
        if self.prefix is not None:
            self.prefix.insert(slot.prefill_tokens, slot.blocks,
                               self.block_size, self.allocator)

    # -- batch assembly -----------------------------------------------------
    def active_indices(self) -> List[int]:
        """Slots currently DECODING (fully prefilled, not finished)."""
        return [i for i, s in enumerate(self.slots)
                if s is not None and not s.done and not s.prefilling]

    def prefill_indices(self) -> List[int]:
        """Slots admitted but still prefilling their (cold) prompt suffix."""
        return [i for i, s in enumerate(self.slots)
                if s is not None and s.prefilling]

    def has_work(self) -> bool:
        return bool(self.active_indices()) or len(self.queue) > 0 or any(
            s is not None for s in self.slots)

    def batch_arrays(self):
        """Fixed-shape arrays for the jitted step. Idle slots point at the
        scratch block / position 0; their outputs are ignored."""
        B, nb = self.n_slots, self.max_blocks_per_seq
        tokens = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        table = np.full((B, nb), SCRATCH_BLOCK, np.int32)
        # idle slots keep their masks: a prefilling slot's row is its warm
        # harvest in progress and must survive interleaved decode steps
        refresh = np.zeros((B,), bool)
        for i in self.active_indices():
            s = self.slots[i]
            tokens[i] = s.out[-1]
            pos[i] = s.next_pos
            table[i, : len(s.blocks)] = s.blocks
            gamma = s.request.reuse_window
            refresh[i] = gamma <= 1 or (s.age % gamma == 0)
            if s.warm and s.age == 0 and gamma > 1:
                # γ-mask already seeded from the prefill activity harvest
                # (engine warm_masks mode): the first window rides it
                # instead of a dense refresh
                refresh[i] = False
        return tokens, pos, table, refresh

    def sampling_arrays(self):
        """Fixed-shape per-slot sampling state for the jitted sampling head:
        (temperature (B,) f32, top_k (B,) i32, top_p (B,) f32, request root
        keys (B, 2) u32, gen (B,) i32). ``gen`` is the slot's next
        generated-token index (len(out) — the key-schedule position), valid
        for both decode (the token sampled this step) and the base index of
        a speculative verify window. Idle/greedy slots read as temperature 0
        → the head's greedy branch; their keys are never consumed."""
        B = self.n_slots
        temps = np.zeros((B,), np.float32)
        top_ks = np.zeros((B,), np.int32)
        top_ps = np.ones((B,), np.float32)
        keys = np.zeros((B, 2), np.uint32)
        gen = np.zeros((B,), np.int32)
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            sp = s.request.sampling
            if sp is not None:
                temps[i] = sp.temperature
                top_ks[i] = sp.top_k
                top_ps[i] = sp.top_p
            if s.request.key is not None:
                keys[i] = s.request.key
            gen[i] = len(s.out)
        return temps, top_ks, top_ps, keys, gen

    def prefill_batch(self, chunk: int, budget: int = 0):
        """Fixed-shape arrays for one chunked-prefill step: the next
        ``chunk`` prefill tokens of every prefilling slot, written at its
        own resume position. Idle/decoding slots get clen 0 (their window
        tokens are scratch-routed in-graph). ``budget`` > 0 caps the TOTAL
        prefill tokens across slots this step (the TTFT-vs-TPOT knob): a
        slot past the cap keeps clen 0 and resumes next step; the first
        prefilling slot always gets at least one token, so prefill can
        never stall. Returns (tokens (B, C), pos0 (B,), table (B, nb),
        clen (B,), first (B,)) — ``first`` marks a slot's FIRST chunk of
        the current prefill pass, whose harvest must replace (not OR into)
        any stale mask left by the slot's previous occupant."""
        B, nb = self.n_slots, self.max_blocks_per_seq
        tokens = np.zeros((B, chunk), np.int32)
        pos0 = np.zeros((B,), np.int32)
        table = np.full((B, nb), SCRATCH_BLOCK, np.int32)
        clen = np.zeros((B,), np.int32)
        first = np.zeros((B,), bool)
        spent = 0
        for i in self.prefill_indices():
            s = self.slots[i]
            p = s.prefilled
            n = min(chunk, s.prefill_len - p)
            if budget > 0:
                n = min(n, max(0, budget - spent))
                if n <= 0:
                    continue  # over budget this step: resume next step
            spent += n
            tokens[i, :n] = s.prefill_tokens[p:p + n]
            pos0[i] = p
            clen[i] = n
            first[i] = p == s.cached_tokens
            table[i, : len(s.blocks)] = s.blocks
        return tokens, pos0, table, clen, first

    def record_prefill(self, nxt: np.ndarray, lp: np.ndarray,
                       clen: np.ndarray, *, warm: bool = False,
                       step: int = 0) -> None:
        """Advance every prefilling slot by its chunk; a slot whose prefill
        just completed is seeded from the logits at its last valid chunk
        position (nxt/lp are the (B, C) per-position greedy outputs).
        ``warm`` marks completed slots to skip their age-0 γ-refresh — the
        harvested prefill activity IS their first window mask."""
        for i in self.prefill_indices():
            s = self.slots[i]
            n = int(clen[i])
            if n <= 0:
                continue
            s.prefilled += n
            if s.prefilled >= s.prefill_len:
                s.warm = bool(warm)
                self.seed(s, int(nxt[i, n - 1]), float(lp[i, n - 1]),
                          step=step)

    def record_io(self, active, dens: np.ndarray) -> None:
        """Accumulate each active slot's per-step FFN weight-read fraction
        (the engine's measured density for this step) so RequestResult can
        report a per-request ``ffn_read_fraction`` — requests co-scheduled
        in one batch see different γ phases / predicted sets, so the
        engine-wide mean hides real per-request variance."""
        for i in active:
            s = self.slots[i]
            s.io_dens_sum += float(dens[i])
            s.io_steps += 1

    def record(self, next_tokens: np.ndarray, logprobs: np.ndarray,
               pred_density: Optional[np.ndarray] = None,
               pred_active: Optional[np.ndarray] = None,
               pred_miss: Optional[np.ndarray] = None) -> None:
        """Append the step's outputs to every active slot. The optional
        (B,) predictor-telemetry arrays (predictor serving mode) accumulate
        per-request: mean weight-tile density, and the in-graph
        active/missed neuron counts behind ``realized_recall``."""
        for i in self.active_indices():
            s = self.slots[i]
            s.age += 1
            s.out.append(int(next_tokens[i]))
            s.lps.append(float(logprobs[i]))
            if self.obs is not None:
                self.obs.req_tokens(s.request.uid, 1)
            self._check_stop(s)
            if pred_density is not None:
                s.pred_dens_sum += float(pred_density[i])
                s.pred_steps += 1
                s.pred_active += int(pred_active[i])
                s.pred_miss += int(pred_miss[i])

    # -- speculative decoding ------------------------------------------------
    def ensure_window_capacity(self, slot: _Slot, W: int) -> int:
        """Window-overflow guard: a slot whose next W-token verify window
        would run past its allocated blocks gets one more block from the
        pool — or, when none is free (or the static table is full), a
        SHRUNKEN window this step. Either way no speculative write can land
        out of range (out-of-window writes are additionally scratch-routed
        in-graph). Returns the slot's effective window length W_s >= 1.

        Because the window is capped at ``slot.remaining`` and the current
        admission policy reserves a request's full lifetime blocks
        (ceil((prompt+max_new)/bs)), neither branch binds today — they are
        the safety net that keeps speculative writes in range under lazier
        allocation policies (admit-on-prompt, block stealing), and are
        unit-tested against exactly such states. W_s >= 1 always holds:
        next_pos <= prompt+max_new-1 while the slot is active, so the
        current token's own position is always writable — the engine can
        never deadlock, it just degrades to plain decoding."""
        need = min(W, slot.remaining)
        while (slot.next_pos + need > len(slot.blocks) * self.block_size
               and len(slot.blocks) < self.max_blocks_per_seq):
            extra = self.allocator.alloc(1)
            if extra is None:
                break  # pool exhausted: shrink rather than defer the slot
            slot.blocks.extend(extra)
        return max(1, min(need,
                          len(slot.blocks) * self.block_size - slot.next_pos))

    def spec_batch(self, W: int):
        """Fixed-shape arrays for the speculative step. Idle slots get
        wlen 0 (their draft/verify writes land in the scratch block).
        Returns (tokens (B,), pos0 (B,), table (B, nb), wlen (B,))."""
        B, nb = self.n_slots, self.max_blocks_per_seq
        tokens = np.zeros((B,), np.int32)
        pos0 = np.zeros((B,), np.int32)
        table = np.full((B, nb), SCRATCH_BLOCK, np.int32)
        wlen = np.zeros((B,), np.int32)
        for i in self.active_indices():
            s = self.slots[i]
            tokens[i] = s.out[-1]
            pos0[i] = s.next_pos
            wlen[i] = self.ensure_window_capacity(s, W)
            table[i, : len(s.blocks)] = s.blocks
        return tokens, pos0, table, wlen

    def record_spec(self, window: np.ndarray, target: np.ndarray,
                    logprobs: np.ndarray, wlen: np.ndarray) -> None:
        """Acceptance + KV rewind bookkeeping for one verify step.

        window: (B, W) = [current token, draft proposals...]; target /
        logprobs: (B, W) the target model's own continuation (and its
        logprob) at every window position — the argmax for greedy requests,
        or the token the target SAMPLES with that position's scheduled key
        (sampling.window_keys) for sampled ones; wlen: (B,) valid window
        lengths.

        Per slot: accept the longest prefix of proposals that equals the
        target's own continuation, then the target's correction /
        continuation token. For greedy requests this is exactly Leviathan
        greedy acceptance; for sampled requests it is key-coupled
        acceptance — every emitted token is the target's scheduled sample,
        so either way the output stream is identical to pure autoregressive
        decoding (greedy or sampled under the same key schedule), for any
        draft. The KV rewind is this bookkeeping: advancing ``age`` by only
        the emitted length rolls ``next_pos`` back over the rejected tail,
        whose stale K/V is overwritten by the next window (and masked by
        position until then). Blocks are never allocated per-window-token,
        so rejection leaks nothing past the scratch-block-0 invariant.

        A stop-sequence match inside the window truncates it: tokens after
        the match are discarded (exactly what autoregressive decoding
        would never have produced) and the slot finishes with "stop"."""
        for i in self.active_indices():
            s = self.slots[i]
            n_prop = int(wlen[i]) - 1
            n_acc = 0
            while (n_acc < n_prop
                   and int(window[i, n_acc + 1]) == int(target[i, n_acc])):
                n_acc += 1
            # produced = accepted proposals (== target[:n_acc]) + correction
            n_emit = 0
            for j in range(n_acc + 1):
                s.out.append(int(target[i, j]))
                s.lps.append(float(logprobs[i, j]))
                n_emit += 1
                if self._check_stop(s) or len(s.out) >= s.request.max_new:
                    break
            s.age += n_emit
            s.draft_proposed += n_prop
            s.draft_accepted += min(n_acc, n_emit)
            s.target_calls += 1
            if self.obs is not None:
                self.obs.req_tokens(s.request.uid, n_emit)
