"""Continuous-batching scheduler: request queue, block allocator, and slot
bookkeeping for the paged KV cache (models/common.py).

Pure host-side logic — no jax — so admission/retirement policy is unit-
testable without a model. The engine (serving/engine.py) owns the device
state (page pool, γ-window masks) and calls into this scheduler every step:

  1. retire slots whose requests finished, returning their blocks;
  2. admit queued requests into free slots while blocks last (strict FIFO);
  3. build the fixed-shape slot batch the jitted decode step consumes.

A request is admitted only if its *entire* lifetime block need fits now
(ceil((prompt + max_new) / block_size)), so decode never stalls mid-flight
on allocation failure.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.models.common import SCRATCH_BLOCK


@dataclasses.dataclass
class Request:
    uid: int
    tokens: np.ndarray  # (s,) int32 prompt
    max_new: int
    # γ-window weight reuse (paper Fig. 7c): refresh the FFN mask every γ
    # decoded tokens; 0 = dense (refresh every step, mask never binds).
    reuse_window: int = 0

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])


@dataclasses.dataclass
class RequestResult:
    uid: int
    tokens: np.ndarray  # (max_new,) int32
    logprobs: np.ndarray  # (max_new,) f32
    prompt_len: int
    admitted_step: int
    finished_step: int
    # speculative-decoding accounting (zero when served autoregressively)
    draft_proposed: int = 0  # draft tokens submitted for verification
    draft_accepted: int = 0  # of those, accepted by the target
    # verify windows this request cost; prefill is NOT included here (the
    # reporting layer, spec_decode.spec_metrics, adds it as +1)
    target_calls: int = 0
    # predictor-mode telemetry (zero-information defaults otherwise)
    predicted_density: float = 1.0  # mean fraction of FFN weight tiles read
    realized_recall: float = 1.0    # 1 - misses/actives, measured in-graph
    pred_misses: int = 0            # masked-out-but-active neurons (count)

    @property
    def accept_rate(self) -> float:
        """Measured α: accepted / proposed drafts (NOT a tokens-per-call
        ratio — see spec_decode.spec_metrics)."""
        return self.draft_accepted / max(1, self.draft_proposed)


class RequestQueue:
    """FIFO admission queue. Head-of-line blocking is deliberate: a large
    request is never starved by small ones slipping past it."""

    def __init__(self):
        self._q: deque = deque()

    def push(self, req: Request) -> None:
        self._q.append(req)

    def peek(self) -> Optional[Request]:
        return self._q[0] if self._q else None

    def pop(self) -> Request:
        return self._q.popleft()

    def __len__(self) -> int:
        return len(self._q)


class BlockAllocator:
    """Free-list over the shared page pool. Block 0 (SCRATCH_BLOCK) is never
    handed out — idle slots and table padding point at it."""

    def __init__(self, n_blocks: int):
        self.n_blocks = n_blocks
        self._free: List[int] = list(range(n_blocks - 1, SCRATCH_BLOCK, -1))

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        if n > len(self._free):
            return None
        return [self._free.pop() for _ in range(n)]

    def free(self, blocks: List[int]) -> None:
        for b in blocks:
            assert b != SCRATCH_BLOCK
            self._free.append(b)


@dataclasses.dataclass
class _Slot:
    request: Request
    blocks: List[int]
    admitted_step: int
    age: int = 0  # decoded tokens since admission (drives the γ phase)
    out: List[int] = dataclasses.field(default_factory=list)
    lps: List[float] = dataclasses.field(default_factory=list)
    # speculative-decoding bookkeeping
    draft_proposed: int = 0
    draft_accepted: int = 0
    target_calls: int = 0
    # predictor-mode accumulators (per decoded token)
    pred_dens_sum: float = 0.0
    pred_steps: int = 0
    pred_active: int = 0
    pred_miss: int = 0

    @property
    def done(self) -> bool:
        return len(self.out) >= self.request.max_new

    @property
    def next_pos(self) -> int:
        """Write position of the current token (prompt occupies 0..s-1)."""
        return self.request.prompt_len + self.age

    @property
    def remaining(self) -> int:
        return self.request.max_new - len(self.out)


class Scheduler:
    def __init__(self, n_slots: int, n_blocks: int, block_size: int,
                 max_blocks_per_seq: int):
        self.n_slots = n_slots
        self.block_size = block_size
        self.max_blocks_per_seq = max_blocks_per_seq
        self.allocator = BlockAllocator(n_blocks)
        self.queue = RequestQueue()
        self.slots: List[Optional[_Slot]] = [None] * n_slots
        self.results: Dict[int, RequestResult] = {}

    # -- lifecycle ----------------------------------------------------------
    def blocks_needed(self, req: Request) -> int:
        return -(-(req.prompt_len + req.max_new) // self.block_size)

    def submit(self, req: Request) -> None:
        # reject malformed requests here, before any slot/block state exists:
        # a prefill failure mid-admission would leave a zombie slot behind
        if req.prompt_len == 0:
            raise ValueError(f"request {req.uid}: empty prompt")
        if req.max_new < 1:
            raise ValueError(f"request {req.uid}: max_new must be >= 1")
        need = self.blocks_needed(req)
        if need > self.max_blocks_per_seq:
            raise ValueError(
                f"request {req.uid}: needs {need} blocks > "
                f"max_blocks_per_seq={self.max_blocks_per_seq}")
        self.queue.push(req)

    def retire_finished(self, step: int) -> List[int]:
        """Free the blocks of finished slots; returns retired request uids."""
        retired = []
        for i, slot in enumerate(self.slots):
            if slot is not None and slot.done:
                self.allocator.free(slot.blocks)
                self.results[slot.request.uid] = RequestResult(
                    uid=slot.request.uid,
                    tokens=np.asarray(slot.out, np.int32),
                    logprobs=np.asarray(slot.lps, np.float32),
                    prompt_len=slot.request.prompt_len,
                    admitted_step=slot.admitted_step,
                    finished_step=step,
                    draft_proposed=slot.draft_proposed,
                    draft_accepted=slot.draft_accepted,
                    target_calls=slot.target_calls,
                    predicted_density=(slot.pred_dens_sum / slot.pred_steps
                                       if slot.pred_steps else 1.0),
                    realized_recall=(1.0 - slot.pred_miss / slot.pred_active
                                     if slot.pred_active else 1.0),
                    pred_misses=slot.pred_miss,
                )
                retired.append(slot.request.uid)
                self.slots[i] = None
        return retired

    def admit(self, step: int) -> List[Tuple[int, _Slot]]:
        """Fill free slots from the queue while blocks last (strict FIFO).
        Returns (slot_index, slot) pairs needing prefill."""
        admitted = []
        for i in range(self.n_slots):
            if self.slots[i] is not None:
                continue
            req = self.queue.peek()
            if req is None:
                break
            blocks = self.allocator.alloc(self.blocks_needed(req))
            if blocks is None:
                break  # head of line doesn't fit yet — wait for retirements
            self.queue.pop()
            slot = _Slot(request=req, blocks=blocks, admitted_step=step)
            self.slots[i] = slot
            admitted.append((i, slot))
        return admitted

    def seed(self, slot: _Slot, token: int, logprob: float) -> None:
        """Record the first generated token (from the prefill logits)."""
        slot.out.append(int(token))
        slot.lps.append(float(logprob))

    # -- batch assembly -----------------------------------------------------
    def active_indices(self) -> List[int]:
        return [i for i, s in enumerate(self.slots)
                if s is not None and not s.done]

    def has_work(self) -> bool:
        return bool(self.active_indices()) or len(self.queue) > 0 or any(
            s is not None for s in self.slots)

    def batch_arrays(self):
        """Fixed-shape arrays for the jitted step. Idle slots point at the
        scratch block / position 0; their outputs are ignored."""
        B, nb = self.n_slots, self.max_blocks_per_seq
        tokens = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        table = np.full((B, nb), SCRATCH_BLOCK, np.int32)
        refresh = np.ones((B,), bool)  # idle slots refresh (mask unused)
        for i in self.active_indices():
            s = self.slots[i]
            tokens[i] = s.out[-1]
            pos[i] = s.next_pos
            table[i, : len(s.blocks)] = s.blocks
            gamma = s.request.reuse_window
            refresh[i] = gamma <= 1 or (s.age % gamma == 0)
        return tokens, pos, table, refresh

    def record(self, next_tokens: np.ndarray, logprobs: np.ndarray,
               pred_density: Optional[np.ndarray] = None,
               pred_active: Optional[np.ndarray] = None,
               pred_miss: Optional[np.ndarray] = None) -> None:
        """Append the step's outputs to every active slot. The optional
        (B,) predictor-telemetry arrays (predictor serving mode) accumulate
        per-request: mean weight-tile density, and the in-graph
        active/missed neuron counts behind ``realized_recall``."""
        for i in self.active_indices():
            s = self.slots[i]
            s.age += 1
            s.out.append(int(next_tokens[i]))
            s.lps.append(float(logprobs[i]))
            if pred_density is not None:
                s.pred_dens_sum += float(pred_density[i])
                s.pred_steps += 1
                s.pred_active += int(pred_active[i])
                s.pred_miss += int(pred_miss[i])

    # -- speculative decoding ------------------------------------------------
    def ensure_window_capacity(self, slot: _Slot, W: int) -> int:
        """Window-overflow guard: a slot whose next W-token verify window
        would run past its allocated blocks gets one more block from the
        pool — or, when none is free (or the static table is full), a
        SHRUNKEN window this step. Either way no speculative write can land
        out of range (out-of-window writes are additionally scratch-routed
        in-graph). Returns the slot's effective window length W_s >= 1.

        Because the window is capped at ``slot.remaining`` and the current
        admission policy reserves a request's full lifetime blocks
        (ceil((prompt+max_new)/bs)), neither branch binds today — they are
        the safety net that keeps speculative writes in range under lazier
        allocation policies (admit-on-prompt, block stealing), and are
        unit-tested against exactly such states. W_s >= 1 always holds:
        next_pos <= prompt+max_new-1 while the slot is active, so the
        current token's own position is always writable — the engine can
        never deadlock, it just degrades to plain decoding."""
        need = min(W, slot.remaining)
        while (slot.next_pos + need > len(slot.blocks) * self.block_size
               and len(slot.blocks) < self.max_blocks_per_seq):
            extra = self.allocator.alloc(1)
            if extra is None:
                break  # pool exhausted: shrink rather than defer the slot
            slot.blocks.extend(extra)
        return max(1, min(need,
                          len(slot.blocks) * self.block_size - slot.next_pos))

    def spec_batch(self, W: int):
        """Fixed-shape arrays for the speculative step. Idle slots get
        wlen 0 (their draft/verify writes land in the scratch block).
        Returns (tokens (B,), pos0 (B,), table (B, nb), wlen (B,))."""
        B, nb = self.n_slots, self.max_blocks_per_seq
        tokens = np.zeros((B,), np.int32)
        pos0 = np.zeros((B,), np.int32)
        table = np.full((B, nb), SCRATCH_BLOCK, np.int32)
        wlen = np.zeros((B,), np.int32)
        for i in self.active_indices():
            s = self.slots[i]
            tokens[i] = s.out[-1]
            pos0[i] = s.next_pos
            wlen[i] = self.ensure_window_capacity(s, W)
            table[i, : len(s.blocks)] = s.blocks
        return tokens, pos0, table, wlen

    def record_spec(self, window: np.ndarray, greedy: np.ndarray,
                    logprobs: np.ndarray, wlen: np.ndarray) -> None:
        """Greedy acceptance + KV rewind bookkeeping for one verify step.

        window: (B, W) = [current token, draft proposals...]; greedy /
        logprobs: (B, W) the target's argmax continuation (and its logprob)
        at every window position; wlen: (B,) valid window lengths.

        Per slot: accept the longest prefix of proposals that equals the
        target's own greedy continuation, then the target's correction /
        continuation token — exactly Leviathan greedy acceptance, so the
        output stream is identical to pure autoregressive decoding. The KV
        rewind is this bookkeeping: advancing ``age`` by only the accepted
        length rolls ``next_pos`` back over the rejected tail, whose stale
        K/V is overwritten by the next window (and masked by position until
        then). Blocks are never allocated per-window-token, so rejection
        leaks nothing past the scratch-block-0 invariant."""
        for i in self.active_indices():
            s = self.slots[i]
            n_prop = int(wlen[i]) - 1
            n_acc = 0
            while (n_acc < n_prop
                   and int(window[i, n_acc + 1]) == int(greedy[i, n_acc])):
                n_acc += 1
            # produced = accepted proposals (== greedy[:n_acc]) + correction
            s.out.extend(int(t) for t in greedy[i, : n_acc + 1])
            s.lps.extend(float(x) for x in logprobs[i, : n_acc + 1])
            s.age += n_acc + 1
            s.draft_proposed += n_prop
            s.draft_accepted += n_acc
            s.target_calls += 1
