"""Speculative-decoding metrics + theory reporting (paper Sec. 5.2, App. C).

The EXECUTION of sparse speculative decoding lives in the continuous-
batching engine: batched γ-token drafting (one jitted scan), one-forward
window verification (models/transformer.py ``verify_window_paged``) and KV
rewind-on-reject are engine/scheduler concerns (serving/engine.py,
serving/scheduler.py). This module is the per-request reporting layer — it
turns the scheduler's raw accept/propose/target-call counts into the
paper's quantities:

* measured α — the per-proposal acceptance fraction, accepted_drafts /
  proposed_drafts. (NOT derived from tokens-per-target-call: a produced/n_t
  ratio folds the free correction token of every window into "acceptance"
  and overstates α.)
* Thm 1 speedup — sparse vs standard speculative verification at the
  measured aggregated window sparsity s_agg(γ);
* Thm 2 speedup — sparse speculative decoding vs plain autoregressive
  decoding at (α, s_agg(γ)).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import spec_theory
from repro.serving.scheduler import RequestResult


@dataclasses.dataclass
class SpecResult:
    tokens: np.ndarray  # (n_new,)
    accept_rate: float  # measured alpha = accepted / proposed drafts
    n_target_calls: int  # verify windows + 1 prefill
    n_draft_calls: int  # drafted proposals submitted for verification
    target_call_reduction: float  # tokens produced per target call
    s_agg_window: float  # measured aggregated sparsity per gamma-window
    thm1_speedup: float  # sparse vs standard spec decoding (App. C)
    thm2_speedup: float  # sparse spec decoding vs autoregressive


def spec_metrics(result: RequestResult, *, gamma: int, c: float,
                 s_agg: float) -> SpecResult:
    """Per-request speculative metrics from an engine ``RequestResult``.

    gamma: the engine's draft length; c: draft/target cost ratio for the
    theory speedups; s_agg: measured aggregated window sparsity (e.g. the
    engine's ``s_agg_window()``).
    """
    alpha = result.accept_rate
    n_t = result.target_calls + 1  # prefill counts as one target call
    return SpecResult(
        tokens=result.tokens,
        accept_rate=alpha,
        n_target_calls=n_t,
        n_draft_calls=result.draft_proposed,
        target_call_reduction=len(result.tokens) / max(1, n_t),
        s_agg_window=s_agg,
        thm1_speedup=spec_theory.thm1_speedup(gamma, c, s_agg),
        thm2_speedup=spec_theory.thm2_speedup(gamma, c, s_agg, alpha),
    )
