"""Speculative decoding — standard (Leviathan) and the paper's SPARSE variant
(Sec. 5.2): the target model verifies the γ draft tokens using only the
aggregated-active FFN rows of the current window, cutting the weight I/O of
verification by s_agg(γ).

On this CPU container the I/O saving is *modeled* (App. C latency model fed
with measured aggregated sparsity); token-level behaviour (accept/reject,
outputs) is executed for real on tiny models and tested for exactness.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import spec_theory
from repro.core.sparsity import AggregatedTracker
from repro.models import common as cm
from repro.models import registry


@dataclasses.dataclass
class SpecResult:
    tokens: np.ndarray  # (n_new,)
    accept_rate: float  # measured alpha
    n_target_calls: int
    n_draft_calls: int
    s_agg_window: float  # mean aggregated sparsity per gamma-window
    thm1_speedup: float  # sparse vs standard spec decoding (App. C)
    thm2_speedup: float  # sparse spec decoding vs autoregressive


def _greedy(logits, vocab):
    return jnp.argmax(logits[:, :vocab], -1).astype(jnp.int32)


def speculative_generate(
    target_cfg: ModelConfig, target_params,
    draft_cfg: ModelConfig, draft_params,
    prompt: jnp.ndarray,  # (1, s) int32
    max_new: int, gamma: int = 4, c: float = 0.1,
    sparse: bool = True,
) -> SpecResult:
    """Greedy speculative decoding for batch=1 (the paper's setting).

    Greedy acceptance: a draft token is accepted iff it equals the target's
    argmax at that position — output is then *identical* to pure target
    greedy decoding (verified in tests).
    """
    tfam = registry.get_family(target_cfg)
    dfam = registry.get_family(draft_cfg)
    d_decode = jax.jit(
        lambda p, c, t, pos: dfam.model_decode(p, c, t, pos, draft_cfg))
    max_len = prompt.shape[1] + max_new + gamma + 2

    t_last, t_cache = tfam.model_prefill(target_params, {"tokens": prompt},
                                         target_cfg, max_len)
    d_last, d_cache = dfam.model_prefill(draft_params, {"tokens": prompt},
                                         draft_cfg, max_len)

    produced: List[int] = []
    n_t, n_d = 1, 0  # prefill counts as one target call
    cur = int(_greedy(t_last, target_cfg.vocab_size)[0])
    s = prompt.shape[1]
    d_pos = s  # next write position in draft cache
    tracker = AggregatedTracker(target_cfg.n_layers, target_cfg.d_ff)
    window_sparsities: List[float] = []

    while len(produced) < max_new:
        produced.append(cur)
        if len(produced) >= max_new:
            break
        # --- draft proposes gamma tokens autoregressively ---
        proposals = []
        dt = jnp.asarray([cur], jnp.int32)
        for g in range(gamma):
            dl, d_cache = d_decode(draft_params, d_cache, dt,
                                   jnp.asarray([d_pos + g], jnp.int32))
            n_d += 1
            dt = _greedy(dl, draft_cfg.vocab_size)
            proposals.append(int(dt[0]))

        # --- target verifies [cur] + proposals in ONE forward ---
        window = jnp.asarray([[cur] + proposals], jnp.int32)  # (1, gamma+1)
        t_logits, t_cache, masks = _target_window(
            tfam, target_params, t_cache, window, s + len(produced) - 1,
            target_cfg, collect=sparse)
        n_t += 1
        if sparse and masks:
            for m in masks:
                tracker.update(m)
            union = np.any(np.stack(masks), axis=0)
            window_sparsities.append(1.0 - float(union.mean()))

        greedy = np.asarray(_greedy(t_logits[0], target_cfg.vocab_size))
        # accept longest prefix where draft token == target argmax
        n_acc = 0
        for g in range(gamma):
            if greedy[g] == proposals[g]:
                n_acc += 1
            else:
                break
        accepted = proposals[:n_acc]
        produced.extend(accepted[: max_new - len(produced)])
        cur = int(greedy[n_acc])  # the target's correction / continuation
        d_pos = s + len(produced) - 1

    alpha = 1.0 - 1.0 / max(1.0, (len(produced) / max(1, n_t)))
    s_agg = float(np.mean(window_sparsities)) if window_sparsities else 0.0
    return SpecResult(
        tokens=np.asarray(produced[:max_new]),
        accept_rate=alpha, n_target_calls=n_t, n_draft_calls=n_d,
        s_agg_window=s_agg,
        thm1_speedup=spec_theory.thm1_speedup(gamma, c, s_agg),
        thm2_speedup=spec_theory.thm2_speedup(gamma, c, s_agg, alpha),
    )


def _target_window(fam, params, cache, window, pos0, cfg, collect):
    """Verify a (1, w) token window: w sequential cached decode steps (kept
    simple and exact; a production verifier fuses this into one forward).
    Returns (logits (1, w, V), cache, per-step activity masks)."""
    logits_all, masks = [], []
    for i in range(window.shape[1]):
        stats = cm.StatsCollector(True) if collect else None
        lg, cache = fam.model_decode(
            params, cache, window[:, i],
            jnp.asarray([pos0 + i], jnp.int32), cfg, stats=stats)
        logits_all.append(lg)
        if collect:
            step = [np.asarray(stats.stats[f"layer{j}/down_act"])
                    for j in range(cfg.n_layers)
                    if f"layer{j}/down_act" in stats.stats]
            if step:
                masks.append(np.stack(step))
    return jnp.stack(logits_all, axis=1), cache, masks
