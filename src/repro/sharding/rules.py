"""Logical-axis sharding rules (MaxText-style, shape/name driven).

Instead of hand-maintaining one PartitionSpec pytree per (family × mode), we
derive the spec of every parameter from its *path name* and *shape*, with an
automatic divisibility guard: a mesh axis is only assigned when the dimension
size divides the axis size (the probe showed jit rejects uneven shardings).
Because every padded dimension (vocab→2048·k, q-heads→16·k, d_ff, d_model,
d_inner) is mesh-divisible by construction, the guard only "fires" where we
*want* replication (e.g. GQA kv-heads of size 2/4/8).

Modes:
  train   — DP over ("pod","data") batch, FSDP over "data" on a weight axis,
            TP over "model" (ffn / heads / vocab): ZeRO-3-style layouts.
  serve   — weights TP-only over "model" (resident, no per-step all-gather);
            MoE expert weights shard their EXPERT dim over "model" (a
            priority assignment, ahead of the trailing-first loop — expert
            routing is the unit the serving engine gathers/accounts at, so
            each device holds whole experts and per-device FFN reads shrink
            by top_k/E × 1/TP); KV cache: batch over DP, seq over "model"
            (flash-decode, DESIGN.md §3).
"""
from __future__ import annotations

import contextlib
import re
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


def tp_size(mesh: Optional[Mesh]) -> int:
    """Tensor-parallel degree of a mesh (1 when mesh is None / no "model"
    axis) — the 1/TP factor in the serving engine's per-device weight-I/O
    accounting."""
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return int(mesh.shape["model"])

# (regex on '/'-joined param path) -> logical axes for the trailing dims.
# Leading stacked-layer dims are detected by ndim surplus and mapped to None.
_PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    (r"pos_embed$", ("seq_weights", "embed")),
    (r"(embed|unembed)$", ("vocab", "embed")),
    (r"attn.*/(wq)$", ("embed", "heads", "head_dim")),
    (r"attn.*/(wk|wv)$", ("embed_kv", "kv_heads", "head_dim")),
    (r"attn.*/wo$", ("heads", "head_dim", "embed")),
    (r"attn.*/(bq)$", ("heads", "head_dim")),
    (r"attn.*/(bk|bv)$", ("kv_heads", "head_dim")),
    (r"(q_norm|k_norm)$", ("head_dim",)),
    (r"ffn/(wu|wg)$", ("embed", "ffn")),
    (r"ffn/wd$", ("ffn", "embed")),
    (r"moe/router$", ("embed", "experts")),
    (r"moe/(wu|wg)$", ("experts", "embed_heavy", "ffn")),
    (r"moe/wd$", ("experts", "ffn", "embed_heavy")),
    # mamba
    (r"ssm/in_proj$", ("embed", "inner_all")),
    (r"ssm/out_proj$", ("inner", "embed")),
    (r"ssm/conv_w$", ("conv_k", "inner")),
    (r"ssm/(conv_b|A_log|D|dt_bias|gate_b)$", ("inner_vec",)),
    (r"ssm/(x_proj|dt_proj_w|B_proj|C_proj|dt_proj)$", ("inner_or_embed", "proj_out")),
    (r"ssm/norm/scale$", ("inner_vec",)),
    # norms / scalars: replicated
    (r"(ln\d*|norm\d*|final_norm|pre_norm|post_norm|input_norm)(/|$)", ()),
    (r"(scale|bias)$", ()),
)


def _logical_axes_for(path: str, ndim: int) -> Tuple[Optional[str], ...]:
    for pat, axes in _PARAM_RULES:
        if re.search(pat, path):
            lead = ndim - len(axes)
            assert lead >= 0, (path, ndim, axes)
            return (None,) * lead + tuple(axes)
    return (None,) * ndim  # unknown -> replicated (safe default)


# logical axis -> mesh axis, per mode. "embed" is the FSDP axis in training.
_MESH_MAP = {
    "train": {
        "vocab": "model", "embed": "data", "embed_kv": "data",
        "embed_heavy": "dp",  # resolves to ("pod","data") on the 2-pod mesh
        "heads": "model", "kv_heads": "model", "head_dim": None,
        "ffn": "model", "experts": None, "seq_weights": None,
        "inner": "model", "inner_all": "model", "inner_vec": "model",
        "inner_or_embed": None, "proj_out": None, "conv_k": None,
    },
    "serve": {
        "vocab": "model", "embed": None, "embed_kv": "model",
        "embed_heavy": "dp",
        "heads": "model", "kv_heads": "model", "head_dim": None,
        "ffn": "model", "experts": "model", "seq_weights": None,
        "inner": "model", "inner_all": "model", "inner_vec": "model",
        "inner_or_embed": None, "proj_out": None, "conv_k": None,
    },
}

# logical axes assigned BEFORE the trailing-first loop: the expert dim must
# win "model" over the same weight's trailing ffn dim — serving gathers and
# accounts I/O at whole-expert granularity (models/moe.py), so devices hold
# whole experts, not expert slivers. Falls through to the trailing loop's
# choices when the dim doesn't divide the axis.
_PRIORITY_AXES = ("experts",)


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def dp_size(mesh: Mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out


def _fits(dim: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return False
    size = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        size *= mesh.shape[a]
    return dim % size == 0 and dim >= size


def param_pspec(path: str, shape: Tuple[int, ...], mesh: Mesh, mode: str) -> P:
    axes = _logical_axes_for(path, len(shape))
    mm = _MESH_MAP[mode]
    out, used = [None] * len(shape), set()

    def assign(i):
        ax = axes[i]
        mesh_ax = mm.get(ax) if ax else None
        if mesh_ax == "dp":  # dynamic: all data-parallel axes of this mesh
            mesh_ax = dp_axes(mesh)
        flat = (mesh_ax if isinstance(mesh_ax, tuple) else (mesh_ax,))
        if mesh_ax is not None and not (set(flat) & used)                 and _fits(shape[i], mesh, mesh_ax):
            out[i] = mesh_ax
            used.update(flat)

    # priority pre-pass (currently: the MoE expert dim claims "model")
    for i in range(len(shape)):
        if axes[i] in _PRIORITY_AXES:
            assign(i)
    # then assign trailing dims first: for MHA the (padded) kv-head dim takes
    # "model"; for GQA (kv < 16) it falls through and the embed dim takes it
    # instead (keeps K/V projection weights sharded at serve time)
    for i in reversed(range(len(shape))):
        if axes[i] not in _PRIORITY_AXES:
            assign(i)
    return P(*out)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def params_shardings(params_shape: PyTree, mesh: Mesh, mode: str) -> PyTree:
    """params_shape: pytree of ShapeDtypeStruct (from jax.eval_shape)."""
    def f(path, leaf):
        return NamedSharding(mesh, param_pspec(_path_str(path), leaf.shape, mesh, mode))
    return jax.tree_util.tree_map_with_path(f, params_shape)


# ---------------------------------------------------------------------------
# activation / input shardings


def batch_pspec(batch_size: int, mesh: Mesh, extra_dims: int = 1) -> P:
    dp = dp_axes(mesh)
    if _fits(batch_size, mesh, dp):
        return P(dp, *([None] * extra_dims))
    if _fits(batch_size, mesh, "data"):
        return P("data", *([None] * extra_dims))
    return P(*([None] * (extra_dims + 1)))


def cache_pspec(shape: Tuple[int, ...], mesh: Mesh) -> P:
    """KV cache, head-major (L, b, kvp, S, hd): batch over DP, seq over
    model (flash-decode partial softmax)."""
    L, b, kvp, S, hd = shape
    dp = dp_axes(mesh)
    baxis = dp if _fits(b, mesh, dp) else ("data" if _fits(b, mesh, "data") else None)
    saxis = "model" if _fits(S, mesh, "model") else None
    return P(None, baxis, None, saxis, None)


def paged_cache_pspec(shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Paged KV block pool (L, n_blocks, kvp, bs, hd) for the continuous-
    batching engine: block axis over "data" (each data shard owns a slice of
    the pool — block tables index across shards, GSPMD inserts the gathers),
    kv heads over "model" (the TP split that keeps decode attention
    shard-local). The divisibility guard replicates either axis when it
    doesn't fit (e.g. GQA kvp=2 on an 8-way model axis)."""
    L, nb, kvp, bs, hd = shape
    baxis = "data" if _fits(nb, mesh, "data") else None
    haxis = "model" if _fits(kvp, mesh, "model") else None
    return P(None, baxis, haxis, None, None)


def serve_masks_pspec(shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Per-slot γ-window FFN mask / activity buffers (L, n_slots, d_ff) or
    (n_slots, d_ff): d_ff over "model" so the union-mask updates stay
    shard-local elementwise ops on each shard's d_ff slice, and the slot
    axis over "data" when it fits — matching the constrain(..., "dp",
    "model") the decode steps put on new_masks, so the donated buffer's
    sharding is stable step-over-step (a mismatch would reshard + retrace
    on every data>1 mesh)."""
    faxis = "model" if _fits(shape[-1], mesh, "model") else None
    saxis = "data" if _fits(shape[-2], mesh, "data") else None
    return P(*([None] * (len(shape) - 2)), saxis, faxis)


def predictor_shardings(pred_params: PyTree, mesh: Mesh) -> PyTree:
    """Shardings for a stacked predictor pytree (repro.predictor): probe
    weights whose trailing axis is d_ff ("w" for sign, "b" for lowrank)
    shard that axis over "model" — each shard probes only its local d_ff
    slice; taus and low-rank input factors are replicated."""
    def f(path, leaf):
        name = _path_str(path)
        axes = [None] * leaf.ndim
        if name.rsplit("/", 1)[-1] in ("w", "b") and leaf.ndim >= 2 \
                and _fits(leaf.shape[-1], mesh, "model"):
            axes[-1] = "model"
        return NamedSharding(mesh, P(*axes))
    return jax.tree_util.tree_map_with_path(f, pred_params)


def ssm_cache_pspec(shape: Tuple[int, ...], mesh: Mesh) -> P:
    """SSM state (L, b, inner, state) / conv state (L, b, k, inner)."""
    dp = dp_axes(mesh)
    out = [None]
    b = shape[1]
    out.append(dp if _fits(b, mesh, dp) else ("data" if _fits(b, mesh, "data") else None))
    for dim in shape[2:]:
        out.append("model" if ("model" not in out and _fits(dim, mesh, "model")
                               and dim >= 1024) else None)
    return P(*out)


def logits_pspec(batch_size: int, mesh: Mesh, with_seq: bool) -> P:
    bp = batch_pspec(batch_size, mesh, extra_dims=0)
    baxis = bp[0] if len(bp) else None
    if with_seq:
        return P(baxis, None, "model")
    return P(baxis, "model")


# ---------------------------------------------------------------------------
# activation-sharding context: model / loss code calls constrain() with
# logical axes; a no-op unless a mesh is installed (dry-run / launcher).

_ENV = {"mesh": None}


def set_mesh(mesh: Optional[Mesh]) -> None:
    _ENV["mesh"] = mesh


def get_mesh() -> Optional[Mesh]:
    return _ENV["mesh"]


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    """Scoped mesh install: constrain() binds ``mesh`` inside the block and
    the previous environment is restored on exit. The serving engine wraps
    its jitted-step *calls* in this (constraints bind at trace time), so a
    sharded engine never leaks a mesh into single-device engines traced
    later in the same process — their frozen lowerings must stay
    constraint-free."""
    prev = _ENV["mesh"]
    _ENV["mesh"] = mesh
    try:
        yield mesh
    finally:
        _ENV["mesh"] = prev


def constrain_params_tree(tree: PyTree, mode: str = "train") -> PyTree:
    """Constrain a params-structured tree (e.g. grads, grad accumulators) to
    the parameter shardings — keeps GSPMD on the ZeRO reduce-scatter path
    instead of materializing replicated f32 gradients."""
    mesh = _ENV["mesh"]
    if mesh is None:
        return tree

    def f(path, leaf):
        return jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, param_pspec(_path_str(path), leaf.shape,
                                                  mesh, mode)))
    return jax.tree_util.tree_map_with_path(f, tree)


def constrain(x, *logical):
    """logical: 'dp' (batch), 'model', 'data', or None per dim."""
    mesh = _ENV["mesh"]
    if mesh is None:
        return x
    axes = []
    for dim, ax in zip(x.shape, logical):
        if ax == "dp":
            dp = dp_axes(mesh)
            axes.append(dp if _fits(dim, mesh, dp) else
                        ("data" if _fits(dim, mesh, "data") else None))
        elif ax in ("model", "data"):
            axes.append(ax if (_fits(dim, mesh, ax)
                               and ax not in axes) else None)
        else:
            axes.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*axes)))
