"""Explicit-collective data-parallel train step (shard_map over the data
axis) with optional int8 error-feedback gradient compression.

This is the "distributed-optimization tricks" path: the gradient all-reduce
is explicit, so it can be compressed (optim/compression.py) or overlapped.
The default production path (train/step.py) uses pjit+GSPMD instead; this
DDP variant exists for pure-DP deployments and as the compression substrate.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, TrainConfig
from repro.optim import adamw, compression, schedules
from repro.train.step import lm_loss

PyTree = Any


def make_ddp_train_step(cfg: ModelConfig, tc: TrainConfig, mesh: Mesh,
                        axis: str = "data"):
    """Returns (train_step, init_state): params/opt replicated, batch sharded
    over `axis`, grads all-reduced explicitly (int8-EF if configured)."""
    compress = tc.grad_compression == "int8_ef"

    def loss_fn(params, batch):
        return lm_loss(params, batch, cfg, remat_policy=tc.remat_policy)

    def shard_step(params, opt_state, ef, batch):
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        if compress:
            grads, ef = compression.compressed_psum_mean(grads, ef, axis)
        else:
            grads = jax.lax.pmean(grads, axis)
        loss = jax.lax.pmean(loss, axis)
        grads, gnorm = adamw.clip_by_global_norm(grads, tc.grad_clip)
        lr = schedules.learning_rate(opt_state.step, tc)
        new_params, new_opt = adamw.adamw_update(grads, opt_state, params, lr, tc)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_params, new_opt, ef, metrics

    rep = P()
    bspec = jax.tree.map(lambda _: P(axis), {"tokens": 0, "loss_mask": 0})

    def train_step(params, opt_state, ef, batch):
        specs_in = (jax.tree.map(lambda _: rep, params),
                    jax.tree.map(lambda _: rep, opt_state),
                    jax.tree.map(lambda _: rep, ef),
                    {k: P(axis) for k in batch})
        specs_out = (jax.tree.map(lambda _: rep, params),
                     jax.tree.map(lambda _: rep, opt_state),
                     jax.tree.map(lambda _: rep, ef),
                     {"loss": rep, "grad_norm": rep, "lr": rep})
        fn = shard_map(shard_step, mesh=mesh, in_specs=specs_in,
                       out_specs=specs_out, check_rep=False)
        return fn(params, opt_state, ef, batch)

    return jax.jit(train_step)
