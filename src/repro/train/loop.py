"""Fault-tolerant training loop.

* auto-resume from the latest checkpoint (params + optimizer + data-iterator
  state survive restarts);
* SIGTERM/SIGINT → checkpoint-and-exit (preemption safe);
* non-finite steps skipped inside the jitted step (train/step.py);
* straggler watchdog: per-step wall-time EMA; steps slower than
  `straggler_factor ×` EMA are logged/counted (on a real cluster this feeds
  the controller's host-health signal — same hook);
* periodic eval on held-out synthetic data.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig, TrainConfig
from repro.data.pipeline import DataConfig, IteratorState, PackedIterator, eval_batches
from repro.models import registry
from repro.optim import adamw
from repro.train.step import lm_loss, make_train_step


@dataclasses.dataclass
class TrainerReport:
    steps: int
    losses: List[float]
    eval_losses: List[float]
    skipped_steps: int
    straggler_steps: int
    resumed_from: Optional[int]


class Trainer:
    def __init__(self, cfg: ModelConfig, tc: TrainConfig, dc: DataConfig,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
                 eval_every: int = 50, straggler_factor: float = 3.0,
                 log: Callable[[str], None] = print):
        self.cfg, self.tc, self.dc = cfg, tc, dc
        self.fam = registry.get_family(cfg)
        self.step_fn = jax.jit(make_train_step(cfg, tc))
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.eval_every = eval_every
        self.straggler_factor = straggler_factor
        self.log = log
        self._stop = False
        self._eval = eval_batches(dc, 2)

    def _install_signals(self):
        def handler(signum, frame):
            self.log(f"[trainer] signal {signum}: checkpoint-and-exit")
            self._stop = True
        try:
            signal.signal(signal.SIGTERM, handler)
            signal.signal(signal.SIGINT, handler)
        except ValueError:
            pass  # not in main thread (tests)

    def eval_loss(self, params) -> float:
        losses = [float(lm_loss(params, {k: jnp.asarray(v) for k, v in b.items()},
                                self.cfg)[0]) for b in self._eval]
        return float(np.mean(losses))

    def run(self, n_steps: int, params=None, opt_state=None) -> TrainerReport:
        self._install_signals()
        cfg, tc, dc = self.cfg, self.tc, self.dc

        resumed_from = None
        start_step = 0
        it_state = None
        if params is None:
            params = self.fam.init_params(jax.random.PRNGKey(tc.seed), cfg)
        if opt_state is None:
            opt_state = adamw.init_opt_state(params)
        if self.ckpt and self.ckpt.latest_step() is not None:
            (params, opt_state), extras = self.ckpt.restore((params, opt_state))
            start_step = int(extras["step"])
            resumed_from = start_step
            it_state = IteratorState.from_dict(extras["data"])
            self.log(f"[trainer] resumed from step {start_step}")

        it = PackedIterator(dc, it_state)
        losses: List[float] = []
        evals: List[float] = []
        skipped = 0
        stragglers = 0
        ema = None

        step = start_step
        while step < n_steps and not self._stop:
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            t0 = time.time()
            params, opt_state, metrics = self.step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            ema = dt if ema is None else 0.9 * ema + 0.1 * dt
            if dt > self.straggler_factor * ema and step > start_step + 2:
                stragglers += 1
                self.log(f"[trainer] straggler step {step}: {dt:.2f}s vs ema {ema:.2f}s")
            if float(metrics["step_ok"]) == 0.0:
                skipped += 1
                self.log(f"[trainer] non-finite step {step} skipped")
            losses.append(loss)
            step += 1
            if self.ckpt and (step % self.ckpt_every == 0 or self._stop):
                self.ckpt.save(step, (params, opt_state),
                               extras={"step": step, "data": it.state().to_dict()})
            if step % self.eval_every == 0:
                ev = self.eval_loss(params)
                evals.append(ev)
                self.log(f"[trainer] step {step} loss {loss:.4f} eval {ev:.4f}")

        if self.ckpt:
            self.ckpt.save(step, (params, opt_state), block=True,
                           extras={"step": step, "data": it.state().to_dict()})
        self.params, self.opt_state = params, opt_state
        return TrainerReport(steps=step - start_step, losses=losses,
                             eval_losses=evals, skipped_steps=skipped,
                             straggler_steps=stragglers,
                             resumed_from=resumed_from)
