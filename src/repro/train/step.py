"""Training step: LM loss, remat policies, microbatched grad accumulation,
global-norm clipping, AdamW, non-finite-step skipping.

The step is a single pjit-able function; batch layout is (num_microbatches ×
per-mb-batch × seq) with per-mb batch kept >= the DP degree so every
microbatch still shards over data (see DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import registry
from repro.models import transformer as T
from repro.optim import adamw, schedules
from repro.sharding import rules

PyTree = Any


def lm_loss(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            *, remat_policy: str = "none",
            stats=None) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Next-token cross-entropy on batch["tokens"]; modality stubs pass through."""
    family = registry.get_family(cfg)
    logits = family.model_forward(params, batch, cfg, stats=stats,
                                  remat_policy=remat_policy)
    logits = rules.constrain(logits, "dp", None, "model")
    tokens = batch["tokens"]
    tgt = tokens[:, 1:]
    lg = logits[:, :-1]
    # vocab-sharding-friendly cross entropy: reductions over the (sharded)
    # vocab axis lower to cheap (b, s) all-reduces; the target logit is a
    # masked select, not a cross-shard gather.
    m = jnp.max(lg, axis=-1)
    shifted = (lg - m[..., None]).astype(jnp.float32)
    lse = m.astype(jnp.float32) + jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    vocab_iota = jnp.arange(lg.shape[-1], dtype=tgt.dtype)
    tl = jnp.sum(jnp.where(vocab_iota == tgt[..., None], lg, 0)
                 .astype(jnp.float32), axis=-1)
    nll = lse - tl
    mask = batch.get("loss_mask")
    if mask is not None:
        mask = mask[:, 1:].astype(jnp.float32)
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    else:
        loss = jnp.mean(nll)
    aux = {"loss": loss}
    if stats is not None and stats.active:
        aux.update(stats.stats)
    return loss, aux


def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    batch["tokens"]: (global_batch, seq). Internally reshaped into
    tc.num_microbatches grad-accumulation slices.
    """

    def loss_fn(params, mb):
        return lm_loss(params, mb, cfg, remat_policy=tc.remat_policy)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state: adamw.OptState, batch):
        nmb = tc.num_microbatches

        if nmb <= 1:
            (loss, aux), grads = grad_fn(params, batch)
            grads = rules.constrain_params_tree(grads)
        else:
            def split(x):
                b = x.shape[0]
                assert b % nmb == 0, (b, nmb)
                return x.reshape((nmb, b // nmb) + x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def accum(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(params, mb)
                g = rules.constrain_params_tree(g)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (rules.constrain_params_tree(g_acc), l_acc + l), None

            g0 = rules.constrain_params_tree(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (g_sum, l_sum), _ = jax.lax.scan(accum, (g0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / nmb, g_sum)
            loss = l_sum / nmb

        grads, gnorm = adamw.clip_by_global_norm(grads, tc.grad_clip)
        lr = schedules.learning_rate(opt_state.step, tc)
        new_params, new_opt = adamw.adamw_update(grads, opt_state, params, lr, tc)

        if tc.skip_nonfinite:
            ok = jnp.isfinite(loss) & jnp.isfinite(gnorm)
            new_params = jax.tree.map(
                lambda new, old: jnp.where(ok, new, old), new_params, params)
            new_opt = jax.tree.map(
                lambda new, old: jnp.where(ok, new, old), new_opt, opt_state)
        else:
            ok = jnp.array(True)

        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr,
                   "step_ok": ok.astype(jnp.float32)}
        return new_params, new_opt, metrics

    return train_step
