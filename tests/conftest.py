"""Shared test configuration.

When the real `hypothesis` package is unavailable (it ships via the
``repro[test]`` extra; CI installs it), install a minimal deterministic
stand-in so the property-test modules still collect and run a reduced,
seeded example sweep instead of erroring at import time. The stub covers
exactly the API surface these tests use: ``given``, ``settings``, and the
``integers`` / ``floats`` / ``sampled_from`` / ``booleans`` strategies.
"""
from __future__ import annotations

import importlib.util

if importlib.util.find_spec("hypothesis") is None:  # pragma: no cover - CI has it
    import functools
    import inspect
    import random
    import sys
    import types
    import zlib

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example_at(self, rng, i):
            return self._draw(rng, i)

    def integers(min_value, max_value):
        return _Strategy(lambda rng, i: min_value if i == 0 else
                         max_value if i == 1 else
                         rng.randint(min_value, max_value))

    def floats(min_value, max_value):
        return _Strategy(lambda rng, i: float(min_value) if i == 0 else
                         float(max_value) if i == 1 else
                         rng.uniform(min_value, max_value))

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng, i: elements[i] if i < len(elements)
                         else rng.choice(elements))

    def booleans():
        return sampled_from([False, True])

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            names = list(inspect.signature(fn).parameters)
            strategies = dict(zip(names, arg_strategies))
            strategies.update(kw_strategies)

            @functools.wraps(fn)
            def wrapper():
                n = getattr(wrapper, "_max_examples", 10)
                rng = random.Random(zlib.crc32(fn.__name__.encode()))
                for i in range(n):
                    kwargs = {k: s.example_at(rng, i)
                              for k, s in strategies.items()}
                    try:
                        fn(**kwargs)
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example (stub hypothesis): "
                            f"{kwargs!r}") from e

            # hide the original parameters from pytest's fixture resolution
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return deco

    def settings(max_examples=10, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.floats = floats
    st_mod.sampled_from = sampled_from
    st_mod.booleans = booleans

    hyp_mod = types.ModuleType("hypothesis")
    hyp_mod.given = given
    hyp_mod.settings = settings
    hyp_mod.strategies = st_mod
    hyp_mod.__stub__ = True

    sys.modules["hypothesis"] = hyp_mod
    sys.modules["hypothesis.strategies"] = st_mod
