"""Shared forced-multi-device subprocess runner for distribution tests.

Tests that need N (fake) host devices run their body in a subprocess so the
main pytest process keeps its single-device view. One copy of this helper:
it is environment-sensitive (the XLA_FLAGS prelude must precede the jax
import, and JAX_PLATFORMS must survive into the stripped child env or jax
hangs probing non-CPU backends on containers that ship them), so fixes must
not have to be applied to per-file clones.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap


def run_forced_devices(src: str, devices: int = 8, timeout: int = 560) -> str:
    """Run dedented ``src`` in a child python with ``devices`` fake host
    devices; returns its stdout, asserting a clean exit."""
    prog = (f"import os\n"
            f"os.environ['XLA_FLAGS'] = "
            f"'--xla_force_host_platform_device_count={devices}'\n"
            + textwrap.dedent(src))
    r = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        timeout=timeout,
        # minimal env, but HOME/PATH from the caller — hardcoding this dev
        # container's /root breaks on CI runners whose HOME is elsewhere
        env={"PYTHONPATH": "src",
             "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
             "HOME": os.environ.get("HOME", "/root"),
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")})
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout
