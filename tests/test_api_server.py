"""Async streaming API tests (serving/api.py + launch/serve_api.py).

The serving contract: the async layer changes WHEN tokens surface, never
WHICH tokens — f32 greedy streams through ``AsyncServingEngine`` are
byte-identical to offline ``engine.run()`` in all three serving modes.
Plus: co-scheduled streams interleave (a short request's first token beats
a long request's finish), mid-stream disconnects cancel cleanly, and the
in-process HTTP/SSE wire path round-trips.
"""
from __future__ import annotations

import asyncio
import json

import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve_api import ApiServer, build_engine, parse_args
from repro.serving import AsyncServingEngine, SamplingParams

TIMEOUT_S = 300.0

BASE_ARGS = ["--arch", "tiny-relu", "--f32", "--n-slots", "2",
             "--block-size", "8", "--max-blocks", "4", "--gamma", "2"]


def _engine(mode: str = "plain"):
    return build_engine(parse_args(BASE_ARGS + ["--mode", mode]))


def _prompts(n: int = 4, seed: int = 0):
    vocab = get_config("tiny-relu").vocab_size
    rng = np.random.RandomState(seed)
    return [[int(t) for t in rng.randint(0, vocab, 3 + 2 * i)]
            for i in range(n)]


def _run(coro):
    return asyncio.run(asyncio.wait_for(coro, TIMEOUT_S))


async def _collect(api, prompt, max_new, **kw):
    """Stream one request; returns (streamed tokens, streamed logprobs,
    terminal event)."""
    tokens, lps, final = [], [], None
    async for ev in api.stream(prompt, max_new, **kw):
        if ev.finished:
            final = ev
        else:
            tokens.append(ev.token)
            lps.append(ev.logprob)
    return tokens, lps, final


@pytest.mark.parametrize("mode", ["plain", "spec", "predictor"])
def test_greedy_streams_byte_identical_to_engine_run(mode):
    """The tentpole exactness contract, per serving mode. One engine serves
    both paths (offline run() first, then the async API) so the comparison
    is over identical weights and identical jitted executables."""
    eng = _engine(mode)
    prompts = _prompts(4)
    budgets = [4 + i % 3 for i in range(len(prompts))]

    uids = [eng.submit(p, m) for p, m in zip(prompts, budgets)]
    ref = eng.run()

    async def serve():
        async with AsyncServingEngine(eng) as api:
            return await asyncio.gather(*[
                _collect(api, p, m) for p, m in zip(prompts, budgets)])

    got = _run(serve())
    for uid, m, (tokens, lps, final) in zip(uids, budgets, got):
        want = ref[uid]
        assert tokens == [int(t) for t in want.tokens]
        np.testing.assert_array_equal(
            np.asarray(lps, np.float32),
            np.asarray([float(x) for x in want.logprobs], np.float32))
        # terminal event mirrors the stream and carries latency metrics
        assert final is not None and final.finish_reason == "length"
        assert tokens == [int(t) for t in final.result.tokens]
        assert len(tokens) == m
        assert final.ttft_s is not None and final.ttft_s >= 0.0
        assert final.total_s is not None and final.total_s >= final.ttft_s


def test_streams_interleave_across_requests():
    """A short request co-scheduled next to a long one streams its first
    token BEFORE the long request finishes — the async layer surfaces
    tokens per step, not per retirement."""
    eng = _engine("plain")
    p_long, p_short = _prompts(2, seed=3)
    order = []

    async def client(api, tag, prompt, max_new):
        async for ev in api.stream(prompt, max_new):
            order.append((tag, "done" if ev.finished else ev.index))

    async def serve():
        async with AsyncServingEngine(eng) as api:
            await asyncio.gather(client(api, "long", p_long, 12),
                                 client(api, "short", p_short, 3))

    _run(serve())
    short_first = order.index(("short", 0))
    long_done = order.index(("long", "done"))
    assert short_first < long_done, order
    # and the short stream fully retired while the long one kept going
    assert order.index(("short", "done")) < long_done, order


def test_midstream_disconnect_cancels_and_serving_continues():
    """Breaking out of events() (the client-disconnect path) retires the
    request with finish_reason "cancelled" and partial output; the engine
    keeps serving other traffic with identical results."""
    eng = _engine("plain")
    p0, p1 = _prompts(2, seed=5)
    ref_uid = eng.submit(p1, 5)
    ref = eng.run()[ref_uid]

    async def serve():
        async with AsyncServingEngine(eng) as api:
            uid = await api.submit(p0, 12)
            got = []
            async for ev in api.events(uid):
                got.append(ev.token)
                if len(got) >= 2:
                    break  # closes the generator -> cancel(uid)
            tokens, lps, final = await _collect(api, p1, 5)
            return uid, got, tokens, final

    uid, got, tokens, final = _run(serve())
    res = eng.scheduler.results[uid]
    assert res.finish_reason == "cancelled"
    assert len(res.tokens) < 12  # partial output only
    assert [int(t) for t in res.tokens][:2] == got
    assert tokens == [int(t) for t in ref.tokens]
    assert final.finish_reason == "length"


def test_submit_validation_surfaces_to_the_caller():
    eng = _engine("plain")

    async def serve():
        async with AsyncServingEngine(eng) as api:
            with pytest.raises(ValueError, match="max_new"):
                await api.submit(_prompts(1)[0], 0)
            with pytest.raises(ValueError, match="empty prompt"):
                await api.submit([], 4)
            with pytest.raises(ValueError, match="blocks"):
                await api.submit(list(range(100)), 4)
            # the loop is still healthy after rejects
            ev = await api.generate(_prompts(1)[0], 3)
            assert ev.finish_reason == "length"

    _run(serve())


# -- in-process HTTP/SSE wire path -------------------------------------------


async def _http(port, method, path, body=None):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    raw = json.dumps(body).encode() if body is not None else b""
    writer.write(f"{method} {path} HTTP/1.1\r\nHost: t\r\n"
                 f"Content-Length: {len(raw)}\r\n\r\n".encode() + raw)
    await writer.drain()
    data = await reader.read()
    writer.close()
    try:
        await writer.wait_closed()
    except (ConnectionResetError, BrokenPipeError):
        pass
    head, _, payload = data.partition(b"\r\n\r\n")
    return head.split(b" ", 2)[1].decode(), payload


def _parse_sse(payload: bytes):
    tokens, final, done = [], None, False
    for frame in payload.split(b"\n\n"):
        for line in frame.splitlines():
            if not line.startswith(b"data: "):
                continue
            if line[6:] == b"[DONE]":
                done = True
            else:
                ev = json.loads(line[6:])
                if ev.get("done"):
                    final = ev
                else:
                    tokens.append(ev["token"])
    return tokens, final, done


def test_http_sse_roundtrip():
    eng = _engine("plain")
    prompt = _prompts(1, seed=9)[0]
    ref_uid = eng.submit(prompt, 4)
    ref = [int(t) for t in eng.run()[ref_uid].tokens]

    async def serve():
        async with AsyncServingEngine(eng) as api:
            server = ApiServer(api, mode="plain")
            await server.start(port=0)
            try:
                status, body = await _http(server.port, "GET", "/healthz")
                assert status == "200" and json.loads(body)["ok"]

                status, body = await _http(
                    server.port, "POST", "/v1/generate",
                    {"prompt": prompt, "max_new": 4})
                assert status == "200"
                tokens, final, done = _parse_sse(body)
                assert done and final is not None
                assert tokens == final["tokens"] == ref
                assert final["finish_reason"] == "length"
                assert final["ttft_s"] is not None

                status, body = await _http(
                    server.port, "POST", "/v1/generate",
                    {"prompt": prompt, "max_new": 4, "stream": False,
                     "temperature": 0.9, "top_k": 8, "seed": 1})
                assert status == "200"
                one = json.loads(body)
                assert one["done"] and len(one["tokens"]) == 4

                status, body = await _http(server.port, "POST",
                                           "/v1/generate", {"max_new": 4})
                assert status == "400" and b"prompt" in body
                status, _ = await _http(server.port, "GET", "/nope")
                assert status == "404"
            finally:
                await server.aclose()

    _run(serve())


def test_http_schema_v1_priority_slo_and_unknown_field_400():
    """Schema v1: ``priority``/``slo_ms`` are accepted and surfaced in the
    terminal event (with ``preemptions`` and the ``slo_met`` verdict); an
    unknown field is a 400 that NAMES the offender instead of being
    silently dropped."""
    eng = _engine("plain")
    prompt = _prompts(1, seed=11)[0]

    async def serve():
        async with AsyncServingEngine(eng) as api:
            server = ApiServer(api, mode="plain")
            await server.start(port=0)
            try:
                status, body = await _http(
                    server.port, "POST", "/v1/generate",
                    {"prompt": prompt, "max_new": 3, "stream": False,
                     "priority": 2, "slo_ms": 60_000.0})
                assert status == "200"
                one = json.loads(body)
                assert one["done"] and one["priority"] == 2
                assert one["preemptions"] == 0
                assert one["slo_met"] is True  # a minute did not elapse
                # no SLO -> no verdict, priority defaults to 0
                status, body = await _http(
                    server.port, "POST", "/v1/generate",
                    {"prompt": prompt, "max_new": 3, "stream": False})
                one = json.loads(body)
                assert one["priority"] == 0 and one["slo_met"] is None

                status, body = await _http(
                    server.port, "POST", "/v1/generate",
                    {"prompt": prompt, "max_new": 3, "prioritty": 1})
                assert status == "400" and b"prioritty" in body
                # a schema error must not have consumed engine capacity
                status, _ = await _http(
                    server.port, "POST", "/v1/generate",
                    {"prompt": prompt, "max_new": 3})
                assert status == "200"
            finally:
                await server.aclose()

    _run(serve())
