"""Chunked prefill + prefix caching through the continuous-batching engine
(ISSUE 4 tentpole): chunked prefill must reproduce whole-prompt prefill
greedy tokens exactly (f32), a prefix-cache hit must decode byte-identical
to a cold prefill of the same prompt — in all three serving modes — plus
the admission bugfixes (submit pool validation, run() never silently
dropping queued requests)."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import registry
from repro.serving import ContinuousBatchingEngine, EngineConfig
from repro.serving.scheduler import Request, Scheduler


def _setup(name="tiny-relu", dtype="float32"):
    cfg = get_config(name)
    if dtype is not None:
        cfg = cfg.replace(compute_dtype=dtype)
    fam = registry.get_family(cfg)
    params = fam.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, lengths, seed=1):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, s).astype(np.int32)
            for s in lengths]


def _engine(cfg, params, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("block_size", 8)
    kw.setdefault("max_blocks_per_seq", 6)
    return ContinuousBatchingEngine(cfg, params, config=EngineConfig(**kw))


def _serve(cfg, params, prompts, max_new, reuse_window=0, **kw):
    eng = _engine(cfg, params, **kw)
    uids = [eng.submit(p, max_new, reuse_window=reuse_window)
            for p in prompts]
    res = eng.run()
    return [res[u].tokens for u in uids], eng


def _serve_serial(eng, prompt, max_new):
    """Submit one request and drain — serial traffic through a persistent
    engine, so later requests can hit the prefix cache the earlier ones
    populated."""
    uid = eng.submit(prompt, max_new)
    eng.run()
    return eng.scheduler.results[uid].tokens


def _spec_kw(cfg, fam, seed=9):
    dcfg = cfg.replace(name=f"{cfg.name}-draft", n_layers=1)
    return dict(draft_cfg=dcfg,
                draft_params=fam.init_params(jax.random.PRNGKey(seed), dcfg),
                gamma=3)


def _predictor_kw(cfg, params):
    from repro.predictor import calibrate
    calib = {"tokens": jax.random.randint(jax.random.PRNGKey(7), (4, 24),
                                          0, cfg.vocab_size)}
    return dict(predictor=calibrate(params, cfg, calib, kind="sign",
                                    probe_dtype="float32",
                                    target_recall=1.0, tile=1))


def _mode_kw(mode, cfg, params):
    if mode == "spec":
        return _spec_kw(cfg, registry.get_family(cfg))
    if mode == "predictor":
        return _predictor_kw(cfg, params)
    return {}


# ---------------------------------------------------------------------------
# exactness: chunked prefill == whole-prompt prefill (acceptance criterion)


@pytest.mark.parametrize("name", ["tiny-relu", "tiny-opt"])
@pytest.mark.parametrize("chunk", [4, 8, 64])
def test_chunked_prefill_matches_whole_prompt(name, chunk):
    """Chunk sizes that split mid-block, align with blocks, and swallow the
    whole prompt in one window must all reproduce the whole-prompt greedy
    stream exactly at f32."""
    cfg, params = _setup(name)
    prompts = _prompts(cfg, [9, 14, 6])
    ref, _ = _serve(cfg, params, prompts, 10)
    got, _ = _serve(cfg, params, prompts, 10, prefill_chunk=chunk)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("mode", ["spec", "predictor"])
def test_chunked_prefill_matches_whole_prompt_other_modes(mode):
    cfg, params = _setup("tiny-relu")
    kw = _mode_kw(mode, cfg, params)
    prompts = _prompts(cfg, [9, 14, 6], seed=2)
    ref, _ = _serve(cfg, params, prompts, 11, **kw)
    got, _ = _serve(cfg, params, prompts, 11, prefill_chunk=4, **kw)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a, b)


def test_chunked_prefill_gamma_requests_exact():
    """γ-window requests are unaffected by HOW the prompt was prefilled
    (warm_masks off): the age-0 dense refresh anchors the same phase."""
    cfg, params = _setup("tiny-relu")
    prompts = _prompts(cfg, [10, 13], seed=3)
    ref, _ = _serve(cfg, params, prompts, 9, reuse_window=3)
    got, _ = _serve(cfg, params, prompts, 9, reuse_window=3, prefill_chunk=4)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a, b)


def test_chunked_prefill_interleaves_with_decode():
    """A request admitted while another is mid-decode chunk-prefills in the
    same engine steps that keep decoding the first — and both streams stay
    exactly their solo streams."""
    cfg, params = _setup("tiny-relu")
    p1, p2 = _prompts(cfg, [9, 14], seed=4)
    eng = _engine(cfg, params, prefill_chunk=4)
    u1 = eng.submit(p1, max_new=12)
    for _ in range(5):
        eng.step()
    out_before = len(eng.scheduler.slots[0].out)
    u2 = eng.submit(p2, max_new=8)
    eng.step()  # prefills u2's first chunk AND decodes u1
    s1 = [s for s in eng.scheduler.slots if s and s.request.uid == u1][0]
    s2 = [s for s in eng.scheduler.slots if s and s.request.uid == u2][0]
    assert len(s1.out) == out_before + 1  # u1 kept decoding
    assert 0 < s2.prefilled < s2.request.prompt_len  # u2 mid-prefill
    res = eng.run()
    ref1, _ = _serve(cfg, params, [p1], 12, prefill_chunk=4)
    ref2, _ = _serve(cfg, params, [p2], 8, prefill_chunk=4)
    np.testing.assert_array_equal(res[u1].tokens, ref1[0])
    np.testing.assert_array_equal(res[u2].tokens, ref2[0])


# ---------------------------------------------------------------------------
# exactness: prefix-cache hit == cold prefill (acceptance criterion)


@pytest.mark.parametrize("mode", ["plain", "spec", "predictor"])
def test_prefix_cache_hit_byte_identical(mode):
    cfg, params = _setup("tiny-relu")
    kw = _mode_kw(mode, cfg, params)
    rng = np.random.RandomState(5)
    shared = rng.randint(0, cfg.vocab_size, 16).astype(np.int32)  # 2 blocks
    pa = np.concatenate([shared,
                         rng.randint(0, cfg.vocab_size, 3).astype(np.int32)])
    pb = np.concatenate([shared,
                         rng.randint(0, cfg.vocab_size, 5).astype(np.int32)])
    cold = _engine(cfg, params, prefill_chunk=4, **kw)
    hot = _engine(cfg, params, prefill_chunk=4, prefix_cache=True, **kw)
    for p in (pa, pb, pa):  # third request re-hits pa's full shareable run
        np.testing.assert_array_equal(_serve_serial(hot, p, 8),
                                      _serve_serial(cold, p, 8))
    assert hot.prefill_tokens_saved() == 16 + 16  # pb hit + pa re-hit
    assert hot.prefix_hit_rate() > 0.0
    assert cold.prefill_tokens_saved() == 0


def test_prefix_blocks_shared_and_refcounted():
    """A later request sharing the prefix maps the SAME pool blocks
    (refcount++), and retirement drops references without freeing blocks
    out from under the trie."""
    cfg, params = _setup("tiny-relu")
    rng = np.random.RandomState(6)
    shared = rng.randint(0, cfg.vocab_size, 16).astype(np.int32)
    pa = np.concatenate([shared,
                         rng.randint(0, cfg.vocab_size, 3).astype(np.int32)])
    pb = np.concatenate([shared,
                         rng.randint(0, cfg.vocab_size, 5).astype(np.int32)])
    eng = _engine(cfg, params, prefill_chunk=4, prefix_cache=True)
    ua = eng.submit(pa, max_new=10)
    for _ in range(6):  # pa prefills (5 chunks) and starts decoding
        eng.step()
    ub = eng.submit(pb, max_new=10)
    eng.step()  # pb admitted: prefix mapped from the trie
    sched = eng.scheduler
    sa = [s for s in sched.slots if s and s.request.uid == ua][0]
    sb = [s for s in sched.slots if s and s.request.uid == ub][0]
    assert sb.blocks[:2] == sa.blocks[:2]  # shared prefix blocks
    assert sb.cached_tokens == 16
    for b in sa.blocks[:2]:
        # slot a + slot b + the trie each hold one reference
        assert sched.allocator.refcount(b) == 3
    res = eng.run()
    assert res[ub].cached_prompt_tokens == 16
    # both retired: only the trie still references the cached blocks
    for b in sa.blocks[:2]:
        assert sched.allocator.refcount(b) == 1
    n_cached = len(sched.prefix)
    assert sched.allocator.available == (
        sched.allocator.n_blocks - 1 - n_cached)


def test_prefix_cache_evicts_under_pool_pressure():
    """Serial distinct prompts through a minimal pool: cached prefixes of
    retired requests must be evicted to admit new work — nothing deadlocks,
    every request completes."""
    cfg, params = _setup("tiny-relu")
    prompts = _prompts(cfg, [17, 18, 17, 19], seed=7)
    # pool = one request's worst case: admission must reclaim trie blocks
    eng = ContinuousBatchingEngine(cfg, params, config=EngineConfig(
        n_slots=1, block_size=8, max_blocks_per_seq=4, n_blocks=5,
        prefill_chunk=8, prefix_cache=True))
    uids = [eng.submit(p, max_new=8) for p in prompts]
    res = eng.run()
    assert sorted(res) == sorted(uids)
    assert all(len(res[u].tokens) == 8 for u in uids)


def test_spec_target_as_draft_chunked_prefill_accepts_everything():
    """Target-as-draft through CHUNKED prefill must still accept every
    proposal: the draft pool's chunk prefill has to produce the same prompt
    K/V the target pool got (a draft prefill that e.g. dropped the FFN
    contribution would silently collapse acceptance while leaving the
    output stream exact)."""
    cfg, params = _setup("tiny-relu")
    eng = _engine(cfg, params, prefill_chunk=4, draft_cfg=cfg,
                  draft_params=params, gamma=3)
    uids = [eng.submit(p, max_new=9) for p in _prompts(cfg, [9, 14], seed=8)]
    res = eng.run()
    for u in uids:
        assert res[u].accept_rate == 1.0


def test_warm_masks_skip_age0_refresh_and_cover_prompt_harvest():
    """warm_masks seeds the first γ-window from the prefill chunks'
    accumulated union activity: the age-0 dense refresh is skipped (the
    mask binds immediately) and some weight I/O is saved on that step.
    Output may differ from the cold-first-window stream — it is a γ-style
    approximation either way."""
    cfg, params = _setup("tiny-relu")
    (p,) = _prompts(cfg, [13], seed=8)  # 4 chunks of 4: accumulation binds
    eng = _engine(cfg, params, prefill_chunk=4, warm_masks=True)
    uid = eng.submit(p, max_new=10, reuse_window=4)
    while not eng.scheduler.active_indices():
        eng._admit()  # chunk-prefill to completion, no decode in between
    sched = eng.scheduler
    (i,) = sched.active_indices()
    assert sched.slots[i].warm and sched.slots[i].age == 0
    _, _, _, refresh = sched.batch_arrays()
    assert not refresh[i]  # the age-0 dense refresh is skipped...
    mask0 = np.asarray(eng.masks[:, i, :])
    assert 0 < mask0.sum() < mask0.size  # ...because a real mask is bound
    res = eng.run()
    assert len(res[uid].tokens) == 10
    assert eng.weight_io_saved() > 0.0
    # a COLD engine refreshes densely at age 0 on the same request
    cold = _engine(cfg, params, prefill_chunk=4)
    cold.submit(p, max_new=10, reuse_window=4)
    while not cold.scheduler.active_indices():
        cold._admit()
    (j,) = cold.scheduler.active_indices()
    assert not cold.scheduler.slots[j].warm
    _, _, _, refresh_c = cold.scheduler.batch_arrays()
    assert refresh_c[j]


# ---------------------------------------------------------------------------
# admission bugfixes (satellites)


def test_submit_rejects_request_larger_than_pool():
    """A request needing more blocks than the pool could EVER free must be
    rejected at submit — previously it queued forever: admit() broke at the
    head, run() drained everything else, and the uid silently vanished."""
    sched = Scheduler(n_slots=2, n_blocks=4, block_size=4,
                      max_blocks_per_seq=8)
    ok = Request(uid=1, tokens=np.zeros(4, np.int32), max_new=4)  # 2 blocks
    sched.submit(ok)
    bad = Request(uid=2, tokens=np.zeros(12, np.int32), max_new=8)  # 5 > 3
    with pytest.raises(ValueError, match="pool"):
        sched.submit(bad)
    assert len(sched.queue) == 1  # the valid request is unaffected


def test_run_raises_on_unadmittable_head_instead_of_silent_drop():
    """If an unadmittable request reaches the queue anyway (emulating a
    policy bug), run() must raise — not return a results dict with the uid
    quietly missing after spinning to max_steps."""
    cfg, params = _setup("tiny-relu")
    eng = _engine(cfg, params)
    good = eng.submit(_prompts(cfg, [6], seed=9)[0], max_new=4)
    # bypass submit()'s validation: 200 tokens needs 50 blocks > pool 12
    eng.scheduler.queue.push(
        Request(uid=999, tokens=np.zeros(200, np.int32), max_new=200))
    with pytest.raises(RuntimeError, match="deadlock"):
        eng.run()
    # the admissible request ahead of it was still served, not dropped
    assert good in eng.scheduler.results


@pytest.mark.parametrize("engine_kw", [
    {},
    {"prefill_chunk": 4},
    {"prefill_chunk": 4, "prefix_cache": True},
])
def test_every_submitted_uid_lands_in_results(engine_kw):
    cfg, params = _setup("tiny-relu", dtype=None)  # default bf16 path too
    rng = np.random.RandomState(10)
    shared = rng.randint(0, cfg.vocab_size, 8).astype(np.int32)
    prompts = [np.concatenate([shared, p]) for p in
               _prompts(cfg, [5, 9, 2, 7, 4, 11], seed=10)]
    eng = _engine(cfg, params, **engine_kw)
    uids = [eng.submit(p, max_new=5) for p in prompts]
    res = eng.run()
    assert sorted(res) == sorted(uids)
    assert all(len(res[u].tokens) == 5 for u in uids)
    assert eng.scheduler.allocator.available == (
        eng.scheduler.allocator.n_blocks - 1
        - (len(eng.scheduler.prefix) if eng.scheduler.prefix else 0))


def test_engine_flag_validation():
    cfg, params = _setup("tiny-relu")
    with pytest.raises(ValueError, match="prefix_cache"):
        _engine(cfg, params, prefix_cache=True)
    with pytest.raises(ValueError, match="warm_masks"):
        _engine(cfg, params, warm_masks=True)
