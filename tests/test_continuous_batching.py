"""Continuous-batching engine tests: mid-decode admission exactness, paged
block lifecycle, per-request γ-window masks under batching, and the paged
cache primitives themselves."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import common as cm
from repro.models import registry
from repro.serving import ContinuousBatchingEngine, ServeEngine
from repro.serving.scheduler import BlockAllocator, Request, Scheduler


def _setup(name="tiny-relu"):
    cfg = get_config(name)
    fam = registry.get_family(cfg)
    params = fam.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, lengths, seed=1):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, s).astype(np.int32)
            for s in lengths]


def _engine(cfg, params, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("block_size", 8)
    kw.setdefault("max_blocks_per_seq", 6)
    return ContinuousBatchingEngine(cfg, params, **kw)


def _solo(cfg, params, prompt, max_new, reuse_window=0, **kw):
    eng = _engine(cfg, params, **kw)
    uid = eng.submit(prompt, max_new, reuse_window=reuse_window)
    return eng.run()[uid].tokens


# ---------------------------------------------------------------------------
# paged cache primitives


def test_paged_roundtrip_matches_contiguous():
    """Writing token-by-token through a shuffled block table and gathering
    reproduces the contiguous head-major cache exactly."""
    rng = np.random.RandomState(0)
    N, kvp, bs, hd, S = 7, 2, 4, 8, 12
    pages = jnp.zeros((1, N, kvp, bs, hd))
    table = jnp.asarray([[5, 2, 6]], jnp.int32)  # out-of-order blocks
    ref = rng.randn(S, kvp, hd).astype(np.float32)
    for t in range(S):
        pages = cm.paged_write_token(pages, 0, table,
                                     jnp.asarray([t], jnp.int32),
                                     jnp.asarray(ref[t][None]), bs)
    got = cm.paged_gather(pages[0], table)  # (1, kvp, 3*bs, hd)
    np.testing.assert_allclose(np.asarray(got[0, :, :S]),
                               ref.transpose(1, 0, 2), rtol=0, atol=0)


def test_paged_prefill_write_matches_token_writes():
    rng = np.random.RandomState(1)
    L, N, kvp, bs, hd, s = 2, 5, 2, 4, 3, 6
    kv = jnp.asarray(rng.randn(L, s, kvp, hd), jnp.float32)
    blocks = jnp.asarray([3, 1], jnp.int32)
    pages = cm.paged_write_prefill(jnp.zeros((L, N, kvp, bs, hd)), kv,
                                   blocks, bs)
    got = cm.paged_gather(pages[1], blocks[None])
    np.testing.assert_allclose(np.asarray(got[0, :, :s]),
                               np.asarray(kv[1]).transpose(1, 0, 2))
    # pad region inside the last block is zero
    assert float(jnp.abs(got[0, :, s:]).sum()) == 0.0


# ---------------------------------------------------------------------------
# scheduler / allocator lifecycle


def test_allocator_reserves_scratch_and_recycles():
    al = BlockAllocator(5)
    assert al.available == 4  # block 0 reserved
    got = al.alloc(4)
    assert got is not None and cm.SCRATCH_BLOCK not in got
    assert al.alloc(1) is None
    al.free(got)
    assert al.available == 4


def test_scheduler_fifo_waits_for_blocks():
    sched = Scheduler(n_slots=2, n_blocks=5, block_size=4,
                      max_blocks_per_seq=4)
    big = Request(uid=1, tokens=np.zeros(8, np.int32), max_new=8)   # 4 blocks
    small = Request(uid=2, tokens=np.zeros(2, np.int32), max_new=2)  # 1 block
    sched.submit(big)
    sched.submit(small)
    admitted = sched.admit(step=0)
    # big takes all 4 free blocks; small must NOT jump the queue into slot 1
    assert [s.request.uid for _, s in admitted] == [1]
    assert len(sched.queue) == 1 and sched.allocator.available == 0
    # retiring big frees its blocks and lets small in
    sched.slots[0].out = [0] * 8
    sched.retire_finished(step=3)
    assert sched.allocator.available == 4
    assert [s.request.uid for _, s in sched.admit(step=3)] == [2]


def test_engine_frees_all_blocks_and_reuses_pool():
    """6 requests through a pool that only fits ~2 concurrently: retirement
    must recycle blocks or the later requests could never be admitted."""
    cfg, params = _setup()
    eng = _engine(cfg, params, n_slots=2, n_blocks=9)  # 8 usable blocks
    prompts = _prompts(cfg, [6, 10, 14, 5, 9, 12])
    uids = [eng.submit(p, max_new=6) for p in prompts]
    res = eng.run()
    assert sorted(res) == sorted(uids)
    assert all(res[u].tokens.shape == (6,) for u in uids)
    assert eng.scheduler.allocator.available == 8  # everything returned


# ---------------------------------------------------------------------------
# exactness: continuous batching == solo decoding


def test_mid_decode_admission_matches_solo():
    """A request admitted while another is mid-decode produces exactly the
    tokens it would produce alone."""
    cfg, params = _setup()
    p1, p2 = _prompts(cfg, [9, 14])

    eng = _engine(cfg, params)
    u1 = eng.submit(p1, max_new=12)
    for _ in range(5):  # r1 decodes alone for 5 steps
        eng.step()
    u2 = eng.submit(p2, max_new=8)  # joins mid-flight
    res = eng.run()

    np.testing.assert_array_equal(res[u1].tokens, _solo(cfg, params, p1, 12))
    np.testing.assert_array_equal(res[u2].tokens, _solo(cfg, params, p2, 8))
    assert res[u2].admitted_step > res[u1].admitted_step


def test_queued_overflow_matches_solo_and_legacy():
    """More requests than slots: queueing + slot reuse keeps every stream
    exact, and agrees with the legacy single-batch engine."""
    cfg, params = _setup()
    prompts = _prompts(cfg, [8, 12, 16, 10])
    eng = _engine(cfg, params, n_slots=2)
    uids = [eng.submit(p, max_new=7) for p in prompts]
    res = eng.run()
    legacy = ServeEngine(cfg, params, max_len=64)
    for uid, p in zip(uids, prompts):
        np.testing.assert_array_equal(res[uid].tokens,
                                      _solo(cfg, params, p, 7))
        leg = legacy.generate({"tokens": jnp.asarray(p[None], jnp.int32)},
                              max_new=7)
        np.testing.assert_array_equal(res[uid].tokens, leg.tokens[0])
        np.testing.assert_allclose(res[uid].logprobs, leg.logprobs[0],
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# γ-window weight reuse under batching


def test_gamma_masks_stay_per_request():
    """Co-scheduled requests with different γ each behave exactly as they
    would alone — the batched masks must not leak across slots."""
    cfg, params = _setup()
    p1, p2 = _prompts(cfg, [10, 13], seed=3)
    eng = _engine(cfg, params)
    u1 = eng.submit(p1, max_new=9, reuse_window=3)  # masked windows
    u2 = eng.submit(p2, max_new=9)                  # dense neighbour
    res = eng.run()
    np.testing.assert_array_equal(
        res[u1].tokens, _solo(cfg, params, p1, 9, reuse_window=3))
    np.testing.assert_array_equal(res[u2].tokens, _solo(cfg, params, p2, 9))
    assert eng.weight_io_saved() > 0.0  # γ actually skipped weight reads


def test_gamma_window_phase_follows_admission():
    """The γ refresh phase is anchored to each request's own age, not the
    engine's global step: staggered admission must not change outputs."""
    cfg, params = _setup()
    p1, p2 = _prompts(cfg, [8, 8], seed=4)
    eng = _engine(cfg, params)
    u1 = eng.submit(p1, max_new=10, reuse_window=4)
    eng.step()
    eng.step()  # u2 arrives at a different global phase
    u2 = eng.submit(p2, max_new=10, reuse_window=4)
    res = eng.run()
    np.testing.assert_array_equal(
        res[u2].tokens, _solo(cfg, params, p2, 10, reuse_window=4))
    np.testing.assert_array_equal(
        res[u1].tokens, _solo(cfg, params, p1, 10, reuse_window=4))


def test_gamma_one_equals_dense():
    """γ=1 refreshes every step, so the mask never binds."""
    cfg, params = _setup()
    (p,) = _prompts(cfg, [11], seed=5)
    t_dense = _solo(cfg, params, p, 8)
    t_g1 = _solo(cfg, params, p, 8, reuse_window=1)
    np.testing.assert_array_equal(t_dense, t_g1)


def test_legacy_gamma_agreement():
    """CB γ-window decode agrees with the legacy engine's Fig. 7c path for a
    single request (both refresh at age % γ == 0)."""
    cfg, params = _setup()
    (p,) = _prompts(cfg, [12], seed=6)
    cb = _solo(cfg, params, p, 10, reuse_window=3)
    leg = ServeEngine(cfg, params, max_len=64).generate(
        {"tokens": jnp.asarray(p[None], jnp.int32)}, max_new=10,
        reuse_window=3)
    np.testing.assert_array_equal(cb, leg.tokens[0])


# ---------------------------------------------------------------------------
# sparsity tracking through the batched path


def test_tracked_aggregated_sparsity_per_request():
    cfg, params = _setup()
    p1, p2 = _prompts(cfg, [8, 12], seed=7)
    eng = _engine(cfg, params, track_sparsity=True)
    u1 = eng.submit(p1, max_new=6)
    u2 = eng.submit(p2, max_new=6)
    eng.run()
    for uid in (u1, u2):
        tr = eng.trackers[uid]
        # first token comes from prefill; the remaining 5 from decode steps
        assert len(tr.curve) == 5
        # aggregated sparsity is non-increasing (paper Sec. 5.1)
        assert all(b <= a + 1e-9 for a, b in zip(tr.curve, tr.curve[1:]))
        assert 0.0 <= tr.aggregated_sparsity() <= 1.0
