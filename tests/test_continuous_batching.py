"""Continuous-batching engine tests: mid-decode admission exactness, paged
block lifecycle, per-request γ-window masks under batching, speculative
decoding through the engine, and the paged cache primitives themselves."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import common as cm
from repro.models import registry
from repro.serving import ContinuousBatchingEngine, EngineConfig, ServeEngine
from repro.serving.scheduler import BlockAllocator, Request, Scheduler


def _setup(name="tiny-relu"):
    cfg = get_config(name)
    fam = registry.get_family(cfg)
    params = fam.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, lengths, seed=1):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, s).astype(np.int32)
            for s in lengths]


def _engine(cfg, params, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("block_size", 8)
    kw.setdefault("max_blocks_per_seq", 6)
    return ContinuousBatchingEngine(cfg, params, config=EngineConfig(**kw))


def _solo(cfg, params, prompt, max_new, reuse_window=0, **kw):
    eng = _engine(cfg, params, **kw)
    uid = eng.submit(prompt, max_new, reuse_window=reuse_window)
    return eng.run()[uid].tokens


# ---------------------------------------------------------------------------
# paged cache primitives


def test_paged_roundtrip_matches_contiguous():
    """Writing token-by-token through a shuffled block table and gathering
    reproduces the contiguous head-major cache exactly."""
    rng = np.random.RandomState(0)
    N, kvp, bs, hd, S = 7, 2, 4, 8, 12
    pages = jnp.zeros((1, N, kvp, bs, hd))
    table = jnp.asarray([[5, 2, 6]], jnp.int32)  # out-of-order blocks
    ref = rng.randn(S, kvp, hd).astype(np.float32)
    for t in range(S):
        pages = cm.paged_write_token(pages, 0, table,
                                     jnp.asarray([t], jnp.int32),
                                     jnp.asarray(ref[t][None]), bs)
    got = cm.paged_gather(pages[0], table)  # (1, kvp, 3*bs, hd)
    np.testing.assert_allclose(np.asarray(got[0, :, :S]),
                               ref.transpose(1, 0, 2), rtol=0, atol=0)


def test_paged_prefill_write_matches_token_writes():
    rng = np.random.RandomState(1)
    L, N, kvp, bs, hd, s = 2, 5, 2, 4, 3, 6
    kv = jnp.asarray(rng.randn(L, s, kvp, hd), jnp.float32)
    blocks = jnp.asarray([3, 1], jnp.int32)
    pages = cm.paged_write_prefill(jnp.zeros((L, N, kvp, bs, hd)), kv,
                                   blocks, bs)
    got = cm.paged_gather(pages[1], blocks[None])
    np.testing.assert_allclose(np.asarray(got[0, :, :s]),
                               np.asarray(kv[1]).transpose(1, 0, 2))
    # pad region inside the last block is zero
    assert float(jnp.abs(got[0, :, s:]).sum()) == 0.0


# ---------------------------------------------------------------------------
# scheduler / allocator lifecycle


def test_allocator_reserves_scratch_and_recycles():
    al = BlockAllocator(5)
    assert al.available == 4  # block 0 reserved
    got = al.alloc(4)
    assert got is not None and cm.SCRATCH_BLOCK not in got
    assert al.alloc(1) is None
    al.free(got)
    assert al.available == 4


def test_scheduler_fifo_waits_for_blocks():
    sched = Scheduler(n_slots=2, n_blocks=5, block_size=4,
                      max_blocks_per_seq=4)
    big = Request(uid=1, tokens=np.zeros(8, np.int32), max_new=8)   # 4 blocks
    small = Request(uid=2, tokens=np.zeros(2, np.int32), max_new=2)  # 1 block
    sched.submit(big)
    sched.submit(small)
    admitted = sched.admit(step=0)
    # big takes all 4 free blocks; small must NOT jump the queue into slot 1
    assert [s.request.uid for _, s in admitted] == [1]
    assert len(sched.queue) == 1 and sched.allocator.available == 0
    # retiring big frees its blocks and lets small in
    sched.slots[0].out = [0] * 8
    sched.retire_finished(step=3)
    assert sched.allocator.available == 4
    assert [s.request.uid for _, s in sched.admit(step=3)] == [2]


def test_engine_frees_all_blocks_and_reuses_pool():
    """6 requests through a pool that only fits ~2 concurrently: retirement
    must recycle blocks or the later requests could never be admitted."""
    cfg, params = _setup()
    eng = _engine(cfg, params, n_slots=2, n_blocks=9)  # 8 usable blocks
    prompts = _prompts(cfg, [6, 10, 14, 5, 9, 12])
    uids = [eng.submit(p, max_new=6) for p in prompts]
    res = eng.run()
    assert sorted(res) == sorted(uids)
    assert all(res[u].tokens.shape == (6,) for u in uids)
    assert eng.scheduler.allocator.available == 8  # everything returned


# ---------------------------------------------------------------------------
# exactness: continuous batching == solo decoding


def test_mid_decode_admission_matches_solo():
    """A request admitted while another is mid-decode produces exactly the
    tokens it would produce alone."""
    cfg, params = _setup()
    p1, p2 = _prompts(cfg, [9, 14])

    eng = _engine(cfg, params)
    u1 = eng.submit(p1, max_new=12)
    for _ in range(5):  # r1 decodes alone for 5 steps
        eng.step()
    u2 = eng.submit(p2, max_new=8)  # joins mid-flight
    res = eng.run()

    np.testing.assert_array_equal(res[u1].tokens, _solo(cfg, params, p1, 12))
    np.testing.assert_array_equal(res[u2].tokens, _solo(cfg, params, p2, 8))
    assert res[u2].admitted_step > res[u1].admitted_step


def test_queued_overflow_matches_solo_and_legacy():
    """More requests than slots: queueing + slot reuse keeps every stream
    exact, and agrees with the legacy single-batch engine."""
    cfg, params = _setup()
    prompts = _prompts(cfg, [8, 12, 16, 10])
    eng = _engine(cfg, params, n_slots=2)
    uids = [eng.submit(p, max_new=7) for p in prompts]
    res = eng.run()
    legacy = ServeEngine(cfg, params, max_len=64)
    for uid, p in zip(uids, prompts):
        np.testing.assert_array_equal(res[uid].tokens,
                                      _solo(cfg, params, p, 7))
        leg = legacy.generate({"tokens": jnp.asarray(p[None], jnp.int32)},
                              max_new=7)
        np.testing.assert_array_equal(res[uid].tokens, leg.tokens[0])
        np.testing.assert_allclose(res[uid].logprobs, leg.logprobs[0],
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# γ-window weight reuse under batching


def test_gamma_masks_stay_per_request():
    """Co-scheduled requests with different γ each behave exactly as they
    would alone — the batched masks must not leak across slots."""
    cfg, params = _setup()
    p1, p2 = _prompts(cfg, [10, 13], seed=3)
    eng = _engine(cfg, params)
    u1 = eng.submit(p1, max_new=9, reuse_window=3)  # masked windows
    u2 = eng.submit(p2, max_new=9)                  # dense neighbour
    res = eng.run()
    np.testing.assert_array_equal(
        res[u1].tokens, _solo(cfg, params, p1, 9, reuse_window=3))
    np.testing.assert_array_equal(res[u2].tokens, _solo(cfg, params, p2, 9))
    assert eng.weight_io_saved() > 0.0  # γ actually skipped weight reads


def test_gamma_window_phase_follows_admission():
    """The γ refresh phase is anchored to each request's own age, not the
    engine's global step: staggered admission must not change outputs."""
    cfg, params = _setup()
    p1, p2 = _prompts(cfg, [8, 8], seed=4)
    eng = _engine(cfg, params)
    u1 = eng.submit(p1, max_new=10, reuse_window=4)
    eng.step()
    eng.step()  # u2 arrives at a different global phase
    u2 = eng.submit(p2, max_new=10, reuse_window=4)
    res = eng.run()
    np.testing.assert_array_equal(
        res[u2].tokens, _solo(cfg, params, p2, 10, reuse_window=4))
    np.testing.assert_array_equal(
        res[u1].tokens, _solo(cfg, params, p1, 10, reuse_window=4))


def test_gamma_one_equals_dense():
    """γ=1 refreshes every step, so the mask never binds."""
    cfg, params = _setup()
    (p,) = _prompts(cfg, [11], seed=5)
    t_dense = _solo(cfg, params, p, 8)
    t_g1 = _solo(cfg, params, p, 8, reuse_window=1)
    np.testing.assert_array_equal(t_dense, t_g1)


def test_legacy_gamma_agreement():
    """CB γ-window decode agrees with the legacy engine's Fig. 7c path for a
    single request (both refresh at age % γ == 0)."""
    cfg, params = _setup()
    (p,) = _prompts(cfg, [12], seed=6)
    cb = _solo(cfg, params, p, 10, reuse_window=3)
    leg = ServeEngine(cfg, params, max_len=64).generate(
        {"tokens": jnp.asarray(p[None], jnp.int32)}, max_new=10,
        reuse_window=3)
    np.testing.assert_array_equal(cb, leg.tokens[0])


# ---------------------------------------------------------------------------
# speculative decoding through the engine (paper Sec. 5.2)


def _spec_setup(name, seed=9, draft_layers=1, dtype=None):
    cfg = get_config(name)
    if dtype is not None:
        cfg = cfg.replace(compute_dtype=dtype)
    fam = registry.get_family(cfg)
    params = fam.init_params(jax.random.PRNGKey(0), cfg)
    dcfg = cfg.replace(name=f"{name}-draft", n_layers=draft_layers)
    dparams = fam.init_params(jax.random.PRNGKey(seed), dcfg)
    return cfg, params, dcfg, dparams


@pytest.mark.parametrize("name", ["tiny-relu", "tiny-opt"])
def test_spec_exact_vs_autoregressive(name):
    """Greedy speculative output ≡ greedy autoregressive output through the
    same engine, including mid-decode admission — at f32 compute, where the
    W=1 decode and W=γ+1 verify executables agree bitwise."""
    cfg, params, dcfg, dparams = _spec_setup(name, dtype="float32")
    prompts = _prompts(cfg, [9, 14, 6], seed=2)

    ar = _engine(cfg, params)
    uids_ar = [ar.submit(p, max_new=11) for p in prompts]
    res_ar = ar.run()

    eng = _engine(cfg, params, draft_cfg=dcfg, draft_params=dparams, gamma=3)
    uids = [eng.submit(p, max_new=11) for p in prompts]
    res = eng.run()

    for ua, us in zip(uids_ar, uids):
        np.testing.assert_array_equal(res_ar[ua].tokens, res[us].tokens)
        np.testing.assert_allclose(res_ar[ua].logprobs, res[us].logprobs,
                                   rtol=1e-5, atol=1e-6)
    # the window is verified in ONE target forward per engine step
    assert sum(res[u].target_calls for u in uids) >= eng.t
    assert all(res[u].target_calls <= len(res[u].tokens) for u in uids)


def test_spec_stream_invariant_to_draft_quality():
    """The output stream must not depend on WHAT the draft proposes — only
    latency may. Good (target-as-draft, α=1), independent, and near-useless
    drafts must produce identical streams at default bf16: rejection +
    KV rewind runs every step for the bad draft, so any stale-KV leak or
    rollback bug shows up as divergence."""
    cfg, params, dcfg, dparams = _spec_setup("tiny-relu")
    prompts = _prompts(cfg, [10, 7], seed=5)

    dcfg2 = cfg.replace(name="tiny-relu-draft2", n_layers=1)
    dparams2 = registry.get_family(cfg).init_params(jax.random.PRNGKey(17),
                                                    dcfg2)
    streams = []
    for dc, dp in [(cfg, params), (dcfg, dparams), (dcfg2, dparams2)]:
        eng = _engine(cfg, params, draft_cfg=dc, draft_params=dp, gamma=3)
        uids = [eng.submit(p, max_new=13) for p in prompts]
        res = eng.run()
        streams.append([res[u] for u in uids])
    # the produced stream is always a prefix-walk of the SAME γ=3 verify
    # executable's greedy outputs, so it is identical whatever the draft
    # proposed — robust even at bf16 (acceptance COUNTS may differ across
    # platforms: draft argmax vs verify argmax crosses executables; γ
    # variation changes the verify executable and is asserted at f32 below)
    for other in streams[1:]:
        for a, b in zip(streams[0], other):
            np.testing.assert_array_equal(a.tokens, b.tokens)
    assert all(r.draft_proposed > 0 for s in streams for r in s)


def test_spec_target_as_draft_accepts_everything():
    """With the target as its own draft every proposal must be accepted,
    and the stream must not depend on γ — asserted at f32 compute, where
    the differently-shaped executables agree bitwise (at bf16 they may
    round differently)."""
    cfg, params, _, _ = _spec_setup("tiny-relu", dtype="float32")
    prompts = _prompts(cfg, [10, 7], seed=5)
    by_gamma = {}
    for gamma in (1, 3):
        eng = _engine(cfg, params, draft_cfg=cfg, draft_params=params,
                      gamma=gamma)
        uids = [eng.submit(p, max_new=13) for p in prompts]
        res = eng.run()
        by_gamma[gamma] = [res[u] for u in uids]
    for r in by_gamma[3]:
        assert r.accept_rate == 1.0
        # 13 tokens in at most ceil(13 / (γ+1)) = 4 verify windows
        assert r.target_calls <= 4
    for a, b in zip(by_gamma[1], by_gamma[3]):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_spec_window_capacity_guard_grows_or_shrinks():
    """The window-overflow guard: a slot whose verify window would run past
    its allocated blocks must get another pool block, or a shrunken window
    when the pool/table can't give one — never an out-of-range write.

    Today's admission reserves full lifetime blocks, so the overflow state
    is constructed the way a lazier policy would create it: the slot holds
    fewer blocks than its lifetime need."""
    sched = Scheduler(n_slots=1, n_blocks=7, block_size=4,
                      max_blocks_per_seq=4)
    sched.submit(Request(uid=1, tokens=np.zeros(5, np.int32), max_new=7))
    ((_, slot),) = sched.admit(step=0)  # reserves 3 blocks (12 positions)
    sched.seed(slot, 1, 0.0)
    # emulate admit-on-prompt: hand back everything past the prompt's blocks
    sched.allocator.free(slot.blocks[2:])
    del slot.blocks[2:]  # capacity 8 < next_pos(5) + W(4)

    # growth: a free pool block extends the table and the full window fits
    _, pos0, table, wlen = sched.spec_batch(W=4)
    assert len(slot.blocks) == 3 and wlen[0] == 4
    assert pos0[0] + wlen[0] <= len(slot.blocks) * 4
    assert sorted(table[0][:3]) == sorted(slot.blocks)

    # shrink: pool exhausted -> the window shrinks to the owned capacity
    sched.allocator.free(slot.blocks[2:])
    del slot.blocks[2:]
    held = sched.allocator.alloc(sched.allocator.available)
    _, pos0, _, wlen = sched.spec_batch(W=4)
    assert len(slot.blocks) == 2  # could not grow
    assert wlen[0] == 2 * 4 - pos0[0] >= 1  # clamped to owned capacity

    # table full (static width) -> shrink even though the pool has blocks
    sched.allocator.free(held)
    sched.max_blocks_per_seq = 2
    _, pos0, _, wlen = sched.spec_batch(W=4)
    assert len(slot.blocks) == 2 and wlen[0] == 2 * 4 - pos0[0]


def test_spec_exact_under_tight_pools():
    """End-to-end: speculative serving through minimal pools (no spare
    blocks beyond one request's lifetime) stays exact and leaks nothing."""
    cfg, params, dcfg, dparams = _spec_setup("tiny-relu", dtype="float32")
    (p,) = _prompts(cfg, [5], seed=6)
    # prompt 5 + max_new 7 = 12 tokens -> exactly 3 blocks of 4
    ar = ContinuousBatchingEngine(cfg, params, n_slots=1, block_size=4,
                                  max_blocks_per_seq=4, n_blocks=5)
    u = ar.submit(p, max_new=7)
    ref = ar.run()[u].tokens

    for max_bps, n_blocks in ((4, 5), (3, 4)):
        eng = ContinuousBatchingEngine(
            cfg, params, n_slots=1, block_size=4, max_blocks_per_seq=max_bps,
            n_blocks=n_blocks, draft_cfg=dcfg, draft_params=dparams, gamma=3)
        u = eng.submit(p, max_new=7)
        res = eng.run()[u]
        np.testing.assert_array_equal(res.tokens, ref)
        # every block returned to the pool
        assert eng.scheduler.allocator.available == n_blocks - 1


def test_spec_counters_and_sparsity_metrics():
    cfg, params, dcfg, dparams = _spec_setup("tiny-relu")
    prompts = _prompts(cfg, [8, 11], seed=7)
    eng = _engine(cfg, params, draft_cfg=dcfg, draft_params=dparams,
                  gamma=2, track_sparsity=True)
    uids = [eng.submit(p, max_new=9) for p in prompts]
    res = eng.run()
    for u in uids:
        r = res[u]
        assert len(r.tokens) == 9
        assert 0.0 <= r.accept_rate <= 1.0
        assert r.draft_accepted <= r.draft_proposed
        # every verify window proposes at most γ drafts
        assert r.draft_proposed <= r.target_calls * 2
        tr = eng.trackers[u]
        assert 0.0 <= tr.aggregated_sparsity() <= 1.0
    # relu models leave most units inactive even unioned over the window
    assert 0.0 < eng.s_agg_window() < 1.0


def test_tracked_aggregated_sparsity_per_request():
    cfg, params = _setup()
    p1, p2 = _prompts(cfg, [8, 12], seed=7)
    eng = _engine(cfg, params, track_sparsity=True)
    u1 = eng.submit(p1, max_new=6)
    u2 = eng.submit(p2, max_new=6)
    eng.run()
    for uid in (u1, u2):
        tr = eng.trackers[uid]
        # first token comes from prefill; the remaining 5 from decode steps
        assert len(tr.curve) == 5
        # aggregated sparsity is non-increasing (paper Sec. 5.1)
        assert all(b <= a + 1e-9 for a, b in zip(tr.curve, tr.curve[1:]))
        assert 0.0 <= tr.aggregated_sparsity() <= 1.0
