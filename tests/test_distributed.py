"""Distribution tests that need multiple (fake) devices — run in
subprocesses so the main pytest process keeps its single-device view."""
import jax
import pytest

from subproc import run_forced_devices as _run

# these tests build explicit-axis-type meshes, an API newer than the jax
# this environment may pin; skip (not fail) where it's absent
pytestmark = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="requires jax.sharding.AxisType (jax >= 0.6)")


def test_ddp_shard_map_8dev():
    """shard_map DDP step with int8-EF compression on 8 fake devices."""
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config, TrainConfig
    from repro.data.pipeline import DataConfig, PackedIterator
    from repro.models import registry
    from repro.optim import adamw, compression
    from repro.train.ddp import make_ddp_train_step
    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    cfg = get_config("tiny-relu")
    fam = registry.get_family(cfg)
    params = fam.init_params(jax.random.PRNGKey(0), cfg)
    tc = TrainConfig(learning_rate=5e-3, total_steps=6, warmup_steps=1,
                     schedule="constant", grad_compression="int8_ef")
    step = make_ddp_train_step(cfg, tc, mesh)
    opt = adamw.init_opt_state(params)
    ef = compression.init_ef_state(params)
    it = PackedIterator(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                   batch_size=8))
    losses = []
    for _ in range(4):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt, ef, m = step(params, opt, ef, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
    print("OK", losses[0], losses[-1])
    """)
    assert "OK" in out


def test_elastic_checkpoint_reshard_8_to_4():
    """Checkpoint written under an 8-device mesh restores onto 4 devices."""
    out = _run("""
    import tempfile, jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint.manager import CheckpointManager
    d = tempfile.mkdtemp()
    mesh8 = jax.make_mesh((8,), ("data",),
                          axis_types=(jax.sharding.AxisType.Auto,))
    w = jax.device_put(jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                       NamedSharding(mesh8, P("data", None)))
    mgr = CheckpointManager(d, async_save=False)
    mgr.save(5, {"w": w}, extras={"step": 5})
    # restore onto a DIFFERENT (4-device) mesh
    mesh4 = jax.make_mesh((4,), ("data",),
                          axis_types=(jax.sharding.AxisType.Auto,),
                          devices=jax.devices()[:4])
    sh4 = {"w": NamedSharding(mesh4, P("data", None))}
    got, extras = mgr.restore({"w": w}, shardings=sh4)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(w))
    assert got["w"].sharding == sh4["w"]
    assert extras["step"] == 5
    print("OK")
    """)
    assert "OK" in out


def test_tiny_pjit_train_on_4x2_mesh():
    """The production train step (FSDP+TP rules) on a tiny 4x2 mesh: loss is
    finite and params shard according to the rules."""
    out = _run("""
    import jax, jax.numpy as jnp
    from repro.configs import get_config, TrainConfig
    from repro.configs.base import ShapeConfig
    from repro.launch import specs as specs_lib
    from repro.models import registry
    from repro.optim import adamw
    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = get_config("tiny-relu").replace(d_ff=256, vocab_size=512)
    shape = ShapeConfig("t", "train", 64, 8, num_microbatches=2)
    tc = TrainConfig(learning_rate=1e-3, num_microbatches=2,
                     remat_policy="minimal", total_steps=4, warmup_steps=1)
    with mesh:
        jitted, (pshape, oshape, bshape) = specs_lib.build_train(
            cfg, shape, mesh, tc)
        fam = registry.get_family(cfg)
        params = fam.init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw.init_opt_state(params)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 64),
                                              0, cfg.vocab_size)}
        p2, o2, m = jitted(params, opt, batch)
    import numpy as np
    assert np.isfinite(float(m["loss"]))
    # FFN weights must actually be sharded over (data, model)
    wd = p2["layers"]["ffn"]["wd"]
    assert len(wd.sharding.device_set) == 8
    print("OK", float(m["loss"]))
    """, devices=8)
    assert "OK" in out


def test_flash_decode_seq_sharded_cache():
    """decode_attention over a sequence-sharded cache == unsharded result
    (GSPMD partial-softmax correctness)."""
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models import common as cm
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    rng = np.random.RandomState(0)
    b, S, kvp, g, d = 2, 32, 2, 2, 8
    q = jnp.asarray(rng.randn(b, kvp, g, d), jnp.float32)
    # head-major layout (b, kvp, S, d); S sharded over "model"
    kc = jnp.asarray(rng.randn(b, kvp, S, d), jnp.float32)
    vc = jnp.asarray(rng.randn(b, kvp, S, d), jnp.float32)
    pos = jnp.asarray([20, 20], jnp.int32)
    want = cm.decode_attention(q, kc, vc, pos)
    csh = NamedSharding(mesh, P("data", None, "model", None))
    with mesh:
        fn = jax.jit(cm.decode_attention,
                     in_shardings=(NamedSharding(mesh, P("data")), csh, csh,
                                   NamedSharding(mesh, P("data"))),
                     static_argnames=())
        got = fn(q, kc, vc, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    print("OK")
    """)
    assert "OK" in out
