"""Fused Pallas decode kernels (ISSUE 7 tentpole): the one-pass
gather-up -> activation -> scatter-down FFN kernel and the in-kernel
block-table paged attention must reproduce the frozen XLA serving path
BYTE-IDENTICALLY at f32 — greedy token streams through
``fast_kernels=True`` equal the frozen-path streams in all three serving
modes (plain γ-window, speculative, predictor), for tiny-relu (GLU) and
tiny-opt (MLP), with chunked prefill composing.

Kernel-level parity is pinned bit-exactly against the unfused Pallas pair
(``sparse_up_matmul`` + ``sparse_matmul_tokens``) — same per-tile dot
shapes, same f32 accumulation order — plus hypothesis properties over the
fixed-capacity tile lists (empty rows, full capacity, duplicated pad
entries revisiting an already-fetched tile exactly once).

Kernels run in interpret mode on CPU (kernels/runtime.resolve_interpret);
the mesh-fallback test runs in a forced-8-device subprocess."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.kernels import fused_decode as kfd
from repro.kernels import paged_attention as kpa
from repro.kernels import sparse_matmul as ksm
from repro.models import common as cm
from repro.models import registry
from repro.predictor.predictors import pack_tile_indices
from repro.serving import ContinuousBatchingEngine

from subproc import run_forced_devices as _run


# ---------------------------------------------------------------------------
# kernel-level parity: fused == unfused pair, BIT-exact


def _case(T=4, d=64, F=512, tile=128, p=0.5, seed=0):
    n_tiles = F // tile
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(T, d), jnp.float32)
    wg = jnp.asarray(rng.randn(d, F) / np.sqrt(d), jnp.float32)
    wu = jnp.asarray(rng.randn(d, F) / np.sqrt(d), jnp.float32)
    wd = jnp.asarray(rng.randn(F, d) / np.sqrt(F), jnp.float32)
    mask = jnp.asarray(rng.rand(T, n_tiles) < p)
    idx, nvalid = pack_tile_indices(mask, n_tiles)
    return x, wg, wu, wd, idx, nvalid, tile, n_tiles


def _unfused(x, wg, wu, wd, idx, nvalid, tile, unit_mask=None, shift=0.0):
    """The frozen two-kernel lowering the fused kernel replaces."""
    pre = ksm.sparse_up_matmul(x, wg, idx, nvalid, tile=tile)
    hh = jnp.maximum(pre - shift, 0.0)
    if wu is not None:
        hh = hh * ksm.sparse_up_matmul(x, wu, idx, nvalid, tile=tile)
    if unit_mask is not None:
        hh = hh * unit_mask
    y = ksm.sparse_matmul_tokens(hh.astype(wd.dtype), wd, idx, nvalid,
                                 tile=tile)
    return y, hh


def test_fused_matches_unfused_glu():
    x, wg, wu, wd, idx, nvalid, tile, n_tiles = _case()
    y, h = kfd.fused_sparse_ffn(x, wg, wd, idx, nvalid, w_up=wu,
                                activation="relu", tile=tile)
    hh = kfd.scatter_compact(h, idx, nvalid, n_tiles)
    y0, hh0 = _unfused(x, wg, wu, wd, idx, nvalid, tile)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y0))
    np.testing.assert_array_equal(np.asarray(hh), np.asarray(hh0))


def test_fused_matches_unfused_mlp():
    x, wg, _, wd, idx, nvalid, tile, n_tiles = _case(seed=3)
    y, h = kfd.fused_sparse_ffn(x, wg, wd, idx, nvalid,
                                activation="relu", tile=tile)
    hh = kfd.scatter_compact(h, idx, nvalid, n_tiles)
    y0, hh0 = _unfused(x, wg, None, wd, idx, nvalid, tile)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y0))
    np.testing.assert_array_equal(np.asarray(hh), np.asarray(hh0))


def test_fused_matches_unfused_masked_and_shifted():
    """The AR-window variant: unit mask applied INSIDE the kernel after the
    GLU multiply, shifted ReLU — both exact (boolean multiply, f32 sub)."""
    x, wg, wu, wd, idx, nvalid, tile, n_tiles = _case(seed=5)
    rng = np.random.RandomState(7)
    eff = jnp.asarray(rng.rand(x.shape[0], wg.shape[1]) < 0.6)
    y, h = kfd.fused_sparse_ffn(x, wg, wd, idx, nvalid, w_up=wu,
                                unit_mask=eff, activation="shifted_relu",
                                shift=0.25, tile=tile)
    hh = kfd.scatter_compact(h, idx, nvalid, n_tiles)
    y0, hh0 = _unfused(x, wg, wu, wd, idx, nvalid, tile, unit_mask=eff,
                       shift=0.25)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y0))
    np.testing.assert_array_equal(np.asarray(hh), np.asarray(hh0))


# ---------------------------------------------------------------------------
# hypothesis: fixed-capacity tile-list edge cases


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10 ** 6), st.floats(0.0, 1.0))
def test_fused_tile_list_property(seed, p):
    """Any mask density — including all-empty rows (nvalid == 0 must yield
    exact zeros) and full capacity (== dense) — matches the unfused pair
    bit-exactly, and the scattered h is zero outside selected tiles."""
    x, wg, wu, wd, _, _, tile, n_tiles = _case(T=3, seed=seed % 997)
    rng = np.random.RandomState(seed % 2 ** 31)
    mask = jnp.asarray(rng.rand(3, n_tiles) < p)
    idx, nvalid = pack_tile_indices(mask, n_tiles)
    y, h = kfd.fused_sparse_ffn(x, wg, wd, idx, nvalid, w_up=wu,
                                activation="relu", tile=tile)
    hh = kfd.scatter_compact(h, idx, nvalid, n_tiles)
    y0, hh0 = _unfused(x, wg, wu, wd, idx, nvalid, tile)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y0))
    np.testing.assert_array_equal(np.asarray(hh), np.asarray(hh0))
    # rows with no live tiles are exactly zero, not epsilon
    empty = ~np.asarray(mask).any(axis=1)
    assert (np.asarray(y)[empty] == 0.0).all()
    assert (np.asarray(hh)[empty] == 0.0).all()
    # h never leaks outside the selected tiles
    units = np.repeat(np.asarray(mask), tile, axis=1)
    assert (np.asarray(hh)[~units] == 0.0).all()


def test_duplicated_pad_tiles_contribute_exactly_once():
    """pack_tile_indices pads by REPEATING the row's first selected tile
    (so padded DMAs revisit an already-fetched block): the kernel must add
    that tile's down-projection exactly once and scatter its h exactly
    once, never per-duplicate."""
    x, wg, wu, wd, _, _, tile, n_tiles = _case(T=2, seed=11)
    # row 0: one live tile + 3 pad duplicates of it; row 1: empty (pads
    # point at tile 0 by construction of top_k on an all-zero mask)
    mask = jnp.zeros((2, n_tiles), bool).at[0, 2].set(True)
    idx, nvalid = pack_tile_indices(mask, n_tiles)
    assert idx[0].tolist() == [2, 2, 2, 2] and nvalid.tolist() == [1, 0]
    y, h = kfd.fused_sparse_ffn(x, wg, wd, idx, nvalid, w_up=wu,
                                activation="relu", tile=tile)
    hh = kfd.scatter_compact(h, idx, nvalid, n_tiles)
    # single-tile reference, computed directly
    sl = slice(2 * tile, 3 * tile)
    h_ref = (jnp.maximum(x[:1] @ wg[:, sl], 0.0) * (x[:1] @ wu[:, sl]))
    np.testing.assert_array_equal(np.asarray(hh[0, sl]),
                                  np.asarray(h_ref)[0])
    np.testing.assert_array_equal(np.asarray(y[0]),
                                  np.asarray(h_ref @ wd[sl])[0])
    assert (np.asarray(y[1]) == 0.0).all() and (np.asarray(hh[1]) == 0.0).all()


# ---------------------------------------------------------------------------
# paged attention kernel vs frozen gather-then-attend


@pytest.mark.parametrize("W,window", [(1, 0), (5, 5)])
def test_paged_attention_matches_gathered(W, window):
    """In-kernel block-table gather == materializing paged_gather + the
    frozen window_attention, for the decode (W=1) and the γ+1 verify
    window shapes."""
    b, kvp, g, hd = 3, 2, 2, 16
    n_blocks, bs, nb = 9, 8, 4
    rng = np.random.RandomState(2)
    q = jnp.asarray(rng.randn(b, W, kvp, g, hd), jnp.float32)
    kp = jnp.asarray(rng.randn(n_blocks, kvp, bs, hd), jnp.float32)
    vp = jnp.asarray(rng.randn(n_blocks, kvp, bs, hd), jnp.float32)
    table = jnp.asarray(rng.randint(1, n_blocks, (b, nb)), jnp.int32)
    pos = (jnp.asarray(rng.randint(W - 1, nb * bs, (b,)), jnp.int32)[:, None]
           + jnp.arange(-W + 1, 1, dtype=jnp.int32)[None, :])
    kg, vg = cm.paged_gather(kp, table), cm.paged_gather(vp, table)
    want = cm.window_attention(q, kg, vg, pos, window=window)
    got = kpa.paged_window_attention(q, kp, vp, table, pos, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-6, atol=2e-6)


# ---------------------------------------------------------------------------
# serving-level: f32 greedy streams byte-identical, fast vs frozen


def _setup(name):
    cfg = get_config(name).replace(compute_dtype="float32")
    fam = registry.get_family(cfg)
    params = fam.init_params(jax.random.PRNGKey(0), cfg)
    rng_prompts = [(1, 9), (2, 5), (3, 13)]
    prompts = [np.random.RandomState(s).randint(
                   0, cfg.vocab_size, ln).astype(np.int32)
               for s, ln in rng_prompts]
    return cfg, fam, params, prompts


def _serve(cfg, params, prompts, max_new=8, **kw):
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2, block_size=8,
                                   max_blocks_per_seq=6, **kw)
    uids = [eng.submit(p, max_new) for p in prompts]
    res = eng.run()
    return [res[u].tokens.tolist() for u in uids], eng


@pytest.mark.parametrize("name", ["tiny-relu", "tiny-opt"])
def test_plain_mode_fast_kernels_byte_identical(name):
    cfg, fam, params, prompts = _setup(name)
    base, e0 = _serve(cfg, params, prompts, fast_kernels=False)
    got, e1 = _serve(cfg, params, prompts, fast_kernels=True)
    assert got == base, (name, base, got)
    assert not e0.fast_kernels and e1.fast_kernels
    # chunked prefill lowers through the same fast window step
    gotc, _ = _serve(cfg, params, prompts, fast_kernels=True, prefill_chunk=4)
    assert gotc == base, (name, "chunked", base, gotc)
    # the fast AR path reads all three (GLU) / both (MLP) projections
    # sparsely — the accounting scope widens accordingly
    n_all = 3 if cfg.ffn_kind == "glu" else 2
    assert e1.weight_io_bytes_per_step() == pytest.approx(
        n_all * e0.weight_io_bytes_per_step())


@pytest.mark.parametrize("name", ["tiny-relu", "tiny-opt"])
def test_speculative_mode_fast_kernels_byte_identical(name):
    cfg, fam, params, prompts = _setup(name)
    dcfg = cfg.replace(name=cfg.name + "-draft", n_layers=1)
    dparams = fam.init_params(jax.random.PRNGKey(2), dcfg)
    kw = dict(draft_cfg=dcfg, draft_params=dparams, gamma=4)
    base, e0 = _serve(cfg, params, prompts, fast_kernels=False, **kw)
    got, e1 = _serve(cfg, params, prompts, fast_kernels=True, **kw)
    assert got == base, (name, base, got)
    # same windows verified -> same acceptance telemetry
    assert abs(e1.s_agg_window() - e0.s_agg_window()) < 1e-9


@pytest.mark.parametrize("name", ["tiny-relu", "tiny-opt"])
def test_predictor_mode_fast_kernels_byte_identical(name):
    from repro.predictor import calibrate_from_config
    cfg, fam, params, prompts = _setup(name)
    cfg = cfg.replace_sparsity(predictor="sign", predictor_recall=1.0)
    calib = {"tokens": jax.random.randint(jax.random.PRNGKey(7), (4, 32),
                                          0, cfg.vocab_size)}
    pred = calibrate_from_config(params, cfg, calib, tile=1)
    base, e0 = _serve(cfg, params, prompts, predictor=pred,
                      fast_kernels=False)
    got, e1 = _serve(cfg, params, prompts, predictor=pred,
                     fast_kernels=True)
    assert got == base, (name, base, got)
    # identical gathered tiles -> identical measured density and savings
    assert abs(e1.weight_io_saved() - e0.weight_io_saved()) < 1e-9
    assert e1.predictor_recall() == e0.predictor_recall()


def test_fast_kernels_autodetect_off_on_cpu():
    """Default (fast_kernels=None) resolves from the backend: off on CPU,
    so CI keeps the frozen XLA paths unless a test opts in."""
    cfg, fam, params, prompts = _setup("tiny-relu")
    _, eng = _serve(cfg, params, prompts[:1], max_new=2)
    assert eng.fast_kernels == (jax.default_backend() != "cpu")


def test_mesh_forces_fallback_with_warning():
    """GSPMD cannot partition pallas_call: under a mesh the engine must
    warn, force fast_kernels=False, and stream identically."""
    out = _run("""
    import warnings
    import jax, numpy as np
    from repro.configs import get_config
    from repro.models import registry
    from repro.launch.mesh import make_host_mesh
    from repro.serving import ContinuousBatchingEngine

    cfg = get_config("tiny-relu").replace(compute_dtype="float32")
    params = registry.get_family(cfg).init_params(jax.random.PRNGKey(0), cfg)
    prompts = [np.random.RandomState(s).randint(
                   0, cfg.vocab_size, ln).astype(np.int32)
               for s, ln in ((1, 9), (2, 5))]

    def serve(**kw):
        eng = ContinuousBatchingEngine(cfg, params, n_slots=2, block_size=8,
                                       max_blocks_per_seq=6, **kw)
        uids = [eng.submit(p, 8) for p in prompts]
        res = eng.run()
        return [res[u].tokens.tolist() for u in uids], eng

    base, _ = serve(fast_kernels=False)
    mesh = make_host_mesh(1, 8)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        got, eng = serve(fast_kernels=True, mesh=mesh)
    assert eng.fast_kernels is False
    assert any("fast_kernels" in str(x.message) for x in w), \\
        [str(x.message) for x in w]
    assert got == base, (base, got)
    print("OK")
    """)
    assert "OK" in out
