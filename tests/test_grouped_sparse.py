"""Grouped shard-local sparse matmul: exactness + equivalence properties."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import common as cm


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 500), st.sampled_from([2, 4]),
       st.sampled_from([256, 512, 608]))
def test_grouped_exact_when_capacity_sufficient(seed, G, per):
    """With enough per-group capacity, grouped sparse == dense."""
    rng = np.random.RandomState(seed)
    F = G * per
    tile = cm.pick_group_tile(F, G)
    tiles_g = per // tile
    T, D = 3, 32
    x = np.zeros((T, F), np.float32)
    # activate <= half the tiles in each group
    for g in range(G):
        n_act = max(1, tiles_g // 2)
        for t_ in rng.choice(tiles_g, n_act, replace=False):
            lo = g * per + t_ * tile
            x[:, lo: lo + tile] = rng.randn(T, tile)
    w = rng.randn(F, D).astype(np.float32) / np.sqrt(F)
    y = cm.grouped_sparse_matmul(jnp.asarray(x), jnp.asarray(w), 0.5, G)
    np.testing.assert_allclose(np.asarray(y), x @ w, rtol=1e-4, atol=1e-4)


def test_pick_group_tile_assigned_archs():
    """Every assigned arch's d_ff (and d_model) admits a valid group tile."""
    for F in (4864, 24576, 9728, 18944, 22016, 14336, 16384, 6400, 3072,
              8192, 896, 6144, 2560, 3584, 4096, 768):
        if F % 16:
            continue
        t = cm.pick_group_tile(F, 16)
        per = F // 16
        assert per % t == 0 and t >= 8, (F, t)


def test_grouped_vs_global_same_when_balanced():
    """When activity is group-balanced, grouped and global selection give the
    same result (densities matched)."""
    rng = np.random.RandomState(7)
    G, per, T, D = 4, 512, 2, 16
    F = G * per
    x = np.zeros((T, F), np.float32)
    for g in range(G):  # exactly 1 of 4 tiles active per group
        lo = g * per
        x[:, lo: lo + 128] = rng.randn(T, 128)
    w = rng.randn(F, D).astype(np.float32) / np.sqrt(F)
    yg = cm.grouped_sparse_matmul(jnp.asarray(x), jnp.asarray(w), 0.25, G)
    sc = cm.tile_scores(jnp.asarray(x), 128)
    idx, mask = cm.select_active_tiles(sc, 0.25, 1)
    yglob = cm.gathered_matmul(jnp.asarray(x), jnp.asarray(w), idx, mask, 128)
    np.testing.assert_allclose(np.asarray(yg), np.asarray(yglob),
                               rtol=1e-4, atol=1e-4)
