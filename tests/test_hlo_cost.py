"""Unit tests for the trip-count-aware HLO cost model (launch/hlo_cost.py)."""
import textwrap

from repro.launch.hlo_cost import CostModel, _split_op_line, parse_module

HLO = textwrap.dedent("""
    HloModule test

    %body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %p = (s32[], f32[8,16]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
      %w = f32[16,16]{1,0} constant({...})
      %dot.1 = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,16]{1,0} all-reduce(%dot.1), replica_groups={}, to_apply=%add
      %one = s32[] constant(1)
      %ni = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[8,16]) tuple(%ni, %ar)
    }

    %cond (p2: (s32[], f32[8,16])) -> pred[] {
      %p2 = (s32[], f32[8,16]) parameter(0)
      %i2 = s32[] get-tuple-element(%p2), index=0
      %lim = s32[] constant(5)
      ROOT %lt = pred[] compare(%i2, %lim), direction=LT
    }

    %add (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %s = f32[] add(%a, %b)
    }

    ENTRY %main (x0: f32[8,16]) -> f32[8,16] {
      %x0 = f32[8,16]{1,0} parameter(0)
      %c0 = s32[] constant(0)
      %init = (s32[], f32[8,16]) tuple(%c0, %x0)
      %while.1 = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body
      ROOT %out = f32[8,16]{1,0} get-tuple-element(%while.1), index=1
    }
    """)


def test_split_op_line_tuple_type():
    got = _split_op_line(
        "  %w = (s32[], bf16[2,3]{1,0}, /*index=2*/f32[4]{0}) while(%init), "
        "condition=%c, body=%b")
    assert got is not None
    name, typestr, opcode, rest = got
    assert name == "w" and opcode == "while"


def test_parse_module_computations():
    comps = parse_module(HLO)
    assert {"body", "cond", "add", "main"} <= set(comps)
    assert "dot.1" in comps["body"].ops


def test_trip_count_multiplication():
    cm = CostModel(HLO)
    # dot flops = 2*8*16*16 = 4096, x5 loop trips
    assert cm.flops == 2 * 8 * 16 * 16 * 5
    # all-reduce wire: 8*16*4B * 2 (ring) * 5 trips
    assert cm.wire == 8 * 16 * 4 * 2 * 5
    assert cm.coll_counts["all-reduce"] == 5


def test_bytes_positive_and_loop_scaled():
    cm = CostModel(HLO)
    assert cm.bytes > 0
    # the dot reads x (512B) + w (1KB) + writes out (512B), x5
    assert cm.bytes >= (512 + 1024 + 512) * 5


def test_dot_weight_bytes_shape_and_name_filters():
    """dots records (trip scale, rhs dtype/shape, op name); the regex
    filters select plain matmuls vs einsum-labeled dots by op name."""
    cm = CostModel(HLO)
    # the while body's dot has rhs (16,16) f32, x5 trips
    assert cm.dot_weight_bytes((16, 16)) == 16 * 16 * 4 * 5
    assert cm.dot_weight_bytes((8, 8)) == 0.0
    assert cm.dot_weight_bytes((16, 16), exclude_re="->") == 16 * 16 * 4 * 5
    assert cm.dot_weight_bytes((16, 16), name_re="->") == 0.0


def test_decode_hlo_down_proj_matches_engine_accounting():
    """Anchor the analytic serving accounting to what XLA actually
    compiled: lower the jitted FROZEN decode step, count the trip-scaled
    (d_ff, d_model)-RHS dot reads in its optimized HLO, and fail if they
    drift more than 10% from the engine's density-accounted
    ``weight_io_bytes_per_step()`` at density 1.0 (where the frozen
    accounting scope is exactly the one down-projection)."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.launch.roofline import hlo_decode_ffn_bytes
    from repro.models import registry
    from repro.serving import ContinuousBatchingEngine

    cfg = get_config("tiny-relu").replace(compute_dtype="float32")
    params = registry.get_family(cfg).init_params(jax.random.PRNGKey(0), cfg)
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2, block_size=8,
                                   max_blocks_per_seq=6, fast_kernels=False)
    prompt = np.random.RandomState(1).randint(
        0, cfg.vocab_size, 9).astype(np.int32)
    eng.submit(prompt, 4)
    eng.run()
    dens = 1.0 if not eng._dens_n else eng._dens_sum / eng._dens_n
    assert dens == 1.0  # the tiny config serves AR at full density
    counted = hlo_decode_ffn_bytes(eng, n_proj=1)
    measured = eng.weight_io_bytes_per_step()
    assert measured > 0
    assert abs(counted / measured - 1.0) <= 0.10, (counted, measured)
