"""Pallas kernel validation: shape/dtype sweeps + hypothesis property tests,
all against the pure-jnp oracles in kernels/ref.py (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.fused_ffn import (fused_up_relu, fused_up_relu_tokens,
                                     fused_up_relu_window, tile_activity,
                                     window_tile_activity)
from repro.kernels.sparse_matmul import sparse_matmul


def _mk(T, F, D, dtype, seed=0, sparsity=0.7):
    rng = np.random.RandomState(seed)
    x = rng.randn(T, F).astype(np.float32)
    x[rng.rand(T, F) < sparsity] = 0.0  # activation sparsity
    w = rng.randn(F, D).astype(np.float32) / np.sqrt(F)
    return jnp.asarray(x, dtype), jnp.asarray(w, dtype)


@pytest.mark.slow
@pytest.mark.parametrize("T,F,D,tile,block_d", [
    (8, 512, 256, 128, 128),
    (16, 1024, 512, 128, 256),
    (1, 256, 512, 128, 512),
    (32, 768, 384, 128, 384),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sparse_matmul_shapes(T, F, D, tile, block_d, dtype):
    x, w = _mk(T, F, D, dtype)
    n_tiles = F // tile
    k = max(1, n_tiles // 2)
    idx = jnp.asarray(np.random.RandomState(1).choice(n_tiles, k, replace=False),
                      jnp.int32)
    nvalid = jnp.asarray(k, jnp.int32)
    got = sparse_matmul(x, w, idx, nvalid, tile=tile, block_d=block_d)
    want = ref.sparse_matmul_ref(x, w, idx, nvalid, tile)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_sparse_matmul_padding_masked():
    """Padded (invalid) index slots must not contribute."""
    x, w = _mk(4, 512, 128, jnp.float32)
    idx = jnp.asarray([1, 3, 0, 0], jnp.int32)  # two valid + two pad dups
    got2 = sparse_matmul(x, w, idx, jnp.asarray(2, jnp.int32))
    want2 = ref.sparse_matmul_ref(x, w, idx, jnp.asarray(2, jnp.int32), 128)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(want2),
                               rtol=1e-5, atol=1e-5)
    # all tiles selected == dense matmul
    idx_all = jnp.arange(4, dtype=jnp.int32)
    got4 = sparse_matmul(x, w, idx_all, jnp.asarray(4, jnp.int32))
    dense = np.asarray(x, np.float32) @ np.asarray(w, np.float32)
    np.testing.assert_allclose(np.asarray(got4), dense, rtol=1e-5, atol=1e-5)


@pytest.mark.slow
@pytest.mark.parametrize("T,d,F,block_f", [
    (8, 256, 512, 256), (4, 128, 1024, 512), (16, 64, 256, 128),
])
@pytest.mark.parametrize("shift", [0.0, 0.5])
def test_fused_up_relu(T, d, F, block_f, shift):
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(T, d), jnp.float32)
    wu = jnp.asarray(rng.randn(d, F) / np.sqrt(d), jnp.float32)
    h, scores = fused_up_relu(x, wu, shift, block_f=block_f)
    h_ref, s_ref = ref.fused_up_relu_ref(x, wu, shift)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(scores), np.asarray(s_ref),
                               rtol=1e-5, atol=1e-5)


def test_fused_up_relu_tokens_per_request_scores():
    """The per-token variant (continuous-batching serving) agrees with the
    shared XLA score definition AND reduces to the batch-union kernel."""
    rng = np.random.RandomState(3)
    T, d, F = 4, 128, 512
    x = jnp.asarray(rng.randn(T, d), jnp.float32)
    wu = jnp.asarray(rng.randn(d, F) / np.sqrt(d), jnp.float32)
    h, scores = fused_up_relu_tokens(x, wu, 0.0, block_f=256)
    assert scores.shape == (T, F // 128)
    np.testing.assert_allclose(np.asarray(scores),
                               np.asarray(tile_activity(h)),
                               rtol=1e-6, atol=1e-6)
    h_u, scores_u = fused_up_relu(x, wu, 0.0, block_f=256)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_u),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(scores).max(0), np.asarray(scores_u),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("B,W,shift", [(3, 4, 0.0), (2, 5, 0.5), (4, 1, 0.0)])
def test_fused_up_relu_window_union_scores(B, W, shift):
    """The γ-window verification kernel: per-slot scores are the UNION (max)
    over the slot's window tokens, matching window_tile_activity, and the
    activations match the per-token kernel on the flattened batch."""
    rng = np.random.RandomState(5)
    d, F = 128, 512
    x = jnp.asarray(rng.randn(B, W, d), jnp.float32)
    wu = jnp.asarray(rng.randn(d, F) / np.sqrt(d), jnp.float32)
    h, scores = fused_up_relu_window(x, wu, shift, block_f=256)
    assert h.shape == (B, W, F) and scores.shape == (B, F // 128)
    np.testing.assert_allclose(np.asarray(scores),
                               np.asarray(window_tile_activity(h)),
                               rtol=1e-6, atol=1e-6)
    h_tok, s_tok = fused_up_relu_tokens(x.reshape(B * W, d), wu, shift,
                                        block_f=256)
    np.testing.assert_allclose(np.asarray(h).reshape(B * W, F),
                               np.asarray(h_tok), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(scores),
        np.asarray(s_tok).reshape(B, W, -1).max(1), rtol=1e-6, atol=1e-6)
    if W == 1:  # single-token window degenerates to the per-token scores
        np.testing.assert_array_equal(np.asarray(scores),
                                      np.asarray(s_tok))


@pytest.mark.slow
def test_sparse_ffn_pipeline_matches_xla():
    """Pallas pipeline == XLA gather fallback == the dry-run's lowered path."""
    rng = np.random.RandomState(0)
    T, d, F = 8, 128, 1024
    x = jnp.asarray(rng.randn(T, d), jnp.float32)
    wu = jnp.asarray(rng.randn(d, F) / np.sqrt(d), jnp.float32)
    wd = jnp.asarray(rng.randn(F, d) / np.sqrt(F), jnp.float32)
    y_p, h_p, idx_p, nv_p = ops.sparse_ffn_apply(x, wu, wd, density=0.5)
    y_x, h_x, idx_x, nv_x = ops.sparse_ffn_apply_xla(x, wu, wd, density=0.5)
    np.testing.assert_allclose(np.asarray(h_p), np.asarray(h_x), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_x), rtol=1e-4,
                               atol=1e-4)


def test_density_one_is_dense():
    rng = np.random.RandomState(2)
    T, d, F = 4, 128, 512
    x = jnp.asarray(rng.randn(T, d), jnp.float32)
    wu = jnp.asarray(rng.randn(d, F) / np.sqrt(d), jnp.float32)
    wd = jnp.asarray(rng.randn(F, d) / np.sqrt(F), jnp.float32)
    y, h, _, _ = ops.sparse_ffn_apply(x, wu, wd, density=1.0)
    dense = np.maximum(np.asarray(x) @ np.asarray(wu), 0) @ np.asarray(wd)
    np.testing.assert_allclose(np.asarray(y), dense, rtol=1e-4, atol=1e-4)


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(
    T=st.sampled_from([1, 4, 8]),
    n_tiles=st.sampled_from([2, 4, 8]),
    D=st.sampled_from([128, 256]),
    nsel=st.integers(1, 8),
    seed=st.integers(0, 10_000),
)
def test_sparse_matmul_property(T, n_tiles, D, nsel, seed):
    """Property: for ANY tile subset, kernel == masked dense oracle."""
    F = n_tiles * 128
    x, w = _mk(T, F, D, jnp.float32, seed=seed % 100)
    rng = np.random.RandomState(seed)
    nsel = min(nsel, n_tiles)
    idx_np = rng.choice(n_tiles, nsel, replace=False).astype(np.int32)
    pad = rng.randint(0, n_tiles, max(0, n_tiles - nsel)).astype(np.int32)
    idx = jnp.asarray(np.concatenate([idx_np, pad]))
    nv = jnp.asarray(nsel, jnp.int32)
    got = sparse_matmul(x, w, idx, nv, block_d=128)
    want = ref.sparse_matmul_ref(x, w, idx, nv, 128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flops_saved_matches_paper_scale():
    """OPT-6.7B-like FFN at 97% sparsity -> ~3x down-proj saving at tile
    granularity (the paper's row-granularity saving is the upper bound)."""
    out = ops.flops_saved(F=16384, D=4096, T=1, density=0.1)
    assert out["flops_saving"] > 0.85
    assert abs(out["io_saving"] - out["flops_saving"]) < 1e-6
