"""MoE through the engine (ISSUE 9): declared serving capabilities,
router-as-sparsity properties, and f32 byte-identity of engine-served
MoE streams against the legacy sequential decode path.

tiny-moe is configured DROP-FREE (capacity_factor >= n_experts), which
makes per-token routing independent of co-batched tokens: the engine's
slot-batched windows route every token exactly as the legacy b=1
sequential loop does, so f32 greedy streams must match byte for byte in
plain AND chunked-prefill modes (and composing with a dense draft in
speculative mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.kernels import sparse_matmul as sm
from repro.models import registry
from repro.models import serving_protocol as sp
from repro.serving import ContinuousBatchingEngine
from repro.serving.engine import ServeEngine


# ---------------------------------------------------------------------------
# declared capabilities: one uniform error, each naming the capability


def test_require_names_every_missing_capability():
    """For EVERY capability an unsupported family's error names it (vlm
    declares none, so all five must fail with the uniform message)."""
    caps = registry.serving_caps("vlm")
    for cap in sp.CAP_FUNCS:
        with pytest.raises(ValueError) as e:
            caps.require(cap, "vlm")
        msg = str(e.value)
        assert f"{cap!r} serving capability" in msg, (cap, msg)
        assert "family 'vlm'" in msg and "declared capabilities" in msg


def test_require_passes_for_declared_and_rejects_unknown():
    caps = registry.serving_caps("moe")
    for cap in ("paged_decode", "chunked_prefill", "spec_verify"):
        caps.require(cap, "moe")  # declared: no raise
    with pytest.raises(KeyError, match="unknown serving capability"):
        caps.require("teleport", "moe")


def test_validate_caps_rejects_typo_and_missing_functions():
    import types
    mod = types.SimpleNamespace(init_paged_cache=1)
    with pytest.raises(ValueError, match="unknown serving capability"):
        sp.validate_caps("x", mod, sp.ServingCaps({"paged_decod"}))
    with pytest.raises(ValueError, match="missing.*model_prefill_paged"):
        sp.validate_caps("x", mod, sp.ServingCaps({"paged_decode"}))


def test_engine_errors_name_missing_capability(moe_setup):
    cfg, params, _ = moe_setup
    # vlm has no paged serving at all -> rejected before params matter
    vcfg = get_config("tiny-relu").replace(name="t-vlm", family="vlm")
    with pytest.raises(ValueError, match="'vlm'.*'paged_decode'"):
        ContinuousBatchingEngine(vcfg, None)
    # moe declares no predictor capability
    with pytest.raises(ValueError, match="'moe'.*'predictor'"):
        ContinuousBatchingEngine(cfg, params, predictor=object())
    # moe as speculative DRAFT (it has no model_draft_gamma_paged)
    dense = get_config("tiny-relu").replace(compute_dtype="float32")
    dparams = registry.get_family(dense).init_params(
        jax.random.PRNGKey(1), dense)
    with pytest.raises(ValueError, match="'moe'.*'spec_draft'"):
        ContinuousBatchingEngine(dense, dparams,
                                 draft_cfg=cfg, draft_params=params)


# ---------------------------------------------------------------------------
# router-as-sparsity: per-token expert tile lists


@settings(max_examples=50, deadline=None)
@given(st.integers(2, 8), st.integers(1, 4), st.integers(1, 8),
       st.integers(0, 10 ** 6))
def test_expert_tile_lists_in_range_and_capacity(E, k, tpe, seed):
    """Indices always land in [0, E*tpe); nvalid respects the top-k
    capacity; each token's tiles are exactly its experts' contiguous
    ranges in routing order."""
    k = min(k, E)
    rng = np.random.RandomState(seed)
    topi = jnp.asarray(rng.randint(0, E, (5, k)), jnp.int32)
    idx, nv = sm.expert_tile_lists(topi, tpe)
    idx, nv = np.asarray(idx), np.asarray(nv)
    assert idx.shape == (5, k * tpe) and ((idx >= 0) & (idx < E * tpe)).all()
    assert (nv == k * tpe).all()
    for t in range(5):
        want = np.concatenate(
            [np.arange(tpe) + e * tpe for e in np.asarray(topi)[t]])
        np.testing.assert_array_equal(idx[t], want)


@settings(max_examples=50, deadline=None)
@given(st.integers(2, 8), st.integers(1, 8), st.integers(0, 10 ** 6))
def test_expert_tile_lists_k_valid_padding_in_range(E, tpe, seed):
    """Capacity-dropped tokens (k_valid < k): entries past nvalid repeat
    the token's FIRST tile, so padded ids stay in range for the kernels'
    scalar-prefetch DMA; live entries are untouched."""
    rng = np.random.RandomState(seed)
    k = min(3, E)
    topi = jnp.asarray(rng.randint(0, E, (6, k)), jnp.int32)
    kv = jnp.asarray(rng.randint(0, k + 1, (6,)), jnp.int32)
    idx, nv = sm.expert_tile_lists(topi, tpe, k_valid=kv)
    idx, nv = np.asarray(idx), np.asarray(nv)
    full, _ = sm.expert_tile_lists(topi, tpe)
    full = np.asarray(full)
    np.testing.assert_array_equal(nv, np.asarray(kv) * tpe)
    assert ((idx >= 0) & (idx < E * tpe)).all()
    for t in range(6):
        np.testing.assert_array_equal(idx[t, : nv[t]], full[t, : nv[t]])
        np.testing.assert_array_equal(idx[t, nv[t]:],
                                      np.full(k * tpe - nv[t], full[t, 0]))


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 8), st.integers(1, 8), st.integers(0, 10 ** 6))
def test_full_capacity_packing_matches_dense(E, tpe, seed):
    """k == E with every expert routed (any permutation order) gathers
    exactly the dense tile set: the sorted list is bit-identical to
    arange(E*tpe) — dense routing as the sparsity limit case."""
    rng = np.random.RandomState(seed)
    topi = jnp.asarray(np.stack([rng.permutation(E) for _ in range(4)]),
                       jnp.int32)
    idx, nv = sm.expert_tile_lists(topi, tpe)
    assert (np.asarray(nv) == E * tpe).all()
    for t in range(4):
        np.testing.assert_array_equal(np.sort(np.asarray(idx)[t]),
                                      np.arange(E * tpe))


def test_expert_gather_kernels_match_dense_reference():
    """expert_up_matmul -> relu -> expert_down_matmul == per-expert dense
    matmuls (numpy reference), including zeroed capacity-dropped slots."""
    E, d, F, tile = 4, 16, 64, 16
    tpe = F // tile
    rng = np.random.RandomState(0)
    T, k = 6, 2
    x = jnp.asarray(rng.randn(T, d), jnp.float32)
    wu = jnp.asarray(rng.randn(E, d, F) * 0.1, jnp.float32)
    wd = jnp.asarray(rng.randn(E, F, d) * 0.1, jnp.float32)
    topi = jnp.asarray(rng.randint(0, E, (T, k)), jnp.int32)
    kv = jnp.asarray([2, 2, 1, 0, 2, 1], jnp.int32)
    idx, nv = sm.expert_tile_lists(topi, tpe, k_valid=kv)
    compact = sm.expert_up_matmul(x, wu, idx, nv, tile=tile, interpret=True)
    h = jnp.maximum(compact, 0.0)
    y = sm.expert_down_matmul(h, wd, idx, nv, block_d=d, interpret=True)
    ref = np.zeros((T, d), np.float32)
    for t in range(T):
        for i in range(int(kv[t])):
            e = int(topi[t, i])
            ref[t] += np.maximum(np.asarray(x)[t] @ np.asarray(wu)[e],
                                 0.0) @ np.asarray(wd)[e]
    np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-4, atol=1e-4)
    # compact rows past nvalid are exact zeros (no stray DMA contribution)
    assert not np.asarray(compact)[3].any()


# ---------------------------------------------------------------------------
# engine-served MoE streams vs legacy sequential decode (f32 byte-identity)


@pytest.fixture(scope="module")
def moe_setup():
    cfg = get_config("tiny-moe").replace(compute_dtype="float32")
    params = registry.get_family(cfg).init_params(jax.random.PRNGKey(0), cfg)
    prompts = [np.random.RandomState(s).randint(
                   0, cfg.vocab_size, ln).astype(np.int32)
               for s, ln in ((1, 9), (2, 5), (3, 13))]
    return cfg, params, prompts


@pytest.fixture(scope="module")
def legacy_streams(moe_setup):
    cfg, params, prompts = moe_setup
    eng = ServeEngine(cfg, params)
    return [np.asarray(eng.generate({"tokens": jnp.asarray(p)[None]},
                                    8).tokens[0])
            for p in prompts]


def _serve(cfg, params, prompts, max_new=8, **kw):
    kws = kw.pop("submit_kw", {})
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2, block_size=8,
                                   max_blocks_per_seq=6, **kw)
    uids = [eng.submit(p, max_new, **kws) for p in prompts]
    res = eng.run()
    return [np.asarray(res[u].tokens) for u in uids], eng


@pytest.mark.parametrize("mode", ["plain", "chunked"])
def test_moe_engine_byte_identical_to_legacy(moe_setup, legacy_streams, mode):
    cfg, params, prompts = moe_setup
    kw = {} if mode == "plain" else {"prefill_chunk": 4}
    got, eng = _serve(cfg, params, prompts, **kw)
    for g, want in zip(got, legacy_streams):
        np.testing.assert_array_equal(g, want)
    # activated-expert accounting: measured density is exactly top_k /
    # n_experts at reuse_window=0 (drop-free, no mask), so bytes/step is
    # the activated-expert fraction of the dense-all-experts figure
    frac = eng.expert_io_fraction()
    assert frac == cfg.top_k / cfg.n_experts
    dense_all = (cfg.n_layers * cfg.d_ff * cfg.d_model * cfg.n_experts
                 * jnp.dtype(cfg.compute_dtype).itemsize)
    assert eng.weight_io_bytes_per_step() == pytest.approx(frac * dense_all)
    assert eng.weight_io_bytes_per_step() < dense_all
    snap = eng.metrics_snapshot()
    assert snap["expert_io_fraction"] == frac


def test_moe_speculative_with_dense_draft_byte_identical(
        moe_setup, legacy_streams):
    """Speculative mode composes: a 1-layer dense draft proposes, the MoE
    target verifies windows — stream still byte-identical (rollback is
    exact) and some drafts are accepted."""
    cfg, params, prompts = moe_setup
    dcfg = get_config("tiny-relu").replace(
        name="tiny-relu-draft", n_layers=1, compute_dtype="float32")
    dparams = registry.get_family(dcfg).init_params(jax.random.PRNGKey(2),
                                                    dcfg)
    got, eng = _serve(cfg, params, prompts, draft_cfg=dcfg,
                      draft_params=dparams, gamma=3)
    for g, want in zip(got, legacy_streams):
        np.testing.assert_array_equal(g, want)
    assert eng.s_agg_window() is not None


def test_moe_gamma_reuse_savings_beat_expert_floor(moe_setup):
    """γ-window reuse composes WITH routing sparsity: measured weight-I/O
    savings must be at least the activated-expert floor 1 − k/E (reuse
    masks then skip rows inside the activated experts on top)."""
    cfg, params, prompts = moe_setup
    _, eng = _serve(cfg, params, prompts, submit_kw={"reuse_window": 2})
    floor = 1.0 - cfg.top_k / cfg.n_experts
    assert eng.weight_io_saved() >= floor - 1e-9
    assert eng.weight_io_bytes_per_step() <= (
        (1.0 - floor) * cfg.n_layers * cfg.d_ff * cfg.d_model
        * cfg.n_experts * 4 + 1e-6)
