"""Observability tests (repro.obs + its engine/scheduler/API wiring).

The house invariant: observability NEVER changes what the engine computes
— f32 greedy streams are byte-identical with metrics on (the default) and
off (``EngineObs.disabled()``) in all three serving modes, and the hooks'
own cost (``obs.self_time_s``, accumulated inside the hooks with
``perf_counter``) stays a small fraction of the step wall time.

The metrics primitives hold their contracts under hypothesis (the
conftest stub when the real package is absent): snapshot merging is
associative, histogram quantiles always land inside the true quantile's
bucket and the observed [min, max], counters are monotone and reject
negative increments.
"""
from __future__ import annotations

import asyncio
import json
import math
import random

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as hst

from repro.configs import get_config
from repro.launch.serve_api import ApiServer, build_engine, parse_args
from repro.obs import (
    EngineObs, Histogram, Registry, format_statusz, hist_quantile,
    merge_snapshots, parse_prometheus, render_prometheus, snapshot_quantile,
)
from repro.serving import AsyncServingEngine

TIMEOUT_S = 300.0

BASE_ARGS = ["--arch", "tiny-relu", "--f32", "--n-slots", "2",
             "--block-size", "8", "--max-blocks", "4", "--gamma", "2"]

MODES = ["plain", "spec", "predictor"]


def _engine(mode: str = "plain", obs_on: bool = True):
    eng = build_engine(parse_args(BASE_ARGS + ["--mode", mode]))
    if not obs_on:
        # swap in the null hub post-build (build_engine always constructs
        # the default enabled one); the scheduler shares the engine's hub
        eng.obs = EngineObs.disabled()
        eng.scheduler.obs = eng.obs
    return eng


def _prompts(n: int = 3, seed: int = 0):
    vocab = get_config("tiny-relu").vocab_size
    rng = np.random.RandomState(seed)
    return [[int(t) for t in rng.randint(0, vocab, 3 + 2 * i)]
            for i in range(n)]


def _serve(eng, prompts, budgets):
    uids = [eng.submit(p, m) for p, m in zip(prompts, budgets)]
    res = eng.run()
    return {u: [int(t) for t in res[u].tokens] for u in uids}


# -- the tentpole invariant: obs on == obs off, byte for byte ----------------


@pytest.mark.parametrize("mode", MODES)
def test_f32_greedy_byte_identical_with_obs_on_and_off(mode):
    prompts, budgets = _prompts(3), [4, 5, 6]
    on = _serve(_engine(mode, obs_on=True), prompts, budgets)
    off = _serve(_engine(mode, obs_on=False), prompts, budgets)
    assert list(on.values()) == list(off.values())


@pytest.mark.parametrize("mode", MODES)
def test_counters_agree_with_served_workload(mode):
    eng = _engine(mode)
    prompts, budgets = _prompts(3), [4, 5, 6]
    streams = _serve(eng, prompts, budgets)
    obs = eng.obs
    assert obs.c_submitted.value() == len(prompts)
    assert obs.c_admitted.value() == len(prompts)
    assert obs.c_finished.value(reason="length") == len(prompts)
    assert obs.c_tokens.value() == sum(len(s) for s in streams.values())
    assert obs.c_prefill.value() == sum(len(p) for p in prompts)
    assert obs.h_ttft.count() == len(prompts)
    assert obs.h_e2e.count() == len(prompts)
    assert obs.h_queue_wait.count() == len(prompts)
    assert obs.c_steps.value() == obs.steps == eng.t > 0
    # phase histograms cover the phases this mode exercises
    phase_series = set(obs.h_phase.series)
    assert 'phase="prefill"' in phase_series
    assert 'phase="dispatch"' in phase_series
    assert 'phase="sample"' in phase_series
    if mode == "spec":
        assert obs.c_draft_proposed.value() > 0
        assert (0 < obs.c_draft_accepted.value()
                <= obs.c_draft_proposed.value())
    if mode == "predictor":
        assert obs.c_pred_active.value() > 0


def test_obs_self_time_is_a_small_fraction_of_step_time():
    eng = _engine("plain")
    _serve(eng, _prompts(3), [5, 5, 5])
    obs = eng.obs
    step_total = obs.h_step.snapshot()["series"][""]["sum"]
    assert step_total > 0
    # hooks are dict writes + a few floats per step; 10% of step wall (plus
    # a 5 ms absolute floor for coarse timers) is a generous ceiling
    assert obs.self_time_s < 0.10 * step_total + 0.005


def test_disabled_obs_records_nothing():
    eng = _engine("plain", obs_on=False)
    _serve(eng, _prompts(2), [4, 4])
    assert eng.obs.snapshot() == {}
    assert eng.obs.spans == {}
    assert eng.obs.self_time_s == 0.0
    assert eng.obs.render() == ""


# -- metric-helper convention: None for unavailable, 0.0 for zero-so-far ----


@pytest.mark.parametrize("mode", MODES)
def test_metric_helpers_never_raise(mode):
    eng = _engine(mode)
    # fresh engine: nothing measured yet -> None (not a raise, not a fake 0)
    assert eng.predictor_density() is None
    assert eng.predictor_recall() is None
    assert eng.s_agg_window() is None
    assert eng.tile_activity_rate() is None
    assert eng.weight_io_saved() == 0.0
    assert eng.prefix_hit_rate() == 0.0
    snap = eng.metrics_snapshot()
    assert None not in snap.values()
    _serve(eng, _prompts(2), [4, 4])
    snap = eng.metrics_snapshot()
    assert None not in snap.values()
    assert snap["steps"] == eng.t
    if mode == "predictor":
        assert 0.0 < snap["predictor_density"] <= 1.0
        assert 0.0 <= snap["predictor_recall"] <= 1.0
    else:
        assert "predictor_density" not in snap
        assert "predictor_recall" not in snap
    if mode != "spec":
        assert "s_agg_window" not in snap


def test_metrics_omit_unavailable_series():
    eng = _engine("plain")
    _serve(eng, _prompts(2), [4, 4])
    text = eng.obs.render()
    # mode-gated series never fire in plain mode -> absent, not zero
    assert "repro_predictor_active_neurons_total" not in text
    assert "repro_draft_tokens_proposed_total" not in text
    assert "repro_requests_submitted_total 2" in text


# -- /metrics + /statusz over the in-process HTTP wire -----------------------


async def _http_get(port, path):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    writer.write(f"GET {path} HTTP/1.1\r\nHost: t\r\n\r\n".encode())
    await writer.drain()
    data = await reader.read()
    writer.close()
    try:
        await writer.wait_closed()
    except (ConnectionResetError, BrokenPipeError):
        pass
    head, _, body = data.partition(b"\r\n\r\n")
    return int(head.split(b" ", 2)[1]), body.decode()


def test_http_metrics_statusz_profilez():
    eng = _engine("plain")
    prompt = _prompts(1, seed=9)[0]

    async def serve():
        async with AsyncServingEngine(eng) as api:
            server = ApiServer(api, mode="plain")
            await server.start(port=0)
            try:
                ev = await api.generate(prompt, 4)
                assert ev.finish_reason == "length"
                status, text = await _http_get(server.port, "/metrics")
                assert status == 200
                m = parse_prometheus(text)
                assert m[("repro_requests_submitted_total", "")] == 1
                assert (m[("repro_generated_tokens_total", "")]
                        == len(ev.result.tokens))
                assert m[("repro_api_request_seconds_count", "")] == 1
                status, text = await _http_get(server.port, "/statusz")
                assert status == 200
                assert "repro serving engine" in text
                assert "recently finished" in text
                # profiling is opt-in: no --profilez-dir -> 403, never 500
                status, _ = await _http_get(server.port, "/profilez?ms=5")
                assert status == 403
                status, body = await _http_get(server.port, "/healthz")
                assert status == 200 and json.loads(body)["ok"]
            finally:
                await server.aclose()

    asyncio.run(asyncio.wait_for(serve(), TIMEOUT_S))


def test_json_event_log_covers_the_lifecycle():
    events = []
    eng = _engine("plain")
    eng.obs.log_event = events.append
    _serve(eng, _prompts(1), [4])
    kinds = [e["event"] for e in events]
    for kind in ("submit", "admit", "first_token", "finish"):
        assert kind in kinds, kinds
    finish = events[kinds.index("finish")]
    assert finish["reason"] == "length" and finish["n_tokens"] == 4
    assert all("ts" in e for e in events)
    json.dumps(events)  # the --log-json stream must be JSON-serializable


def test_statusz_renders_for_disabled_obs():
    eng = _engine("plain", obs_on=False)
    _serve(eng, _prompts(1), [3])
    text = format_statusz(eng)
    assert "observability=off" in text
    assert "latency" not in text


# -- metrics primitives under hypothesis -------------------------------------


def _hist_from(values, lo=1e-3, factor=2.0, n_buckets=12):
    h = Histogram("h", "", lo=lo, factor=factor, n_buckets=n_buckets)
    for v in values:
        h.observe(v)
    return h


def _values(seed: int, n: int):
    rng = random.Random(seed)
    # span below-lo, in-range, and overflow territory
    return [rng.uniform(1e-4, 50.0) for _ in range(n)]


def _approx_equal(x, y):
    if isinstance(x, dict):
        return (isinstance(y, dict) and x.keys() == y.keys()
                and all(_approx_equal(x[k], y[k]) for k in x))
    if isinstance(x, list):
        return (isinstance(y, list) and len(x) == len(y)
                and all(map(_approx_equal, x, y)))
    if isinstance(x, float):
        return y == pytest.approx(x, rel=1e-9, abs=1e-12)
    return x == y


@settings(max_examples=30)
@given(hst.integers(0, 10 ** 6), hst.integers(1, 40), hst.integers(1, 40),
       hst.integers(1, 40))
def test_merge_snapshots_is_associative(seed, na, nb, nc):
    vals = _values(seed, na + nb + nc)
    snaps = []
    for chunk in (vals[:na], vals[na:na + nb], vals[na + nb:]):
        r = Registry()
        h = r.histogram("h", "x", lo=1e-3, factor=2.0, n_buckets=12)
        c = r.counter("c", "x")
        g = r.gauge("g", "x")
        for v in chunk:
            h.observe(v)
            c.inc(v, kind="a")
        g.set(chunk[-1] if chunk else 0.0)
        snaps.append(r.snapshot())
    a, b, c_ = snaps
    left = merge_snapshots(merge_snapshots(a, b), c_)
    right = merge_snapshots(a, merge_snapshots(b, c_))
    # bucket counts / counts / min / max are exact; the float sums are
    # associative only up to ulp rounding
    assert _approx_equal(left, right)
    merged = merge_snapshots(*snaps)
    assert merged["h"]["series"][""]["count"] == len(vals)
    assert merged["c"]["series"]['kind="a"'] == pytest.approx(sum(vals))
    # and the merged quantile answers from the union
    q = snapshot_quantile(merged, "h", 1.0)
    assert q == pytest.approx(max(vals))


@settings(max_examples=30)
@given(hst.integers(0, 10 ** 6), hst.integers(1, 50),
       hst.floats(0.0, 1.0))
def test_quantile_lands_in_the_true_quantile_bucket(seed, n, q):
    vals = _values(seed, n)
    h = _hist_from(vals)
    got = h.quantile(q)
    assert min(vals) <= got <= max(vals)
    rank = max(1, math.ceil(q * len(vals)))
    true_val = sorted(vals)[rank - 1]
    # got is >= the true quantile and <= its bucket's upper edge
    assert got >= true_val - 1e-12
    upper = next((b for b in h.bounds if true_val <= b), math.inf)
    assert got <= min(upper, max(vals)) + 1e-12


@settings(max_examples=20)
@given(hst.integers(0, 10 ** 6), hst.integers(1, 30))
def test_counter_monotone_and_rejects_negative(seed, n):
    from repro.obs import Counter
    c = Counter("c", "")
    rng = random.Random(seed)
    last = 0.0
    for _ in range(n):
        c.inc(rng.uniform(0, 5))
        assert c.value() >= last
        last = c.value()
    with pytest.raises(ValueError, match="negative"):
        c.inc(-1.0)
    assert c.value() == last  # the failed inc must not corrupt the series


def test_quantile_rejects_out_of_range_q():
    h = _hist_from([1.0])
    with pytest.raises(ValueError, match="outside"):
        h.quantile(1.5)
    assert Histogram("e", "").quantile(0.5) is None  # empty -> None


def test_prometheus_render_parse_roundtrip():
    r = Registry()
    r.counter("repro_x_total", "a counter").inc(3, reason="length")
    r.gauge("repro_g", "a gauge").set(0.5)
    h = r.histogram("repro_h_seconds", "a histogram", lo=1e-3,
                    factor=2.0, n_buckets=4)
    h.observe(0.002)
    h.observe(10.0)  # overflow bucket
    text = r.render()
    assert '# TYPE repro_x_total counter' in text
    assert '# TYPE repro_h_seconds histogram' in text
    m = parse_prometheus(text)
    assert m[("repro_x_total", 'reason="length"')] == 3
    assert m[("repro_g", "")] == 0.5
    assert m[("repro_h_seconds_count", "")] == 2
    assert m[("repro_h_seconds_bucket", 'le="+Inf"')] == 2
    # cumulative buckets: the last finite edge holds only the small obs
    assert m[("repro_h_seconds_bucket", 'le="0.008"')] == 1
    # unobserved metrics are omitted entirely
    assert render_prometheus(Registry().snapshot()) == ""


def test_merge_rejects_mismatched_geometry_and_kind():
    r1, r2 = Registry(), Registry()
    r1.histogram("h", "", lo=1e-3).observe(1.0)
    r2.histogram("h", "", lo=1e-2).observe(1.0)
    with pytest.raises(ValueError, match="bounds"):
        merge_snapshots(r1.snapshot(), r2.snapshot())
    r3 = Registry()
    r3.counter("h", "").inc()
    with pytest.raises(ValueError, match="histogram"):
        merge_snapshots(r1.snapshot(), r3.snapshot())


def test_hist_quantile_single_observation_is_exact():
    h = _hist_from([0.0123])
    for q in (0.0, 0.5, 1.0):
        assert h.quantile(q) == pytest.approx(0.0123)
    s = {"bounds": h.bounds, **h.snapshot()["series"][""]}
    assert hist_quantile(s, 0.5) == pytest.approx(0.0123)
