"""Predictor-subsystem tests: tile-gather kernel variants, calibration
(target recall, serialization), predictor-mode serving exactness at
recall-1.0, telemetry, and the hypothesis properties the issue pins
(recall monotone in threshold; padded tile indices always in range)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.kernels.sparse_matmul import (sparse_matmul_tokens,
                                         sparse_up_matmul)
from repro.models import registry
from repro.predictor import (calibrate, load_predictor, pack_tile_indices,
                             save_predictor, sign_predictor)
from repro.serving import ContinuousBatchingEngine


def _setup(name="tiny-relu", dtype=None):
    cfg = get_config(name)
    if dtype is not None:
        cfg = cfg.replace(compute_dtype=dtype)
    fam = registry.get_family(cfg)
    params = fam.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _calib_batch(cfg, seed=2, shape=(4, 24)):
    return {"tokens": jax.random.randint(jax.random.PRNGKey(seed), shape, 0,
                                         cfg.vocab_size)}


def _prompts(cfg, lengths, seed=1):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, s).astype(np.int32)
            for s in lengths]


# ---------------------------------------------------------------------------
# kernel variants (interpret autodetects CPU — no interpret= arg anywhere)


def test_sparse_matmul_tokens_per_row_gather():
    """Each row accumulates only its own tiles; zero-valid rows are zero."""
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(3, 256), jnp.float32)
    w = jnp.asarray(rng.randn(256, 64), jnp.float32)
    idx = jnp.asarray([[1, 1], [0, 1], [0, 0]], jnp.int32)
    nv = jnp.asarray([1, 2, 0], jnp.int32)
    y = np.asarray(sparse_matmul_tokens(x, w, idx, nv, tile=128, block_d=64))
    np.testing.assert_allclose(y[0], np.asarray(x[0, 128:] @ w[128:]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(y[1], np.asarray(x[1] @ w), rtol=1e-5,
                               atol=1e-5)
    assert np.abs(y[2]).sum() == 0.0


def test_sparse_up_matmul_zero_outside_selection():
    """Output-tile gather: selected tiles match the dense product, the rest
    are exactly zero (the predictor's correctness contract)."""
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(2, 32), jnp.float32)
    w = jnp.asarray(rng.randn(32, 64), jnp.float32)
    idx = jnp.asarray([[3, 0, 0], [1, 2, 1]], jnp.int32)
    nv = jnp.asarray([1, 2], jnp.int32)
    y = np.asarray(sparse_up_matmul(x, w, idx, nv, tile=16))
    full = np.asarray(x @ w)
    np.testing.assert_allclose(y[0, 48:], full[0, 48:], rtol=1e-5, atol=1e-5)
    assert np.abs(y[0, :48]).sum() == 0.0
    np.testing.assert_allclose(y[1, 16:48], full[1, 16:48], rtol=1e-5,
                               atol=1e-5)
    assert np.abs(y[1, :16]).sum() == 0.0 and np.abs(y[1, 48:]).sum() == 0.0


@pytest.mark.skipif(jax.default_backend() != "cpu",
                    reason="autodetect contract differs off-CPU")
def test_interpret_autodetect_matches_explicit():
    """interpret=None resolves to interpret mode on this CPU container and
    agrees with the explicit override."""
    from repro.kernels.sparse_matmul import _resolve_interpret, sparse_matmul
    assert _resolve_interpret(None) is True
    assert _resolve_interpret(False) is False
    rng = np.random.RandomState(2)
    x = jnp.asarray(rng.randn(2, 256), jnp.float32)
    w = jnp.asarray(rng.randn(256, 64), jnp.float32)
    idx, nv = jnp.asarray([0, 1], jnp.int32), jnp.asarray(2)
    auto = sparse_matmul(x, w, idx, nv, tile=128, block_d=64)
    expl = sparse_matmul(x, w, idx, nv, tile=128, block_d=64, interpret=True)
    np.testing.assert_allclose(np.asarray(auto), np.asarray(expl))


# ---------------------------------------------------------------------------
# calibration


@pytest.mark.parametrize("kind,kw", [
    ("sign", dict(probe_dtype="bfloat16", target_recall=0.95)),
    ("lowrank", dict(rank=8, target_recall=0.9)),
])
def test_calibration_hits_target_recall(kind, kw):
    cfg, params = _setup()
    pred = calibrate(params, cfg, _calib_batch(cfg), kind=kind, tile=1, **kw)
    assert len(pred.reports) == cfg.n_layers
    for r in pred.reports:
        assert r.recall >= kw["target_recall"] - 1e-9
        assert 0.0 <= r.precision <= 1.0
        assert 0.0 < r.tile_density <= 1.0
        assert r.tile_recall >= r.recall  # tiles only ever add coverage


def test_sign_recall_one_is_structural():
    """target_recall=1.0 clamps the sign tau to the firing threshold, so
    calibration recall is 1.0 by construction, not by luck."""
    cfg, params = _setup(dtype="float32")
    pred = calibrate(params, cfg, _calib_batch(cfg), kind="sign",
                     probe_dtype="float32", target_recall=1.0, tile=1)
    assert all(r.recall == 1.0 for r in pred.reports)
    assert np.all(np.asarray(pred.params["tau"]) <= 0.0)


def test_predictor_checkpoint_roundtrip(tmp_path):
    cfg, params = _setup()
    pred = calibrate(params, cfg, _calib_batch(cfg), kind="lowrank", rank=4,
                     target_recall=0.9, tile=1)
    save_predictor(pred, str(tmp_path))
    back = load_predictor(str(tmp_path))
    assert back.kind == pred.kind and back.k_tiles == pred.k_tiles
    assert back.tile == pred.tile and back.n_tiles == pred.n_tiles
    for k in pred.params:
        np.testing.assert_allclose(np.asarray(back.params[k], np.float32),
                                   np.asarray(pred.params[k], np.float32),
                                   rtol=1e-6, atol=1e-6)
    assert [r.recall for r in back.reports] == [r.recall
                                                for r in pred.reports]


# ---------------------------------------------------------------------------
# predictor-mode serving


@pytest.mark.parametrize("name", ["tiny-relu", "tiny-opt"])
def test_predictor_mode_exact_at_recall_one(name):
    """Recall-1.0 calibration (full-precision sign probe) reproduces the
    dense greedy stream exactly — asserted at f32 compute, where the
    differently-shaped executables agree (the bf16 cross-executable
    rounding gotcha documented on apply_block_decode_paged)."""
    cfg, params = _setup(name, dtype="float32")
    prompts = _prompts(cfg, [9, 14], seed=3)

    dense = ContinuousBatchingEngine(cfg, params, n_slots=2, block_size=8,
                                     max_blocks_per_seq=6)
    uids_d = [dense.submit(p, max_new=7) for p in prompts]
    ref = dense.run()

    pred = calibrate(params, cfg, _calib_batch(cfg), kind="sign",
                     probe_dtype="float32", target_recall=1.0, tile=1)
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2, block_size=8,
                                   max_blocks_per_seq=6, predictor=pred)
    uids_p = [eng.submit(p, max_new=7) for p in prompts]
    res = eng.run()

    for ud, up in zip(uids_d, uids_p):
        np.testing.assert_array_equal(ref[ud].tokens, res[up].tokens)
        np.testing.assert_allclose(ref[ud].logprobs, res[up].logprobs,
                                   rtol=1e-5, atol=1e-6)
    assert eng.predictor_recall() == 1.0
    assert eng.weight_io_saved() > 0.0  # rows were actually skipped
    for u in uids_p:
        assert res[u].pred_misses == 0
        assert res[u].realized_recall == 1.0
        assert 0.0 < res[u].predicted_density < 1.0


def test_predictor_telemetry_and_gamma_composition():
    """Lossy (lowrank) predictor at default bf16: telemetry lands on
    RequestResult, engine aggregates stay in range, and composing the
    γ-window mask (reuse_window) keeps serving every request."""
    cfg, params = _setup()
    pred = calibrate(params, cfg, _calib_batch(cfg), kind="lowrank", rank=8,
                     target_recall=0.9, tile=1)
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2, block_size=8,
                                   max_blocks_per_seq=6, predictor=pred,
                                   track_sparsity=True)
    uids = [eng.submit(p, max_new=6, reuse_window=3)
            for p in _prompts(cfg, [8, 11], seed=4)]
    res = eng.run()
    for u in uids:
        r = res[u]
        assert len(r.tokens) == 6
        assert 0.0 < r.predicted_density <= 1.0
        assert 0.0 <= r.realized_recall <= 1.0
        assert (r.pred_misses == 0) == (r.realized_recall == 1.0)
        assert 0.0 <= eng.trackers[u].aggregated_sparsity() <= 1.0
    assert 0.0 <= eng.predictor_recall() <= 1.0
    assert 0.0 < eng.predictor_density() <= 1.0


def test_predictor_telemetry_off_same_stream_no_probe_metrics():
    """predictor_telemetry=False (the production configuration: no dense
    recall probe in the graph) must serve the identical token stream;
    recall is then unmeasured and predictor_recall() says so."""
    cfg, params = _setup(dtype="float32")
    pred = calibrate(params, cfg, _calib_batch(cfg), kind="sign",
                     probe_dtype="float32", target_recall=1.0, tile=1)
    prompts = _prompts(cfg, [9], seed=6)
    streams = []
    for telemetry in (True, False):
        eng = ContinuousBatchingEngine(cfg, params, n_slots=2, block_size=8,
                                       max_blocks_per_seq=6, predictor=pred,
                                       predictor_telemetry=telemetry)
        uid = eng.submit(prompts[0], max_new=6)
        streams.append(eng.run()[uid].tokens)
    np.testing.assert_array_equal(streams[0], streams[1])
    assert eng.weight_io_saved() > 0.0  # density accounting still works
    # unmeasured -> None (the metric-helper convention: never a fake 1.0,
    # never a raise); /metrics likewise omits the recall series entirely
    assert eng.predictor_recall() is None
    assert "repro_predictor_active_neurons_total" not in eng.obs.render()


def test_predictor_and_speculative_are_exclusive():
    cfg, params = _setup()
    pred = sign_predictor(params, cfg, tile=1)
    with pytest.raises(ValueError, match="mutually exclusive"):
        ContinuousBatchingEngine(cfg, params, predictor=pred,
                                 draft_cfg=cfg, draft_params=params)


def test_sign_predictor_requires_sparse_activation():
    cfg, params = _setup("tiny")  # silu
    with pytest.raises(ValueError, match="firing threshold"):
        sign_predictor(params, cfg)


# ---------------------------------------------------------------------------
# hypothesis properties


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(-2.0, 2.0), st.floats(0.0, 2.0))
def test_recall_monotone_in_threshold(seed, tau_lo, gap):
    """Raising the threshold can only LOWER recall: the predicted set
    shrinks monotonically in tau."""
    rng = np.random.RandomState(seed % (2**31 - 1))
    probe = rng.randn(16, 64).astype(np.float32)
    active = rng.randn(16, 64) > 0.3
    n_act = max(1, int(active.sum()))
    tau_hi = tau_lo + gap

    def recall(tau):
        return float(((probe > tau) & active).sum() / n_act)

    assert recall(tau_lo) >= recall(tau_hi)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 8), st.integers(1, 10),
       st.floats(0.0, 1.0))
def test_packed_tile_indices_always_in_range(seed, n_tiles, k, p_active):
    """Padded/truncated tile indices never leave [0, n_tiles), whatever the
    mask density or capacity — no gather can touch a tile that does not
    exist (kernel index maps dereference these raw)."""
    rng = np.random.RandomState(seed % (2**31 - 1))
    mask = jnp.asarray(rng.rand(5, n_tiles) < p_active)
    idx, nvalid = pack_tile_indices(mask, k)
    idx, nvalid = np.asarray(idx), np.asarray(nvalid)
    assert idx.shape == (5, min(k, n_tiles))
    assert (idx >= 0).all() and (idx < n_tiles).all()
    assert (nvalid <= min(k, n_tiles)).all() and (nvalid >= 0).all()
    # every VALID index names a truly-masked tile, with no duplicates
    m = np.asarray(mask)
    for t in range(5):
        sel = idx[t, : nvalid[t]]
        assert len(set(sel.tolist())) == nvalid[t]
        assert m[t, sel].all()
