"""Property tests (hypothesis; stub-compatible) for the refcounted block
allocator and the prompt-prefix trie (ISSUE 4): no double-free, refcounts
never negative, the scratch block never handed out or freed, and arbitrary
interleaved admit/prefill/decode/retire sequences conserve the pool —
every one of the n_blocks - 1 allocatable blocks is at all times either on
the free list or accounted for by exactly refcount(b) holders (slots
sharing it + the trie)."""
from collections import Counter

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models.common import SCRATCH_BLOCK
from repro.serving.scheduler import (BlockAllocator, PrefixCache, Request,
                                     Scheduler)


# ---------------------------------------------------------------------------
# allocator-level properties


@settings(max_examples=12, deadline=None)
@given(st.integers(2, 24), st.integers(0, 10_000))
def test_allocator_refcount_conservation(n_blocks, seed):
    """Random alloc/ref/free interleavings: the allocator's refcounts track
    an independently maintained ledger exactly, the scratch block is never
    handed out, and free-list + live blocks always partition the pool."""
    rng = np.random.RandomState(seed)
    al = BlockAllocator(n_blocks)
    ledger = {}  # block -> refcount we believe it has
    for _ in range(200):
        op = rng.randint(3)
        if op == 0:
            n = rng.randint(1, n_blocks + 1)
            got = al.alloc(n)
            if n > (n_blocks - 1) - len(ledger):
                assert got is None  # over-ask fails atomically
            else:
                assert got is not None and len(got) == len(set(got)) == n
                assert SCRATCH_BLOCK not in got
                assert not set(got) & set(ledger)  # never double-handed-out
                for b in got:
                    ledger[b] = 1
        elif op == 1 and ledger:
            b = list(ledger)[rng.randint(len(ledger))]
            al.ref([b])
            ledger[b] += 1
        elif op == 2 and ledger:
            b = list(ledger)[rng.randint(len(ledger))]
            al.free([b])
            ledger[b] -= 1
            if ledger[b] == 0:
                del ledger[b]
        assert al.available + len(ledger) == n_blocks - 1
        assert al.allocated == len(ledger)
        for b, n_refs in ledger.items():
            assert al.refcount(b) == n_refs > 0


def test_allocator_double_free_guarded():
    al = BlockAllocator(4)
    (b,) = al.alloc(1)
    al.free([b])
    with pytest.raises(AssertionError, match="double free"):
        al.free([b])
    assert al.available == 3  # the guard fired before corrupting the pool


def test_allocator_scratch_never_handed_out_or_freed():
    al = BlockAllocator(3)
    assert SCRATCH_BLOCK not in al.alloc(2)
    assert al.alloc(1) is None  # pool exhausted without touching scratch
    with pytest.raises(AssertionError):
        al.free([SCRATCH_BLOCK])


def test_allocator_ref_of_free_block_guarded():
    al = BlockAllocator(4)
    with pytest.raises(AssertionError, match="unallocated"):
        al.ref([1])


# ---------------------------------------------------------------------------
# scheduler + trie properties under interleaved admit/prefill/decode/retire


def _check_invariants(sched: Scheduler, n_blocks: int):
    """refcount(b) == (#slots holding b) + (#trie nodes holding b), for
    every block; pool partition; scratch reserved."""
    owners = Counter(b for s in sched.slots if s is not None
                     for b in s.blocks)
    trie = Counter(sched.prefix.blocks()) if sched.prefix else Counter()
    assert SCRATCH_BLOCK not in owners and SCRATCH_BLOCK not in trie
    live = set(owners) | set(trie)
    assert sched.allocator.allocated == len(live)
    assert sched.allocator.available + len(live) == n_blocks - 1
    for b in live:
        assert sched.allocator.refcount(b) == owners[b] + trie[b]
    for count in trie.values():
        assert count == 1  # a block backs at most one trie node


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(0, 10_000))
def test_interleaved_admit_retire_conserves_pool(n_slots, seed):
    """Random request mixes — many sharing block-aligned prefixes — driven
    through admit / chunked prefill / decode / retire with the invariants
    checked at every step. Afterwards only the trie may still hold blocks,
    and evicting it returns the pool to exactly n_blocks - 1 free."""
    rng = np.random.RandomState(seed)
    bs, max_bps = 4, 4
    n_blocks = 1 + n_slots * max_bps
    sched = Scheduler(n_slots, n_blocks, bs, max_bps, prefix_cache=True)

    # shared-prefix library: full-block token runs (1 or 2 blocks)
    lib = [rng.randint(0, 50, bs * k).astype(np.int32) for k in (1, 2, 1)]
    n_req = rng.randint(3, 9)
    for uid in range(1, n_req + 1):
        parts = []
        if rng.rand() < 0.7:
            parts.append(lib[rng.randint(len(lib))])
        parts.append(rng.randint(0, 50, rng.randint(1, 5)).astype(np.int32))
        tokens = np.concatenate(parts)
        max_new = rng.randint(1, max_bps * bs - len(tokens) + 1)
        sched.submit(Request(uid=uid, tokens=tokens, max_new=int(max_new)))

    chunk = 3
    for step in range(1000):
        sched.retire_finished(step)
        if not sched.has_work():
            break
        sched.admit(step)
        _check_invariants(sched, n_blocks)
        if sched.prefill_indices():
            _, _, _, clen, _ = sched.prefill_batch(chunk)
            sched.record_prefill(
                np.zeros((n_slots, chunk), np.int64),
                np.zeros((n_slots, chunk), np.float32), clen)
            _check_invariants(sched, n_blocks)  # seeding inserts trie nodes
        if sched.active_indices():
            sched.record(np.zeros(n_slots, np.int64),
                         np.zeros(n_slots, np.float32))
    else:
        raise AssertionError("scheduler failed to drain")

    sched.retire_finished(step)
    assert len(sched.results) == n_req
    for res in sched.results.values():
        assert res.cached_prompt_tokens % bs == 0
        assert res.cached_prompt_tokens < res.prompt_len
    _check_invariants(sched, n_blocks)
    # only the trie still holds blocks; evicting everything frees the pool
    n_cached = len(sched.prefix)
    assert sched.allocator.available == n_blocks - 1 - n_cached
    assert sched.prefix.evict(sched.allocator, n_cached) == n_cached
    assert sched.allocator.available == n_blocks - 1
    assert len(sched.prefix) == 0


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(0, 10_000))
def test_random_preempt_resume_conserves_pool(n_slots, seed):
    """ISSUE 10: the drain loop above, now with randomly injected
    preemptions (mixed priorities, preemption also firing organically via
    admission). Preempt parks written blocks in the trie and frees the
    slot's references; resume maps them back — the ledger must balance at
    every step and every request must still complete with its full
    output."""
    rng = np.random.RandomState(seed)
    bs, max_bps = 4, 4
    n_blocks = 1 + n_slots * max_bps
    sched = Scheduler(n_slots, n_blocks, bs, max_bps, prefix_cache=True,
                      aging_steps=16)
    lib = [rng.randint(0, 50, bs * k).astype(np.int32) for k in (1, 2)]
    n_req = rng.randint(3, 9)
    expect_len = {}
    for uid in range(1, n_req + 1):
        parts = []
        if rng.rand() < 0.5:
            parts.append(lib[rng.randint(len(lib))])
        parts.append(rng.randint(0, 50, rng.randint(1, 5)).astype(np.int32))
        tokens = np.concatenate(parts)
        max_new = int(rng.randint(1, max_bps * bs - len(tokens) + 1))
        expect_len[uid] = max_new
        sched.submit(Request(uid=uid, tokens=tokens, max_new=max_new,
                             priority=int(rng.randint(3))))

    chunk, forced = 3, 0
    for step in range(2000):
        sched.retire_finished(step)
        if not sched.has_work():
            break
        sched.admit(step)
        _check_invariants(sched, n_blocks)
        victims = [i for i, s in enumerate(sched.slots)
                   if s is not None and not s.done]
        if victims and forced < 6 and rng.rand() < 0.15:
            sched.preempt(int(victims[rng.randint(len(victims))]), step)
            forced += 1
            _check_invariants(sched, n_blocks)
            continue  # re-admit before advancing (as the engine would)
        if sched.prefill_indices():
            _, _, _, clen, _ = sched.prefill_batch(chunk)
            sched.record_prefill(
                np.zeros((n_slots, chunk), np.int64),
                np.zeros((n_slots, chunk), np.float32), clen)
            _check_invariants(sched, n_blocks)
        if sched.active_indices():
            sched.record(np.zeros(n_slots, np.int64),
                         np.zeros(n_slots, np.float32))
    else:
        raise AssertionError("scheduler failed to drain under preemption")

    sched.retire_finished(step)
    assert sorted(sched.results) == sorted(expect_len)
    for uid, res in sched.results.items():
        assert len(res.tokens) == expect_len[uid]  # no token lost/duplicated
    assert (sched.preemption_count >= forced)
    _check_invariants(sched, n_blocks)
    n_cached = len(sched.prefix)
    assert sched.allocator.available == n_blocks - 1 - n_cached
    assert sched.prefix.evict(sched.allocator, n_cached) == n_cached
    assert sched.allocator.available == n_blocks - 1


@settings(max_examples=8, deadline=None)
@given(st.integers(2, 5), st.integers(0, 10_000))
def test_trie_lookup_is_longest_block_aligned_proper_prefix(bs, seed):
    """Trie semantics directly: a hit returns blocks for the longest cached
    full-block prefix, capped one token short of the querying prompt, and
    holds exactly one reference per cached node."""
    rng = np.random.RandomState(seed)
    al = BlockAllocator(16)
    trie = PrefixCache()
    prompt = rng.randint(0, 9, 3 * bs + 1).astype(np.int32)  # 3 full blocks
    blocks = al.alloc(3)
    trie.insert(prompt, blocks, bs, al)
    assert len(trie) == 3 and all(al.refcount(b) == 2 for b in blocks)

    # identical prompt: full 3-block hit
    assert trie.lookup(prompt, bs) == blocks
    # same tokens but EXACTLY 3 blocks long: the last block must stay cold
    assert trie.lookup(prompt[: 3 * bs], bs) == blocks[:2]
    # diverging inside block 2: only block 0 matches
    q = prompt.copy()
    q[bs] = (q[bs] + 1) % 9
    assert trie.lookup(q, bs) == blocks[:1]
    # shorter than one block: nothing can match
    assert trie.lookup(prompt[: bs - 1], bs) == []

    # the original owner releases its references; eviction returns all 3
    al.free(blocks)
    assert trie.evict(al, 99) == 3
    assert al.available == 15 and len(trie) == 0


def test_trie_eviction_spares_shared_blocks():
    """evict() must never reclaim a cached block a live request shares
    (refcount > 1), however stale its LRU stamp."""
    al = BlockAllocator(8)
    trie = PrefixCache()
    bs = 2
    old = np.asarray([1, 2, 9], np.int32)  # 1 full block, stale
    hot = np.asarray([3, 4, 9], np.int32)  # 1 full block, shared by a slot
    b_old = al.alloc(1)
    trie.insert(old, b_old, bs, al)
    b_hot = al.alloc(1)
    trie.insert(hot, b_hot, bs, al)
    al.ref(b_hot)  # a live request maps the hot prefix
    al.free(b_old)  # its owner retired: only the trie holds it
    al.free(b_hot)  # hot owner retired too, but the sharer remains
    assert trie.evict(al, 2) == 1  # only the stale, unshared block moved
    assert al.refcount(b_hot[0]) == 2 and al.refcount(b_old[0]) == 0
