"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import activations as acts
from repro.core import spec_theory
from repro.models import common as cm
from repro.sharding import rules


# ---------------------------------------------------------------------------
# activations (paper Sec. 3: the β-gated family interpolates SiLU -> ReLU)


@settings(max_examples=30, deadline=None)
@given(st.floats(-5, 5))
def test_beta_family_limits(x):
    x = jnp.float32(x)
    silu = acts.get("silu")(x)
    b1 = acts.get("beta=1")(x)
    np.testing.assert_allclose(float(silu), float(b1), rtol=1e-5, atol=1e-6)
    big = acts.get("beta=200")(x)
    relu = acts.get("relu")(x)
    assert abs(float(big) - float(relu)) < 0.05


@settings(max_examples=20, deadline=None)
@given(st.floats(0.0, 3.0), st.integers(0, 1000))
def test_shifted_relu_sparsity_monotone(shift, seed):
    """Larger shift -> more zeros (paper Sec. 5.3)."""
    x = jnp.asarray(np.random.RandomState(seed).randn(256), jnp.float32)
    s0 = float(acts.sparsity_of(acts.shifted_relu(x, 0.0)))
    s1 = float(acts.sparsity_of(acts.shifted_relu(x, shift)))
    assert s1 >= s0 - 1e-6


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 100))
def test_relu_sparsity_definition(seed):
    x = jnp.asarray(np.random.RandomState(seed).randn(512), jnp.float32)
    y = acts.get("relu")(x)
    assert float(acts.sparsity_of(y)) == pytest.approx(
        float(jnp.mean((x <= 0).astype(jnp.float32))), abs=1e-6)


# ---------------------------------------------------------------------------
# attention: chunked online-softmax == naive attention


def _naive_attention(q, k, v, causal, window=0):
    b, s, kvp, g, d = q.shape
    qf = q.astype(jnp.float32) / np.sqrt(d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    i = jnp.arange(s)[:, None]
    j = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((s, k.shape[1]), bool)
    if causal:
        mask &= j <= i
    if window:
        mask &= j > i - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, -1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", w, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 50), st.sampled_from([(16, 8), (32, 8), (24, 12)]),
       st.booleans(), st.sampled_from([0, 8]))
def test_flash_attention_matches_naive(seed, sq, causal, window):
    s, chunk = sq
    rng = np.random.RandomState(seed)
    b, kvp, g, d = 2, 2, 2, 8
    q = jnp.asarray(rng.randn(b, s, kvp, g, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, s, kvp, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, kvp, d), jnp.float32)
    got = cm.flash_attention(q, k, v, causal=causal, window=window,
                             q_chunk=chunk, kv_chunk=chunk)
    want = _naive_attention(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_decode_attention_matches_naive():
    rng = np.random.RandomState(0)
    b, S, kvp, g, d = 2, 16, 2, 2, 8
    q = jnp.asarray(rng.randn(b, kvp, g, d), jnp.float32)
    # head-major cache layout (b, kvp, S, d)
    kc = jnp.asarray(rng.randn(b, kvp, S, d), jnp.float32)
    vc = jnp.asarray(rng.randn(b, kvp, S, d), jnp.float32)
    pos = jnp.asarray([7, 12], jnp.int32)
    got = cm.decode_attention(q, kc, vc, pos)
    # manual masked softmax reference
    qf = q.astype(jnp.float32) / np.sqrt(d)
    logits = jnp.einsum("bhgd,bhsd->bhgs", qf, kc)
    valid = jnp.arange(S)[None, :] <= pos[:, None]
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, -1)
    want = jnp.einsum("bhgs,bhsd->bhgd", w, vc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# tile selection / gathered matmul (the paper's mechanism)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([0.25, 0.5, 1.0]))
def test_gathered_matmul_exact_when_capacity_sufficient(seed, density):
    """If the true number of active tiles <= capacity, sparse == dense."""
    rng = np.random.RandomState(seed)
    T, F, D, tile = 4, 512, 64, 128
    n_tiles = F // tile
    k_active = max(1, int(density * n_tiles))
    x = np.zeros((T, F), np.float32)
    active = rng.choice(n_tiles, k_active, replace=False)
    for t_ in active:
        x[:, t_ * tile:(t_ + 1) * tile] = rng.randn(T, tile)
    xj = jnp.asarray(x)
    w = jnp.asarray(rng.randn(F, D) / np.sqrt(F), jnp.float32)
    sc = cm.tile_scores(xj, tile)
    idx, mask = cm.select_active_tiles(sc, density)
    y = cm.gathered_matmul(xj, w, idx, mask, tile)
    dense = x @ np.asarray(w)
    np.testing.assert_allclose(np.asarray(y), dense, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 100))
def test_select_active_tiles_static_shape(seed):
    """Capacity is static regardless of the input (XLA requirement)."""
    rng = np.random.RandomState(seed)
    sc1 = jnp.asarray(np.abs(rng.randn(8)), jnp.float32)
    sc2 = jnp.asarray(np.zeros(8), jnp.float32)
    i1, m1 = cm.select_active_tiles(sc1, 0.5)
    i2, m2 = cm.select_active_tiles(sc2, 0.5)
    assert i1.shape == i2.shape == (4,)
    assert float(m2.sum()) == 0.0  # nothing truly active


# ---------------------------------------------------------------------------
# sharding rules invariants


@settings(max_examples=50, deadline=None)
@given(st.sampled_from(["layers/attn/wq", "layers/attn/wk", "layers/ffn/wu",
                        "layers/ffn/wd", "embed", "layers/moe/wu",
                        "layers/ssm/in_proj", "layers/ssm/out_proj"]),
       st.sampled_from(["train", "serve"]))
def test_param_pspec_invariants(path, mode):
    mesh = None
    import jax as _jax
    mesh = _jax.sharding.Mesh(
        np.array(_jax.devices() * 256).reshape(16, 16)[:16, :16],
        ("data", "model"))
    shapes = {
        "layers/attn/wq": (4, 2560, 32, 128),
        "layers/attn/wk": (4, 2560, 8, 128),
        "layers/ffn/wu": (4, 2560, 9728),
        "layers/ffn/wd": (4, 9728, 2560),
        "embed": (153600, 2560),
        "layers/moe/wu": (4, 8, 6144, 16384),
        "layers/ssm/in_proj": (4, 4096, 16384),
        "layers/ssm/out_proj": (4, 8192, 4096),
    }
    shape = shapes[path]
    spec = rules.param_pspec(path, shape, mesh, mode)
    named = [a for a in spec if a is not None]
    assert len(named) == len(set(named))  # no axis used twice
    for dim, ax in zip(shape, spec):
        if ax is not None:
            size = mesh.shape[ax] if isinstance(ax, str) else \
                int(np.prod([mesh.shape[a] for a in ax]))
            assert dim % size == 0  # always divisible


# ---------------------------------------------------------------------------
# speculative decoding theory (paper App. C)


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 64), st.floats(0.01, 0.5), st.floats(0.0, 0.99))
def test_thm1_speedup_geq_one(gamma, c, s_agg):
    assert spec_theory.thm1_speedup(gamma, c, s_agg) >= 1.0 - 1e-9


@settings(max_examples=20, deadline=None)
@given(st.floats(0.01, 0.3), st.floats(0.5, 0.95))
def test_sparse_optimal_gamma_not_larger(c, alpha):
    """Paper Fig. 10a: the sparse optimum γ* is <= the standard one."""
    g_std, _ = spec_theory.optimal_gamma(c, alpha)
    g_sparse, _ = spec_theory.optimal_gamma(
        c, alpha, lambda g: 0.3 + 0.3 * (0.97 ** g))
    assert g_sparse <= g_std


def test_thm2_matches_paper_case():
    """Paper App. C: alpha=.8, c=.02 -> standard optimum γ=12, sparse γ~10."""
    g_std, _ = spec_theory.optimal_gamma(0.02, 0.8)
    assert 10 <= g_std <= 14
