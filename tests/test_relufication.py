"""Relufication surgery + serving-config tests (paper Sec. 4 / 5.3)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import relufication as rf
from repro.core.sparsity import measure_site_sparsity
from repro.models import registry


def test_surgery_is_config_only():
    cfg = get_config("tiny")
    c1 = rf.relufy_stage1(cfg)
    c2 = rf.relufy_stage2(cfg)
    assert c1.activation == "relu" and not c1.post_norm_relu
    assert c2.activation == "relu" and c2.post_norm_relu
    # weights pass through unchanged: same init works under both configs
    fam = registry.get_family(cfg)
    params = fam.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.zeros((1, 8), jnp.int32)}
    for c in (cfg, c1, c2):
        logits = fam.model_forward(params, batch, c)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


def test_stage2_sparsifies_qkv_input():
    cfg = rf.relufy_stage2(get_config("tiny"))
    fam = registry.get_family(cfg)
    params = fam.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                          cfg.vocab_size)}
    sp2 = measure_site_sparsity(params, batch, cfg)
    sp1 = measure_site_sparsity(params, batch, rf.relufy_stage1(cfg).replace(
        post_norm_relu=False))
    # post-norm ReLU must create qkv-input sparsity; stage 1 has none
    assert sp2["mean/qkv"] > 0.2
    assert sp1["mean/qkv"] < 0.01


def test_calibrate_shift_hits_target():
    """The calibrated b should push sparsity toward the target (Sec. 5.3)."""
    cfg = rf.relufy_stage1(get_config("tiny"))
    fam = registry.get_family(cfg)
    params = fam.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                          cfg.vocab_size)}
    b = rf.calibrate_shift(params, batch, cfg, target_sparsity=0.9)
    assert b > 0
    shifted = rf.shifted_relufy(cfg, b)
    sp = measure_site_sparsity(params, batch, shifted)
    base = measure_site_sparsity(params, batch, cfg)
    assert sp["mean/down"] > base["mean/down"] + 0.1
    assert sp["mean/down"] > 0.6  # near the 0.9 target (glu product dilutes)


def test_enable_sparse_serving_roundtrip():
    cfg = rf.enable_sparse_serving(get_config("tiny"), 0.25, 0.75,
                                   reuse_window=8)
    assert cfg.sparsity.enabled
    assert cfg.sparsity.ffn_tile_density == 0.25
    assert cfg.sparsity.reuse_window == 8
    # JSON round-trip keeps the sparsity config (deployable descriptor)
    cfg2 = type(cfg).from_json(cfg.to_json())
    assert cfg2.sparsity == cfg.sparsity


def test_norm_ppf_sane():
    assert abs(rf._norm_ppf(0.5)) < 1e-6
    assert abs(rf._norm_ppf(0.975) - 1.96) < 0.01
    assert abs(rf._norm_ppf(0.025) + 1.96) < 0.01
