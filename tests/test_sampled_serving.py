"""Sampled decoding through the serving engine (determinism + spec mode).

Pins the serving contracts that make sampling production-safe here:

* restart determinism — a seeded request replays the SAME stream across
  engine instances and under shuffled admission order (the PRNG key hangs
  off (seed, request fingerprint), never uid/slot/admission order);
* speculative exactness under sampling — key-coupled acceptance makes the
  sampled spec stream identical to the autoregressive sampled stream for
  ANY draft (a junk draft only costs accept rate, never changes tokens),
  and a perfect draft accepts everything;
* temperature 0 with a seed is byte-identical to the greedy path;
* stop sequences truncate identically in AR and spec modes;
* the jitted decode step never retraces on sampling config (params are
  traced arrays, not static values).
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve_api import build_engine, parse_args
from repro.models import registry
from repro.serving import ContinuousBatchingEngine, SamplingParams

BASE_ARGS = ["--arch", "tiny-relu", "--f32", "--n-slots", "2",
             "--block-size", "8", "--max-blocks", "4", "--gamma", "2"]


def _engine(mode: str = "plain", extra=()):
    return build_engine(parse_args(BASE_ARGS + ["--mode", mode]
                                   + list(extra)))


def _prompts(n: int = 4, seed: int = 0):
    vocab = get_config("tiny-relu").vocab_size
    rng = np.random.RandomState(seed)
    return [[int(t) for t in rng.randint(0, vocab, 3 + 2 * i)]
            for i in range(n)]


def _workload():
    """(prompt, max_new, sampling) triples: seeded sampled, unseeded
    sampled, and greedy traffic sharing the batch."""
    ps = _prompts(4)
    return [
        (ps[0], 6, SamplingParams(temperature=0.9, top_k=40, seed=11)),
        (ps[1], 7, SamplingParams(temperature=1.2, top_p=0.9, seed=12)),
        (ps[2], 6, SamplingParams(temperature=0.8)),  # base_seed key
        (ps[3], 5, None),                             # greedy
    ]


def _serve(eng, work, order=None):
    """Submit ``work`` (optionally permuted) and drain; returns results
    keyed by WORK INDEX so callers compare across admission orders."""
    order = list(order if order is not None else range(len(work)))
    uids = {}
    for i in order:
        p, m, sp = work[i]
        uids[i] = eng.submit(p, m, sampling=sp)
    res = eng.run()
    return {i: res[u] for i, u in uids.items()}


def _toks(r):
    return [int(t) for t in r.tokens]


def test_seeded_sampling_is_restart_deterministic_under_shuffled_admission():
    """Regression (satellite bugfix): the per-request key must not depend
    on uid, slot, or admission order — a fresh engine admitting the same
    requests in a different order replays identical streams."""
    work = _workload()
    a = _serve(_engine(), work)
    b = _serve(_engine(), work, order=[2, 0, 3, 1])
    for i in range(len(work)):
        assert _toks(a[i]) == _toks(b[i]), f"request {i} stream changed"
        np.testing.assert_array_equal(
            np.asarray(a[i].logprobs, np.float32),
            np.asarray(b[i].logprobs, np.float32))


def test_spec_sampled_stream_equals_autoregressive():
    """Key-coupled acceptance: the spec engine's sampled output is the
    target's scheduled sample at every position, so ANY draft — here a
    1-layer randomly initialised one — yields the exact AR stream."""
    work = _workload()
    ar = _serve(_engine("plain"), work)
    sp = _serve(_engine("spec"), work)
    for i in range(len(work)):
        assert _toks(ar[i]) == _toks(sp[i]), f"request {i} diverged"
        np.testing.assert_array_equal(
            np.asarray(ar[i].logprobs, np.float32),
            np.asarray(sp[i].logprobs, np.float32))
    # drafts were really proposed (exactness must not come from gamma=0)
    assert all(sp[i].draft_proposed > 0 for i in range(len(work)))


def test_spec_with_target_as_draft_accepts_everything():
    """A perfect draft (the target itself) passes key-coupled acceptance
    at every position: accept_rate 1.0, stream unchanged."""
    cfg = get_config("tiny-relu").replace(compute_dtype="float32")
    fam = registry.get_family(cfg)
    params = fam.init_params(jax.random.PRNGKey(0), cfg)
    eng = ContinuousBatchingEngine(
        cfg, params, n_slots=2, block_size=8, max_blocks_per_seq=4,
        draft_cfg=cfg, draft_params=params, gamma=2)
    work = _workload()
    got = _serve(eng, work)
    ar = _serve(_engine("plain"), work)
    for i in range(len(work)):
        assert _toks(got[i]) == _toks(ar[i])
        assert got[i].accept_rate == 1.0, (
            f"request {i}: perfect draft accept_rate {got[i].accept_rate}")


def test_temperature_zero_with_seed_is_the_greedy_path():
    p = _prompts(1, seed=4)[0]
    eng = _engine()
    u_greedy = eng.submit(p, 6)
    u_seeded = eng.submit(p, 6, sampling=SamplingParams(temperature=0.0,
                                                        seed=123,
                                                        top_k=5, top_p=0.5))
    res = eng.run()
    assert _toks(res[u_greedy]) == _toks(res[u_seeded])
    np.testing.assert_array_equal(
        np.asarray(res[u_greedy].logprobs, np.float32),
        np.asarray(res[u_seeded].logprobs, np.float32))
    assert res[u_seeded].finish_reason == "length"


def _stop_truncate(tokens, stop):
    """First emitted position at which the stream ends with a stop
    sequence (tokens can repeat — scan, don't search)."""
    for n in range(1, len(tokens) + 1):
        out = tokens[:n]
        if any(len(s) <= n and tuple(out[-len(s):]) == tuple(s)
               for s in stop):
            return out
    return tokens


@pytest.mark.parametrize("mode", ["plain", "spec"])
def test_stop_sequences_truncate_the_stream(mode):
    p = _prompts(1, seed=7)[0]
    full = _toks(_serve(_engine(mode), [(p, 8, None)])[0])
    stop = ((full[2], full[3]),)
    want = _stop_truncate(full, stop)
    assert len(want) < len(full)  # the stop really binds

    eng = _engine(mode)
    u = eng.submit(p, 8, sampling=SamplingParams(stop=stop))
    r = eng.run()[u]
    assert _toks(r) == want
    assert r.finish_reason == "stop"
    # a length-1 stop on the prompt-seeded token halts immediately
    u2 = eng.submit(p, 8, sampling=SamplingParams(stop=((full[0],),)))
    r2 = eng.run()[u2]
    assert _toks(r2) == [full[0]] and r2.finish_reason == "stop"


def test_base_seed_keys_unseeded_requests():
    """Requests without a seed draw their key from the engine's base_seed:
    same base_seed -> identical replay, different base_seed -> a different
    (still deterministic) stream."""
    p = _prompts(1, seed=8)[0]
    work = [(p, 8, SamplingParams(temperature=1.0))]
    a = _toks(_serve(_engine(extra=["--base-seed", "0"]), work)[0])
    b = _toks(_serve(_engine(extra=["--base-seed", "0"]), work)[0])
    c = _toks(_serve(_engine(extra=["--base-seed", "99"]), work)[0])
    assert a == b
    assert a != c


def test_decode_never_retraces_on_sampling_config():
    """Mixed greedy + seeded + unseeded traffic with distinct temperature /
    top-k / top-p settings must reuse ONE decode executable — sampling
    params enter as traced arrays."""
    eng = _engine()
    _serve(eng, _workload())
    _serve(eng, [(_prompts(1, seed=6)[0], 4,
                  SamplingParams(temperature=2.0, top_k=3, top_p=0.4,
                                 seed=77))])
    assert eng._decode._cache_size() == 1
