"""Properties of the serving sampling head (serving/sampling.py).

Hypothesis-driven invariants over filter_logits / sample_head — support
sizes, renormalization, the greedy special case — plus the host-side key
schedule contract (fingerprints independent of uid/admission order).
Logits are generated with DISTINCT values so top-k/top-p supports are
unambiguous (ties legitimately grow the support; that path is covered
explicitly at the end).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serving import sampling as smp

V = 64


def _distinct_logits(seed: int, B: int = 3, scale: float = 0.37):
    """(B, V) f32 rows with all-distinct values."""
    rng = np.random.RandomState(seed)
    return jnp.asarray(
        rng.permutation(B * V).reshape(B, V).astype(np.float32) * scale)


def _keys(seed: int, B: int = 3):
    return jnp.stack([jnp.asarray(
        jax.random.fold_in(jax.random.PRNGKey(seed), i), jnp.uint32)
        for i in range(B)])


def _full(B, t=1.0, k=0, p=1.0):
    return (jnp.full((B,), t, jnp.float32), jnp.full((B,), k, jnp.int32),
            jnp.full((B,), p, jnp.float32))


# -- greedy branch -----------------------------------------------------------


@settings(max_examples=12)
@given(st.integers(0, 10_000))
def test_temperature_zero_is_exact_argmax(seed):
    logits = _distinct_logits(seed)
    t, k, p = _full(3, t=0.0, k=5, p=0.5)  # filters must not bind at T=0
    nxt, lp = smp.sample_head(logits, V, t, k, p, _keys(seed))
    want = jnp.argmax(logits, -1).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(nxt), np.asarray(want))
    want_lp = jnp.take_along_axis(jax.nn.log_softmax(logits, -1),
                                  want[:, None], -1)[:, 0]
    np.testing.assert_array_equal(np.asarray(lp), np.asarray(want_lp))


@settings(max_examples=8)
@given(st.integers(0, 10_000))
def test_temperature_to_zero_recovers_argmax(seed):
    """T→0+ through the SAMPLING branch: the scaled distribution collapses
    onto the argmax, so categorical sampling returns it."""
    logits = _distinct_logits(seed)
    t, k, p = _full(3, t=1e-5)
    nxt, _ = smp.sample_head(logits, V, t, k, p, _keys(seed))
    np.testing.assert_array_equal(np.asarray(nxt),
                                  np.asarray(jnp.argmax(logits, -1)))


# -- support invariants ------------------------------------------------------


@settings(max_examples=12)
@given(st.integers(1, V), st.integers(0, 10_000))
def test_top_k_support(k, seed):
    logits = _distinct_logits(seed)
    t, kk, p = _full(3, t=1.0, k=k)
    filt = np.asarray(smp.filter_logits(logits, kk, p, t))
    for b in range(3):
        kept = np.flatnonzero(np.isfinite(filt[b]))
        assert len(kept) == min(k, V)
        # the kept set IS the k largest logits
        want = np.argsort(np.asarray(logits[b]))[-k:]
        assert set(kept.tolist()) == set(want.tolist())


@settings(max_examples=12)
@given(st.floats(0.05, 1.0), st.integers(0, 10_000))
def test_top_p_support_is_minimal_nucleus(p, seed):
    logits = _distinct_logits(seed)
    t, k, pp = _full(3, t=1.0, p=p)
    filt = np.asarray(smp.filter_logits(logits, k, pp, t))
    probs = np.asarray(jax.nn.softmax(logits, -1))
    for b in range(3):
        kept = np.flatnonzero(np.isfinite(filt[b]))
        assert len(kept) >= 1
        mass = probs[b, kept].sum()
        # the nucleus reaches p...
        assert mass >= p - 1e-5
        # ...and is minimal: dropping its least-probable member dips below
        if len(kept) > 1:
            assert mass - probs[b, kept].min() < p + 1e-5
        # and it is a prefix of the probability ordering
        want = np.argsort(probs[b])[-len(kept):]
        assert set(kept.tolist()) == set(want.tolist())


@settings(max_examples=10)
@given(st.integers(1, V), st.floats(0.1, 1.0), st.integers(0, 10_000),
       st.floats(0.2, 3.0))
def test_filtered_rows_renormalize(k, p, seed, temp):
    """log_softmax over the filtered row sums to 1 on its support, and the
    reported logprob of a sampled token matches that renormalized
    distribution (NOT the unfiltered one)."""
    logits = _distinct_logits(seed)
    t, kk, pp = _full(3, t=temp, k=k, p=p)
    filt = smp.filter_logits(logits, kk, pp, t)
    lsm = np.asarray(jax.nn.log_softmax(filt, -1))
    for b in range(3):
        kept = np.isfinite(np.asarray(filt[b]))
        np.testing.assert_allclose(np.exp(lsm[b][kept]).sum(), 1.0,
                                   rtol=1e-5)
    nxt, lp = smp.sample_head(logits, V, t, kk, pp, _keys(seed))
    for b in range(3):
        assert np.isfinite(np.asarray(filt[b])[int(nxt[b])])  # in support
        np.testing.assert_allclose(float(lp[b]), lsm[b][int(nxt[b])],
                                   rtol=1e-5)


@settings(max_examples=10)
@given(st.integers(1, 8), st.integers(0, 10_000))
def test_sampled_token_respects_joint_support(k, seed):
    """top-k AND top-p together: the sample lands in the intersection."""
    logits = _distinct_logits(seed)
    t, kk, pp = _full(3, t=1.3, k=k, p=0.7)
    filt = np.asarray(smp.filter_logits(logits, kk, pp, t))
    nxt, _ = smp.sample_head(logits, V, t, kk, pp, _keys(seed))
    for b in range(3):
        assert np.isfinite(filt[b][int(nxt[b])])


def test_ties_keep_the_argmax_reachable():
    """Tied boundary values all stay in the support (the support can only
    grow on ties — never lose the argmax)."""
    row = np.zeros((1, V), np.float32)
    row[0, :4] = 5.0  # four-way tie at the top
    t, k, p = _full(1, t=1.0, k=2)
    filt = np.asarray(smp.filter_logits(jnp.asarray(row), k, p, t))
    kept = np.flatnonzero(np.isfinite(filt[0]))
    assert set(kept.tolist()) == {0, 1, 2, 3}


# -- determinism / key schedule ----------------------------------------------


def test_same_key_same_sample_different_key_varies():
    logits = _distinct_logits(1)
    t, k, p = _full(3, t=1.0)
    keys = _keys(11)
    a, _ = smp.sample_head(logits, V, t, k, p, keys)
    b, _ = smp.sample_head(logits, V, t, k, p, keys)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    draws = [np.asarray(smp.sample_head(logits, V, t, k, p, _keys(s))[0])
             for s in range(40)]
    assert len({tuple(d.tolist()) for d in draws}) > 1


def test_request_fingerprint_contract():
    """Fingerprint covers prompt + distribution params; excludes max_new
    and stop (stream-prefix stability), and python-hash salting never
    enters (blake2b)."""
    sp = smp.SamplingParams(temperature=0.8, top_k=10, top_p=0.9, seed=3)
    f = smp.request_fingerprint([1, 2, 3], sp)
    assert f == smp.request_fingerprint([1, 2, 3], sp)
    assert f != smp.request_fingerprint([1, 2, 4], sp)
    assert f != smp.request_fingerprint(
        [1, 2, 3], smp.SamplingParams(temperature=0.9, seed=3))
    # stop sequences and seed do NOT shift the fingerprint (seed enters
    # the key via PRNGKey(seed), not the hash)
    assert f == smp.request_fingerprint([1, 2, 3], smp.SamplingParams(
        temperature=0.8, top_k=10, top_p=0.9, seed=4, stop=((7,),)))
    k1 = smp.request_prng_key([1, 2, 3], sp)
    k2 = smp.request_prng_key([1, 2, 3], sp)
    np.testing.assert_array_equal(k1, k2)
    assert k1.shape == (2,) and k1.dtype == np.uint32
    # different seed -> different key, same fingerprint
    k3 = smp.request_prng_key([1, 2, 3], smp.SamplingParams(
        temperature=0.8, top_k=10, top_p=0.9, seed=4))
    assert not np.array_equal(k1, k3)


def test_sampling_params_validation():
    import pytest
    with pytest.raises(ValueError):
        smp.SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        smp.SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        smp.SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        smp.SamplingParams(top_p=1.5)
    assert smp.SamplingParams().is_greedy
    assert not smp.SamplingParams(temperature=0.5).is_greedy
