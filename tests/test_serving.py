"""Serving-layer tests: engine generation, γ-reuse semantics, aggregated
tracker, speculative decoding exactness + metrics accounting."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.sparsity import AggregatedTracker
from repro.models import registry
from repro.serving import ContinuousBatchingEngine
from repro.serving.engine import ServeEngine
from repro.serving.scheduler import RequestResult
from repro.serving.spec_decode import spec_metrics


def _setup(name="tiny-relu"):
    cfg = get_config(name)
    fam = registry.get_family(cfg)
    params = fam.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                          cfg.vocab_size)}
    return cfg, params, batch


def test_generate_shapes_and_determinism():
    cfg, params, batch = _setup()
    eng = ServeEngine(cfg, params, max_len=64)
    r1 = eng.generate(batch, max_new=10)
    r2 = eng.generate(batch, max_new=10)
    assert r1.tokens.shape == (2, 10)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)  # greedy = detrm.


def test_reuse_window_full_masks_noop():
    """γ-reuse with every-step refresh (window=1) must equal fresh decode."""
    cfg, params, batch = _setup()
    eng = ServeEngine(cfg, params, max_len=64)
    base = eng.generate(batch, max_new=8)
    reuse1 = eng.generate(batch, max_new=8, reuse_window=1)
    np.testing.assert_array_equal(base.tokens, reuse1.tokens)


def test_aggregated_tracker_invariants():
    tr = AggregatedTracker(2, 10)
    rng = np.random.RandomState(0)
    prev = 1.0
    for _ in range(20):
        tr.update(rng.rand(2, 10) < 0.3)
        # aggregated sparsity is non-increasing (paper Sec. 5.1)
        assert tr.curve[-1] <= prev + 1e-9
        prev = tr.curve[-1]
    assert 0.0 <= tr.aggregated_sparsity() <= 1.0
    assert tr.random_baseline() <= tr.per_token_sparsity[0] + 1e-9


def test_spec_decode_exact_and_fewer_target_calls():
    """Engine speculative mode vs engine autoregressive mode (f32 compute so
    the two executables agree bitwise — see test_continuous_batching for the
    bf16 same-executable exactness properties)."""
    tcfg = get_config("tiny-relu").replace(compute_dtype="float32")
    fam = registry.get_family(tcfg)
    tparams = fam.init_params(jax.random.PRNGKey(0), tcfg)
    dcfg = tcfg.replace(name="tiny-draft", n_layers=1)
    dparams = fam.init_params(jax.random.PRNGKey(9), dcfg)
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (8,), 0,
                                           tcfg.vocab_size), np.int32)

    ar = ContinuousBatchingEngine(tcfg, tparams, n_slots=1, block_size=8,
                                  max_blocks_per_seq=4)
    u = ar.submit(prompt, max_new=12)
    pure = ar.run()[u]

    eng = ContinuousBatchingEngine(tcfg, tparams, n_slots=1, block_size=8,
                                   max_blocks_per_seq=4, draft_cfg=dcfg,
                                   draft_params=dparams, gamma=3)
    u = eng.submit(prompt, max_new=12)
    res = eng.run()[u]

    np.testing.assert_array_equal(res.tokens, pure.tokens)
    # verification is batched: never more target calls than tokens, and the
    # whole window goes through ONE forward per engine step
    assert res.target_calls <= 12
    assert res.target_calls == eng.t
    m = spec_metrics(res, gamma=3, c=0.1, s_agg=eng.s_agg_window())
    assert m.thm1_speedup >= 1.0
    assert m.target_call_reduction >= 1.0


def test_spec_metrics_alpha_is_per_proposal_fraction():
    """α must be accepted/proposed — not the tokens-per-target-call ratio,
    which counts every window's free correction token as 'accepted'."""
    res = RequestResult(uid=1, tokens=np.zeros(10, np.int32),
                        logprobs=np.zeros(10, np.float32), prompt_len=4,
                        admitted_step=0, finished_step=5, draft_proposed=12,
                        draft_accepted=9, target_calls=4)
    assert res.accept_rate == 9 / 12
    m = spec_metrics(res, gamma=3, c=0.1, s_agg=0.4)
    assert m.accept_rate == 9 / 12
    assert m.n_target_calls == 5  # + prefill
    assert m.n_draft_calls == 12
    assert m.target_call_reduction == 2.0
    # all-rejected requests must report alpha 0, not a prefill-skewed ratio
    res0 = RequestResult(uid=2, tokens=np.zeros(6, np.int32),
                         logprobs=np.zeros(6, np.float32), prompt_len=4,
                         admitted_step=0, finished_step=6, draft_proposed=15,
                         draft_accepted=0, target_calls=5)
    assert res0.accept_rate == 0.0
    assert spec_metrics(res0, gamma=3, c=0.1, s_agg=0.0).accept_rate == 0.0
    # alpha == 1 (target-as-draft) takes the geometric-series limit, it must
    # not divide by zero: expected tokens per window = gamma + 1
    res1 = RequestResult(uid=3, tokens=np.zeros(12, np.int32),
                         logprobs=np.zeros(12, np.float32), prompt_len=4,
                         admitted_step=0, finished_step=3, draft_proposed=9,
                         draft_accepted=9, target_calls=3)
    m1 = spec_metrics(res1, gamma=3, c=0.1, s_agg=0.5)
    assert m1.accept_rate == 1.0
    np.testing.assert_allclose(m1.thm2_speedup, 4.0 / (0.3 + 0.5))


def test_engine_scores_perplexity():
    cfg, params, batch = _setup()
    eng = ServeEngine(cfg, params, max_len=64)
    nll = eng.score(batch)
    assert np.isfinite(nll) and nll > 0
