"""Serving-layer tests: engine generation, γ-reuse semantics, aggregated
tracker, speculative decoding exactness."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.sparsity import AggregatedTracker
from repro.models import registry
from repro.serving.engine import ServeEngine
from repro.serving.spec_decode import speculative_generate


def _setup(name="tiny-relu"):
    cfg = get_config(name)
    fam = registry.get_family(cfg)
    params = fam.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                          cfg.vocab_size)}
    return cfg, params, batch


def test_generate_shapes_and_determinism():
    cfg, params, batch = _setup()
    eng = ServeEngine(cfg, params, max_len=64)
    r1 = eng.generate(batch, max_new=10)
    r2 = eng.generate(batch, max_new=10)
    assert r1.tokens.shape == (2, 10)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)  # greedy = detrm.


def test_reuse_window_full_masks_noop():
    """γ-reuse with every-step refresh (window=1) must equal fresh decode."""
    cfg, params, batch = _setup()
    eng = ServeEngine(cfg, params, max_len=64)
    base = eng.generate(batch, max_new=8)
    reuse1 = eng.generate(batch, max_new=8, reuse_window=1)
    np.testing.assert_array_equal(base.tokens, reuse1.tokens)


def test_aggregated_tracker_invariants():
    tr = AggregatedTracker(2, 10)
    rng = np.random.RandomState(0)
    prev = 1.0
    for _ in range(20):
        tr.update(rng.rand(2, 10) < 0.3)
        # aggregated sparsity is non-increasing (paper Sec. 5.1)
        assert tr.curve[-1] <= prev + 1e-9
        prev = tr.curve[-1]
    assert 0.0 <= tr.aggregated_sparsity() <= 1.0
    assert tr.random_baseline() <= tr.per_token_sparsity[0] + 1e-9


def test_spec_decode_exact_and_fewer_target_calls():
    tcfg, tparams, batch = _setup("tiny-relu")
    dcfg = get_config("tiny").replace(n_layers=1)
    dparams = registry.get_family(dcfg).init_params(jax.random.PRNGKey(9), dcfg)
    prompt = batch["tokens"][:1]
    res = speculative_generate(tcfg, tparams, dcfg, dparams, prompt,
                               max_new=12, gamma=3, sparse=False)
    eng = ServeEngine(tcfg, tparams, max_len=64)
    pure = eng.generate({"tokens": prompt}, max_new=12)
    np.testing.assert_array_equal(res.tokens, pure.tokens[0])
    # verification is batched: strictly fewer target calls than tokens
    # whenever anything was accepted; never more than tokens
    assert res.n_target_calls <= 12
    assert res.thm1_speedup >= 1.0


def test_engine_scores_perplexity():
    cfg, params, batch = _setup()
    eng = ServeEngine(cfg, params, max_len=64)
    nll = eng.score(batch)
    assert np.isfinite(nll) and nll > 0
