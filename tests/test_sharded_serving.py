"""Tensor-parallel sharded serving (ISSUE 5 tentpole): the continuous-
batching engine on a (data, model) mesh must produce f32 greedy streams
BYTE-IDENTICAL to the single-device engine in all three serving modes
(plain γ-window, speculative, predictor), with per-device FFN weight I/O
reported as measured_density x dense_bytes / TP.

Engine runs execute in subprocesses with a forced-8-host-device CPU mesh
(the test_distributed.py pattern) so the main pytest process keeps its
single-device view. These tests do NOT need jax >= 0.6: make_host_mesh is
version-capable (implicit Auto axis types on the 0.4.x pin)."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from subproc import run_forced_devices as _run


# indented like the per-test sources so textwrap.dedent normalizes the
# concatenation (an unindented prelude would swallow the indented test body
# into its last function definition)
_COMMON = """
    import jax, numpy as np
    from repro.configs import get_config
    from repro.models import registry
    from repro.launch.mesh import make_host_mesh
    from repro.serving import ContinuousBatchingEngine

    def setup(name):
        cfg = get_config(name).replace(compute_dtype="float32")
        fam = registry.get_family(cfg)
        params = fam.init_params(jax.random.PRNGKey(0), cfg)
        prompts = [np.random.RandomState(s).randint(
                       0, cfg.vocab_size, ln).astype(np.int32)
                   for s, ln in ((1, 9), (2, 5), (3, 13))]
        return cfg, fam, params, prompts

    def serve(cfg, params, prompts, max_new=8, **kw):
        eng = ContinuousBatchingEngine(cfg, params, n_slots=2, block_size=8,
                                       max_blocks_per_seq=6, **kw)
        uids = [eng.submit(p, max_new) for p in prompts]
        res = eng.run()
        return ([res[u].tokens.tolist() for u in uids], eng,
                [res[u] for u in uids])
"""


def test_plain_mode_sharded_byte_identical():
    """Plain γ-window serving on a (1, 8) mesh == single-device, for
    tiny-relu + tiny-opt; chunked prefill composes; per-device weight
    bytes report the 1/TP split."""
    out = _run(_COMMON + """
    mesh = make_host_mesh(1, 8)
    assert dict(mesh.shape) == {"data": 1, "model": 8}, mesh.shape
    for name in ("tiny-relu", "tiny-opt"):
        cfg, fam, params, prompts = setup(name)
        base, e0, _ = serve(cfg, params, prompts)
        got, e1, _ = serve(cfg, params, prompts, mesh=mesh)
        assert got == base, (name, base, got)
        assert e0.tp == 1 and e1.tp == 8
        # per-device FFN weight I/O = total / TP at equal measured density
        b0 = e0.weight_io_bytes_per_step()
        b1 = e1.weight_io_bytes_per_step()
        assert abs(b1 - b0 / 8) < 1e-6, (name, b0, b1)
        assert abs(e1.weight_io_bytes_per_step(per_device=False) - b0) < 1e-6
        # chunked prefill lowers through the same sharded window step
        gotc, _, _ = serve(cfg, params, prompts, mesh=mesh, prefill_chunk=4)
        assert gotc == base, (name, "chunked", base, gotc)
        # sharded params really are distributed over the 8 devices
        wu = e1.params["layers"]["ffn"]["wu"]
        assert len(wu.sharding.device_set) == 8, wu.sharding
        print(name, "OK")
    """)
    assert out.count("OK") == 2


def test_speculative_mode_sharded_byte_identical():
    """Speculative serving (γ=4, draft + verify both TP-sharded) on a
    (1, 8) mesh == single-device, for tiny-relu + tiny-opt."""
    out = _run(_COMMON + """
    mesh = make_host_mesh(1, 8)
    for name in ("tiny-relu", "tiny-opt"):
        cfg, fam, params, prompts = setup(name)
        dcfg = cfg.replace(name=cfg.name + "-draft", n_layers=1)
        dparams = fam.init_params(jax.random.PRNGKey(2), dcfg)
        kw = dict(draft_cfg=dcfg, draft_params=dparams, gamma=4)
        base, e0, r0 = serve(cfg, params, prompts, **kw)
        got, e1, r1 = serve(cfg, params, prompts, mesh=mesh, **kw)
        assert got == base, (name, base, got)
        # acceptance bookkeeping identical too (same windows were verified)
        assert [r.draft_accepted for r in r1] == \
               [r.draft_accepted for r in r0]
        assert abs(e1.s_agg_window() - e0.s_agg_window()) < 1e-9
        print(name, "OK")
    """)
    assert out.count("OK") == 2


def test_predictor_mode_sharded_byte_identical():
    """Predictor serving (model-axis-local packed tile lists) on a (1, 8)
    mesh == single-device, for tiny-relu + tiny-opt: streams, weight-I/O
    savings and in-graph recall telemetry all match."""
    out = _run(_COMMON + """
    from repro.predictor import calibrate_from_config
    mesh = make_host_mesh(1, 8)
    for name in ("tiny-relu", "tiny-opt"):
        cfg, fam, params, prompts = setup(name)
        cfg = cfg.replace_sparsity(predictor="sign", predictor_recall=1.0)
        calib = {"tokens": jax.random.randint(jax.random.PRNGKey(7), (4, 32),
                                              0, cfg.vocab_size)}
        pred = calibrate_from_config(params, cfg, calib, tile=1)
        base, e0, r0 = serve(cfg, params, prompts, predictor=pred)
        got, e1, r1 = serve(cfg, params, prompts, predictor=pred, mesh=mesh)
        assert got == base, (name, base, got)
        assert abs(e1.weight_io_saved() - e0.weight_io_saved()) < 1e-9
        # in-graph recall telemetry identical (bf16 probe may miss a unit —
        # tiny-opt records one — but sharding must not change what it sees)
        assert e1.predictor_recall() == e0.predictor_recall()
        assert [r.pred_misses for r in r1] == [r.pred_misses for r in r0]
        # engine must not mutate the shared Predictor (e0 traced before e1)
        assert pred.params is e0.predictor.params
        b1 = e1.weight_io_bytes_per_step()
        assert abs(b1 - e0.weight_io_bytes_per_step() / 8) < 1e-6
        print(name, "OK")
    """)
    assert out.count("OK") == 2


def test_moe_sharded_expert_dim_byte_identical():
    """MoE serving on a (2, 4) mesh with the EXPERT dim sharded over
    "model" (sharding/rules.py serve map priority axis): f32 greedy streams
    byte-identical to single-device — exact because top_k=2 combine sums
    are two-term (f32 addition is commutative, and the cross-device
    partial-sum reduction only ever adds exact zeros) — and per-device
    weight I/O reports the expert-axis 1/TP split."""
    out = _run(_COMMON + """
    from jax.sharding import PartitionSpec as P
    from repro.sharding import rules
    mesh = make_host_mesh(2, 4)
    cfg, fam, params, prompts = setup("tiny-moe")
    # the serve map puts "model" on the expert dim (priority pre-pass),
    # not on the trailing ffn dim
    spec = rules.param_pspec("layers/moe/wu", (2, 4, 64, 256), mesh, "serve")
    assert spec[1] == "model" and spec[3] is None, spec
    assert rules.param_pspec("layers/moe/wd",
                             (2, 4, 256, 64), mesh, "serve")[1] == "model"
    base, e0, _ = serve(cfg, params, prompts)
    got, e1, _ = serve(cfg, params, prompts, mesh=mesh)
    assert got == base, (base, got)
    assert e0.tp == 1 and e1.tp == 4 and e1.ffn_tp == 4
    b0, b1 = e0.weight_io_bytes_per_step(), e1.weight_io_bytes_per_step()
    assert abs(b1 - b0 / 4) < 1e-6, (b0, b1)
    # expert weights really are distributed
    wu = e1.params["layers"]["moe"]["wu"]
    assert len(wu.sharding.device_set) == 8, wu.sharding
    # chunked prefill composes sharded too
    gotc, _, _ = serve(cfg, params, prompts, mesh=mesh, prefill_chunk=4)
    assert gotc == base, ("chunked", base, gotc)
    print("OK")
    """)
    assert "OK" in out


def test_data_axis_sharded_pool():
    """A (2, 4) mesh shards the paged block pool over "data" as well —
    streams still byte-identical (block-table gathers cross shards)."""
    out = _run(_COMMON + """
    mesh = make_host_mesh(2, 4)
    cfg, fam, params, prompts = setup("tiny-relu")
    # n_blocks=14: the engine default (1 + n_slots*max_blocks_per_seq = 13)
    # is odd, so the divisibility guard would silently replicate the block
    # axis and this test would never exercise the cross-shard gathers
    base, _, _ = serve(cfg, params, prompts, n_blocks=14)
    got, eng, _ = serve(cfg, params, prompts, n_blocks=14, mesh=mesh)
    assert got == base, (base, got)
    assert eng.tp == 4
    # the pool REALLY is data-sharded: each shard holds half the blocks
    # (after run() the jit output carries a GSPMDSharding — check shard
    # shapes, not a PartitionSpec)
    shard_blocks = eng.pages["k"].addressable_shards[0].data.shape[1]
    assert shard_blocks == 14 // 2, shard_blocks
    print("OK")
    """)
    assert "OK" in out


# ---------------------------------------------------------------------------
# host-side pieces (no multi-device subprocess needed)


def test_make_host_mesh_degenerate_warns_and_strict_raises():
    from repro.launch.mesh import make_host_mesh
    n = len(jax.devices())
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        mesh = make_host_mesh(1, n + 1)  # unsatisfiable -> clamp + warn
    assert dict(mesh.shape)["model"] <= n
    assert any("degenerating" in str(x.message) for x in w), \
        "silent degenerate clamp"
    with pytest.raises(ValueError, match="degenerating"):
        make_host_mesh(1, n + 1, strict=True)
    # satisfiable shapes stay silent
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        make_host_mesh(1, 1)
    assert not w


def test_engine_rejects_mesh_without_serve_axes():
    from repro.configs import get_config
    from repro.models import registry
    from repro.serving import ContinuousBatchingEngine
    cfg = get_config("tiny-relu")
    params = registry.get_family(cfg).init_params(jax.random.PRNGKey(0), cfg)
    mesh = jax.make_mesh((1,), ("rows",))
    with pytest.raises(ValueError, match="data.*model|model.*data"):
        ContinuousBatchingEngine(cfg, params, mesh=mesh)


# ---------------------------------------------------------------------------
# model-axis-local tile packing (predictors.pack_tile_indices n_groups)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 6), st.integers(0, 2 ** 20 - 1), st.integers(1, 20))
def test_grouped_packing_matches_global_at_full_capacity(g_pow, bits, seed):
    """At full capacity (k == n_tiles) the grouped packing selects the same
    tiles in the same (ascending) order as the global packing — the
    invariant that keeps sharded streams byte-identical."""
    from repro.predictor.predictors import pack_tile_indices
    n_groups = 2 ** (g_pow % 4)  # 1, 2, 4, 8
    nT = 16
    rng = np.random.RandomState(seed)
    mask = jnp.asarray((rng.rand(3, nT) < 0.4) | (np.arange(nT) == bits % nT))
    idx0, nv0 = pack_tile_indices(mask, nT)
    idx1, nv1 = pack_tile_indices(mask, nT, n_groups=n_groups)
    np.testing.assert_array_equal(np.asarray(nv0), np.asarray(nv1))
    for t in range(mask.shape[0]):
        n = int(nv0[t])
        np.testing.assert_array_equal(np.asarray(idx0[t, :n]),
                                      np.asarray(idx1[t, :n]))


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 16), st.integers(1, 4), st.integers(0, 10 ** 6))
def test_grouped_packing_truncation_in_range_and_balanced(k, g_pow, seed):
    """Under truncation every index stays in range, valid entries come
    first (kernel contract), and each group's selection is drawn from its
    own shard-local slice."""
    from repro.predictor.predictors import pack_tile_indices
    n_groups = 2 ** (g_pow % 3)  # 1, 2, 4
    nT = 16
    rng = np.random.RandomState(seed)
    mask = jnp.asarray(rng.rand(4, nT) < 0.7)
    idx, nv = pack_tile_indices(mask, k, n_groups=n_groups)
    idx, nv = np.asarray(idx), np.asarray(nv)
    assert ((idx >= 0) & (idx < nT)).all()
    k_g = min(nT // n_groups, -(-min(k, nT) // n_groups))
    assert (nv <= n_groups * k_g).all()
    gsz = nT // n_groups
    for t in range(mask.shape[0]):
        sel = idx[t, : nv[t]]
        assert (np.diff(sel) > 0).all(), "valid entries not ascending"
        # every selected tile was truly active, per its own group's slice
        assert np.asarray(mask)[t, sel].all()
        # per-group capacity respected
        for g in range(n_groups):
            assert ((sel // gsz) == g).sum() <= k_g
