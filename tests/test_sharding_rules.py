"""Property tests for the logical-axis sharding rules (sharding/rules.py):
the divisibility guard must never emit a PartitionSpec axis that does not
divide its dimension, and mesh-divisible PADDED dimensions (vocab, d_ff,
d_model — padded to multiples of the production TP degree by construction)
must actually be sharded over "model" in serve mode, never silently
replicated.

Uses a lightweight stand-in mesh (only ``.shape`` and ``.axis_names`` are
consulted by the rules) so arbitrary mesh sizes are testable on the
single-CPU container without forcing device counts."""
from types import SimpleNamespace

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models.common import padded_vocab
from repro.sharding import rules

# every param-rule path pattern, exercised with representative shapes built
# from (d_model, d_ff, heads, kv, head_dim, vocab_p) below
_PATHS = (
    ("layers/attn/wq", lambda d, f, h, kv, hd, v: (d, h, hd)),
    ("layers/attn/wk", lambda d, f, h, kv, hd, v: (d, kv, hd)),
    ("layers/attn/wv", lambda d, f, h, kv, hd, v: (d, kv, hd)),
    ("layers/attn/wo", lambda d, f, h, kv, hd, v: (h, hd, d)),
    ("layers/ffn/wu", lambda d, f, h, kv, hd, v: (d, f)),
    ("layers/ffn/wg", lambda d, f, h, kv, hd, v: (d, f)),
    ("layers/ffn/wd", lambda d, f, h, kv, hd, v: (f, d)),
    ("layers/moe/wu", lambda d, f, h, kv, hd, v: (8, d, f)),
    ("layers/moe/wd", lambda d, f, h, kv, hd, v: (8, f, d)),
    ("embed", lambda d, f, h, kv, hd, v: (v, d)),
    ("unembed", lambda d, f, h, kv, hd, v: (v, d)),
    ("pos_embed", lambda d, f, h, kv, hd, v: (64, d)),
    ("layers/ln1/scale", lambda d, f, h, kv, hd, v: (d,)),
    ("layers/ssm/in_proj", lambda d, f, h, kv, hd, v: (d, 2 * f)),
    ("layers/ssm/out_proj", lambda d, f, h, kv, hd, v: (f, d)),
)


def _mesh(data: int, model: int, pod: int = 0):
    if pod:
        return SimpleNamespace(shape={"pod": pod, "data": data,
                                      "model": model},
                               axis_names=("pod", "data", "model"))
    return SimpleNamespace(shape={"data": data, "model": model},
                           axis_names=("data", "model"))


def _axis_size(mesh, ax) -> int:
    size = 1
    for a in (ax if isinstance(ax, tuple) else (ax,)):
        size *= mesh.shape[a]
    return size


@settings(max_examples=60, deadline=None)
@given(st.integers(0, len(_PATHS) - 1), st.integers(0, 4), st.integers(0, 4),
       st.sampled_from(["train", "serve"]), st.integers(1, 64),
       st.integers(1, 12), st.booleans())
def test_param_pspec_divisibility(pi, dpow, mpow, mode, dm_mult, kv,
                                  multi_pod):
    """Every axis a derived PartitionSpec assigns divides its dimension, and
    no mesh axis is used twice."""
    mesh = _mesh(2 ** dpow, 2 ** mpow, pod=2 if multi_pod else 0)
    d, f = 8 * dm_mult, 16 * dm_mult
    h, hd = 16, 8
    path, shape_fn = _PATHS[pi]
    shape = shape_fn(d, f, h, kv, hd, padded_vocab(1000))
    spec = rules.param_pspec(path, shape, mesh, mode)
    assert len(spec) == len(shape)
    used = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            continue
        size = _axis_size(mesh, ax)
        assert dim % size == 0 and dim >= size, (path, shape, spec, mesh.shape)
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            assert a not in used, f"mesh axis {a} assigned twice: {spec}"
            used.append(a)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 4), st.integers(1, 32), st.integers(1, 40))
def test_padded_dims_never_replicated_on_model(mpow, ff_mult, vk):
    """Serve mode: mesh-divisible padded dims — the (2048-multiple) vocab,
    d_ff and the FFN weights' d_ff axis — take the "model" axis; the guard
    may only *replicate* where divisibility genuinely fails."""
    model = 2 ** mpow  # 1..16: every production TP degree
    mesh = _mesh(1, model)
    vp = padded_vocab(vk * 777)       # 2048-multiple >= any model size
    f = 128 * ff_mult * model         # d_ff padded mesh-divisible
    d = 64 * model
    assert rules.param_pspec("embed", (vp, d), mesh, "serve")[0] == "model"
    assert rules.param_pspec("unembed", (vp, d), mesh, "serve")[0] == "model"
    wu = rules.param_pspec("layers/ffn/wu", (d, f), mesh, "serve")
    assert wu[1] == "model", (wu, f, model)
    wd = rules.param_pspec("layers/ffn/wd", (f, d), mesh, "serve")
    assert wd[0] == "model", (wd, f, model)
    # γ-mask buffers and the paged pool follow the same guard
    assert rules.serve_masks_pspec((2, 4, f), mesh)[-1] == "model"
    pool = rules.paged_cache_pspec((2, 17, 16, 8, 8), mesh)
    if 16 % model == 0:
        assert pool[2] == "model"


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 64), st.integers(0, 3), st.integers(0, 3),
       st.integers(1, 6))
def test_batch_and_cache_pspec_divisibility(b, dpow, mpow, s_mult):
    mesh = _mesh(2 ** dpow, 2 ** mpow)
    bp = rules.batch_pspec(b, mesh, extra_dims=1)
    if bp[0] is not None:
        assert b % _axis_size(mesh, bp[0]) == 0
    shape = (2, b, 16, 128 * s_mult, 8)
    cp = rules.cache_pspec(shape, mesh)
    for dim, ax in zip(shape, cp):
        if ax is not None:
            assert dim % _axis_size(mesh, ax) == 0
    pp = rules.paged_cache_pspec(shape, mesh)
    for dim, ax in zip(shape, pp):
        if ax is not None:
            assert dim % _axis_size(mesh, ax) == 0
