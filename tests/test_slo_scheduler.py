"""SLO-aware scheduling (ISSUE 10): priority-ordered admission with aging,
preempt-and-requeue under KV-pressure, resume via chunked prefill — plus
the redesigned EngineConfig/submit surface.

Scheduler-level tests drive the policy directly (obs=None, no jax);
engine-level tests pin the house exactness invariant: an f32 greedy stream
FORCED through a preempt/resume cycle is byte-identical to the unpreempted
stream, in all three serving modes."""
import warnings

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import registry
from repro.serving import ContinuousBatchingEngine, EngineConfig
from repro.serving import config as config_mod
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import Request, Scheduler


def _setup(name="tiny-relu", dtype="float32"):
    cfg = get_config(name)
    if dtype is not None:
        cfg = cfg.replace(compute_dtype=dtype)
    fam = registry.get_family(cfg)
    params = fam.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, lengths, seed=1):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, s).astype(np.int32)
            for s in lengths]


def _engine(cfg, params, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("block_size", 8)
    kw.setdefault("max_blocks_per_seq", 6)
    return ContinuousBatchingEngine(cfg, params, config=EngineConfig(**kw))


def _spec_kw(cfg, fam, seed=9):
    dcfg = cfg.replace(name=f"{cfg.name}-draft", n_layers=1)
    return dict(draft_cfg=dcfg,
                draft_params=fam.init_params(jax.random.PRNGKey(seed), dcfg),
                gamma=3)


def _predictor_kw(cfg, params):
    from repro.predictor import calibrate
    calib = {"tokens": jax.random.randint(jax.random.PRNGKey(7), (4, 24),
                                          0, cfg.vocab_size)}
    return dict(predictor=calibrate(params, cfg, calib, kind="sign",
                                    probe_dtype="float32",
                                    target_recall=1.0, tile=1))


def _mode_kw(mode, cfg, params):
    if mode == "spec":
        return _spec_kw(cfg, registry.get_family(cfg))
    if mode == "predictor":
        return _predictor_kw(cfg, params)
    return {}


def _req(uid, prompt_len=4, max_new=4, priority=0, slo_ms=None, seed=0):
    rng = np.random.RandomState(seed + uid)
    return Request(uid=uid,
                   tokens=rng.randint(0, 97, prompt_len).astype(np.int32),
                   max_new=max_new, priority=priority, slo_ms=slo_ms)


def _start_decode(sched, slot, token=7, step=0):
    """Whole-prompt-prefill shortcut: seed() completes prefill and emits
    the first token, exactly like the engine's prefill_chunk=0 path."""
    sched.seed(slot, token, 0.0, step=step)


def _decode_steps(sched, n):
    for _ in range(n):
        sched.record(np.full(sched.n_slots, 7, np.int32),
                     np.zeros(sched.n_slots, np.float32))


# ---------------------------------------------------------------------------
# admission order: priorities, FIFO within class, aging


def test_priority_orders_admission():
    sched = Scheduler(n_slots=1, n_blocks=8, block_size=4,
                      max_blocks_per_seq=4)
    for uid, prio in ((1, 0), (2, 2), (3, 1)):
        sched.submit(_req(uid, priority=prio))
    admitted = sched.admit(step=0)
    assert [s.request.uid for _, s in admitted] == [2]
    assert sched.queue.uids() == [3, 1]  # remaining order: prio 1, then 0


def test_fifo_within_priority_class():
    sched = Scheduler(n_slots=2, n_blocks=8, block_size=4,
                      max_blocks_per_seq=4)
    for uid in (1, 2, 3):
        sched.submit(_req(uid, priority=1))
    admitted = sched.admit(step=0)
    assert [s.request.uid for _, s in admitted] == [1, 2]


def test_aging_promotes_waiting_low_priority():
    """A low-priority entry that has waited gains one class per
    aging_steps, eventually outranking a fresh high-priority arrival."""
    sched = Scheduler(n_slots=1, n_blocks=8, block_size=4,
                      max_blocks_per_seq=4, aging_steps=4)
    sched.submit(_req(1, priority=0), step=0)
    sched.submit(_req(2, priority=1), step=16)
    # at step 16: uid 1 effective = 0 + 16//4 = 4 > uid 2's 1 + 0
    admitted = sched.admit(step=16)
    assert [s.request.uid for _, s in admitted] == [1]


def test_aging_disabled_means_raw_priority():
    sched = Scheduler(n_slots=1, n_blocks=8, block_size=4,
                      max_blocks_per_seq=4, aging_steps=0)
    sched.submit(_req(1, priority=0), step=0)
    sched.submit(_req(2, priority=1), step=10_000)
    admitted = sched.admit(step=10_000)
    assert [s.request.uid for _, s in admitted] == [2]


# ---------------------------------------------------------------------------
# bugfix: an unfit head is SKIPPED, bounded by the aging barrier


def test_unfit_head_is_skipped_not_a_hard_stop():
    """Historically admit() broke at the first entry that didn't fit; now
    later entries admit around it while it has not yet aged."""
    sched = Scheduler(n_slots=2, n_blocks=4, block_size=4,
                      max_blocks_per_seq=3, aging_steps=32)
    sched.submit(_req(1, prompt_len=2, max_new=2))  # 1 block
    assert len(sched.admit(step=0)) == 1            # 2 of 3 blocks left
    sched.submit(_req(2, prompt_len=8, max_new=4), step=0)  # 3 blocks: unfit
    sched.submit(_req(3, prompt_len=2, max_new=2), step=0)  # 1 block: fits
    admitted = sched.admit(step=0)
    assert [s.request.uid for _, s in admitted] == [3]
    assert sched.queue.uids() == [2]  # still queued, not dropped/rejected


def test_aged_unfit_entry_becomes_admission_barrier():
    """Once the skipped entry has waited aging_steps it becomes a barrier:
    nothing admits past it, restoring the head-of-line guarantee."""
    sched = Scheduler(n_slots=2, n_blocks=5, block_size=4,
                      max_blocks_per_seq=3, aging_steps=8)
    sched.submit(_req(1, prompt_len=4, max_new=4))  # 2 of 4 blocks
    assert len(sched.admit(step=0)) == 1
    sched.submit(_req(2, prompt_len=8, max_new=4), step=0)   # unfit, aging
    sched.submit(_req(3, prompt_len=2, max_new=2), step=32)  # would fit
    assert sched.admit(step=32) == []  # uid 2 aged into a barrier
    # the moment uid 2 fits, it admits first and the barrier lifts
    sched.slots[0].finish = "stop"
    sched.retire_finished(step=33)
    admitted = sched.admit(step=33)
    assert [s.request.uid for _, s in admitted] == [2, 3]


# ---------------------------------------------------------------------------
# preemption: victim selection, requeue, resume, ledger


def _full_house(prefix_cache=False, preemption=True):
    """Two decoding slots (prio 0 and 1) holding the whole pool."""
    sched = Scheduler(n_slots=2, n_blocks=5, block_size=4,
                      max_blocks_per_seq=4, prefix_cache=prefix_cache,
                      preemption=preemption)
    sched.submit(_req(1, prompt_len=4, max_new=4, priority=0))
    sched.submit(_req(2, prompt_len=4, max_new=4, priority=1))
    for _, slot in sched.admit(step=0):
        _start_decode(sched, slot)
    assert sched.allocator.available == 0
    return sched


def test_preemption_evicts_strictly_lower_priority():
    sched = _full_house()
    sched.submit(_req(3, prompt_len=4, max_new=4, priority=2), step=1)
    admitted = sched.admit(step=1)
    assert [s.request.uid for _, s in admitted] == [3]
    assert sched.preemption_count == 1
    live = {s.request.uid for s in sched.slots if s is not None}
    assert live == {2, 3}           # prio-0 uid 1 was the victim
    assert sched.queue.uids() == [1]
    entry = sched.queue.ordered()[0]
    assert entry.resume is not None
    assert entry.resume.preemptions == 1
    # requeued with prompt + generated prefix frozen for recompute
    np.testing.assert_array_equal(
        entry.resume.resume_tokens,
        np.concatenate([entry.req.tokens,
                        np.asarray(entry.resume.out, np.int32)]))


def test_no_preemption_within_the_same_class():
    """Equal priority never evicts: the candidate waits for retirement."""
    sched = _full_house()
    sched.submit(_req(3, prompt_len=4, max_new=4, priority=0), step=1)
    assert sched.admit(step=1) == []
    assert sched.preemption_count == 0
    assert sched.queue.uids() == [3]


def test_preemption_flag_off_never_evicts():
    sched = _full_house(preemption=False)
    sched.submit(_req(3, prompt_len=4, max_new=4, priority=5), step=1)
    assert sched.admit(step=1) == []
    assert sched.preemption_count == 0


def test_victim_is_least_progress_within_lowest_class():
    sched = Scheduler(n_slots=2, n_blocks=5, block_size=8,
                      max_blocks_per_seq=4)
    sched.submit(_req(1, prompt_len=4, max_new=8, priority=0))
    for _, slot in sched.admit(step=0):
        _start_decode(sched, slot)
    _decode_steps(sched, 3)  # uid 1 is 4 tokens in
    sched.submit(_req(2, prompt_len=4, max_new=8, priority=0))
    for _, slot in sched.admit(step=3):
        _start_decode(sched, slot)  # uid 2 just seeded: 1 token
    sched.submit(_req(3, prompt_len=4, max_new=4, priority=1), step=4)
    sched.admit(step=4)
    live = {s.request.uid for s in sched.slots if s is not None}
    assert live == {1, 3}  # uid 2 (least progress) was evicted


def test_preempt_frees_blocks_and_ledger_balances():
    sched = _full_house()
    held = sum(len(s.blocks) for s in sched.slots if s is not None)
    sched.preempt(0, step=1)
    assert sched.allocator.available == 2  # victim's blocks back in the pool
    now_held = sum(len(s.blocks) for s in sched.slots if s is not None)
    assert held - now_held == 2
    assert sched.allocator.available + sched.allocator.allocated == (
        sched.allocator.n_blocks - 1)


def test_resume_reuses_slot_and_maps_parked_blocks():
    """Re-admission of a preempted request reuses the SAME _Slot (output,
    γ phase, sampling position intact) and maps its parked full blocks
    back from the trie — only the cold tail is left to prefill."""
    sched = Scheduler(n_slots=1, n_blocks=6, block_size=4,
                      max_blocks_per_seq=4, prefix_cache=True)
    sched.submit(_req(1, prompt_len=8, max_new=4, priority=0))
    ((_, slot),) = sched.admit(step=0)
    _start_decode(sched, slot)
    _decode_steps(sched, 2)  # out = 3 tokens, written K/V through pos 10
    out_before = list(slot.out)
    sched.preempt(0, step=3)
    ((_, resumed),) = sched.admit(step=4)
    assert resumed is slot  # progress carried by the very same slot
    assert resumed.out == out_before
    assert resumed.preemptions == 1
    # prompt(8) + out(3) = 11 to cover; 2 full written blocks were parked
    assert resumed.prefill_len == 11
    assert resumed.cached_tokens == 8
    assert resumed.prefilling and resumed.prefilled == 8
    # finishing the cold tail re-derives the next token and continues
    sched.seed(resumed, 9, 0.0, step=5)
    assert resumed.out == out_before + [9]
    assert resumed.age == len(resumed.out) - 1  # γ phase pinned


def test_cancel_preempted_request_emits_partial_result():
    sched = _full_house()
    (i,) = [i for i, s in enumerate(sched.slots)
            if s is not None and s.request.uid == 1]
    sched.preempt(i, step=1)
    parked = sched.queue.ordered()[0].resume
    assert sched.cancel(1)
    res = sched.results[1]
    assert res.finish_reason == "cancelled"
    assert res.preemptions == 1
    np.testing.assert_array_equal(res.tokens,
                                  np.asarray(parked.out, np.int32))
    assert len(sched.queue) == 0


def test_result_carries_slo_and_step_stamps():
    sched = Scheduler(n_slots=1, n_blocks=8, block_size=4,
                      max_blocks_per_seq=4)
    sched.submit(_req(1, max_new=1, priority=3, slo_ms=60_000.0), step=3)
    ((_, slot),) = sched.admit(step=5)
    _start_decode(sched, slot, step=7)
    sched.retire_finished(step=8)
    res = sched.results[1]
    assert res.priority == 3 and res.slo_ms == 60_000.0
    assert res.submit_step == 3 and res.first_token_step == 7
    assert res.slo_met is True  # a minute of wall clock cannot have passed
    sched.submit(_req(2, max_new=1, slo_ms=0.0))
    ((_, slot),) = sched.admit(step=9)
    _start_decode(sched, slot, step=9)
    sched.retire_finished(step=9)
    assert sched.results[2].slo_met is False
    sched.submit(_req(3, max_new=1))  # no SLO → no verdict
    ((_, slot),) = sched.admit(step=10)
    _start_decode(sched, slot, step=10)
    sched.retire_finished(step=10)
    assert sched.results[3].slo_met is None


# ---------------------------------------------------------------------------
# exactness: forced preempt/resume is byte-identical (acceptance criterion)


@pytest.mark.parametrize("mode", ["plain", "spec", "predictor"])
def test_forced_preempt_resume_byte_identical(mode):
    """Preempt the only decoding slot mid-stream, let it resume through
    trie-mapped blocks + chunked prefill of the cold tail: the f32 greedy
    stream must equal the never-preempted stream exactly."""
    cfg, params = _setup("tiny-relu")
    kw = _mode_kw(mode, cfg, params)
    (p,) = _prompts(cfg, [11], seed=13)
    ref_eng = _engine(cfg, params, prefill_chunk=4, prefix_cache=True, **kw)
    ref_uid = ref_eng.submit(p, max_new=10)
    ref = ref_eng.run()[ref_uid]

    eng = _engine(cfg, params, prefill_chunk=4, prefix_cache=True, **kw)
    uid = eng.submit(p, max_new=10)
    while True:  # run until mid-decode with a few tokens out
        eng.step()
        slots = [s for s in eng.scheduler.slots if s is not None]
        if slots and not slots[0].prefilling and len(slots[0].out) >= 3:
            break
    (i,) = [i for i, s in enumerate(eng.scheduler.slots) if s is not None]
    eng.scheduler.preempt(i, eng.t)
    res = eng.run()[uid]

    np.testing.assert_array_equal(res.tokens, ref.tokens)
    np.testing.assert_allclose(res.logprobs, ref.logprobs,
                               rtol=1e-6, atol=1e-6)
    assert res.preemptions == 1 and ref.preemptions == 0
    assert res.cached_prompt_tokens > 0  # resume mapped parked blocks


def test_forced_preempt_resume_sampled_stream_identical():
    """A SAMPLED request's key schedule is positional (gen = len(out)), so
    the resumed slot keeps drawing the same per-token keys it would have
    drawn unpreempted — the stochastic stream is reproducible too."""
    cfg, params = _setup("tiny-relu")
    (p,) = _prompts(cfg, [9], seed=14)
    sp = SamplingParams(temperature=0.8, top_k=20, seed=42)
    ref_eng = _engine(cfg, params, prefill_chunk=4, prefix_cache=True)
    ref_uid = ref_eng.submit(p, max_new=8, sampling=sp)
    ref = ref_eng.run()[ref_uid]

    eng = _engine(cfg, params, prefill_chunk=4, prefix_cache=True)
    uid = eng.submit(p, max_new=8, sampling=sp)
    while True:
        eng.step()
        slots = [s for s in eng.scheduler.slots if s is not None]
        if slots and not slots[0].prefilling and len(slots[0].out) >= 3:
            break
    (i,) = [i for i, s in enumerate(eng.scheduler.slots) if s is not None]
    eng.scheduler.preempt(i, eng.t)
    res = eng.run()[uid]
    np.testing.assert_array_equal(res.tokens, ref.tokens)


def test_engine_priority_preemption_end_to_end():
    """A high-priority submit against a saturated engine preempts a
    batch-class slot, decodes first, and the victim still completes with
    its exact solo stream."""
    cfg, params = _setup("tiny-relu")
    pb, pi = _prompts(cfg, [10, 8], seed=15)
    ref_eng = _engine(cfg, params, n_slots=1, max_blocks_per_seq=4,
                      n_blocks=5, prefill_chunk=4, prefix_cache=True)
    rb = ref_eng.submit(pb, max_new=12)
    ref = ref_eng.run()[rb]

    eng = _engine(cfg, params, n_slots=1, max_blocks_per_seq=4, n_blocks=5,
                  prefill_chunk=4, prefix_cache=True)
    ub = eng.submit(pb, max_new=12, priority=0, slo_ms=1e6)
    while not eng.scheduler.active_indices():
        eng.step()
    for _ in range(3):
        eng.step()
    ui = eng.submit(pi, max_new=4, priority=2, slo_ms=1e6)
    res = eng.run()
    assert res[ub].preemptions >= 1
    assert res[ui].preemptions == 0
    # the interactive request got the slot: it finished first
    assert res[ui].finished_step < res[ub].finished_step
    np.testing.assert_array_equal(res[ub].tokens, ref.tokens)
    assert res[ub].priority == 0 and res[ui].priority == 2
    assert res[ui].slo_met is True


# ---------------------------------------------------------------------------
# per-step prefill token budget (TTFT-vs-TPOT knob)


def test_prefill_batch_budget_caps_total_tokens():
    sched = Scheduler(n_slots=2, n_blocks=9, block_size=4,
                      max_blocks_per_seq=4)
    sched.submit(_req(1, prompt_len=8, max_new=4))
    sched.submit(_req(2, prompt_len=8, max_new=4))
    sched.admit(step=0)
    _, _, _, clen, _ = sched.prefill_batch(chunk=4, budget=6)
    assert clen.sum() == 6 and list(clen) == [4, 2]
    # the first prefilling slot always advances, even under a 1-token budget
    _, _, _, clen, _ = sched.prefill_batch(chunk=4, budget=1)
    assert clen.sum() == 1
    # budget=0 disables the cap entirely
    _, _, _, clen, _ = sched.prefill_batch(chunk=4, budget=0)
    assert list(clen) == [4, 4]


def test_engine_prefill_budget_is_exact_and_slower():
    """The budgeted engine produces the identical streams, just spread over
    more prefill steps."""
    cfg, params = _setup("tiny-relu")
    prompts = _prompts(cfg, [9, 11], seed=16)
    ref_eng = _engine(cfg, params, prefill_chunk=4)
    ref_uids = [ref_eng.submit(p, max_new=6) for p in prompts]
    ref = ref_eng.run()
    eng = _engine(cfg, params, prefill_chunk=4, prefill_budget=4)
    uids = [eng.submit(p, max_new=6) for p in prompts]
    eng.step()  # both slots admitted; budget lets only 4 tokens prefill
    assert sum(s.prefilled for s in eng.scheduler.slots
               if s is not None) == 4
    res = eng.run()
    for ru, u in zip(ref_uids, uids):
        np.testing.assert_array_equal(res[u].tokens, ref[ru].tokens)


# ---------------------------------------------------------------------------
# EngineConfig surface: validation, legacy shim, downgrades


def test_engine_config_validate_errors():
    with pytest.raises(ValueError, match="pool"):
        EngineConfig(n_slots=2, block_size=4, max_blocks_per_seq=4,
                     n_blocks=4).validate()
    with pytest.raises(ValueError, match="prefix_cache"):
        EngineConfig(prefix_cache=True).validate()
    with pytest.raises(ValueError, match="warm_masks"):
        EngineConfig(warm_masks=True).validate()


def test_engine_config_defaults_validate():
    cfg = EngineConfig().validate()
    assert cfg.resolved_n_blocks == 1 + cfg.n_slots * cfg.max_blocks_per_seq
    assert cfg.preemption is True and cfg.aging_steps > 0


def test_engine_rejects_config_plus_legacy_kwargs():
    cfg, params = _setup("tiny-relu")
    with pytest.raises(TypeError, match="not both"):
        ContinuousBatchingEngine(cfg, params, config=EngineConfig(),
                                 n_slots=2)


def test_legacy_kwargs_shim_warns_once_and_matches_config():
    cfg, params = _setup("tiny-relu")
    config_mod._LEGACY_KWARGS_WARNED = False
    with pytest.warns(DeprecationWarning, match="EngineConfig"):
        legacy = ContinuousBatchingEngine(cfg, params, n_slots=2,
                                          block_size=8, max_blocks_per_seq=6,
                                          prefill_chunk=4)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        ContinuousBatchingEngine(cfg, params, n_slots=2, block_size=8,
                                 max_blocks_per_seq=6)
    assert not [w for w in caught  # warn-ONCE: the second use is silent
                if issubclass(w.category, DeprecationWarning)
                and "EngineConfig" in str(w.message)]
    assert legacy.config == EngineConfig(n_slots=2, block_size=8,
                                         max_blocks_per_seq=6,
                                         prefill_chunk=4)
    with pytest.raises(TypeError, match="bogus_knob"):
        ContinuousBatchingEngine(cfg, params, bogus_knob=1)


def test_preemption_downgraded_without_chunked_prefill():
    """Resume needs the chunked-prefill path; a prefill_chunk=0 engine must
    not break under the default-on preemption knob."""
    cfg, params = _setup("tiny-relu")
    eng = _engine(cfg, params)  # prefill_chunk=0
    assert eng.scheduler.preemption is False
    eng = _engine(cfg, params, prefill_chunk=4)
    assert eng.scheduler.preemption is True
