"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train step on CPU, asserting output shapes and no NaNs; plus
prefill→decode vs full-forward consistency (the cache path is exact)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, TrainConfig, smoke_config
from repro.models import registry
from repro.models.common import padded_vocab
from repro.optim import adamw
from repro.train.step import make_train_step


def make_batch(cfg, rng, b=2, s=16):
    batch = {"tokens": jax.random.randint(rng, (b, s), 0, cfg.vocab_size)}
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            rng, (b, cfg.n_vision_tokens, cfg.d_model), cdt) * 0.02
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            rng, (b, cfg.n_audio_frames, cfg.d_model), cdt) * 0.02
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_shapes_and_finite(arch):
    cfg = smoke_config(arch)
    fam = registry.get_family(cfg)
    rng = jax.random.PRNGKey(0)
    params = fam.init_params(rng, cfg)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits = fam.model_forward(params, batch, cfg)
    b, s = batch["tokens"].shape
    assert logits.shape == (b, s, padded_vocab(cfg.vocab_size))
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step(arch):
    cfg = smoke_config(arch)
    fam = registry.get_family(cfg)
    rng = jax.random.PRNGKey(0)
    params = fam.init_params(rng, cfg)
    tc = TrainConfig(num_microbatches=2, remat_policy="minimal",
                     total_steps=4, warmup_steps=1, learning_rate=1e-3)
    step = jax.jit(make_train_step(cfg, tc))
    opt = adamw.init_opt_state(params)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    p2, o2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert float(m["step_ok"]) == 1.0
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum())
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_decode_matches_forward(arch):
    cfg = smoke_config(arch)
    fam = registry.get_family(cfg)
    rng = jax.random.PRNGKey(0)
    params = fam.init_params(rng, cfg)
    b, s = 2, 12
    offset = cfg.n_vision_tokens if cfg.family == "vlm" else 0
    batch = make_batch(cfg, jax.random.PRNGKey(1), b=b, s=s)
    last, cache = fam.model_prefill(params, batch, cfg, max_len=offset + s + 4)

    # prefill last-token logits == full forward last-token logits
    full = fam.model_forward(params, batch, cfg)
    np.testing.assert_allclose(
        np.asarray(last, np.float32), np.asarray(full[:, -1], np.float32),
        rtol=0.06, atol=0.06)

    # decode one token == forward on s+1 tokens
    nxt = jnp.argmax(last[:, : cfg.vocab_size], -1).astype(jnp.int32)
    pos = jnp.full((b,), offset + s, jnp.int32)
    dl, _ = fam.model_decode(params, cache, nxt, pos, cfg)
    batch2 = dict(batch, tokens=jnp.concatenate(
        [batch["tokens"], nxt[:, None]], axis=1))
    full2 = fam.model_forward(params, batch2, cfg)
    np.testing.assert_allclose(
        np.asarray(dl, np.float32), np.asarray(full2[:, -1], np.float32),
        rtol=0.08, atol=0.08)
