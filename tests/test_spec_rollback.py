"""Property tests (hypothesis; stub-compatible) for speculative decoding's
paged-cache rollback: arbitrary accept/reject sequences must preserve
block-table integrity, never touch the scratch block's reservation, and
leave the KV prefix identical to pure token-by-token autoregressive writes.
"""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.models import common as cm
from repro.serving.scheduler import Request, Scheduler


# ---------------------------------------------------------------------------
# cache-level property: window writes + rewind == sequential writes


@settings(max_examples=12, deadline=None)
@given(st.integers(2, 8), st.integers(1, 5), st.integers(0, 10_000))
def test_window_write_rewind_kv_prefix_matches_sequential(
        block_size, gamma, seed):
    """Drive a single slot through random speculative windows (random accept
    counts, scratch-routed overhang) and check the three rollback
    invariants: (1) the KV prefix up to the rewound position is bitwise
    identical to sequential one-token-per-step writes of the accepted
    stream; (2) pool blocks outside the slot's table are never written;
    (3) the block table never contains the scratch block."""
    rng = np.random.RandomState(seed)
    W = gamma + 1
    nb = 4  # table width
    n_blocks = nb + 3  # scratch + table + 2 never-owned sentinels
    total = nb * block_size
    sentinel = -7.0

    pages = jnp.full((1, n_blocks, 1, block_size, 1), sentinel, jnp.float32)
    owned = list(rng.permutation(np.arange(1, n_blocks))[:nb])
    table = jnp.asarray(np.asarray(owned, np.int32)[None])
    assert cm.SCRATCH_BLOCK not in owned

    pos = 0
    accepted_vals = []  # the autoregressive reference stream
    while pos < total - 1 and len(accepted_vals) < 3 * total:
        wlen = min(W, total - pos)
        n_acc = rng.randint(0, wlen)  # accepted proposals this window
        # window token values: the value AT position p is 100 + p for the
        # accepted prefix; rejected tail writes recognizable garbage
        vals = np.full((1, W), 0.0, np.float32)
        for i in range(wlen):
            vals[0, i] = (100.0 + pos + i) if i <= n_acc else -1000.0 - i
        wpos = jnp.asarray(np.arange(pos, pos + W, dtype=np.int32)[None])
        enable = jnp.asarray((np.arange(W) < wlen)[None])
        pages = cm.paged_write_window(
            pages, 0, table, wpos, jnp.asarray(vals)[..., None, None],
            block_size, enable)
        accepted_vals.extend(100.0 + pos + i for i in range(n_acc + 1))
        pos += n_acc + 1  # the rewind: rejected tail stays stale

    got = np.asarray(cm.paged_gather(pages[0], table))[0, 0, :, 0]
    np.testing.assert_array_equal(got[:pos], np.asarray(accepted_vals))
    # blocks the slot does not own were never written
    for b in range(1, n_blocks):
        if b not in owned:
            assert (np.asarray(pages)[0, b] == sentinel).all(), b


@settings(max_examples=8, deadline=None)
@given(st.integers(2, 6), st.integers(0, 10_000))
def test_scratch_routing_protects_foreign_blocks(block_size, seed):
    """Out-of-window (disabled) writes — including positions past the table
    — always land in the scratch block, whatever the position says."""
    rng = np.random.RandomState(seed)
    nb, n_blocks, W = 2, 5, 4
    pages = jnp.zeros((1, n_blocks, 1, block_size, 1), jnp.float32)
    table = jnp.asarray([[3, 1]], jnp.int32)
    # positions deliberately run past the table's capacity
    base = rng.randint(0, 3 * nb * block_size)
    wpos = jnp.asarray(np.arange(base, base + W, dtype=np.int32)[None])
    pages = cm.paged_write_window(
        pages, 0, table, wpos, jnp.ones((1, W, 1, 1), jnp.float32),
        block_size, enable=jnp.zeros((1, W), bool))
    changed = np.nonzero(np.asarray(pages)[0].reshape(n_blocks, -1).any(1))[0]
    assert set(changed) <= {cm.SCRATCH_BLOCK}


# ---------------------------------------------------------------------------
# scheduler-level property: spec bookkeeping keeps the pool consistent


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(1, 4), st.integers(0, 10_000))
def test_scheduler_spec_bookkeeping_integrity(n_slots, gamma, seed):
    """Random request mix + random accept patterns through spec_batch /
    record_spec / retire: block tables stay disjoint, the scratch block is
    never handed out, every block returns to the pool, and every request
    finishes with exactly max_new tokens."""
    rng = np.random.RandomState(seed)
    bs, max_bps = 4, 4
    n_blocks = 1 + n_slots * max_bps
    sched = Scheduler(n_slots, n_blocks, bs, max_bps)
    W = gamma + 1
    n_req = rng.randint(2, 6)
    for uid in range(1, n_req + 1):
        prompt = rng.randint(3, 2 * bs + 1)
        max_new = rng.randint(1, max_bps * bs - prompt)
        sched.submit(Request(uid=uid, tokens=np.zeros(prompt, np.int32),
                             max_new=max_new))

    for step in range(500):
        sched.retire_finished(step)
        if not sched.has_work():
            break
        for _, slot in sched.admit(step):
            sched.seed(slot, int(rng.randint(0, 256)), -1.0)
        if not sched.active_indices():
            continue
        tokens, pos0, table, wlen = sched.spec_batch(W)

        # -- invariants under arbitrary accept patterns ---------------------
        owned = [b for s in sched.slots if s is not None for b in s.blocks]
        assert len(owned) == len(set(owned))  # disjoint tables
        assert cm.SCRATCH_BLOCK not in owned
        assert sched.allocator.available + len(owned) == n_blocks - 1
        for i in sched.active_indices():
            s = sched.slots[i]
            assert 1 <= wlen[i] <= W
            # the whole window fits the slot's blocks: no write out of range
            assert pos0[i] + wlen[i] <= len(s.blocks) * bs
            assert len(s.blocks) <= max_bps

        # fabricate a verify outcome with a random acceptance prefix
        window = np.concatenate(
            [tokens[:, None],
             rng.randint(0, 256, (n_slots, W - 1)).astype(np.int32)], axis=1)
        greedy = rng.randint(0, 256, (n_slots, W)).astype(np.int32)
        for i in sched.active_indices():
            n_acc = rng.randint(0, wlen[i])
            greedy[i, :n_acc] = window[i, 1: n_acc + 1]
            if n_acc < wlen[i] - 1:  # force rejection right after the prefix
                greedy[i, n_acc] = (window[i, n_acc + 1] + 1) % 256
        sched.record_spec(window, greedy,
                          np.zeros((n_slots, W), np.float32), wlen)
    else:
        raise AssertionError("scheduler failed to drain")

    assert sched.allocator.available == n_blocks - 1  # all blocks returned
    assert len(sched.results) == n_req
    for uid, res in sched.results.items():
        # seed token + (accepted + correction) per verify window, exactly
        assert len(res.tokens) == 1 + res.draft_accepted + res.target_calls
        assert res.draft_accepted <= res.draft_proposed
