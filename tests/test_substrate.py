"""Substrate tests: data pipeline determinism/resume, checkpoint atomicity +
elastic restore, trainer resume, gradient compression convergence, FLOPs
accounting vs the paper's Table-1 numbers."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import TrainConfig, get_config
from repro.configs.base import ModelConfig
from repro.core import flops as flops_lib
from repro.data.pipeline import DataConfig, IteratorState, PackedIterator
from repro.models import registry
from repro.optim import adamw, compression
from repro.train.loop import Trainer


def test_data_determinism_and_resume():
    dc = DataConfig(batch_size=2, seq_len=32)
    it1 = PackedIterator(dc)
    b1 = [next(it1) for _ in range(3)]
    state = it1.state()
    b_next = next(it1)

    it2 = PackedIterator(dc)
    b2 = [next(it2) for _ in range(3)]
    for a, b in zip(b1, b2):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    it3 = PackedIterator(dc, state)
    b3 = next(it3)
    # resumed iterator consumes the same docs (carry buffer differs, so the
    # doc id stream must match)
    assert it3.state().next_doc >= state.next_doc


def test_data_host_sharding_disjoint():
    dc0 = DataConfig(batch_size=1, seq_len=64, host_index=0, host_count=2)
    dc1 = DataConfig(batch_size=1, seq_len=64, host_index=1, host_count=2)
    it0, it1 = PackedIterator(dc0), PackedIterator(dc1)
    next(it0), next(it1)
    assert it0.next_doc % 2 == 0 and it1.next_doc % 2 == 1


def test_checkpoint_roundtrip_and_keep(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": [jnp.ones((4,), jnp.int32)]}
    for s in (10, 20, 30):
        mgr.save(s, tree, extras={"step": s, "data": {"next_doc": s}})
    assert mgr.all_steps() == [20, 30]  # keep=2 GC
    got, extras = mgr.restore(tree)
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
    assert extras["step"] == 30


@pytest.mark.skipif(not hasattr(jax.sharding, "AxisType"),
                    reason="requires jax.sharding.AxisType (jax >= 0.6)")
def test_checkpoint_elastic_reshard(tmp_path):
    """Save under one layout, restore with explicit target shardings."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(4, 8)}
    mgr.save(1, tree, extras={"step": 1})
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = {"w": NamedSharding(mesh, P("data", None))}
    got, _ = mgr.restore(tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
    assert got["w"].sharding == sh["w"]


def test_trainer_runs_and_resumes(tmp_path):
    cfg = get_config("tiny-relu")
    tc = TrainConfig(learning_rate=3e-3, total_steps=8, warmup_steps=2,
                     num_microbatches=1, remat_policy="none", seed=0)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, batch_size=4)
    tr = Trainer(cfg, tc, dc, ckpt_dir=str(tmp_path), ckpt_every=4,
                 eval_every=100, log=lambda *_: None)
    rep = tr.run(6)
    assert rep.steps == 6
    assert np.isfinite(rep.losses).all()

    # simulate restart: a fresh trainer must resume from the checkpoint
    tr2 = Trainer(cfg, tc, dc, ckpt_dir=str(tmp_path), ckpt_every=4,
                  eval_every=100, log=lambda *_: None)
    rep2 = tr2.run(8)
    assert rep2.resumed_from == 6
    assert rep2.steps == 2


def test_loss_decreases_tiny():
    cfg = get_config("tiny-relu")
    tc = TrainConfig(learning_rate=5e-3, total_steps=30, warmup_steps=3,
                     schedule="constant", num_microbatches=1,
                     remat_policy="none")
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=48, batch_size=8)
    tr = Trainer(cfg, tc, dc, log=lambda *_: None)
    rep = tr.run(30)
    assert np.mean(rep.losses[-5:]) < np.mean(rep.losses[:5]) - 0.1


def test_int8_ef_compression_roundtrip():
    x = jnp.asarray(np.random.RandomState(0).randn(64, 32), jnp.float32)
    q, s = compression.quantize_int8(x)
    err = np.abs(np.asarray(compression.dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.51 + 1e-6


@pytest.mark.skipif(not hasattr(jax.sharding, "AxisType"),
                    reason="requires jax.sharding.AxisType (jax >= 0.6)")
def test_ddp_compressed_matches_uncompressed_direction():
    """int8-EF DDP step loss should track the uncompressed step closely."""
    from repro.train.ddp import make_ddp_train_step
    cfg = get_config("tiny-relu")
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, batch_size=4)
    it = PackedIterator(dc)
    fam = registry.get_family(cfg)
    params = fam.init_params(jax.random.PRNGKey(0), cfg)

    losses = {}
    for comp in ("none", "int8_ef"):
        tc = TrainConfig(learning_rate=5e-3, total_steps=10, warmup_steps=1,
                         schedule="constant", grad_compression=comp)
        step = make_ddp_train_step(cfg, tc, mesh)
        p = jax.tree.map(jnp.copy, params)
        opt = adamw.init_opt_state(p)
        ef = compression.init_ef_state(p)
        it2 = PackedIterator(dc)
        ls = []
        for _ in range(8):
            batch = {k: jnp.asarray(v) for k, v in next(it2).items()}
            p, opt, ef, m = step(p, opt, ef, batch)
            ls.append(float(m["loss"]))
        losses[comp] = ls
    # both decrease, and end within 10% of each other
    for comp in losses:
        assert losses[comp][-1] < losses[comp][0]
    assert abs(losses["int8_ef"][-1] - losses["none"][-1]) < 0.1 * losses["none"][-1] + 0.2


def test_table1_flops_reproduction():
    """The analytic accounting reproduces the paper's Table-1 MACs/token."""
    opt67 = get_config("opt-6.7b")
    dense = flops_lib.macs_per_token(opt67) / 1e9
    assert abs(dense - 6.6) < 0.3  # paper: 6.6 G
    s1 = flops_lib.macs_per_token(
        opt67, flops_lib.SparsityLevels(down=0.97)) / 1e9
    assert abs(s1 - 4.5) < 0.3  # paper: 4.5 G
    s2 = flops_lib.macs_per_token(
        opt67, flops_lib.SparsityLevels(qkv=0.5, up=0.40, down=0.97)) / 1e9
    assert abs(s2 - 2.8) < 0.3  # paper: 2.8 G

    falcon = get_config("falcon-7b")
    fd = flops_lib.macs_per_token(falcon) / 1e9
    assert abs(fd - 6.6) < 0.5  # paper: 6.6 G
    f2 = flops_lib.macs_per_token(
        falcon, flops_lib.SparsityLevels(qkv=0.56, up=0.56, down=0.95)) / 1e9
    assert abs(f2 - 2.2) < 0.4  # paper: 2.2 G

    llama = get_config("llama-7b")
    ld = flops_lib.macs_per_token(llama) / 1e9
    assert abs(ld - 6.6) < 0.5  # paper: 6.6 G
    l2 = flops_lib.macs_per_token(
        llama, flops_lib.SparsityLevels(qkv=0.51, up=0.67, down=0.65)) / 1e9
    assert abs(l2 - 2.9) < 0.5  # paper: 2.9 G
